"""Synthetic CT volumes + ROI masks mimicking the paper's KITS19 test set.

The paper benchmarks on 20 KITS19 kidney/tumour cases spanning image sizes
50 kB - 9 MB and 2 700 - 236 588 mesh vertices (Table 2).  The dataset is not
shipped in this offline container, so we generate deterministic synthetic
cases with the *exact image dimensions* of Table 2 and organic multi-
ellipsoid ROIs that land in the same vertex-count regime.

``table2_cases()`` returns the 20 (name, shape) pairs from the paper;
``make_case`` builds (image, mask, spacing) for any shape + seed.
"""
from __future__ import annotations

import numpy as np

# (case id, image dims (x, y, z)) -- from paper Table 2.
TABLE2_CASES = [
    ("00000-1", (231, 104, 264)),
    ("00000-2", (28, 30, 59)),
    ("00001-1", (322, 126, 219)),
    ("00001-2", (51, 62, 135)),
    ("00002-1", (230, 109, 163)),
    ("00002-2", (50, 45, 44)),
    ("00003-1", (237, 122, 135)),
    ("00003-2", (39, 35, 31)),
    ("00004-1", (254, 70, 36)),
    ("00004-2", (35, 37, 10)),
    ("00005-1", (167, 94, 285)),
    ("00005-2", (51, 53, 121)),
    ("00006-1", (308, 102, 36)),
    ("00006-2", (41, 43, 13)),
    ("00007-1", (265, 101, 39)),
    ("00007-2", (39, 43, 12)),
    ("00008-1", (288, 177, 54)),
    ("00008-2", (127, 154, 41)),
    ("00009-1", (241, 95, 47)),
    ("00009-2", (39, 33, 11)),
]


def table2_cases():
    return list(TABLE2_CASES)


def make_case(shape, seed=0, spacing=(1.0, 1.0, 1.0), n_blobs=None,
              roi_contrast=60.0):
    """Deterministic synthetic (image, mask, spacing) for one case.

    The ROI is a union of overlapping random ellipsoids with a low-frequency
    boundary perturbation, producing organic surfaces whose vertex counts
    scale with the volume like the kidney/tumour ROIs in KITS19.

    The image is a CT-like float32 intensity volume (soft-tissue
    N(40, 15) background, ``roi_contrast`` HU added inside the ROI) --
    the input the firstorder/glcm feature families consume; shape-only
    extraction ignores it.  ``roi_contrast=0.0`` makes the ROI
    statistically identical to the background (a texture-null case).
    """
    rng = np.random.default_rng(seed)
    nx, ny, nz = shape
    gx = np.arange(nx, dtype=np.float32)[:, None, None]
    gy = np.arange(ny, dtype=np.float32)[None, :, None]
    gz = np.arange(nz, dtype=np.float32)[None, None, :]

    if n_blobs is None:
        n_blobs = int(rng.integers(2, 5))
    mask = np.zeros(shape, dtype=bool)
    center0 = np.array([nx, ny, nz]) * (0.35 + 0.3 * rng.random(3))
    for _ in range(n_blobs):
        c = center0 + (rng.random(3) - 0.5) * np.array([nx, ny, nz]) * 0.25
        r = np.maximum(2.5, np.array([nx, ny, nz]) * (0.12 + 0.18 * rng.random(3)))
        d2 = ((gx - c[0]) / r[0]) ** 2 + ((gy - c[1]) / r[1]) ** 2 + ((gz - c[2]) / r[2]) ** 2
        # low-frequency wobble makes the surface organic (more vertices)
        wob = (
            0.15 * np.sin(gx * rng.uniform(0.1, 0.35) + rng.random() * 7)
            * np.sin(gy * rng.uniform(0.1, 0.35) + rng.random() * 7)
            * np.sin(gz * rng.uniform(0.1, 0.35) + rng.random() * 7)
        )
        mask |= d2 + wob < 1.0
    if not mask.any():  # degenerate shapes (tiny volumes): central voxel
        mask[nx // 2, ny // 2, nz // 2] = True

    # CT-like image: soft-tissue background + ROI contrast + noise
    image = rng.normal(40.0, 15.0, size=shape).astype(np.float32)
    image[mask] += np.float32(roi_contrast)
    return image, mask, np.asarray(spacing, np.float32)


def stream_cases(n, dims_pool=None, seed=0, spacing=(1.0, 1.0, 1.0),
                 skip=()):
    """Lazy case stream for the dataset-level pipeline front-end.

    Yields ``(name, image, mask, spacing)`` one case at a time -- the
    shape `BatchedExtractor.extract_stream` consumes (after dropping the
    name), without materialising the whole dataset: the streaming
    pipeline preps window k+1 while the device executes window k, so the
    producer must be an iterator, not a list.  ``dims_pool`` defaults to
    the small-to-medium Table-2 dimensions; ``skip`` names cases to
    exclude (the cluster example's restart path).

    Always yields exactly ``n`` SURVIVING cases: a skipped name advances
    the index past it rather than shrinking the output, so a restart
    that excludes already-done cases still processes the promised count.
    Each case's content stays keyed to its original index (``case-i``
    is identical whether or not earlier names were skipped).
    """
    if dims_pool is None:
        dims_pool = [d for _, d in TABLE2_CASES if min(d) >= 10][:8]
    produced, i = 0, 0
    while produced < n:
        name = f"case-{i:05d}"
        if name in skip:
            i += 1
            continue
        img, msk, sp = make_case(dims_pool[i % len(dims_pool)],
                                 seed=seed + i, spacing=spacing)
        yield name, img, msk, sp
        produced += 1
        i += 1


def mixed_traffic_stream(n, seed=0, huge_every=16, small_dims=None,
                         huge_dims=(96, 96, 96), spacing=(1.0, 1.0, 1.0)):
    """Mixed service traffic: many small ROIs plus rare huge cases.

    The workload shape of the serving tier (clinic-sized single studies
    interleaved with occasional research-cohort volumes): every
    ``huge_every``-th case uses ``huge_dims``, the rest cycle a pool of
    small dimensions.  Yields ``(name, image, mask, spacing)`` like
    :func:`stream_cases`; ``huge_every=0`` disables the huge cases.
    Drives ``launch/serve`` and ``benchmarks/serve_latency``.
    """
    if small_dims is None:
        small_dims = [(24, 28, 32), (32, 36, 40), (28, 40, 34), (36, 30, 26)]
    for i in range(n):
        huge = bool(huge_every) and (i % huge_every == huge_every - 1)
        dims = huge_dims if huge else small_dims[i % len(small_dims)]
        name = f"{'huge' if huge else 'small'}-{i:05d}"
        img, msk, sp = make_case(dims, seed=seed + i, spacing=spacing)
        yield name, img, msk, sp


def table2_suite(seed=0, spacing=(1.0, 1.0, 1.0)):
    """The full 20-case synthetic suite with Table-2 dimensions."""
    out = []
    for i, (name, shape) in enumerate(TABLE2_CASES):
        img, msk, sp = make_case(shape, seed=seed * 1000 + i, spacing=spacing)
        out.append((name, img, msk, sp))
    return out
