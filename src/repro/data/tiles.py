"""Out-of-core tile streaming: slab sources and the ``TiledCase`` unit.

A :class:`TiledCase` is what the tiled extraction engine
(``core/tiled.py``) consumes instead of a materialized ``(image, mask,
spacing)`` tuple: a pair of *slab sources* that can serve any z-window
``[z0, z1)`` of the volume on demand, without the whole volume ever
existing in memory.  NIfTI stores Fortran order (x fastest), so a
z-slab is one contiguous byte range on disk -- the natural streaming
unit (see ``data/nifti.py::read_nifti_slab``).

Three source flavours cover the loader spectrum:

* :class:`NiftiSlabSource` -- an uncompressed ``.nii`` on disk, windowed
  via header peek + seek; the genuinely out-of-core path.
* :class:`ArraySlabSource` -- an in-memory ndarray; the volume exists on
  the host but is staged to the DEVICE one tile at a time (the device
  budget is what the tile layer guards, the host array is cheap by
  comparison).
* :class:`FnSlabSource` -- an analytic/synthetic generator
  ``fn(z0, z1) -> (X, Y, z1-z0)``; lets a 1024^3 case exist nowhere at
  all (used by the out-of-core acceptance demo and the benches).

Halo contract: the engine asks each source for frame-aligned slabs plus
one extra plane below/above (halo width 1), so marching-cubes cells and
vertex edges on a tile face are computed from the same neighbour values
as the in-core path and counted by exactly one owning tile.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.data.nifti import read_nifti_header, read_nifti_slab

__all__ = [
    "ArraySlabSource",
    "FnSlabSource",
    "NiftiSlabSource",
    "TiledCase",
    "as_slab_source",
]


class ArraySlabSource:
    """Slab views over an in-memory 3D array (no copy until sliced)."""

    def __init__(self, array, spacing=None):
        array = np.asarray(array)
        if array.ndim != 3:
            raise ValueError(f"slab source needs a 3D array, got {array.shape}")
        self._array = array
        self.shape = tuple(int(s) for s in array.shape)
        self.spacing = None if spacing is None else np.asarray(spacing, np.float32)

    def read(self, z0: int, z1: int) -> np.ndarray:
        return self._array[:, :, z0:z1]


class NiftiSlabSource:
    """Windowed reads from an uncompressed ``.nii`` file.

    The constructor only peeks the 352-byte header (shape/dtype/spacing);
    data planes are read per ``read`` call.  Compressed ``.nii.gz`` is
    rejected up front with the ``read_nifti_slab`` workaround message --
    better at construction than on the first mid-stream slab.
    """

    def __init__(self, path):
        self.path = Path(path)
        hdr = read_nifti_header(self.path)
        if hdr.gzipped:
            # surface the seek restriction immediately, with the workaround
            read_nifti_slab(self.path, 0, 0)
        if len(hdr.shape) != 3:
            raise ValueError(
                f"tiled extraction needs a 3D volume, {self.path.name} has "
                f"shape {hdr.shape}"
            )
        self.header = hdr
        self.shape = tuple(int(s) for s in hdr.shape)
        self.spacing = np.asarray(hdr.spacing, np.float32)

    def read(self, z0: int, z1: int) -> np.ndarray:
        slab, _ = read_nifti_slab(self.path, z0, z1)
        return slab


class FnSlabSource:
    """Analytic slab generator: ``fn(z0, z1) -> (X, Y, z1-z0)`` ndarray.

    The volume never exists anywhere -- each window is synthesized on
    demand.  This is how the 1024^3 acceptance case runs on a machine
    whose host memory could not hold it either.
    """

    def __init__(self, fn, shape, spacing=None):
        self._fn = fn
        self.shape = tuple(int(s) for s in shape)
        if len(self.shape) != 3:
            raise ValueError(f"slab source needs a 3D shape, got {shape}")
        self.spacing = None if spacing is None else np.asarray(spacing, np.float32)

    def read(self, z0: int, z1: int) -> np.ndarray:
        slab = np.asarray(self._fn(z0, z1))
        want = (self.shape[0], self.shape[1], z1 - z0)
        if slab.shape != want:
            raise ValueError(
                f"slab fn returned shape {slab.shape} for planes "
                f"[{z0}, {z1}), expected {want}"
            )
        return slab


def as_slab_source(obj, spacing=None):
    """Coerce an ndarray / path / existing source into a slab source."""
    if hasattr(obj, "read") and hasattr(obj, "shape"):
        return obj
    if isinstance(obj, (str, Path)):
        return NiftiSlabSource(obj)
    return ArraySlabSource(obj, spacing)


class TiledCase:
    """One extraction case served as z-slabs instead of whole volumes.

    ``mask`` is required; ``image`` only when an intensity family
    (firstorder) is requested.  ``spacing`` resolution order: explicit
    argument > mask source's own spacing (NIfTI header) > unit spacing.
    ``BatchedExtractor`` routes any ``TiledCase`` through the tiled
    engine unconditionally -- constructing one IS the opt-in.
    """

    def __init__(self, mask, image=None, spacing=None, name=None):
        self.mask_source = as_slab_source(mask, spacing)
        self.image_source = None if image is None else as_slab_source(image, spacing)
        if (self.image_source is not None
                and tuple(self.image_source.shape) != tuple(self.mask_source.shape)):
            raise ValueError(
                f"image shape {tuple(self.image_source.shape)} != mask shape "
                f"{tuple(self.mask_source.shape)}"
            )
        if spacing is None:
            spacing = getattr(self.mask_source, "spacing", None)
        self.spacing = np.asarray(
            (1.0, 1.0, 1.0) if spacing is None else spacing, np.float32
        )
        self.name = name

    @property
    def shape(self) -> tuple:
        return tuple(self.mask_source.shape)

    def mask_slab(self, z0: int, z1: int) -> np.ndarray:
        return self.mask_source.read(z0, z1)

    def image_slab(self, z0: int, z1: int) -> np.ndarray:
        if self.image_source is None:
            raise ValueError(
                "this TiledCase has no image source (intensity families "
                "need one)"
            )
        return self.image_source.read(z0, z1)

    def materialize(self):
        """Whole volumes, for parity tests on sizes the in-core path can
        run.  Defeats the point on genuinely large cases -- test use only."""
        nz = self.shape[2]
        mask = np.ascontiguousarray(self.mask_slab(0, nz))
        image = None
        if self.image_source is not None:
            image = np.ascontiguousarray(self.image_slab(0, nz))
        return image, mask, self.spacing
