"""Minimal NIfTI-1 reader/writer (pure numpy + stdlib gzip).

Supports the subset PyRadiomics workflows need: single-file ``.nii`` /
``.nii.gz``, scalar volumes, little-endian, dtypes {uint8, int16, int32,
float32, float64}, pixdim spacing, ``scl_slope``/``scl_inter`` intensity
rescaling, and >3D files whose trailing dims are all size 1 (a common
export quirk: 4D with one timepoint).  Enough to round-trip the
synthetic KITS19-like suite and to ingest real CT volumes and
segmentation masks.  Big-endian files are detected and rejected with a
clear error rather than misread.
"""
from __future__ import annotations

import gzip
import struct
from pathlib import Path

import numpy as np

_DTYPES = {2: np.uint8, 4: np.int16, 8: np.int32, 16: np.float32, 64: np.float64}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def read_nifti(path):
    """Returns (data (x,y,z) ndarray, spacing (3,) float32).

    Applies the header's ``scl_slope``/``scl_inter`` intensity rescale
    (``slope * stored + inter``, as float32) whenever it is a real
    rescale -- slope outside {0, 1} or a nonzero intercept; a slope of 0
    means "unset" per the standard and is treated as 1.  Files with more
    than 3 dims are accepted when every trailing dim is 1 (squeezed
    away); genuinely >3D data still raises.
    """
    path = Path(path)
    raw = path.read_bytes()
    if path.suffix == ".gz" or raw[:2] == b"\x1f\x8b":
        raw = gzip.decompress(raw)
    if len(raw) < 352:
        raise ValueError("not a NIfTI-1 file (too short)")
    sizeof_hdr = struct.unpack_from("<i", raw, 0)[0]
    if sizeof_hdr != 348:
        # a byte-swapped sizeof_hdr is the standard's endianness probe:
        # tell the user what the file IS, not just that the header looks bad
        if struct.unpack_from(">i", raw, 0)[0] == 348:
            raise ValueError(
                "big-endian NIfTI byte order unsupported (this reader is "
                "little-endian only); convert the file first"
            )
        raise ValueError(f"unsupported NIfTI header size {sizeof_hdr}")
    dim = struct.unpack_from("<8h", raw, 40)
    ndim = dim[0]
    if not 1 <= ndim <= 7:
        raise ValueError(f"bad NIfTI dim[0]={ndim}, got dim={dim}")
    shape = tuple(int(d) for d in dim[1 : 1 + ndim])
    # tolerate degenerate >3D exports (e.g. a 4D file with one timepoint):
    # squeeze trailing size-1 dims, reject anything still >3D after that
    while len(shape) > 3 and shape[-1] == 1:
        shape = shape[:-1]
    if len(shape) > 3:
        raise ValueError(f"only 1-3D volumes supported, got dim={dim}")
    datatype = struct.unpack_from("<h", raw, 70)[0]
    if datatype not in _DTYPES:
        raise ValueError(f"unsupported datatype code {datatype}")
    pixdim = struct.unpack_from("<8f", raw, 76)
    vox_offset = int(struct.unpack_from("<f", raw, 108)[0])
    scl_slope, scl_inter = struct.unpack_from("<2f", raw, 112)
    magic = raw[344:348]
    if magic not in (b"n+1\x00", b"ni1\x00"):
        raise ValueError(f"bad NIfTI magic {magic!r}")
    dt = np.dtype(_DTYPES[datatype]).newbyteorder("<")
    count = int(np.prod(shape))
    data = np.frombuffer(raw, dt, count=count, offset=vox_offset or 352)
    # NIfTI stores Fortran order (x fastest)
    data = data.reshape(shape, order="F")
    data = np.ascontiguousarray(data)
    if (
        (scl_slope not in (0.0, 1.0) or scl_inter != 0.0)
        and np.isfinite(scl_slope)
        and np.isfinite(scl_inter)
    ):
        # slope 0 with a real intercept means "slope unset": apply as 1
        slope = scl_slope if scl_slope != 0.0 else 1.0
        data = (np.float32(slope) * data.astype(np.float32)
                + np.float32(scl_inter))
    spacing = np.asarray(pixdim[1:4], np.float32)
    spacing[spacing == 0] = 1.0
    return data, spacing


def write_nifti(path, data: np.ndarray, spacing=(1.0, 1.0, 1.0),
                scl_slope: float = 0.0, scl_inter: float = 0.0):
    path = Path(path)
    data = np.asarray(data)
    if data.dtype not in _CODES:
        data = data.astype(np.float32)
    hdr = bytearray(352)
    struct.pack_into("<i", hdr, 0, 348)
    dim = [data.ndim] + list(data.shape) + [1] * (7 - data.ndim)
    struct.pack_into("<8h", hdr, 40, *dim)
    struct.pack_into("<h", hdr, 70, _CODES[np.dtype(data.dtype)])
    struct.pack_into("<h", hdr, 72, data.dtype.itemsize * 8)
    pix = [0.0] + list(np.asarray(spacing, np.float32)) + [0.0] * (7 - 3)
    struct.pack_into("<8f", hdr, 76, *pix)
    struct.pack_into("<f", hdr, 108, 352.0)
    struct.pack_into("<2f", hdr, 112, scl_slope, scl_inter)
    hdr[344:348] = b"n+1\x00"
    payload = bytes(hdr) + np.asfortranarray(data).tobytes(order="F")
    if str(path).endswith(".gz"):
        path.write_bytes(gzip.compress(payload, compresslevel=1))
    else:
        path.write_bytes(payload)
    return path
