"""Minimal NIfTI-1 reader/writer (pure numpy + stdlib gzip).

Supports the subset PyRadiomics workflows need: single-file ``.nii`` /
``.nii.gz``, scalar volumes, little-endian, dtypes {uint8, int16, int32,
float32, float64}, pixdim spacing, ``scl_slope``/``scl_inter`` intensity
rescaling, and >3D files whose trailing dims are all size 1 (a common
export quirk: 4D with one timepoint).  Enough to round-trip the
synthetic KITS19-like suite and to ingest real CT volumes and
segmentation masks.  Big-endian files are detected and rejected with a
clear error rather than misread.

Three access granularities share ONE parse/read path:

* :func:`read_nifti_header` -- 352-byte peek (shape, dtype, spacing,
  rescale, offset) without touching the data section.  Admission control
  (``serve/service.py::estimate_case_bytes``) and the tile planner size
  work from this alone.
* :func:`read_nifti_slab` -- a windowed z-slab ``[z0, z1)`` read via
  ``seek``: NIfTI stores Fortran order (x fastest), so a z-slab is one
  contiguous byte range.  This is what lets ``data/tiles.py`` stream a
  volume far larger than memory.  Refused for ``.nii.gz`` (a DEFLATE
  stream cannot seek) with an error naming the workaround.
* :func:`read_nifti` -- the full volume, implemented as a slab read over
  the whole z-range (gz files are decompressed to an in-memory stream
  first, which is the only way to random-access them).
"""
from __future__ import annotations

import gzip
import io
import struct
from pathlib import Path
from typing import NamedTuple

import numpy as np

_DTYPES = {2: np.uint8, 4: np.int16, 8: np.int32, 16: np.float32, 64: np.float64}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}

_HDR_BYTES = 352  # 348-byte header + 4-byte extension flag


class NiftiHeader(NamedTuple):
    """Parsed NIfTI-1 header: everything needed to plan a read.

    ``shape`` has degenerate trailing dims already squeezed (so it is at
    most 3-long); ``vox_offset`` is the byte offset of the data section;
    ``gzipped`` records how the bytes on disk are stored, which decides
    whether :func:`read_nifti_slab` can seek.
    """

    shape: tuple
    dtype: np.dtype
    spacing: np.ndarray
    vox_offset: int
    scl_slope: float
    scl_inter: float
    gzipped: bool

    @property
    def shape3(self) -> tuple:
        """``shape`` padded with trailing 1s to exactly 3 dims."""
        return tuple(self.shape) + (1,) * (3 - len(self.shape))

    @property
    def data_bytes(self) -> int:
        """Size of the stored data section (pre-rescale dtype)."""
        return int(np.prod(self.shape, dtype=np.int64)) * self.dtype.itemsize


def _parse_header(raw: bytes, gzipped: bool) -> NiftiHeader:
    if len(raw) < _HDR_BYTES:
        raise ValueError("not a NIfTI-1 file (too short)")
    sizeof_hdr = struct.unpack_from("<i", raw, 0)[0]
    if sizeof_hdr != 348:
        # a byte-swapped sizeof_hdr is the standard's endianness probe:
        # tell the user what the file IS, not just that the header looks bad
        if struct.unpack_from(">i", raw, 0)[0] == 348:
            raise ValueError(
                "big-endian NIfTI byte order unsupported (this reader is "
                "little-endian only); convert the file first"
            )
        raise ValueError(f"unsupported NIfTI header size {sizeof_hdr}")
    dim = struct.unpack_from("<8h", raw, 40)
    ndim = dim[0]
    if not 1 <= ndim <= 7:
        raise ValueError(f"bad NIfTI dim[0]={ndim}, got dim={dim}")
    shape = tuple(int(d) for d in dim[1 : 1 + ndim])
    # tolerate degenerate >3D exports (e.g. a 4D file with one timepoint):
    # squeeze trailing size-1 dims, reject anything still >3D after that
    while len(shape) > 3 and shape[-1] == 1:
        shape = shape[:-1]
    if len(shape) > 3:
        raise ValueError(f"only 1-3D volumes supported, got dim={dim}")
    datatype = struct.unpack_from("<h", raw, 70)[0]
    if datatype not in _DTYPES:
        raise ValueError(f"unsupported datatype code {datatype}")
    pixdim = struct.unpack_from("<8f", raw, 76)
    vox_offset = int(struct.unpack_from("<f", raw, 108)[0])
    scl_slope, scl_inter = struct.unpack_from("<2f", raw, 112)
    magic = raw[344:348]
    if magic not in (b"n+1\x00", b"ni1\x00"):
        raise ValueError(f"bad NIfTI magic {magic!r}")
    spacing = np.asarray(pixdim[1:4], np.float32)
    spacing[spacing == 0] = 1.0
    return NiftiHeader(
        shape=shape,
        dtype=np.dtype(_DTYPES[datatype]).newbyteorder("<"),
        spacing=spacing,
        vox_offset=vox_offset or _HDR_BYTES,
        scl_slope=float(scl_slope),
        scl_inter=float(scl_inter),
        gzipped=gzipped,
    )


def _is_gzipped(path: Path) -> bool:
    if path.suffix == ".gz":
        return True
    with open(path, "rb") as f:
        return f.read(2) == b"\x1f\x8b"


def read_nifti_header(path) -> NiftiHeader:
    """Peek the 352-byte header without reading the data section.

    For ``.nii.gz`` this streams just enough of the DEFLATE stream to
    decompress the header -- still O(1) in the volume size.
    """
    path = Path(path)
    gzipped = _is_gzipped(path)
    opener = gzip.open if gzipped else open
    with opener(path, "rb") as f:
        raw = f.read(_HDR_BYTES)
    return _parse_header(raw, gzipped)


def _apply_scl(data: np.ndarray, hdr: NiftiHeader) -> np.ndarray:
    """Header intensity rescale (``slope * stored + inter``, float32).

    Applied whenever it is a real rescale -- slope outside {0, 1} or a
    nonzero intercept; a slope of 0 means "unset" per the standard and
    is treated as 1.
    """
    scl_slope, scl_inter = hdr.scl_slope, hdr.scl_inter
    if (
        (scl_slope not in (0.0, 1.0) or scl_inter != 0.0)
        and np.isfinite(scl_slope)
        and np.isfinite(scl_inter)
    ):
        slope = scl_slope if scl_slope != 0.0 else 1.0
        data = (np.float32(slope) * data.astype(np.float32)
                + np.float32(scl_inter))
    return data


def _slab_from_stream(f, hdr: NiftiHeader, z0: int, z1: int) -> np.ndarray:
    """Read planes ``[z0, z1)`` from a seekable byte stream.

    NIfTI data is Fortran order: flat offset of voxel ``(x, y, z)`` is
    ``x + y*X + z*X*Y``, so a z-slab is a single contiguous byte range.
    Returns an ``(X, Y, z1-z0)`` C-contiguous array (stored dtype,
    rescale not yet applied).
    """
    nx, ny, nz = hdr.shape3
    if not 0 <= z0 <= z1 <= nz:
        raise ValueError(f"slab [{z0}, {z1}) out of range for nz={nz}")
    plane = nx * ny * hdr.dtype.itemsize
    f.seek(hdr.vox_offset + z0 * plane)
    want = (z1 - z0) * plane
    buf = f.read(want)
    if len(buf) < want:
        raise ValueError(
            f"truncated NIfTI data section: wanted {want} bytes for planes "
            f"[{z0}, {z1}), got {len(buf)}"
        )
    data = np.frombuffer(buf, hdr.dtype, count=nx * ny * (z1 - z0))
    return np.ascontiguousarray(data.reshape((nx, ny, z1 - z0), order="F"))


def read_nifti_slab(path, z0: int, z1: int):
    """Windowed read of z-planes ``[z0, z1)`` without loading the volume.

    Returns ``(slab (X, Y, z1-z0) ndarray, spacing (3,) float32)`` with
    the header's intensity rescale applied (same rule as
    :func:`read_nifti`).  Only uncompressed ``.nii`` can be windowed: a
    ``.nii.gz`` DEFLATE stream has no random access, so it is refused
    with the workaround spelled out rather than silently buffering the
    whole file.
    """
    path = Path(path)
    hdr = read_nifti_header(path)
    if hdr.gzipped:
        raise ValueError(
            f"cannot read a slab from compressed NIfTI {path.name}: gzip "
            "streams do not support seeking; decompress it first (e.g. "
            "`gunzip` to a .nii file, or load fully via read_nifti)"
        )
    with open(path, "rb") as f:
        slab = _slab_from_stream(f, hdr, z0, z1)
    return _apply_scl(slab, hdr), hdr.spacing


def read_nifti(path):
    """Returns (data (x,y,z) ndarray, spacing (3,) float32).

    Applies the header's ``scl_slope``/``scl_inter`` intensity rescale
    (``slope * stored + inter``, as float32) whenever it is a real
    rescale -- slope outside {0, 1} or a nonzero intercept; a slope of 0
    means "unset" per the standard and is treated as 1.  Files with more
    than 3 dims are accepted when every trailing dim is 1 (squeezed
    away); genuinely >3D data still raises.

    Implemented as a whole-z-range :func:`_slab_from_stream` read so the
    windowed and full-volume loaders share one read path; ``.nii.gz``
    is decompressed to an in-memory stream first.
    """
    path = Path(path)
    if _is_gzipped(path):
        raw = gzip.decompress(path.read_bytes())
        hdr = _parse_header(raw[:_HDR_BYTES], gzipped=True)
        stream = io.BytesIO(raw)
    else:
        hdr = read_nifti_header(path)
        stream = open(path, "rb")
    try:
        data = _slab_from_stream(stream, hdr, 0, hdr.shape3[2])
    finally:
        stream.close()
    data = data.reshape(hdr.shape)
    return _apply_scl(data, hdr), hdr.spacing


def write_nifti(path, data: np.ndarray, spacing=(1.0, 1.0, 1.0),
                scl_slope: float = 0.0, scl_inter: float = 0.0):
    path = Path(path)
    data = np.asarray(data)
    if data.dtype not in _CODES:
        data = data.astype(np.float32)
    hdr = bytearray(352)
    struct.pack_into("<i", hdr, 0, 348)
    dim = [data.ndim] + list(data.shape) + [1] * (7 - data.ndim)
    struct.pack_into("<8h", hdr, 40, *dim)
    struct.pack_into("<h", hdr, 70, _CODES[np.dtype(data.dtype)])
    struct.pack_into("<h", hdr, 72, data.dtype.itemsize * 8)
    pix = [0.0] + list(np.asarray(spacing, np.float32)) + [0.0] * (7 - 3)
    struct.pack_into("<8f", hdr, 76, *pix)
    struct.pack_into("<f", hdr, 108, 352.0)
    struct.pack_into("<2f", hdr, 112, scl_slope, scl_inter)
    hdr[344:348] = b"n+1\x00"
    payload = bytes(hdr) + np.asfortranarray(data).tobytes(order="F")
    if str(path).endswith(".gz"):
        path.write_bytes(gzip.compress(payload, compresslevel=1))
    else:
        path.write_bytes(payload)
    return path
