"""Minimal NIfTI-1 reader/writer (pure numpy + stdlib gzip).

Supports the subset PyRadiomics workflows need: single-file ``.nii`` /
``.nii.gz``, scalar volumes, little-endian, dtypes {uint8, int16, int32,
float32, float64}, pixdim spacing.  Enough to round-trip the synthetic
KITS19-like suite and to ingest real segmentation masks.
"""
from __future__ import annotations

import gzip
import struct
from pathlib import Path

import numpy as np

_DTYPES = {2: np.uint8, 4: np.int16, 8: np.int32, 16: np.float32, 64: np.float64}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def read_nifti(path):
    """Returns (data (x,y,z) ndarray, spacing (3,) float32)."""
    path = Path(path)
    raw = path.read_bytes()
    if path.suffix == ".gz" or raw[:2] == b"\x1f\x8b":
        raw = gzip.decompress(raw)
    if len(raw) < 352:
        raise ValueError("not a NIfTI-1 file (too short)")
    sizeof_hdr = struct.unpack_from("<i", raw, 0)[0]
    if sizeof_hdr != 348:
        raise ValueError(f"unsupported NIfTI header size {sizeof_hdr}")
    dim = struct.unpack_from("<8h", raw, 40)
    ndim = dim[0]
    if not 1 <= ndim <= 3:
        raise ValueError(f"only 1-3D volumes supported, got dim={dim}")
    shape = tuple(int(d) for d in dim[1 : 1 + ndim])
    datatype = struct.unpack_from("<h", raw, 70)[0]
    if datatype not in _DTYPES:
        raise ValueError(f"unsupported datatype code {datatype}")
    pixdim = struct.unpack_from("<8f", raw, 76)
    vox_offset = int(struct.unpack_from("<f", raw, 108)[0])
    magic = raw[344:348]
    if magic not in (b"n+1\x00", b"ni1\x00"):
        raise ValueError(f"bad NIfTI magic {magic!r}")
    dt = np.dtype(_DTYPES[datatype]).newbyteorder("<")
    count = int(np.prod(shape))
    data = np.frombuffer(raw, dt, count=count, offset=vox_offset or 352)
    # NIfTI stores Fortran order (x fastest)
    data = data.reshape(shape, order="F")
    spacing = np.asarray(pixdim[1 : 1 + max(3, ndim)][:3], np.float32)
    spacing[spacing == 0] = 1.0
    return np.ascontiguousarray(data), spacing


def write_nifti(path, data: np.ndarray, spacing=(1.0, 1.0, 1.0)):
    path = Path(path)
    data = np.asarray(data)
    if data.dtype not in _CODES:
        data = data.astype(np.float32)
    hdr = bytearray(352)
    struct.pack_into("<i", hdr, 0, 348)
    dim = [data.ndim] + list(data.shape) + [1] * (7 - data.ndim)
    struct.pack_into("<8h", hdr, 40, *dim)
    struct.pack_into("<h", hdr, 70, _CODES[np.dtype(data.dtype)])
    struct.pack_into("<h", hdr, 72, data.dtype.itemsize * 8)
    pix = [0.0] + list(np.asarray(spacing, np.float32)) + [0.0] * (7 - 3)
    struct.pack_into("<8f", hdr, 76, *pix)
    struct.pack_into("<f", hdr, 108, 352.0)
    hdr[344:348] = b"n+1\x00"
    payload = bytes(hdr) + np.asfortranarray(data).tobytes(order="F")
    if str(path).endswith(".gz"):
        path.write_bytes(gzip.compress(payload, compresslevel=1))
    else:
        path.write_bytes(payload)
    return path
