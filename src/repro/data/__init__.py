"""Data substrate: synthetic CT volumes, minimal NIfTI IO, token pipelines."""
