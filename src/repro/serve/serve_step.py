"""Serve step factory: one decode step + sampling against a KV/state cache.

``make_serve_step(model)`` returns
    (params, cache, tokens (B,1), rng) -> (next_tokens (B,1), logits, cache)
with greedy or temperature sampling; padded-vocab logit slots are masked.
This is the function the decode-shape dry-run cells lower (one new token
against a seq_len cache, per the assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_serve_step(model, temperature: float = 0.0):
    cfg = model.cfg

    def serve_step(params, cache, tokens, rng):
        logits, cache = model.decode_step(params, cache, tokens)
        x = logits[:, -1].astype(jnp.float32)
        valid = jnp.arange(x.shape[-1]) < cfg.vocab_size
        x = jnp.where(valid[None, :], x, -1e30)
        if temperature > 0:
            nxt = jax.random.categorical(rng, x / temperature, axis=-1)
        else:
            nxt = jnp.argmax(x, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


def make_prefill_fn(model):
    """Full-sequence forward used by the prefill-shape cells.

    Returns last-position logits; the cache write is the cheap epilogue of
    the same compute (see DESIGN.md 'prefill lowering' note).
    """
    cfg = model.cfg

    def prefill(params, tokens, *extra):
        if cfg.family in ("audio", "encdec"):
            logits, _ = model.forward(params, tokens, extra[0])
        elif cfg.frontend_tokens:
            logits, _ = model.forward(params, tokens, prefix_embeds=extra[0])
        else:
            logits, _ = model.forward(params, tokens)
        return logits[:, -1:]

    return prefill
