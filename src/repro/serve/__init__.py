"""Serving tier: the persistent multi-tenant extraction service (PR 8).

``service`` is the radiomics-as-a-service driver (cross-tenant window
fusion, deadlines, backpressure); ``serve_step`` is the older decode-
step scaffold kept for the sampling utilities.
"""
from repro.serve.service import (  # noqa: F401  (re-exports)
    DeadlineExceeded,
    ExtractionService,
    ServeFuture,
    ServeResult,
    ServiceClosed,
    ServiceError,
    ServiceOverloaded,
    estimate_case_bytes,
)
