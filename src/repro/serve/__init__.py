"""Serving substrate: decode steps, sampling, batched engine."""
