"""Radiomics-as-a-service: a persistent extraction service (PR 8).

The batch pipeline answers "extract these 40 000 cases"; this module
answers "keep extracting, forever, for everyone" -- ROADMAP direction 3,
the millions-of-users story (Nyxus in PAPERS.md frames feature
extraction the same way: an always-on component of big-data/AI
pipelines, not a one-shot script).  The mechanism is exactly what the
sync-free pipeline was built for: because ``prep='hint'`` +
``schedule='static'`` submit windows without ever blocking on a device
sync, cases from UNRELATED clients can be fused into shared windows and
the device never waits on a straggling tenant.

Architecture (one driver thread owns all device work)::

    client threads                 driver thread (the only JAX caller)
    --------------                 ------------------------------------
    submit(cases, deadline_s=..)   loop:
      |  admission control           pull queued cases (FIFO across
      |  (bounded queue BYTES          tenants -- arrival order IS the
      |   via plan.meta_bytes;         fusion order)
      |   block / Overloaded)        expired request? -> deadline error,
      v                                NO window slot occupied
    [FIFO queue of (req, case)]      prep (executor.prep_case) + census
      ...                            close the open window when:
    future.result()  <---------        * CostModel.should_close (the
         rows + errors,                  throughput rule), or
         input order                   * CostModel.deadline_at_risk (the
                                         latency rule: modeled window
                                         cost threatens the OLDEST
                                         pending deadline), or
                                       * the queue went idle (no
                                         co-tenant traffic to fuse)
                                     submit window k+1 BEFORE draining
                                       window k (extract_stream's
                                       overlap), demux rows to futures

Contracts:

* **parity** -- served rows are bit-identical to ``extract_stream`` /
  ``run`` on the same cases (windowing never changes a feature row;
  tier-1-locked in ``tests/test_service.py`` on ref + interpret);
* **backpressure** -- admission is bounded by ESTIMATED queue bytes
  (``plan.meta_bytes`` over metadata-only ``CaseMeta``, a conservative
  over-estimate since the real prep crops first): a full queue blocks
  the submitter (or raises :class:`ServiceOverloaded` with
  ``block=False``), so a burst cannot OOM the host staging area;
* **deadlines** -- ``deadline_s`` is relative to submit.  A request
  whose deadline passes while it is still QUEUED completes with a
  :class:`DeadlineExceeded` error row per unprocessed case and never
  occupies a window slot; co-tenant cases in the same windows are
  untouched (tier-1-locked).  A request admitted to a window is always
  delivered (possibly late -- ``ServeResult.late``); the cost model's
  ``deadline_at_risk`` closes windows early to make that rare;
* **quarantine** -- a poisoned / unloadable case degrades to the
  executor's row-level error (all-NaN row + message), reported in
  ``ServeResult.errors`` by the request's own case index; the window's
  co-tenant rows are bit-identical to a run without it.

``BatchedExtractor.serve()`` is the facade entry point;
``python -m repro.launch.serve`` the CLI; ``benchmarks/serve_latency``
the gated mixed-traffic p50/p99 benchmark.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time

import numpy as np

from repro.core import plan as planlib


class ServiceError(RuntimeError):
    """Base class for service-level failures."""


class ServiceClosed(ServiceError):
    """The service is no longer accepting requests."""


class ServiceOverloaded(ServiceError):
    """Admission control rejected the request (queue byte budget full)."""


class DeadlineExceeded(ServiceError):
    """The request's deadline passed before its cases reached a window."""


DEFAULT_MAX_QUEUE_MB = 256.0
# byte charge for a lazy loader case whose shape is unknown at admission
# (callers that know their shapes pass ``shape_hints=``); sized like a
# mid-range Table-2 case so loader-heavy traffic still gets backpressure
DEFAULT_LOADER_CASE_BYTES = 8 << 20


def _peek_loader_shape(loader):
    """(shape, spacing) from a loader callable's NIfTI path, if it has one.

    Loaders that want byte-accurate admission control attach the mask
    file they will read (``loader.path`` / ``nifti_path`` / ``mask_path``
    -- a ``functools.partial`` keyword works too); the peek reads only
    the 352-byte header.  Any failure (no path, unreadable, not NIfTI)
    falls back to ``(None, None)`` -- the flat default charge -- because
    admission control must never raise on a weird loader.
    """
    for attr in ("path", "nifti_path", "mask_path"):
        path = getattr(loader, attr, None)
        if path is None:
            kw = getattr(loader, "keywords", None)  # functools.partial
            path = kw.get(attr) if isinstance(kw, dict) else None
        if path is None:
            continue
        try:
            from repro.data.nifti import read_nifti_header

            hdr = read_nifti_header(path)
        except Exception:
            continue
        shape = tuple(int(s) for s in hdr.shape3)
        return shape, np.asarray(hdr.spacing, np.float32)
    return None, None


def estimate_case_bytes(case, needs_intensity: bool = False,
                        shape_hint=None) -> int:
    """Admission-control byte estimate for one queued case.

    Metadata-only (``plan.meta_bytes`` over a :class:`plan.CaseMeta`
    built from the UNCROPPED mask shape), so the queue budget is
    enforceable before any prep work runs.  Over-estimates -- the real
    pass 0 crops to the ROI first -- which is the right direction for
    backpressure.  A loader callable exposing a NIfTI ``path`` (or
    ``nifti_path`` / ``mask_path``) attribute is sized by a 352-byte
    header peek (``data.nifti.read_nifti_header``); only a loader with
    no usable path charges the flat :data:`DEFAULT_LOADER_CASE_BYTES`.
    """
    shape = spacing = None
    if shape_hint is not None:
        shape = tuple(int(s) for s in shape_hint)
    elif callable(case):
        shape, spacing = _peek_loader_shape(case)
    else:
        try:
            _, mask, spacing = case
            shape = tuple(int(s) for s in np.shape(mask))
        except (TypeError, ValueError):
            shape = None
    if shape is None or len(shape) != 3:
        return DEFAULT_LOADER_CASE_BYTES
    hint = planlib.vertex_hint(shape, spacing)
    meta = planlib.CaseMeta(
        shape=planlib.shape_bucket(shape),
        roi_shape=shape,
        vertex_cap=planlib.vertex_bucket(hint),
        n_vertices=hint,
        intensity=needs_intensity,
    )
    return planlib.meta_bytes(meta)


@dataclasses.dataclass
class ServeResult:
    """What one request got back: rows by the request's own case order."""

    rows: list                     # one (n_features,) np row per case
    errors: dict                   # {case index: message} (quarantine,
    #                                deadline, or a window-level failure)
    latency_s: float = 0.0         # submit -> last row resolved
    late: bool = False             # delivered after the deadline passed

    @property
    def ok(self) -> bool:
        return not self.errors


class ServeFuture:
    """Handle a client polls/blocks on for one submitted request."""

    def __init__(self, request: "_Request"):
        self._req = request

    def done(self) -> bool:
        return self._req.event.is_set()

    def result(self, timeout: float | None = None) -> ServeResult:
        """Block until the request resolves; raises ``TimeoutError`` if
        ``timeout`` (seconds) elapses first."""
        if not self._req.event.wait(timeout):
            raise TimeoutError(
                f"request {self._req.rid} not resolved within {timeout}s"
            )
        r = self._req
        return ServeResult(
            rows=list(r.rows), errors=dict(r.errors),
            latency_s=r.done_t - r.submit_t,
            late=(r.deadline is not None and r.done_t > r.deadline),
        )


class _Request:
    """Driver-side state of one submitted request (single or batch)."""

    __slots__ = ("rid", "tenant", "deadline", "submit_t", "done_t",
                 "rows", "errors", "remaining", "case_bytes", "event")

    def __init__(self, rid: int, tenant: str, n_cases: int,
                 deadline: float | None, case_bytes: list):
        self.rid = rid
        self.tenant = tenant
        self.deadline = deadline          # absolute time.monotonic()
        self.submit_t = time.monotonic()
        self.done_t = 0.0
        self.rows: list = [None] * n_cases
        self.errors: dict = {}
        self.remaining = n_cases
        self.case_bytes = case_bytes
        self.event = threading.Event()


class ExtractionService:
    """Persistent multi-tenant extraction service over one executor.

    See the module docstring for the architecture and contracts.  All
    device work runs on the single internal driver thread (JAX dispatch
    is not re-entered from client threads); client threads only estimate
    bytes and enqueue.  Construct via ``BatchedExtractor.serve()`` or
    directly; the driver starts immediately and ``close()`` (or the
    context manager) drains and joins it.

    ``max_queue_bytes`` bounds ESTIMATED bytes of queued-but-unresolved
    cases (admission control); ``idle_tick_s`` is how long the driver
    waits for more co-tenant traffic before shipping a non-empty window
    (the fusion opportunity window) and also the deadline-check cadence.
    """

    def __init__(self, extractor, *,
                 max_queue_bytes: float | None = None,
                 idle_tick_s: float = 0.002,
                 loader_case_bytes: int = DEFAULT_LOADER_CASE_BYTES):
        self.ex = getattr(extractor, "executor", extractor)
        if max_queue_bytes is None:
            max_queue_bytes = DEFAULT_MAX_QUEUE_MB * 2**20
        self.max_queue_bytes = float(max_queue_bytes)
        self.idle_tick_s = float(idle_tick_s)
        self.loader_case_bytes = int(loader_case_bytes)
        self._needs_intensity = planlib.needs_intensity(self.ex.families)

        self._cond = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._queue_bytes = 0
        self._rid = itertools.count()
        self._closing = False
        self._failure: BaseException | None = None

        # census counters (snapshot via .stats())
        self._windows: list = []       # [(n_cases, n_tenants)] per window
        self._served_cases = 0
        self._expired_cases = 0
        self._quarantined_cases = 0
        self._requests = 0

        self._driver = threading.Thread(
            target=self._drive, name="repro-serve-driver", daemon=True
        )
        self._driver.start()

    # -- client surface ------------------------------------------------------

    def submit(self, cases, *, tenant: str = "default",
               deadline_s: float | None = None, shape_hints=None,
               block: bool = True, timeout: float | None = None) -> ServeFuture:
        """Enqueue a batch of cases; returns a :class:`ServeFuture`.

        Each case is an ``(image, mask, spacing)`` tuple or a zero-arg
        loader callable (the executor's contract).  ``deadline_s`` is
        relative to now; ``shape_hints`` (optional, one mask shape per
        case) tightens the byte estimate for loader cases.  A full queue
        blocks (``block=True``, up to ``timeout`` seconds) or raises
        :class:`ServiceOverloaded` -- the backpressure contract.
        """
        cases = list(cases)
        if not cases:
            raise ValueError("submit() needs at least one case")
        hints = list(shape_hints) if shape_hints is not None else [None] * len(cases)
        if len(hints) != len(cases):
            raise ValueError("shape_hints must match cases 1:1")
        case_bytes = [
            self.loader_case_bytes if (callable(c) and h is None)
            else estimate_case_bytes(c, self._needs_intensity, h)
            for c, h in zip(cases, hints)
        ]
        need = sum(case_bytes)
        deadline = None if deadline_s is None else time.monotonic() + deadline_s
        t_wait0 = time.monotonic()
        with self._cond:
            # an oversize request (need > whole budget) can never fit next
            # to other traffic: it is admitted alone, when the queue drains
            while (self._queue_bytes + need > self.max_queue_bytes
                   and self._queue_bytes > 0):
                self._raise_if_down()
                if not block:
                    raise ServiceOverloaded(
                        f"queue at {self._queue_bytes}B + {need}B would "
                        f"exceed the {int(self.max_queue_bytes)}B budget"
                    )
                remaining = (None if timeout is None
                             else timeout - (time.monotonic() - t_wait0))
                if remaining is not None and remaining <= 0:
                    raise ServiceOverloaded(
                        f"queue still over budget after {timeout}s"
                    )
                self._cond.wait(remaining if remaining is not None
                                else self.idle_tick_s * 50)
            self._raise_if_down()
            req = _Request(next(self._rid), tenant, len(cases), deadline,
                           case_bytes)
            self._requests += 1
            self._queue_bytes += need
            for ci, case in enumerate(cases):
                self._queue.append((req, ci, case))
            self._cond.notify_all()
        return ServeFuture(req)

    def submit_case(self, case, **kw) -> ServeFuture:
        """Single-case convenience wrapper around :meth:`submit`."""
        return self.submit([case], **kw)

    def stats(self) -> dict:
        """Snapshot of the service census (windows, fusion, expiries)."""
        with self._cond:
            return {
                "requests": self._requests,
                "served_cases": self._served_cases,
                "expired_cases": self._expired_cases,
                "quarantined_cases": self._quarantined_cases,
                "windows": len(self._windows),
                "window_cases": [n for n, _ in self._windows],
                "window_tenants": [t for _, t in self._windows],
                "queue_bytes": self._queue_bytes,
            }

    def close(self, timeout: float | None = None):
        """Stop accepting requests, drain everything queued, join the driver."""
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        self._driver.join(timeout)
        if self._driver.is_alive():
            raise TimeoutError("service driver did not drain in time")

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -- driver internals ----------------------------------------------------

    def _raise_if_down(self):
        if self._failure is not None:
            raise ServiceClosed(
                f"service driver failed: {self._failure!r}"
            ) from self._failure
        if self._closing:
            raise ServiceClosed("service is closed")

    def _next_item(self, timeout: float | None):
        """Pop one queued case; None on idle timeout or drained shutdown."""
        with self._cond:
            while not self._queue:
                if self._closing:
                    return None
                if timeout is not None:
                    self._cond.wait(timeout)
                    if not self._queue:
                        return None
                else:
                    self._cond.wait()
            return self._queue.popleft()

    def _nan_row(self):
        return np.full(self.ex.n_features, np.nan, np.float32)

    def _resolve(self, req: _Request, ci: int, row, error: str | None):
        """Deliver one case's outcome back to its request (driver thread)."""
        if row is None:
            row = self._nan_row()
        req.rows[ci] = np.asarray(row)
        if error is not None:
            req.errors[ci] = str(error)
        req.remaining -= 1
        done = req.remaining == 0
        if done:
            req.done_t = time.monotonic()
        with self._cond:
            self._queue_bytes -= req.case_bytes[ci]
            if error is None:
                self._served_cases += 1
            elif error.startswith("DeadlineExceeded"):
                self._expired_cases += 1
            else:
                self._served_cases += 1
                self._quarantined_cases += 1
            self._cond.notify_all()  # bytes freed: unblock submitters
        if done:
            req.event.set()

    def _oldest_slack_us(self, buf, now: float) -> float | None:
        deadlines = [r.deadline for r, _, _ in buf if r.deadline is not None]
        if not deadlines:
            return None
        return (min(deadlines) - now) * 1e6

    def _drive(self):
        ex = self.ex
        cm = ex.cost_model
        buf: list = []                # [(req, ci, prepped)]
        census = planlib.WindowCensus()
        pending = None                # (submitted window state, recs)

        def drain(entry):
            state, recs = entry
            try:
                rows, stats = ex.collect_window(state)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # window died past any retry policy:
                # fail ITS requests, not the service
                for req, ci in recs:
                    self._resolve(req, ci, None,
                                  f"{type(e).__name__}: {e}")
                return
            errors = stats.get("errors", {})
            for j, (req, ci) in enumerate(recs):
                self._resolve(req, ci, rows[j], errors.get(j))

        def flush():
            nonlocal buf, census, pending
            state = ex.submit_prepped([p for _, _, p in buf])
            recs = [(r, ci) for r, ci, _ in buf]
            with self._cond:
                self._windows.append(
                    (len(buf), len({r.tenant for r, _, _ in buf}))
                )
            prev, pending = pending, (state, recs)
            buf, census = [], planlib.WindowCensus()
            if prev is not None:
                # window k+1 submitted BEFORE window k drains: the
                # extract_stream overlap, now across tenants
                drain(prev)

        try:
            while True:
                busy = bool(buf) or pending is not None
                item = self._next_item(self.idle_tick_s if busy else None)
                now = time.monotonic()
                if buf and cm.deadline_at_risk(
                        census, self._oldest_slack_us(buf, now)):
                    flush()  # the latency rule: ship before the deadline
                if item is None:
                    if buf:
                        flush()  # queue idle: no co-tenant traffic to fuse
                    elif pending is not None:
                        drain(pending)
                        pending = None
                    elif self._closing and not self._queue:
                        return
                    continue
                req, ci, case = item
                if req.deadline is not None and now >= req.deadline:
                    # expired while queued: deadline error, no window slot
                    self._resolve(
                        req, ci, None,
                        f"DeadlineExceeded: expired "
                        f"{(now - req.deadline) * 1e3:.1f}ms before reaching "
                        f"a window",
                    )
                    continue
                p = ex.prep_case(case)
                meta = ex.case_meta(p)
                if buf and cm.should_close(census, meta):
                    flush()  # the throughput rule (same as window='auto')
                buf.append((req, ci, p))
                census.add(meta)
        except BaseException as e:  # driver must never die silently
            with self._cond:
                self._failure = e
                # fail everything in flight and queued
                leftovers = list(self._queue)
                self._queue.clear()
                self._cond.notify_all()
            for req, ci, _ in buf:
                self._resolve(req, ci, None, f"ServiceFailed: {e!r}")
            if pending is not None:
                for req, ci in pending[1]:
                    self._resolve(req, ci, None, f"ServiceFailed: {e!r}")
            for req, ci, _ in leftovers:
                self._resolve(req, ci, None, f"ServiceFailed: {e!r}")
            raise
