"""Runtime substrate: checkpointing, fault tolerance, elasticity."""
