"""Cost model: measured autotune tables + plan censuses -> scheduling decisions.

Four PRs built the *mechanisms* of the batched pipeline -- pruned two-pass
execution, device-resident compaction, the sync-free static schedule, the
streaming front-end -- but left their *selection* to hand-chosen knobs
(``schedule=``, ``window=``, count- vs hint-sized prep).  The paper's
claim is transparent acceleration "in all scenarios", which means the
pipeline must pick its own execution strategy: this module is that
component.  It is fed by exactly two information sources, both already
persisted:

* the **v3 autotune cache** (``runtime/autotune``): measured per-bucket,
  per-batch-depth kernel timings (the ``us`` field of every
  ``diameter/<backend>/M<bucket>/B<depth>`` record) plus the new
  ``sync/<backend>`` d2h-latency probe;
* the **plan layer's census** (``core/plan``): per-case metadata --
  shape buckets, vertex caps, hint counts, pad-waste fractions -- that
  exists BEFORE any device work runs.

Decisions served (wired through ``core/executor``):

``choose_schedule(metas)``
    Counted vs static per window.  The counted schedule pays one d2h
    sync per cap group but sweeps each case at its tight M' bucket; the
    static schedule is sync-free but sweeps at the cap's aligned
    power-of-two target (``plan.static_bucket``).  The model compares
    ``n_groups * sync_us + tight-sweep cost`` against the padded-sweep
    cost; on a zero-latency local device counted wins (the measured PR 4
    trade-off), on a high-latency link (a large calibrated
    ``sync/<backend>`` entry) static wins.

``should_close(census, meta)``
    Adaptive streaming windows (``extract_stream(window='auto')``).
    Close the open window early when the incoming case introduces a new
    shape/cap bucket while every current sub-batch already sits at or
    past its break-even depth (a fresh singleton bucket would only
    fragment a healthy window); extend homogeneous runs until the
    memory-budgeted cap (``REPRO_STREAM_MEM_MB``, default 512 MiB of
    staged masks + vertex stacks) or the absolute case cap.

``break_even_depth(cap)``
    The smallest power-of-two sub-batch depth whose measured per-case
    cost is within :data:`BREAK_EVEN_SLACK` of the best measured depth
    for that bucket -- read straight off the v3 depth-keyed tables.
    With fewer than two measured depths (fresh cache, 'ref' backend) the
    conservative :data:`DEFAULT_BREAK_EVEN_DEPTH` applies.

``deadline_at_risk(census, slack_us)``
    The serving tier's latency-vs-throughput decision
    (``serve/service.py`` -- PR 8): every decision above optimises
    THROUGHPUT, but a persistent service also owes each request its
    deadline.  The open window's modeled collect cost
    (:meth:`CostModel.window_cost_us`: the diameter sweeps, which
    dominate per Table 2, plus one sync per cap group) is compared
    against the slack remaining before the OLDEST pending deadline; once
    the cost -- times a :data:`DEADLINE_SAFETY` margin for everything
    the model cannot see (MC, staging, drain) -- reaches the slack, the
    window must close NOW, even though throughput alone would keep
    absorbing cases.  No deadline pending means no latency pressure and
    the throughput rules above decide alone.

Roofline fallback (the estimate hierarchy): an unmeasured bucket's price
comes from the FIRST source in this ladder that can answer --

1. **measured**: a ``diameter/<backend>/M<bucket>/B<depth>`` autotune
   entry (the nearest shallower measured depth is consulted next) --
   real wall time always wins;
2. **roofline**: ``max(flops/peak_flops, bytes/mem_bw)`` from the
   structural work model (``runtime/roofline.diameter_cost``) under the
   backend's hardware profile.  The profile resolves through
   ``core/dispatcher.hw_profile`` -> ``autotune.get_hw_profile``: a
   measured ``hw/<backend>`` cache entry when one exists, a tiny
   one-time probe where probing is allowed (same policy as the
   ``sync/`` probe: pallas by default, ``REPRO_AUTOTUNE=1`` forces,
   ``=0`` disables), or the static per-backend default profile;
3. **analytic constant**: ``(cap/1024)^2 * PAIR_SWEEP_US`` -- reachable
   only when NO hardware profile exists (an unknown backend string, or
   ``REPRO_ROOFLINE=0`` explicitly disabling the roofline layer).

Determinism contract (tier-1-locked): every decision is a pure function
of (backend, cache file contents, plan metadata) -- with sweeps/probes
disabled (``REPRO_AUTOTUNE=0``) the model never measures, never writes,
and returns identical answers for identical inputs, which is what makes
an auto-configured run reproducible from its committed cache.  The
roofline layer preserves this: with probing disabled the hardware
profile is the static per-backend default, a constant.
"""
from __future__ import annotations

import os
import warnings

from repro.core import plan as planlib
from repro.runtime import autotune
from repro.runtime import roofline as rooflib

# analytic fallback for an unmeasured diameter bucket: the pair sweep is
# O(cap^2), anchored at ~PAIR_SWEEP_US per (1024)^2-pair launch (the order
# of the measured CPU-ref numbers in BENCH_diameter.json).  Only RATIOS
# between bucket sizes matter to the decisions, not the absolute scale.
# Reached only when no hardware profile exists -- see "Roofline fallback"
# in the module docstring.
PAIR_SWEEP_US = 200.0

# fraction of pre-prune vertices assumed to survive the exact bound when no
# count exists yet (the autotune compact probe uses the same ~25% figure)
ASSUMED_KEEP_FRACTION = 0.25

# a sub-batch depth is "past break-even" when its measured per-case cost is
# within this factor of the best measured depth for the bucket
BREAK_EVEN_SLACK = 1.25
DEFAULT_BREAK_EVEN_DEPTH = 4
MAX_PROBED_DEPTH = 64

DEFAULT_WINDOW_MEM_MB = 512.0
DEFAULT_WINDOW_MAX_CASES = 256

# safety margin on the modeled window cost when weighing it against a
# request deadline: the model only sees the diameter sweeps + syncs, not
# MC, staging, or the drain itself, so it under-estimates wall time
DEADLINE_SAFETY = 2.0

# environment variables already warned about this process (warn ONCE per
# variable: a streaming run reads the budget on every CostModel build)
_warned_env: set = set()


def _env_float(name: str, default: float) -> float:
    """Float from the environment; malformed values warn ONCE and fall back.

    An unset (or empty) variable is simply the default -- only a value
    that is present but unparseable warns: a typo'd
    ``REPRO_STREAM_MEM_MB=512MB`` silently becoming 512 MiB-the-default
    is exactly the kind of config rot a long-running service never
    notices (the satellite bugfix of PR 8).
    """
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return default
    try:
        return float(raw)
    except ValueError:
        if name not in _warned_env:
            _warned_env.add(name)
            warnings.warn(
                f"malformed {name}={raw!r} in the environment; "
                f"falling back to the default {default!r}",
                RuntimeWarning,
                stacklevel=2,
            )
        return default


class CostModel:
    """Backend-calibrated decision layer over the autotune cache.

    One instance per executor; lookups are memoised per instance (the
    cache file is re-read at most once per distinct query), so a
    streaming run of thousands of windows costs no repeated JSON I/O.
    """

    def __init__(self, backend: str, cache: autotune.AutotuneCache | None = None,
                 *, assumed_keep: float = ASSUMED_KEEP_FRACTION,
                 break_even_default: int = DEFAULT_BREAK_EVEN_DEPTH,
                 window_mem_bytes: float | None = None,
                 window_max_cases: int | None = None):
        self.backend = backend
        self.cache = cache or autotune.AutotuneCache()
        self.assumed_keep = assumed_keep
        self.break_even_default = break_even_default
        if window_mem_bytes is None:
            window_mem_bytes = (
                _env_float("REPRO_STREAM_MEM_MB", DEFAULT_WINDOW_MEM_MB) * 2**20
            )
        self.window_mem_bytes = float(window_mem_bytes)
        if window_max_cases is None:
            window_max_cases = int(
                _env_float("REPRO_STREAM_MAX_CASES", DEFAULT_WINDOW_MAX_CASES)
            )
        self.window_max_cases = int(window_max_cases)
        self._sync_us: float | None = None
        self._hw_profile: dict | None | str = "unresolved"
        self._diam_us: dict = {}
        self._break_even: dict = {}

    # -- measured lookups ---------------------------------------------------

    def sync_cost_us(self) -> float:
        """Per-fetch d2h latency: the calibrated ``sync/<backend>`` entry."""
        if self._sync_us is None:
            from repro.core import dispatcher  # local import: avoid cycle

            self._sync_us = dispatcher.sync_cost(self.backend, cache=self.cache)
        return self._sync_us

    def hw_profile(self) -> dict | None:
        """The backend's hardware roofline profile (None: no profile).

        Resolved once per instance through ``dispatcher.hw_profile`` --
        the cached/probed/default ladder documented in the module
        docstring's "Roofline fallback" section.
        """
        if self._hw_profile == "unresolved":
            from repro.core import dispatcher  # local import: avoid cycle

            self._hw_profile = dispatcher.hw_profile(
                self.backend, cache=self.cache
            )
        return self._hw_profile

    def _measured_us(self, key: str) -> float | None:
        hit = self.cache.get(key)
        if hit is None:
            return None
        try:
            us = float(hit["us"])
        except (KeyError, TypeError, ValueError):
            return None
        return us if us > 0 else None

    def diameter_case_us(self, cap: int, depth: int = 1) -> float:
        """Modeled PER-CASE pair-sweep cost at a (bucket, depth) pair.

        The estimate hierarchy (module docstring, "Roofline fallback"):
        a measured ``diameter/<backend>/M<cap>/B<depth>`` entry wins (its
        ``us`` is the whole launch: divide by the depth bucket; the
        nearest shallower measured depth is consulted next); an
        unmeasured bucket is priced by the roofline bound under the
        backend's hardware profile; the analytic O(cap^2) constant
        applies only when no profile exists.
        """
        cap = int(cap)
        d = autotune.batch_bucket(max(1, depth))
        memo = (cap, d)
        if memo in self._diam_us:
            return self._diam_us[memo]
        out = None
        probe = d
        while probe >= 1:  # nearest shallower measured depth
            us = self._measured_us(autotune.sweep_key(cap, self.backend, probe))
            if us is not None:
                out = us / probe
                break
            probe //= 2
        if out is None:
            profile = self.hw_profile()
            if profile is not None:
                flops, nbytes = rooflib.diameter_cost(cap, 1)
                out = rooflib.roofline_us(flops, nbytes, profile)
            else:
                out = (cap / 1024.0) ** 2 * PAIR_SWEEP_US
        self._diam_us[memo] = out
        return out

    def break_even_depth(self, cap: int) -> int:
        """Smallest measured depth within BREAK_EVEN_SLACK of the best.

        Reads the depth ladder ``.../B1, .../B2, ...`` of the bucket's
        diameter entries; fewer than two measured depths mean the ladder
        cannot be ranked and the conservative default applies.
        """
        cap = int(cap)
        if cap in self._break_even:
            return self._break_even[cap]
        per_case = {}
        d = 1
        while d <= MAX_PROBED_DEPTH:
            us = self._measured_us(autotune.sweep_key(cap, self.backend, d))
            if us is not None:
                per_case[d] = us / d
            d *= 2
        if len(per_case) < 2:
            out = self.break_even_default
        else:
            best = min(per_case.values())
            out = next(
                d for d in sorted(per_case)
                if per_case[d] <= BREAK_EVEN_SLACK * best
            )
        self._break_even[cap] = out
        return out

    # -- decision: counted vs static schedule --------------------------------

    def choose_schedule(self, metas) -> str:
        """Pick the pass-2b schedule for one window of case metadata.

        counted:  one sync per cap group + tight (estimated M') sweeps;
        static:   zero syncs + padded sweeps at the aligned cap target.
        The keep fraction is estimated (``assumed_keep``) because the
        whole point of the decision is that no count has been fetched
        yet.  Ties break toward counted, the zero-latency default.
        """
        sync_us = self.sync_cost_us()
        groups: dict[int, list] = {}
        for m in metas:
            if not getattr(m, "empty", False) and m.vertex_cap:
                groups.setdefault(int(m.vertex_cap), []).append(m)
        if not groups:
            return "counted"
        counted = static = 0.0
        for cap, group in groups.items():
            depth = autotune.batch_bucket(len(group))
            counted += sync_us  # the (B, 2) count fetch, one per cap group
            target = planlib.static_bucket(cap) or cap
            for m in group:
                kept = max(2, int(m.n_vertices * self.assumed_keep))
                tight = min(planlib.vertex_bucket(kept), cap)
                counted += self.diameter_case_us(tight, depth)
                static += self.diameter_case_us(target, depth)
        return "counted" if counted <= static else "static"

    # -- decision: latency vs throughput (the serving tier) ------------------

    def window_cost_us(self, census: planlib.WindowCensus) -> float:
        """Modeled collect-side cost of the OPEN window, in microseconds.

        The diameter sweeps dominate extraction (95.7-99.9% per the
        paper's Table 2), so the model is their per-(cap, depth) cost
        off the measured tables -- the same lookups
        :meth:`choose_schedule` uses -- plus one d2h sync per cap group.
        Deliberately an under-estimate of wall time (no MC, staging, or
        drain term): callers weighing it against a deadline apply
        :data:`DEADLINE_SAFETY`.
        """
        total = 0.0
        for cap, depth in census.cap_depths.items():
            d = autotune.batch_bucket(max(1, depth))
            total += self.sync_cost_us()
            total += depth * self.diameter_case_us(cap, d)
        return total

    def deadline_at_risk(self, census: planlib.WindowCensus,
                         slack_us: float | None,
                         safety: float = DEADLINE_SAFETY) -> bool:
        """Must the open window close NOW to honour its oldest deadline?

        ``slack_us`` is the time remaining until the oldest pending
        deadline among the window's requests (``None``: no deadline, no
        latency pressure).  True once the modeled window cost, padded by
        ``safety``, reaches the slack -- the first latency-vs-throughput
        decision in the pipeline: a throughput-optimal window keeps
        absorbing cases, a deadline-safe one stops batching and ships.
        An already-expired deadline (slack <= 0) always closes.
        """
        if census.cases == 0 or slack_us is None:
            return False
        if slack_us <= 0:
            return True
        return self.window_cost_us(census) * safety >= slack_us

    # -- decision: adaptive stream windows -----------------------------------

    def window_budget_cases(self, census: planlib.WindowCensus) -> int:
        """Memory-budgeted case cap for the open window (>= 1)."""
        if census.cases and census.bytes:
            per_case = census.bytes / census.cases
            return max(1, min(self.window_max_cases,
                              int(self.window_mem_bytes // per_case)))
        return self.window_max_cases

    def should_close(self, census: planlib.WindowCensus,
                     meta: planlib.CaseMeta) -> bool:
        """Close the open window before admitting ``meta``?

        True when the window hit its memory/case budget, or when ``meta``
        introduces a new shape/cap bucket while every current sub-batch
        already sits at or past its break-even depth -- a fresh singleton
        bucket would fragment a window whose groups are all healthy,
        whereas a still-shallow window keeps absorbing heterogeneity
        (windows must be allowed to grow past one bucket at all).
        """
        if census.cases == 0:
            return False
        if census.cases >= self.window_budget_cases(census):
            return True
        if census.bytes + planlib.meta_bytes(meta) > self.window_mem_bytes:
            return True
        if not census.fragments(meta):
            return False
        depths = list(census.shape_depths.values()) + list(
            census.cap_depths.values()
        )
        if not depths:  # only empty-mask cases so far: nothing to fragment
            return False
        break_even = max(self.break_even_depth(cap)
                         for cap in census.cap_depths) if census.cap_depths \
            else self.break_even_default
        return min(depths) >= break_even
