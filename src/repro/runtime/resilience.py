"""Resilience layer: resumable manifests, fault injection, retry, soak.

The paper's workload -- feature extraction over ~40 000 CT scans on a
shared cluster -- is exactly the regime where jobs get preempted,
stragglers stall windows, and a single poisoned case can kill hours of
work.  This module promotes the cluster example's ad-hoc JSONL
checkpointing into a first-class layer over the plan/executor pipeline:

* :class:`RunManifest` -- a resumable run manifest.  Case identity is a
  CONTENT hash of the mask bytes + spacing (:meth:`RunManifest.case_id`),
  so resume survives renames, reorderings, and regenerated inputs; the
  file is atomic append-only JSONL (one record per case, one ``write``
  per record) with a done-set built by :meth:`RunManifest.resume`, which
  also repairs a torn tail (a record cut mid-write by a kill) by
  truncating back to the last complete line.  ``record`` is idempotent:
  a case id already in the done-set is never written twice, which is
  what makes re-submitting the at-most-one in-flight window safe.

* :class:`FaultPlan` -- deterministic seeded fault injection for testing
  and soaking: per-case load errors and NaN/empty-mask poisoned cases
  (keyed by ``(seed, case index)`` so a resumed run sees the identical
  fault pattern), transient collect-time faults raised through the
  executor's ``transfer_callback`` (exercising the retry path), simulated
  SIGTERM preemption through the REAL signal machinery
  (:class:`~repro.runtime.fault_tolerance.PreemptionHandler`), and
  artificial per-window latency for straggler testing.

* :class:`RetryPolicy` -- per-window retry with exponential backoff,
  consumed by ``PlanExecutor.collect_window``: a failed window collect
  is re-submitted from its already-prepped device state
  (``resubmit_window``, bit-identical by the pipeline's padding
  invariance) and re-drained, up to ``max_retries`` times.  ``timeout_s``
  is advisory: a window whose collect exceeds it is flagged in the
  window stats (a blocking device fetch cannot be interrupted), which
  the straggler census picks up.

* :class:`ResilientRunner` -- the driver that threads all of it through
  the streaming front-end's submit/collect overlap: skip-done by content
  id, per-case quarantine (a poisoned case degrades to a row-level
  ``error`` record instead of killing the window -- the executor's
  contract), manifest writes as each window drains, preemption checks at
  window boundaries (at most ONE window of work is ever redone after a
  kill), and window wall-times observed by a
  :class:`~repro.runtime.fault_tolerance.StragglerDetector`.

Manifest record format (one JSON object per line)::

    {"id": "<blake2b-128 of mask bytes+shape+dtype+spacing>",
     "name": "<optional caller-supplied case name>",
     "status": "done" | "error",
     "features": {"MeshVolume": ..., ...},     # status == "done"
     "error": "<quarantine reason>",           # status == "error"
     "window": <window ordinal that produced the row>}

Resume guarantees (locked by tier-1 tests + ``benchmarks/soak.py``):

* a run preempted mid-stream and resumed produces a manifest whose
  record SET is bit-identical to an uninterrupted run's;
* zero lost and zero duplicated case ids (idempotent ``record`` + the
  done-set skip);
* at most one window of extraction work is redone after a kill.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import signal
import time
from pathlib import Path

import numpy as np

from repro.runtime.fault_tolerance import PreemptionHandler, StragglerDetector

# canonical feature-row column names, single-sourced from the family
# registry (the default shape-only request; pass a multi-family
# ``plan.feature_names(families)`` as ``feature_names=`` for wider rows)
from repro.core.plan import feature_names as _plan_feature_names

FEATURE_NAMES = _plan_feature_names()


class InjectedFault(RuntimeError):
    """A fault raised by :class:`FaultPlan` (distinguishable from real bugs)."""


# ---------------------------------------------------------------------------
# resumable run manifest
# ---------------------------------------------------------------------------


class RunManifest:
    """Atomic append-only JSONL run manifest with a content-hashed done-set.

    See the module docstring for the record format and the resume
    guarantees.  ``fsync=True`` additionally fsyncs every record (safe
    against power loss, ~10x slower on many small rows; the default
    flush-per-record already survives process kills, which is the
    cluster-preemption threat model).
    """

    def __init__(self, path, fsync: bool = False):
        self.path = Path(path)
        self.fsync = fsync
        self._done: dict[str, dict] = {}
        self._f = None
        self._loaded = False

    # -- identity ------------------------------------------------------------

    @staticmethod
    def case_id(mask, spacing) -> str:
        """Content hash of one case: mask bytes + shape + dtype + spacing.

        The id is what makes resume independent of names, ordering, and
        the loader that produced the case -- and is also an integrity
        check: a silently-changed input hashes to a NEW case.
        """
        m = np.ascontiguousarray(np.asarray(mask))
        h = hashlib.blake2b(digest_size=16)
        h.update(repr((m.shape, str(m.dtype))).encode())
        h.update(m.tobytes())
        h.update(np.asarray(spacing, np.float64).tobytes())
        return h.hexdigest()

    # -- read / resume -------------------------------------------------------

    def resume(self) -> set[str]:
        """Load the manifest; return the done-set of case ids.

        Tolerates (and REPAIRS) a torn tail: a process killed mid-write
        leaves a final line with no terminator or invalid JSON; every
        complete record before it is kept, the torn bytes are truncated
        away so the next append starts on a clean line boundary, and the
        partial case simply re-runs (it was never committed).
        """
        self.close()
        self._done = {}
        self._loaded = True
        if not self.path.exists():
            return set()
        data = self.path.read_bytes()
        good_end = 0
        pos = 0
        while pos < len(data):
            nl = data.find(b"\n", pos)
            if nl < 0:
                break  # unterminated tail: torn write
            line = data[pos : nl]
            try:
                rec = json.loads(line)
                rid = rec["id"]
            except (ValueError, KeyError, TypeError):
                break  # corrupt line: everything after it is suspect
            self._done.setdefault(rid, rec)
            pos = good_end = nl + 1
        if good_end < len(data):  # repair: truncate the torn tail
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
        return set(self._done)

    @property
    def done(self) -> dict:
        """``{case id: record}`` of committed rows (resume() must run first)."""
        return self._done

    def rows(self) -> list[dict]:
        """Committed records, in first-written order."""
        return list(self._done.values())

    # -- write ---------------------------------------------------------------

    def record(self, case_id: str, status: str, *, name=None, features=None,
               error=None, window=None) -> bool:
        """Append one record; returns False (no write) if already done.

        The idempotence is the manifest's dedup guarantee: a re-submitted
        in-flight window whose rows were partially committed before a
        kill re-records only the missing cases.  One ``write`` call per
        record on an O_APPEND stream keeps each line atomic against
        interleaved writers and clean against kills (the torn-tail repair
        handles the partial line).
        """
        if not self._loaded:
            self.resume()
        if case_id in self._done:
            return False
        rec = {"id": case_id, "status": status}
        if name is not None:
            rec["name"] = name
        if status == "done":
            rec["features"] = {k: float(v) for k, v in (features or {}).items()}
        if error is not None:
            rec["error"] = str(error)
        if window is not None:
            rec["window"] = int(window)
        if self._f is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._f = open(self.path, "ab")
        self._f.write((json.dumps(rec, sort_keys=True) + "\n").encode())
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._done[case_id] = rec
        return True

    def flush(self):
        if self._f is not None:
            self._f.flush()

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        self.resume()
        return self

    def __exit__(self, *a):
        self.close()


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

# executor fetch stages that belong to window COLLECT (transient faults
# target these so a submit never dies half-planned; under the sync-free
# static+hint configuration they are the only fetch stages at all)
COLLECT_STAGES = frozenset(
    ("pass2", "pass2a", "pass2b", "pass2b_counts", "pass2b_retry",
     "collect_counts", "hint_retry")
)


@dataclasses.dataclass
class FaultPlan:
    """Seeded, deterministic fault injection for resilience testing.

    Every per-case decision is keyed by ``(seed, case index)`` and every
    per-window decision by ``(seed, window ordinal)``, so a resumed run
    replays the IDENTICAL fault pattern -- which is what lets the soak
    assert the faulted+preempted+resumed manifest equals the faulted
    uninterrupted one bit-for-bit.

    * ``load_error_rate``: the case raises :class:`InjectedFault` at load
      time (a corrupt file / dead NFS mount) -> quarantined by name;
    * ``poison_nan_rate``: the mask is replaced by a float copy with NaNs
      scattered in (a poisoned segmentation) -> quarantined by the
      executor's non-finite validation as a row-level ``error`` record;
    * ``poison_empty_rate``: the mask is zeroed -> the pipeline's
      all-zero-row degenerate contract (NOT an error);
    * ``window_fault_rate`` / ``fail_windows``: one transient
      :class:`InjectedFault` per selected window, raised from the
      executor's ``transfer_callback`` during collect -> exercises the
      :class:`RetryPolicy` backoff/re-submit path;
    * ``preempt_at_case``: when the runner reaches this case ordinal it
      sends a REAL ``SIGTERM`` to the process (once), driving the
      installed :class:`PreemptionHandler` exactly like a cluster
      preemption notice;
    * ``straggle_windows`` + ``straggle_seconds``: artificial latency
      added inside the named windows' timed collect region, for
      :class:`StragglerDetector` testing.
    """

    seed: int = 0
    load_error_rate: float = 0.0
    poison_nan_rate: float = 0.0
    poison_empty_rate: float = 0.0
    window_fault_rate: float = 0.0
    fail_windows: tuple = ()
    preempt_at_case: int | None = None
    straggle_windows: tuple = ()
    straggle_seconds: float = 0.0

    def __post_init__(self):
        self._preempted = False
        self._pending_fault = None
        self._spent_windows: set[int] = set()

    # -- per-case faults -----------------------------------------------------

    def inject_case(self, index: int, case):
        """Apply this plan's per-case faults to ``(image, mask, spacing)``.

        Raises :class:`InjectedFault` for a load-error case; returns the
        (possibly poisoned) case otherwise.  Deterministic per index.
        """
        r = np.random.default_rng((self.seed, 101, index)).random(3)
        if r[0] < self.load_error_rate:
            raise InjectedFault(f"load error injected at case {index}")
        image, mask, spacing = case
        if r[1] < self.poison_nan_rate:
            bad = np.asarray(mask, np.float32).copy()
            flat = bad.reshape(-1)
            idx = np.random.default_rng((self.seed, 102, index)).integers(
                0, flat.size, size=max(1, flat.size // 64)
            )
            flat[idx] = np.nan
            return image, bad, spacing
        if r[2] < self.poison_empty_rate:
            return image, np.zeros_like(np.asarray(mask)), spacing
        return image, mask, spacing

    # -- per-window faults ---------------------------------------------------

    def begin_window(self, widx: int):
        """Arm (at most) one transient collect fault for window ``widx``."""
        if widx in self._spent_windows:
            return
        armed = widx in self.fail_windows
        if not armed and self.window_fault_rate:
            armed = (
                np.random.default_rng((self.seed, 103, widx)).random()
                < self.window_fault_rate
            )
        if armed:
            self._pending_fault = widx

    def transfer_hook(self, stage: str, x):
        """``PlanExecutor`` transfer_callback: raise the armed fault once."""
        if self._pending_fault is not None and stage in COLLECT_STAGES:
            w, self._pending_fault = self._pending_fault, None
            self._spent_windows.add(w)
            raise InjectedFault(
                f"transient collect fault injected (window {w}, stage {stage})"
            )

    def maybe_straggle(self, widx: int):
        """Sleep inside window ``widx``'s timed region (straggler sim)."""
        if widx in self.straggle_windows and self.straggle_seconds > 0:
            time.sleep(self.straggle_seconds)

    def should_preempt(self, index: int) -> bool:
        """True exactly once, when the case ordinal reaches the trigger."""
        if self.preempt_at_case is None or self._preempted:
            return False
        if index >= self.preempt_at_case:
            self._preempted = True
            return True
        return False


# ---------------------------------------------------------------------------
# retry / backoff policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-window retry with exponential backoff (no jitter: deterministic).

    Consumed by ``PlanExecutor.collect_window``: a window whose collect
    raises is re-submitted from its prepped device state and re-drained
    after ``base_delay * multiplier^k`` seconds (capped at ``max_delay``),
    up to ``max_retries`` times; the last failure re-raises.
    ``timeout_s`` is advisory -- a collect exceeding it is flagged in the
    window stats (``collect_timeout``) for the straggler census, since a
    blocking device fetch cannot be interrupted portably.
    """

    max_retries: int = 2
    base_delay: float = 0.05
    multiplier: float = 2.0
    max_delay: float = 2.0
    timeout_s: float | None = None

    def delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based)."""
        return min(self.base_delay * self.multiplier ** attempt, self.max_delay)


# ---------------------------------------------------------------------------
# the resilient run driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """What one :meth:`ResilientRunner.run` call did."""

    status: str = "complete"  # 'complete' | 'preempted'
    skipped: int = 0          # cases already in the manifest (or re-recorded)
    processed: int = 0        # rows written this run (done + error)
    quarantined: int = 0      # of processed: row-level error records
    windows: int = 0          # windows collected this run
    window_retries: int = 0   # collect retries the executor performed
    stragglers: list = dataclasses.field(default_factory=list)
    seconds: float = 0.0

    @property
    def cases_per_second(self) -> float:
        return self.processed / self.seconds if self.seconds > 0 else 0.0


class ResilientRunner:
    """Drive an extractor over a case stream with full resilience.

    ``cases`` yields ``(name, image, mask, spacing)`` tuples or lazy
    ``(name, loader)`` pairs (``loader() -> (image, mask, spacing)``);
    lazy loaders keep load faults quarantinable per case.  The runner
    mirrors ``extract_stream``'s submit/collect overlap (window k+1 is
    submitted before window k is drained) and interleaves the resilience
    duties at the window boundaries:

    * done-set skip by content id BEFORE any prep work;
    * per-case quarantine via the executor's safe prep (a poisoned case
      becomes a row-level ``error`` record, never a window abort);
    * manifest ``record`` per row as each window drains (a kill loses at
      most the in-flight window);
    * preemption checks each case: on SIGTERM the open buffer is
      abandoned and -- with ``drain_on_preempt=True`` (the grace-period
      behaviour) -- the already-submitted window is still drained and
      committed, so at most ONE window of work is ever redone;
    * per-window wall-times observed by the straggler detector and
      surfaced through ``stats_callback(widx, stats)`` (census print).
    """

    def __init__(self, extractor, manifest: RunManifest, *, window: int = 16,
                 fault_plan: FaultPlan | None = None,
                 straggler: StragglerDetector | None = None,
                 preemption: PreemptionHandler | None = None,
                 drain_on_preempt: bool = True, stats_callback=None,
                 feature_names=FEATURE_NAMES):
        if not isinstance(window, int) or window < 1:
            raise ValueError(f"window must be a positive int, got {window!r}")
        self.extractor = extractor
        self.ex = getattr(extractor, "executor", extractor)
        self.manifest = manifest
        self.window = window
        self.fault_plan = fault_plan
        self.straggler = straggler or StragglerDetector(
            window=8, warmup=1, min_samples=4
        )
        self.preemption = preemption
        self.drain_on_preempt = drain_on_preempt
        self.stats_callback = stats_callback
        self.feature_names = tuple(feature_names)

    # -- internals -----------------------------------------------------------

    def _load(self, index: int, item):
        """Materialise one case; faults (injected or real) raise here."""
        if len(item) == 2 and callable(item[1]):
            case = item[1]()
        else:
            case = tuple(item[1:])
        if self.fault_plan is not None:
            case = self.fault_plan.inject_case(index, case)
        if len(case) != 3:
            raise ValueError(f"case must be (image, mask, spacing), "
                             f"got {len(case)} elements")
        return case

    def _collect(self, pending, report: RunReport):
        """Drain one submitted window; write its manifest rows."""
        widx, state, recs = pending
        fp = self.fault_plan
        if fp is not None:
            fp.begin_window(widx)
        t0 = time.perf_counter()
        if fp is not None:
            fp.maybe_straggle(widx)  # inside the timed region
        rows, stats = self.ex.collect_window(state)
        dt = time.perf_counter() - t0
        slow = self.straggler.observe(widx, dt)
        if slow:
            report.stragglers.append((widx, dt))
        errors = stats.get("errors", {})
        for j, ((cid, name), row) in enumerate(zip(recs, rows)):
            # rows align with recs by construction.  Quarantine is keyed
            # off the executor's authoritative window-relative ``errors``
            # map -- NOT by sniffing NaN in the row, which would silently
            # misrecord a legitimate feature row that happens to contain
            # a NaN value as quarantined.
            if j in errors:
                err = errors[j]
                wrote = self.manifest.record(
                    cid, "error", name=name, error=err, window=widx
                )
                if wrote:
                    report.processed += 1
                    report.quarantined += 1
                else:
                    report.skipped += 1
                continue
            wrote = self.manifest.record(
                cid, "done", name=name,
                features=dict(zip(self.feature_names, np.asarray(row))),
                window=widx,
            )
            if wrote:
                report.processed += 1
            else:
                report.skipped += 1
        report.windows += 1
        if self.stats_callback is not None:
            census = dict(state.plan.stats())
            census.update(
                window=widx, seconds=dt, straggler=slow,
                quarantined=stats.get("quarantined_cases", 0),
                straggler_median=self.straggler.median,
            )
            self.stats_callback(widx, census)

    # -- driving -------------------------------------------------------------

    def run(self, cases) -> RunReport:
        """Stream ``cases`` through the extractor with full resilience."""
        ex = self.ex
        man = self.manifest
        if not man._loaded:
            man.resume()
        handler = self.preemption or PreemptionHandler()
        own_handler = self.preemption is None
        handler.install()
        report = RunReport()
        retries0 = getattr(ex, "window_retries", 0)
        t0 = time.perf_counter()
        pending = None  # (widx, submitted window state, [(case id, name)])
        buf: list = []  # [(case id, name, prepped)]
        widx = 0
        preempted = False
        fp = self.fault_plan
        try:
            for index, item in enumerate(cases):
                if fp is not None and fp.should_preempt(index):
                    os.kill(os.getpid(), signal.SIGTERM)  # the real signal
                if handler.requested:
                    preempted = True
                    break
                name = item[0]
                try:
                    case = self._load(index, item)
                    cid = RunManifest.case_id(case[1], case[2])
                except (KeyboardInterrupt, SystemExit):
                    raise
                except Exception as e:
                    # load error: no content to hash -- quarantine under
                    # a STABLE name-keyed id, so a resume over a filtered
                    # or reordered stream recognises the record instead of
                    # recording the same failing case under a new
                    # position-dependent id and double-counting it.  The
                    # stream index is a tiebreaker for anonymous cases only.
                    eid = f"load-error:{name}" if name else f"load-error:@{index}"
                    if man.record(eid, "error", name=name,
                                  error=f"{type(e).__name__}: {e}"):
                        report.processed += 1
                        report.quarantined += 1
                    else:
                        report.skipped += 1
                    continue
                if cid in man.done:
                    report.skipped += 1
                    continue
                buf.append((cid, name, ex.prep_case(case)))
                if len(buf) >= self.window:
                    # submit k+1 BEFORE draining k: the stream overlap
                    state = ex.submit_prepped([p for _, _, p in buf])
                    if pending is not None:
                        self._collect(pending, report)
                    pending = (widx, state, [(c, n) for c, n, _ in buf])
                    buf = []
                    widx += 1
            if not preempted and buf:
                state = ex.submit_prepped([p for _, _, p in buf])
                if pending is not None:
                    self._collect(pending, report)
                pending = (widx, state, [(c, n) for c, n, _ in buf])
                buf = []
                widx += 1
            if pending is not None and (not preempted or self.drain_on_preempt):
                # grace-period drain: the in-flight window was already
                # submitted; committing it is what bounds the redo to the
                # (abandoned) open buffer.  drain_on_preempt=False models
                # a hard kill: the whole in-flight window is redone.
                self._collect(pending, report)
                pending = None
        finally:
            if own_handler:
                handler.uninstall()
            man.flush()
        report.status = "preempted" if (preempted or handler.requested) \
            else "complete"
        report.seconds = time.perf_counter() - t0
        report.window_retries = getattr(ex, "window_retries", 0) - retries0
        return report
