"""Fault tolerance: step watchdog, straggler detection, elastic restart.

At thousand-node scale three failure classes dominate; each has a handler
here that the Trainer wires in:

  * **crash / preemption** -> checkpoint-restart: the Trainer resumes from
    ``CheckpointManager.latest_step`` automatically, and a SIGTERM handler
    writes an emergency checkpoint before exit (preemption notice).
  * **stragglers** -> ``StragglerDetector`` keeps a robust EWMA of step
    times; steps slower than ``threshold x`` median trigger a callback
    (log / exclude host / re-mesh decision is deployment policy).
  * **node loss** -> ``elastic_remesh``: rebuild a smaller mesh from the
    surviving devices and reshard the latest checkpoint onto it
    (reshard-on-load makes this a pure data movement).
"""
from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh


@dataclass
class StragglerDetector:
    window: int = 50
    threshold: float = 2.0
    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if this step was a straggler."""
        self._times.append(seconds)
        if len(self._times) < max(8, self.window // 4):
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if seconds > self.threshold * med:
            self.slow_steps.append((step, seconds, med))
            return True
        return False

    @property
    def median(self):
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]


class PreemptionHandler:
    """SIGTERM -> request a final checkpoint at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def install(self):
        def handler(signum, frame):
            self.requested = True
            if callable(self._prev):  # pragma: no cover
                self._prev(signum, frame)

        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


def surviving_mesh(axis_names=("data", "model"), model_parallel: int = 1,
                   devices=None) -> Mesh:
    """Build the largest well-formed mesh from surviving devices.

    Drops trailing devices so the data axis stays a whole number; at real
    scale 'surviving' comes from the coordinator's health service, here
    from ``jax.devices()``.
    """
    import numpy as np

    devices = list(devices if devices is not None else jax.devices())
    n = (len(devices) // model_parallel) * model_parallel
    devices = devices[:n]
    arr = np.array(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, axis_names)


def elastic_remesh(ckpt_manager, skeleton, make_shardings, *, devices=None,
                   model_parallel: int = 1):
    """Resume the latest checkpoint on a smaller (surviving) mesh.

    ``make_shardings(mesh)`` -> tree of NamedShardings for ``skeleton``.
    Returns (mesh, step, tree, extras) or None when no checkpoint exists.
    """
    mesh = surviving_mesh(model_parallel=model_parallel, devices=devices)
    out = ckpt_manager.restore_latest(skeleton, make_shardings(mesh))
    if out is None:
        return None
    step, tree, extras = out
    return mesh, step, tree, extras


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
