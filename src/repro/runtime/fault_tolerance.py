"""Fault tolerance: step watchdog, straggler detection, elastic restart.

At thousand-node scale three failure classes dominate; each has a handler
here that the Trainer wires in:

  * **crash / preemption** -> checkpoint-restart: the Trainer resumes from
    ``CheckpointManager.latest_step`` automatically, and a SIGTERM handler
    writes an emergency checkpoint before exit (preemption notice).
  * **stragglers** -> ``StragglerDetector`` keeps a robust EWMA of step
    times; steps slower than ``threshold x`` median trigger a callback
    (log / exclude host / re-mesh decision is deployment policy).
  * **node loss** -> ``elastic_remesh``: rebuild a smaller mesh from the
    surviving devices and reshard the latest checkpoint onto it
    (reshard-on-load makes this a pure data movement).
"""
from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh


@dataclass
class StragglerDetector:
    """Median-based outlier detection over step (or window) wall-times.

    ``warmup`` observations are swallowed entirely -- neither flagged nor
    admitted to the median window -- because the first step/window of a
    jax pipeline pays its cold compiles and would otherwise both (a) be
    flagged as a spurious straggler and (b) inflate the median every
    real straggler is compared against.  ``min_samples`` overrides the
    default ``max(8, window // 4)`` flagging threshold for short runs
    (e.g. the streaming pipeline's per-window census, where a 13-window
    job should still flag its stalled 9th window).
    """

    window: int = 50
    threshold: float = 2.0
    warmup: int = 0
    min_samples: int | None = None
    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    slow_steps: list = field(default_factory=list)
    _seen: int = field(default=0, repr=False)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if this step was a straggler."""
        self._seen += 1
        if self._seen <= self.warmup:
            return False  # cold-compile grace: excluded from the median too
        self._times.append(seconds)
        need = self.min_samples if self.min_samples is not None \
            else max(8, self.window // 4)
        if len(self._times) < max(2, need):
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if seconds > self.threshold * med:
            self.slow_steps.append((step, seconds, med))
            return True
        return False

    @property
    def median(self):
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]


class PreemptionHandler:
    """SIGTERM -> request a graceful stop at the next step/window boundary.

    ``install`` CHAINS any pre-existing Python SIGTERM handler (it still
    fires after ours -- two independent layers both get their preemption
    notice) and is idempotent: a second ``install`` is a no-op rather
    than making the handler its own predecessor.  ``uninstall`` restores
    exactly what was installed before -- including ``SIG_DFL``/``SIG_IGN``
    dispositions and the C-level ``None`` case (restored as ``SIG_DFL``,
    the closest Python can express).
    """

    def __init__(self):
        self.requested = False
        self._prev = None
        self._installed = False

    def install(self):
        if self._installed:
            return self

        def handler(signum, frame):
            self.requested = True
            prev = self._prev
            if callable(prev) and prev is not handler:
                prev(signum, frame)

        self._prev = signal.signal(signal.SIGTERM, handler)
        self._installed = True
        return self

    def uninstall(self):
        if not self._installed:
            return
        prev = self._prev if self._prev is not None else signal.SIG_DFL
        signal.signal(signal.SIGTERM, prev)
        self._prev = None
        self._installed = False

    def reset(self):
        """Clear a consumed preemption notice (e.g. between runner calls)."""
        self.requested = False


def surviving_mesh(axis_names=("data", "model"), model_parallel: int = 1,
                   devices=None) -> Mesh:
    """Build the largest well-formed mesh from surviving devices.

    Drops trailing devices so the data axis stays a whole number; at real
    scale 'surviving' comes from the coordinator's health service, here
    from ``jax.devices()``.
    """
    import numpy as np

    devices = list(devices if devices is not None else jax.devices())
    n = (len(devices) // model_parallel) * model_parallel
    devices = devices[:n]
    arr = np.array(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, axis_names)


def elastic_remesh(ckpt_manager, skeleton, make_shardings, *, devices=None,
                   model_parallel: int = 1):
    """Resume the latest checkpoint on a smaller (surviving) mesh.

    ``make_shardings(mesh)`` -> tree of NamedShardings for ``skeleton``.
    Returns (mesh, step, tree, extras) or None when no checkpoint exists.
    """
    mesh = surviving_mesh(model_parallel=model_parallel, devices=devices)
    out = ckpt_manager.restore_latest(skeleton, make_shardings(mesh))
    if out is None:
        return None
    step, tree, extras = out
    return mesh, step, tree, extras


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
