"""Roofline pricing layer: plan work items -> FLOPs/bytes -> microseconds.

The cost model (``runtime/costmodel``) needs a price for kernel launches
the autotune sweeps have never measured.  Until this layer existed that
price was a single analytic constant (``(cap/1024)^2 * PAIR_SWEEP_US``)
that knew nothing about the hardware OR about any kernel except the pair
sweep.  This module replaces it with a two-part roofline estimate:

1. **Structural work models.**  For every launch kind the executor
   dispatches (the pair-sweep diameter kernel, the prune bound, the
   segmented compaction, fused marching cubes, and the first-order/GLCM
   intensity families) a closed-form FLOPs + bytes count as a function of
   the plan metadata alone -- vertex bucket M, batch depth, padded volume
   shape.  The per-unit constants in :data:`CAL` are CALIBRATED against
   ``jax.jit(...).lower(...).compile().cost_analysis()`` on the 'ref'
   kernels (loop-corrected via ``repro.utils.roofline.jaxpr_cost``, since
   XLA counts a scan body once) at the canonical batch depth
   :data:`CAL_DEPTH`; ``tests/test_roofline.py`` and the CI ``roofline``
   stage pin the agreement to within :data:`AGREEMENT_RTOL`.

2. **A hardware profile.**  Peak FLOP/s and memory bandwidth for the
   resolved backend, from ``runtime/autotune.get_hw_profile`` -- a
   measured ``hw/<backend>`` cache entry when one exists, a tiny one-time
   probe where probing is allowed, or the static per-backend default.

The estimate is then the classic roofline bound

    time = max(flops / peak_flops, bytes / mem_bw)

which is a LOWER bound on real wall time; like the analytic constant it
replaces, only ratios between buckets feed scheduling decisions, so the
model being uniformly optimistic is harmless.  ``benchmarks/
roofline_report.py`` closes the loop by measuring each kernel and
reporting the achieved fraction of this bound as gated bench rows.

Calibration provenance: constants fitted on the jax CPU backend
(cost_analysis of the 'ref' kernels) at depth 4, k_dirs=16, n_bins=32,
MC chunk_z=32 -- the pipeline defaults.  The fit is linear per kind and
stable to ~3% across buckets/shapes; the 10% agreement gate leaves that
much headroom plus room for upstream jaxpr drift.
"""
from __future__ import annotations

import math

from repro.core import plan as planlib

# canonical batch depth the CAL constants were fitted at: the correction
# ratio (jaxpr loops-multiplied / loops-once) scales loop-EXTERNAL work
# together with the loop bodies, so the fitted per-unit constants carry a
# mild depth dependence -- agreement checks must compare at this depth
CAL_DEPTH = 4

# relative tolerance of the plan-census == cost_analysis agreement gate
AGREEMENT_RTOL = 0.10

# per-kind calibrated work models (FLOPs and bytes per structural unit):
#   diameter    per vertex pair:      depth * M^2 units
#   prune       per case, affine in M (the K-dir projections + the fixed
#               (2K)^2 extreme brute-force and 8-corner bound terms)
#   compact     per case, affine in (M, cap_out)
#   mc          per padded slab cell: nslabs * chunk_z * nx * ny units
#               (the z-scan pads the slab range, so cost follows the
#               padded slab volume, not the raw cell count)
#   firstorder  per padded voxel (n_bins=32 histogram + moment stats)
#   glcm        per padded voxel (13-direction pair accumulation)
CAL = {
    "diameter": {"flops": 22.2, "bytes": 36.9},
    "prune": {"flops_m": 2527.6, "flops_c": 36531.0,
              "bytes_m": 3390.3, "bytes_c": 9215.0},
    "compact": {"flops_m": 25.04, "flops_cap": 1.0,
                "bytes_m": 36.71, "bytes_cap": 13.0},
    "mc": {"flops": 773.0, "bytes": 2035.0},
    "firstorder": {"flops": 226.0, "bytes": 338.0},
    "glcm": {"flops": 51.3, "bytes": 92.6},
}

MC_CHUNK_Z = 32  # the ref backend's z-slab scan chunk (kernels/ops.py)


# ---------------------------------------------------------------------------
# structural work models
# ---------------------------------------------------------------------------

def diameter_cost(m: int, depth: int = 1) -> tuple[float, float]:
    """(flops, bytes) of one pair-sweep launch: ``depth`` cases at bucket M."""
    pairs = float(depth) * float(m) ** 2
    c = CAL["diameter"]
    return c["flops"] * pairs, c["bytes"] * pairs


def prune_cost(m: int, depth: int = 1) -> tuple[float, float]:
    """(flops, bytes) of one batched prune-bound launch (k_dirs=16)."""
    c = CAL["prune"]
    d = float(depth)
    return (d * (c["flops_m"] * m + c["flops_c"]),
            d * (c["bytes_m"] * m + c["bytes_c"]))


def compact_cost(m: int, cap: int, depth: int = 1) -> tuple[float, float]:
    """(flops, bytes) of one segmented-compaction launch M -> cap."""
    c = CAL["compact"]
    d = float(depth)
    return (d * (c["flops_m"] * m + c["flops_cap"] * cap),
            d * (c["bytes_m"] * m + c["bytes_cap"] * cap))


def mc_slab_cells(shape, chunk_z: int = MC_CHUNK_Z) -> float:
    """Padded slab-volume cell count the fused-MC z-scan actually visits."""
    nx, ny, nz = (int(s) for s in shape)
    nslabs = max(1, math.ceil((nz - 1) / chunk_z))
    return float(nslabs * chunk_z * nx * ny)


def mc_cost(shape, depth: int = 1) -> tuple[float, float]:
    """(flops, bytes) of one fused marching-cubes launch at a shape bucket."""
    cells = float(depth) * mc_slab_cells(shape)
    c = CAL["mc"]
    return c["flops"] * cells, c["bytes"] * cells


def family_cost(family: str, shape, depth: int = 1) -> tuple[float, float]:
    """(flops, bytes) of one intensity-family launch (n_bins=32)."""
    c = CAL[family]
    vox = float(depth) * float(math.prod(int(s) for s in shape))
    return c["flops"] * vox, c["bytes"] * vox


def work_item_cost(item: planlib.WorkItem) -> tuple[float, float]:
    """Price one plan :class:`~repro.core.plan.WorkItem` as (flops, bytes)."""
    if item.kind == "diameter":
        return diameter_cost(item.m, item.depth)
    if item.kind == "prune":
        return prune_cost(item.m, item.depth)
    if item.kind == "compact":
        return compact_cost(item.m, item.cap, item.depth)
    if item.kind == "mc":
        return mc_cost(item.shape, item.depth)
    if item.kind in ("firstorder", "glcm"):
        return family_cost(item.kind, item.shape, item.depth)
    raise ValueError(
        f"unknown work item kind {item.kind!r}; known kinds: "
        f"{planlib.WORK_KINDS}"
    )


def plan_cost(plan: planlib.ExtractionPlan) -> dict:
    """Total (flops, bytes) of every launch a plan implies, plus per-kind."""
    per_kind: dict = {}
    total_f = total_b = 0.0
    for item in plan.work_census():
        f, b = work_item_cost(item)
        kf, kb = per_kind.get(item.kind, (0.0, 0.0))
        per_kind[item.kind] = (kf + f, kb + b)
        total_f += f
        total_b += b
    return {"flops": total_f, "bytes": total_b, "per_kind": per_kind}


# ---------------------------------------------------------------------------
# roofline pricing
# ---------------------------------------------------------------------------

def roofline_us(flops: float, nbytes: float, profile: dict) -> float:
    """``max(compute, memory)`` bound in MICROSECONDS under a hw profile."""
    compute_s = flops / float(profile["peak_flops"])
    memory_s = nbytes / float(profile["mem_bw"])
    return max(compute_s, memory_s) * 1e6


def work_item_us(item: planlib.WorkItem, profile: dict) -> float:
    """Roofline bound of one planned launch, in microseconds."""
    f, b = work_item_cost(item)
    return roofline_us(f, b, profile)


# ---------------------------------------------------------------------------
# cost_analysis cross-check (the calibration the CAL table is pinned to)
# ---------------------------------------------------------------------------

def xla_kernel_cost(kind: str, *, depth: int = CAL_DEPTH, m: int | None = None,
                    cap: int | None = None,
                    shape: tuple | None = None) -> tuple[float, float]:
    """Loop-corrected ``cost_analysis()`` (flops, bytes) of one REF launch.

    Builds exactly the batched 'ref' launch the executor would dispatch
    for the given bucket, lowers and compiles it, and returns XLA's FLOP
    and bytes-accessed counts scaled by the jaxpr loop correction
    (``repro.utils.roofline``) -- the ground truth the structural models
    above are calibrated against.  Compiles a kernel, so tests and the CI
    agreement stage call it, the hot path never does.
    """
    import jax
    import jax.numpy as jnp

    from repro.utils import roofline as uro

    if kind == "diameter":
        from repro.kernels import ref as _ref

        args = (jnp.zeros((depth, m, 3), jnp.float32),
                jnp.ones((depth, m), bool))

        def fn(v, msk):
            return jax.lax.map(
                lambda a: _ref.max_diameters_sq(a[0], a[1]), (v, msk)
            )
    elif kind == "prune":
        from repro.kernels import prune as _prune

        args = (jnp.zeros((depth, m, 3), jnp.float32),
                jnp.ones((depth, m), bool))

        def fn(v, msk):
            return _prune.keep_mask_batch(v, msk, 16)
    elif kind == "compact":
        from repro.kernels import compact as _compact

        args = (jnp.zeros((depth, m, 3), jnp.float32),
                jnp.ones((depth, m), bool))

        def fn(v, keep):
            return _compact.compact_batch_ref(v, keep, cap)
    elif kind == "mc":
        from repro.kernels import ops as _ops

        args = (jnp.zeros((depth,) + tuple(shape), jnp.float32),
                jnp.ones((depth, 3), jnp.float32))

        def fn(vols, sps):
            return _ops.mc_volume_area_batch(vols, 0.5, sps, backend="ref")
    elif kind in ("firstorder", "glcm"):
        from repro.kernels import firstorder as _fo
        from repro.kernels import glcm as _glcm

        op = (_fo.firstorder_packed_batch_ref if kind == "firstorder"
              else _glcm.glcm_matrix_batch_ref)
        args = (jnp.zeros((depth,) + tuple(shape), jnp.float32),
                jnp.ones((depth,) + tuple(shape), bool))

        def fn(images, masks):
            return op(images, masks, 32)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")

    compiled = jax.jit(fn).lower(*args).compile()
    raw_f, raw_b = uro.compiled_cost(compiled)
    fc, bc, _ = uro.loop_corrections(fn, *args)
    return raw_f * fc, raw_b * bc


def model_kernel_cost(kind: str, *, depth: int = CAL_DEPTH,
                      m: int | None = None, cap: int | None = None,
                      shape: tuple | None = None) -> tuple[float, float]:
    """The structural model's (flops, bytes) for the same launch."""
    return work_item_cost(
        planlib.WorkItem(kind=kind, depth=depth, m=m, cap=cap, shape=shape)
    )


def agreement(kind: str, *, depth: int = CAL_DEPTH, m: int | None = None,
              cap: int | None = None, shape: tuple | None = None) -> dict:
    """Model-vs-XLA agreement report for one launch configuration.

    ``flops_rel_err`` / ``bytes_rel_err`` are relative to the XLA side;
    ``ok`` is both within :data:`AGREEMENT_RTOL`.
    """
    mf, mb = model_kernel_cost(kind, depth=depth, m=m, cap=cap, shape=shape)
    xf, xb = xla_kernel_cost(kind, depth=depth, m=m, cap=cap, shape=shape)
    f_err = abs(mf - xf) / xf if xf else float("inf")
    b_err = abs(mb - xb) / xb if xb else float("inf")
    return {
        "kind": kind,
        "model_flops": mf, "xla_flops": xf, "flops_rel_err": f_err,
        "model_bytes": mb, "xla_bytes": xb, "bytes_rel_err": b_err,
        "ok": f_err <= AGREEMENT_RTOL and b_err <= AGREEMENT_RTOL,
    }


#: The (kind, bucket) grid the CI roofline stage checks agreement on --
#: one small and one larger bucket per kind where the launch compiles in
#: well under a second on the CPU 'ref' backend.
AGREEMENT_GRID = (
    {"kind": "diameter", "m": 512},
    {"kind": "diameter", "m": 2048},
    {"kind": "prune", "m": 512},
    {"kind": "prune", "m": 2048},
    {"kind": "compact", "m": 1024, "cap": 512},
    {"kind": "compact", "m": 4096, "cap": 2048},
    {"kind": "mc", "shape": (34, 34, 34)},
    {"kind": "mc", "shape": (66, 66, 66)},
    {"kind": "firstorder", "shape": (34, 34, 34)},
    {"kind": "glcm", "shape": (34, 34, 34)},
)
