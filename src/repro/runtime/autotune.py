"""Measured kernel-configuration selection (diameter variants + MC bricks).

The Fig.1-style variant study shows no single configuration wins at every
problem size: small vertex buckets want one big block (grid overhead), large
buckets want the triangular prefetch schedule or the MXU 'gram' path, and
the marching-cubes kernel has the same trade-off along its ``(bx, by, bz)``
brick shape and in-kernel ``chunk`` length (VMEM residency vs grid overhead).
This module turns that study into infrastructure: per static *bucket* (the
vertex padding cap from ``ops.vertex_bucket`` for the diameter kernel, the
padded volume shape for MC) it sweeps the candidate configurations once on
the resolved backend, caches the winner in a JSON file, and hands the cached
choice to every later call -- the TPU analogue of a CUDA occupancy/launch-
bound autotuner.

Cache schema (versioned): one JSON object ``{"schema": 3, "entries": {...}}``
with entries keyed ``"diameter/<backend>/M<bucket>/B<depth>"``,
``"mc/<backend>/S<nx>x<ny>x<nz>/B<depth>"``,
``"compact/<backend>/M<bucket>/B<depth>"`` (the segmented-compaction
scatter block), ``"firstorder/<backend>/S<nx>x<ny>x<nz>/B<depth>"`` /
``"glcm/<backend>/S<nx>x<ny>x<nz>/B<depth>"`` (the intensity-family
reduction/pair-scatter blocks, one namespace per registered feature
family -- see ``repro.core.plan.FamilySpec``), ``"sync/<backend>"``
(the measured device->host
fetch latency -- the quantity the counted-vs-static schedule decision
of ``runtime/costmodel`` turns on; probed once per backend, not per
bucket, since a (B, 2) count fetch is latency- not bandwidth-bound),
and ``"hw/<backend>"`` (the measured hardware roofline profile -- peak
FLOP/s + memory bandwidth -- that prices unmeasured buckets via
``runtime/roofline``; probed once per host per backend, same policy as
the sync probe).  ``B<depth>`` is the power-of-two *batch-depth bucket*
(:func:`batch_bucket`): under ``lax.map`` / the batched pipeline the best
(variant, block) / (brick, chunk) can shift with how many cases a launch
carries, so the winning configuration is cached per (bucket, depth) pair
and the sweeps measure at the requested depth.  Each record holds the
winning configuration plus the full measured table (microseconds), so the
sweep is also a persisted perf trajectory.  PR 1 wrote a *flat*
``{key: record}`` object (schema v1) and PR 2/3 a v2 envelope with
depth-less keys; loads migrate both transparently (depth-less keys gain
``/B1`` -- those sweeps measured single-case launches) and the next
``put`` rewrites the file in v3 form.  Unknown future schemas and
malformed files load as empty (worst case: re-measure) -- the cache never
crashes a run.
The path comes from ``REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro_autotune.json``); writes are atomic (tmp + rename) so
concurrent processes at worst re-measure.

Sweeping policy: measured sweeps run by default only on the compiled
``pallas`` backend.  ``interpret`` is a correctness backend -- Python timings
there are meaningless for TPU choices -- so it uses the default config
unless ``REPRO_AUTOTUNE=1`` forces a sweep (used by tests to exercise the
round-trip) ; ``REPRO_AUTOTUNE=0`` disables sweeping everywhere.  The
``ref`` backend has no configuration axis at all.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time

import jax
import numpy as np

SCHEMA_VERSION = 3

DEFAULT_VARIANTS = ("seqacc", "tri_prefetch", "nomask", "gram")
DEFAULT_BLOCKS = (128, 256, 512)

DEFAULT_MC_BLOCKS = ((8, 8, 8), (16, 8, 8), (8, 8, 16), (16, 16, 8))
DEFAULT_MC_CHUNKS = (256, 512, 1024)

DEFAULT_COMPACT_BLOCKS = (128, 256, 512)

# first-order blocks MUST be multiples of the canonical accumulation chunk
# (kernels/firstorder.CANON_CHUNK) -- the sweep enforces this, so a tuned
# block can never change feature bits
DEFAULT_FIRSTORDER_BLOCKS = (1024, 2048, 4096)
DEFAULT_GLCM_BLOCKS = (512, 1024, 2048, 4096)


@dataclasses.dataclass(frozen=True)
class DiameterConfig:
    variant: str
    block: int


@dataclasses.dataclass(frozen=True)
class MCConfig:
    block: tuple[int, int, int]
    chunk: int


@dataclasses.dataclass(frozen=True)
class CompactConfig:
    block: int


@dataclasses.dataclass(frozen=True)
class FamilyConfig:
    """One intensity-family kernel configuration (block is the only axis)."""

    block: int


DEFAULT_CONFIG = DiameterConfig("seqacc", 256)
DEFAULT_MC_CONFIG = MCConfig((8, 8, 8), 512)
DEFAULT_COMPACT_CONFIG = CompactConfig(256)
DEFAULT_FIRSTORDER_CONFIG = FamilyConfig(2048)
DEFAULT_GLCM_CONFIG = FamilyConfig(2048)


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_autotune.json")


def _migrate_key(key: str) -> str:
    """v1/v2 -> v3 key migration: depth-less keys gain the ``/B1`` segment.

    PR 1-3 sweeps measured single-case launches, so their records are
    exactly the depth-1 entries of the v3 key space; unknown key shapes
    pass through untouched (an unrecognised entry is merely never read).
    """
    parts = key.split("/")
    if len(parts) == 3 and parts[0] in ("diameter", "mc", "compact"):
        return key + "/B1"
    return key


class AutotuneCache:
    """Tiny versioned JSON key->record store with atomic writes.

    On disk: ``{"schema": 3, "entries": {key: record}}``.  Schema v1 (the
    PR 1 layout: a flat ``{key: record}`` object with no ``schema`` field)
    and schema v2 (the PR 2/3 envelope with depth-less keys) are migrated
    on load (see :func:`_migrate_key`); an unknown schema or a malformed
    file reads as empty so stale caches degrade to a re-sweep, never a
    crash.
    """

    def __init__(self, path: str | None = None):
        self.path = path or cache_path()

    def _read_raw(self) -> dict:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        return data if isinstance(data, dict) else {}

    def _entries(self) -> dict:
        raw = self._read_raw()
        if "schema" not in raw:
            # v1 (PR 1): flat key -> record mapping, depth-less keys
            return {
                _migrate_key(k): v
                for k, v in raw.items() if isinstance(v, dict)
            }
        if raw.get("schema") == 2:
            # v2 (PR 2/3): right envelope, depth-less keys
            ent = raw.get("entries")
            if not isinstance(ent, dict):
                return {}
            return {
                _migrate_key(k): v
                for k, v in ent.items() if isinstance(v, dict)
            }
        if raw.get("schema") != SCHEMA_VERSION:
            return {}  # future schema: don't guess, re-measure
        ent = raw.get("entries")
        return ent if isinstance(ent, dict) else {}

    def get(self, key: str):
        return self._entries().get(key)

    def put(self, key: str, record: dict) -> None:
        raw = self._read_raw()
        schema = raw.get("schema")
        if isinstance(schema, int) and schema > SCHEMA_VERSION:
            # a NEWER code version owns this file; rewriting it as v2 would
            # destroy its entries.  Skip the write -- re-measuring every run
            # is the documented worst case, losing data is not.
            return
        entries = self._entries()  # migrates v1 entries forward
        entries[key] = record
        payload = {"schema": SCHEMA_VERSION, "entries": entries}
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:  # pragma: no cover - cache is best-effort
            try:
                os.unlink(tmp)
            except OSError:
                pass


def batch_bucket(depth: int) -> int:
    """Power-of-two batch-depth bucket (limits the per-depth key space)."""
    b = 1
    while b < int(depth):
        b *= 2
    return b


def sweep_key(bucket: int, backend: str, batch: int = 1) -> str:
    return f"diameter/{backend}/M{int(bucket)}/B{batch_bucket(batch)}"


def mc_key(shape, backend: str, batch: int = 1) -> str:
    nx, ny, nz = (int(s) for s in shape)
    return f"mc/{backend}/S{nx}x{ny}x{nz}/B{batch_bucket(batch)}"


def compact_key(bucket: int, backend: str, batch: int = 1) -> str:
    return f"compact/{backend}/M{int(bucket)}/B{batch_bucket(batch)}"


def family_key(family: str, shape, backend: str, batch: int = 1) -> str:
    """Key for an intensity-family block entry: ``<ns>/<backend>/S../B..``.

    ``family`` is the autotune namespace a :class:`repro.core.plan.FamilySpec`
    registered (``firstorder`` / ``glcm``); ``shape`` the padded-volume
    bucket the launch carries.
    """
    nx, ny, nz = (int(s) for s in shape)
    return f"{family}/{backend}/S{nx}x{ny}x{nz}/B{batch_bucket(batch)}"


def mc_shape_bucket(shape, step: int = 32) -> tuple[int, int, int]:
    """Pad a volume shape up to the autotune bucket grid (limits key space)."""
    return tuple(max(step, int(math.ceil(int(s) / step)) * step) for s in shape)


# ---------------------------------------------------------------------------
# diameter kernel sweep
# ---------------------------------------------------------------------------


def measure_diameter_config(
    bucket: int,
    backend: str,
    variant: str,
    block: int,
    *,
    batch: int = 1,
    repeat: int = 2,
    warmup: int = 1,
    seed: int = 0,
) -> float:
    """Best-of-``repeat`` wall-clock seconds for one configuration.

    ``batch > 1`` measures the launch the pipeline actually issues at
    that depth -- a ``lax.map`` over a (batch, bucket, 3) stack -- since
    grid overhead amortises differently under a mapped sub-batch.
    """
    from repro.core import dispatcher
    from repro.kernels import diameter as dk

    rng = np.random.default_rng(seed)
    kw = dispatcher.kernel_kwargs(backend)

    if batch <= 1:
        verts = np.asarray(rng.normal(size=(bucket, 3)) * 10.0, np.float32)
        mask = np.ones((bucket,), np.float32)

        def call():
            return dk.max_diameters_sq_pallas(
                verts, mask, block=block, variant=variant, **kw
            )
    else:
        verts = np.asarray(
            rng.normal(size=(batch, bucket, 3)) * 10.0, np.float32
        )
        masks = np.ones((batch, bucket), np.float32)

        @jax.jit
        def mapped(v, m):
            return jax.lax.map(
                lambda a: dk.max_diameters_sq_pallas(
                    a[0], a[1], block=block, variant=variant, **kw
                ),
                (v, m),
            )

        def call():
            return mapped(verts, masks)

    for _ in range(warmup):
        jax.block_until_ready(call())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def sweep_diameter(
    bucket: int,
    backend: str,
    *,
    variants=DEFAULT_VARIANTS,
    blocks=DEFAULT_BLOCKS,
    batch: int = 1,
    repeat: int = 2,
):
    """Measure every (variant, block) candidate; returns (best, table).

    ``table`` maps ``"variant/block"`` to measured microseconds.  Blocks
    larger than the bucket only pad the grid, so they are dropped (the
    smallest candidate block is clamped in instead when all are too big).
    """
    usable = [b for b in blocks if b <= bucket] or [min(min(blocks), bucket)]
    table: dict[str, float] = {}
    best, best_t = None, float("inf")
    for variant in variants:
        for block in usable:
            t = measure_diameter_config(
                bucket, backend, variant, block, batch=batch, repeat=repeat
            )
            table[f"{variant}/{block}"] = t * 1e6
            if t < best_t:
                best, best_t = DiameterConfig(variant, block), t
    return best, table


def _sweep_allowed(backend: str) -> bool:
    flag = os.environ.get("REPRO_AUTOTUNE")
    if flag == "0":
        return False
    if flag == "1":
        return True
    return backend == "pallas"  # interpret timings don't transfer to TPU


def get_diameter_config(
    bucket: int,
    backend: str,
    *,
    batch: int = 1,
    cache: AutotuneCache | None = None,
    variants=DEFAULT_VARIANTS,
    blocks=DEFAULT_BLOCKS,
    repeat: int = 2,
) -> DiameterConfig:
    """Cached-or-swept best (variant, block) for a (bucket, depth) pair.

    The fast path is a cache hit -- no kernel runs at all.  A miss sweeps
    (when allowed, see module docstring) at the batch-depth bucket of
    ``batch``, persists the winner + table, and returns it; when sweeping
    is disallowed the default config is returned without being cached (so
    a later TPU run can still measure).
    """
    from repro.kernels import diameter as dk

    if backend == "ref":
        return DEFAULT_CONFIG
    cache = cache or AutotuneCache()
    key = sweep_key(bucket, backend, batch)
    hit = cache.get(key)
    if hit is not None:
        # validate: the persistent cache can outlive a rename/removal of a
        # variant (or be malformed) -- treat anything unusable as a miss
        try:
            cfg = DiameterConfig(str(hit["variant"]), int(hit["block"]))
        except (KeyError, TypeError, ValueError):
            cfg = None
        if cfg is not None and cfg.variant in dk.VARIANTS and cfg.block > 0:
            return cfg
    if not _sweep_allowed(backend):
        return DEFAULT_CONFIG
    best, table = sweep_diameter(
        bucket, backend, variants=variants, blocks=blocks,
        batch=batch_bucket(batch), repeat=repeat,
    )
    cache.put(
        key,
        {
            "variant": best.variant,
            "block": best.block,
            "us": table[f"{best.variant}/{best.block}"],
            "table": table,
            "swept_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    )
    return best


# ---------------------------------------------------------------------------
# marching-cubes brick sweep
# ---------------------------------------------------------------------------


def _mc_probe_volume(shape) -> np.ndarray:
    """Surface-bearing synthetic mask for MC timing: a centred ellipsoid.

    A representative occupancy matters more than the exact surface: the
    kernel's work is per-brick, and an ellipsoid at ~0.35 radius exercises
    both surface bricks (full triangle tables) and empty/interior ones.
    """
    nx, ny, nz = shape
    g = np.indices(shape, dtype=np.float32)
    c = (np.asarray(shape, np.float32) - 1.0) / 2.0
    r = np.maximum(np.asarray(shape, np.float32) * 0.35, 2.0)
    d2 = sum(((g[i] - c[i]) / r[i]) ** 2 for i in range(3))
    return (d2 < 1.0).astype(np.float32)


def measure_mc_config(
    shape,
    backend: str,
    block,
    chunk: int,
    *,
    batch: int = 1,
    repeat: int = 2,
    warmup: int = 1,
) -> float:
    """Best-of-``repeat`` wall-clock seconds for one MC (block, chunk).

    ``batch > 1`` measures the staged batched launch
    (``mc_volume_area_batch_pallas`` over a (batch, ...) stack) the
    device-pool pass-2a feed actually issues at that depth.
    """
    from repro.core import dispatcher
    from repro.kernels import marching_cubes as mck

    vol = _mc_probe_volume(tuple(int(s) for s in shape))
    kw = dispatcher.kernel_kwargs(backend)

    if batch <= 1:
        def call():
            return mck.mc_volume_area_pallas(
                vol, 0.5, (1.0, 1.0, 1.0), block=tuple(block), chunk=chunk,
                **kw
            )
    else:
        vols = np.broadcast_to(vol, (batch,) + vol.shape)
        sps = np.ones((batch, 3), np.float32)

        def call():
            return mck.mc_volume_area_batch_pallas(
                vols, 0.5, sps, block=tuple(block), chunk=chunk, **kw
            )

    for _ in range(warmup):
        jax.block_until_ready(call())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def mc_candidates(blocks=DEFAULT_MC_BLOCKS, chunks=DEFAULT_MC_CHUNKS):
    """Valid (block, chunk) pairs: chunk must tile the brick's cell count.

    Candidates that only clamp to an already-listed chunk are dropped so
    the sweep never measures the same effective configuration twice.
    """
    from repro.kernels import marching_cubes as mck

    out = []
    for block in blocks:
        bx, by, bz = (int(b) for b in block)
        usable = []
        for c in chunks:
            try:
                eff = mck.normalize_chunk((bx, by, bz), c)
            except ValueError:
                continue
            if eff == c:  # clamped duplicates measure nothing new
                usable.append(c)
        if not usable:
            usable = [bx * by * bz]
        out.extend(((bx, by, bz), c) for c in usable)
    return out


def sweep_mc(
    shape,
    backend: str,
    *,
    blocks=DEFAULT_MC_BLOCKS,
    chunks=DEFAULT_MC_CHUNKS,
    batch: int = 1,
    repeat: int = 2,
):
    """Measure every valid MC (block, chunk) candidate; (best, table).

    ``table`` maps ``"BXxBYxBZ/chunk"`` to measured microseconds.
    """
    table: dict[str, float] = {}
    best, best_t = None, float("inf")
    for block, chunk in mc_candidates(blocks, chunks):
        t = measure_mc_config(
            shape, backend, block, chunk, batch=batch, repeat=repeat
        )
        table[f"{block[0]}x{block[1]}x{block[2]}/{chunk}"] = t * 1e6
        if t < best_t:
            best, best_t = MCConfig(block, chunk), t
    return best, table


def _valid_mc_record(hit) -> MCConfig | None:
    from repro.kernels import marching_cubes as mck

    try:
        block = tuple(int(b) for b in hit["block"])
        chunk = int(hit["chunk"])
    except (KeyError, TypeError, ValueError):
        return None
    if len(block) != 3 or any(b <= 0 for b in block) or chunk <= 0:
        return None
    try:
        if mck.normalize_chunk(block, chunk) != chunk:
            return None  # stale entry: chunk no longer tiles the brick
    except ValueError:
        return None
    return MCConfig(block, chunk)


def get_mc_config(
    shape,
    backend: str,
    *,
    batch: int = 1,
    cache: AutotuneCache | None = None,
    blocks=DEFAULT_MC_BLOCKS,
    chunks=DEFAULT_MC_CHUNKS,
    repeat: int = 2,
) -> MCConfig:
    """Cached-or-swept best MC (brick, chunk) per (volume bucket, depth).

    Same contract as :func:`get_diameter_config`: cache hit -> no kernel
    runs; miss sweeps when allowed and persists winner + table; disallowed
    sweeps return the default uncached.  ``shape`` should already be an
    autotune bucket (see :func:`mc_shape_bucket`) so the key space stays
    bounded.
    """
    if backend == "ref":
        return DEFAULT_MC_CONFIG
    shape = tuple(int(s) for s in shape)
    cache = cache or AutotuneCache()
    key = mc_key(shape, backend, batch)
    hit = cache.get(key)
    if hit is not None:
        cfg = _valid_mc_record(hit)
        if cfg is not None:
            return cfg
    if not _sweep_allowed(backend):
        return DEFAULT_MC_CONFIG
    best, table = sweep_mc(
        shape, backend, blocks=blocks, chunks=chunks,
        batch=batch_bucket(batch), repeat=repeat,
    )
    cache.put(
        key,
        {
            "block": list(best.block),
            "chunk": best.chunk,
            "us": table[f"{best.block[0]}x{best.block[1]}x{best.block[2]}/{best.chunk}"],
            "table": table,
            "swept_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    )
    return best


# ---------------------------------------------------------------------------
# segmented-compaction scatter-block sweep
# ---------------------------------------------------------------------------


def measure_compact_config(
    bucket: int,
    backend: str,
    block: int,
    *,
    batch: int = 4,
    repeat: int = 2,
    warmup: int = 1,
    seed: int = 0,
) -> float:
    """Best-of-``repeat`` wall-clock seconds for one compaction block.

    The probe keeps ~25% of a ``(batch, bucket)`` stack -- the pipeline's
    typical keep fraction -- and compacts into the ``bucket // 4`` bucket,
    so the measured trade-off (grid steps vs per-step one-hot matmul size)
    matches the production scatter.  The one-hot matmul cost scales with
    the (B, M, cap) triple, so ``batch`` tracks the cap-group depth the
    pipeline actually launches.
    """
    from repro.core import dispatcher
    from repro.kernels import compact as ck

    batch = max(1, int(batch))
    rng = np.random.default_rng(seed)
    verts = np.asarray(rng.normal(size=(batch, bucket, 3)) * 10.0, np.float32)
    keep = rng.random((batch, bucket)) < 0.25
    cap = max(512, int(bucket) // 4)
    kw = dispatcher.kernel_kwargs(backend)

    def call():
        return ck.compact_batch_pallas(verts, keep, cap, block=block, **kw)

    for _ in range(warmup):
        jax.block_until_ready(call())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def sweep_compact(
    bucket: int,
    backend: str,
    *,
    blocks=DEFAULT_COMPACT_BLOCKS,
    batch: int = 4,
    repeat: int = 2,
):
    """Measure every compaction block candidate; returns (best, table).

    ``table`` maps ``str(block)`` to measured microseconds.  Blocks larger
    than the bucket only pad the grid, so they are dropped (the smallest
    candidate is clamped in when all are too big), mirroring the diameter
    sweep's policy.
    """
    usable = [b for b in blocks if b <= bucket] or [min(min(blocks), bucket)]
    table: dict[str, float] = {}
    best, best_t = None, float("inf")
    for block in usable:
        t = measure_compact_config(
            bucket, backend, block, batch=batch, repeat=repeat
        )
        table[str(block)] = t * 1e6
        if t < best_t:
            best, best_t = CompactConfig(block), t
    return best, table


def get_compact_config(
    bucket: int,
    backend: str,
    *,
    batch: int = 1,
    cache: AutotuneCache | None = None,
    blocks=DEFAULT_COMPACT_BLOCKS,
    repeat: int = 2,
) -> CompactConfig:
    """Cached-or-swept best compaction scatter block per (M bucket, depth).

    Same contract as :func:`get_diameter_config`: cache hit -> no kernel
    runs; miss sweeps when allowed and persists winner + table; disallowed
    sweeps return the default uncached.
    """
    if backend == "ref":
        return DEFAULT_COMPACT_CONFIG
    cache = cache or AutotuneCache()
    key = compact_key(bucket, backend, batch)
    hit = cache.get(key)
    if hit is not None:
        try:
            cfg = CompactConfig(int(hit["block"]))
        except (KeyError, TypeError, ValueError):
            cfg = None
        if cfg is not None and cfg.block > 0:
            return cfg
    if not _sweep_allowed(backend):
        return DEFAULT_COMPACT_CONFIG
    best, table = sweep_compact(
        bucket, backend, blocks=blocks, batch=batch_bucket(batch),
        repeat=repeat,
    )
    cache.put(
        key,
        {
            "block": best.block,
            "us": table[str(best.block)],
            "table": table,
            "swept_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    )
    return best


# ---------------------------------------------------------------------------
# intensity-family (firstorder / glcm) block sweeps
# ---------------------------------------------------------------------------


def _family_blocks(family: str):
    if family == "firstorder":
        return DEFAULT_FIRSTORDER_BLOCKS
    if family == "glcm":
        return DEFAULT_GLCM_BLOCKS
    raise ValueError(f"unknown autotune family namespace {family!r}")


def _family_default(family: str) -> FamilyConfig:
    return (DEFAULT_FIRSTORDER_CONFIG if family == "firstorder"
            else DEFAULT_GLCM_CONFIG)


def _probe_intensity_case(shape, seed: int = 0):
    """Masked intensity probe: the MC ellipsoid mask + a CT-like image."""
    mask = _mc_probe_volume(shape)
    rng = np.random.default_rng(seed)
    image = np.asarray(rng.normal(40.0, 15.0, size=shape), np.float32)
    return image, mask


def measure_family_config(
    family: str,
    shape,
    backend: str,
    block: int,
    *,
    batch: int = 4,
    repeat: int = 2,
    warmup: int = 1,
) -> float:
    """Best-of-``repeat`` wall-clock seconds for one family block.

    Measures the batched launch the executor actually issues: the whole
    (batch, *shape) stack through the family's Pallas kernel.
    """
    from repro.core import dispatcher
    from repro.kernels import firstorder as fok
    from repro.kernels import glcm as gk

    image, mask = _probe_intensity_case(tuple(int(s) for s in shape))
    batch = max(1, int(batch))
    images = np.broadcast_to(image, (batch,) + image.shape)
    masks = np.broadcast_to(mask, (batch,) + mask.shape)
    kw = dispatcher.kernel_kwargs(backend)

    # measure the traced device payload (what the executor launches);
    # feature finalisation is host-side numpy and not part of the launch
    if family == "firstorder":
        def call():
            return fok.firstorder_packed_batch_pallas(
                images, masks, block=block, **kw
            )
    elif family == "glcm":
        def call():
            return gk.glcm_matrix_batch_pallas(
                images, masks, block=block, **kw
            )
    else:
        raise ValueError(f"unknown autotune family namespace {family!r}")

    for _ in range(warmup):
        jax.block_until_ready(call())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def sweep_family(
    family: str,
    shape,
    backend: str,
    *,
    blocks=None,
    batch: int = 4,
    repeat: int = 2,
):
    """Measure every family block candidate; returns (best, table).

    ``table`` maps ``str(block)`` to measured microseconds.  For the
    first-order family, candidates that are not multiples of the
    canonical accumulation chunk are dropped (they would violate the
    bitwise left-fold contract, not just waste time).
    """
    from repro.kernels import firstorder as fok

    blocks = tuple(blocks) if blocks is not None else _family_blocks(family)
    if family == "firstorder":
        usable = [b for b in blocks if b % fok.CANON_CHUNK == 0]
        if not usable:
            usable = [fok.DEFAULT_BLOCK]
    else:
        usable = list(blocks)
    table: dict[str, float] = {}
    best, best_t = None, float("inf")
    for block in usable:
        t = measure_family_config(
            family, shape, backend, block, batch=batch, repeat=repeat
        )
        table[str(block)] = t * 1e6
        if t < best_t:
            best, best_t = FamilyConfig(block), t
    return best, table


def get_family_config(
    family: str,
    shape,
    backend: str,
    *,
    batch: int = 1,
    cache: AutotuneCache | None = None,
    blocks=None,
    repeat: int = 2,
) -> FamilyConfig:
    """Cached-or-swept best family block per (volume bucket, depth).

    Same contract as :func:`get_diameter_config`: cache hit -> no kernel
    runs; miss sweeps when allowed and persists winner + table; disallowed
    sweeps return the default uncached.  ``shape`` should already be an
    autotune bucket (see :func:`mc_shape_bucket`).
    """
    from repro.kernels import firstorder as fok

    if backend == "ref":
        return _family_default(family)
    shape = tuple(int(s) for s in shape)
    cache = cache or AutotuneCache()
    key = family_key(family, shape, backend, batch)
    hit = cache.get(key)
    if hit is not None:
        try:
            cfg = FamilyConfig(int(hit["block"]))
        except (KeyError, TypeError, ValueError):
            cfg = None
        if cfg is not None and cfg.block > 0 and not (
            family == "firstorder" and cfg.block % fok.CANON_CHUNK
        ):
            return cfg
    if not _sweep_allowed(backend):
        return _family_default(family)
    best, table = sweep_family(
        family, shape, backend, blocks=blocks, batch=batch_bucket(batch),
        repeat=repeat,
    )
    cache.put(
        key,
        {
            "block": best.block,
            "us": table[str(best.block)],
            "table": table,
            "swept_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    )
    return best


# ---------------------------------------------------------------------------
# device->host sync-cost probe
# ---------------------------------------------------------------------------

# fallback per-fetch d2h latency (us) when probing is disallowed: roughly a
# local PCIe/ICI round-trip -- deliberately modest, so the auto schedule
# only abandons the counted default on a MEASURED expensive link
DEFAULT_SYNC_US = 150.0

SYNC_PROBE_SHAPE = (32, 2)  # the (B, 2) count matrix pass 1 actually fetches


def sync_key(backend: str) -> str:
    return f"sync/{backend}"


def measure_sync_cost(*, repeat: int = 64, warmup: int = 8) -> float:
    """Best-of-``repeat`` wall-clock seconds for one small d2h fetch.

    The probe materialises an already-ready (32, 2) int32 device array to
    host numpy -- the exact shape of the counted schedule's pass-1 count
    fetch -- so what is measured is the per-sync LATENCY (dispatch-queue
    flush + transfer round-trip), not bandwidth.  ``block_until_ready``
    before timing keeps device compute out of the measurement.
    """
    x = jax.block_until_ready(jax.numpy.zeros(SYNC_PROBE_SHAPE, jax.numpy.int32))
    for _ in range(warmup):
        np.asarray(x)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        np.asarray(x)
        best = min(best, time.perf_counter() - t0)
    return best


def _sync_probe_allowed(backend: str) -> bool:
    # same policy shape as _sweep_allowed, but the d2h probe is meaningful
    # on any REAL device (it measures the link, not a kernel), so only the
    # interpret/ref-on-CI determinism concern gates it by default
    flag = os.environ.get("REPRO_AUTOTUNE")
    if flag == "0":
        return False
    if flag == "1":
        return True
    return backend == "pallas"


def get_sync_cost(
    backend: str,
    *,
    cache: AutotuneCache | None = None,
    repeat: int = 64,
) -> float:
    """Cached-or-probed per-fetch d2h latency in MICROSECONDS.

    Same contract as the config getters: cache hit -> no probe runs; a
    miss probes when allowed and persists the measurement under
    ``sync/<backend>``; disallowed probes return :data:`DEFAULT_SYNC_US`
    uncached (so a later real-hardware run can still measure).  Unlike
    the kernel sweeps this consults the cache for EVERY backend,
    including 'ref': the sync cost belongs to the device link, not to a
    kernel configuration, and the cost model must honour a calibrated
    (or operator-pinned) entry regardless of which kernels run.
    """
    cache = cache or AutotuneCache()
    hit = cache.get(sync_key(backend))
    if hit is not None:
        try:
            us = float(hit["us"])
        except (KeyError, TypeError, ValueError):
            us = None
        if us is not None and us > 0:
            return us
    if not _sync_probe_allowed(backend):
        return DEFAULT_SYNC_US
    t = measure_sync_cost(repeat=repeat)
    cache.put(
        sync_key(backend),
        {"us": t * 1e6, "probed_at": time.strftime("%Y-%m-%dT%H:%M:%S")},
    )
    return t * 1e6


# ---------------------------------------------------------------------------
# hardware roofline profile (peak FLOP/s + memory bandwidth) probe
# ---------------------------------------------------------------------------

# Static per-backend fallback profiles, used when no ``hw/<backend>`` entry
# exists and probing is disallowed.  The cost model only consumes RATIOS of
# these numbers (compute-vs-memory bound, bucket-vs-bucket cost), so modest
# order-of-magnitude figures suffice:
#   pallas          -- v5e VPU f32 throughput + HBM bandwidth (the
#                      extraction kernels are elementwise/VPU work, not
#                      MXU matmuls; see benchmarks/common.V5E)
#   ref / interpret -- a single CPU core driving numpy-like jnp ops
# Unknown backend strings have NO default profile: ``get_hw_profile``
# returns None and the cost model falls back to its analytic constant.
DEFAULT_HW_PROFILES = {
    "pallas": {"peak_flops": 7.0e12, "mem_bw": 819.0e9, "source": "default"},
    "ref": {"peak_flops": 8.0e9, "mem_bw": 20.0e9, "source": "default"},
    "interpret": {"peak_flops": 8.0e9, "mem_bw": 20.0e9, "source": "default"},
}

HW_PROBE_MATMUL_N = 512   # f32 matmul edge for the peak-FLOP/s probe
HW_PROBE_COPY_ELEMS = 1 << 22  # 16 MiB f32 stream for the bandwidth probe


def hw_key(backend: str) -> str:
    return f"hw/{backend}"


def measure_hw_profile(*, repeat: int = 8, warmup: int = 2) -> dict:
    """Measured ``{"peak_flops", "mem_bw"}`` for the local device.

    Two tiny best-of-``repeat`` probes: an (N, N) f32 matmul for peak
    FLOP/s (2*N^3 flops) and an add-scaled copy over a 16 MiB f32 stream
    for memory bandwidth (read a + read b + write out = 3 arrays).  Both
    are deliberately small -- the probe runs once per host per backend,
    cached under ``hw/<backend>``, and must never dominate a run the way
    a kernel sweep can.
    """
    n = HW_PROBE_MATMUL_N
    a = jax.block_until_ready(
        jax.numpy.ones((n, n), jax.numpy.float32) * 0.5
    )
    mm = jax.jit(lambda x: x @ x)
    for _ in range(warmup):
        jax.block_until_ready(mm(a))
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(mm(a))
        best = min(best, time.perf_counter() - t0)
    peak_flops = 2.0 * n ** 3 / best

    m = HW_PROBE_COPY_ELEMS
    x = jax.block_until_ready(jax.numpy.ones((m,), jax.numpy.float32))
    y = jax.block_until_ready(jax.numpy.full((m,), 2.0, jax.numpy.float32))
    axpy = jax.jit(lambda u, v: u + 0.5 * v)
    for _ in range(warmup):
        jax.block_until_ready(axpy(x, y))
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(axpy(x, y))
        best = min(best, time.perf_counter() - t0)
    mem_bw = 3.0 * 4.0 * m / best
    return {"peak_flops": peak_flops, "mem_bw": mem_bw}


def get_hw_profile(
    backend: str,
    *,
    cache: AutotuneCache | None = None,
    repeat: int = 8,
) -> dict | None:
    """Cached-or-probed hardware roofline profile for ``backend``.

    Contract mirrors :func:`get_sync_cost`: a valid ``hw/<backend>``
    cache entry wins without running anything; a miss probes when allowed
    (same policy as the sync probe -- pallas by default,
    ``REPRO_AUTOTUNE=1`` forces, ``=0`` disables) and persists the
    measurement; a disallowed probe returns the static
    :data:`DEFAULT_HW_PROFILES` entry uncached.  Returns ``None`` -- "no
    profile exists" -- under ``REPRO_ROOFLINE=0`` (the escape hatch back
    to the cost model's analytic constant) and for backend strings with
    no default profile when probing is disallowed.
    """
    if os.environ.get("REPRO_ROOFLINE") == "0":
        return None
    cache = cache or AutotuneCache()
    hit = cache.get(hw_key(backend))
    if hit is not None:
        try:
            peak = float(hit["peak_flops"])
            bw = float(hit["mem_bw"])
        except (KeyError, TypeError, ValueError):
            peak = bw = 0.0
        if peak > 0 and bw > 0:
            return {"peak_flops": peak, "mem_bw": bw,
                    "source": "measured"}
    if not _sync_probe_allowed(backend):
        return DEFAULT_HW_PROFILES.get(backend)
    prof = measure_hw_profile(repeat=repeat)
    cache.put(
        hw_key(backend),
        {**prof, "probed_at": time.strftime("%Y-%m-%dT%H:%M:%S")},
    )
    return {**prof, "source": "measured"}
