"""Measured (variant, block) selection for the diameter kernel.

The Fig.1-style variant study shows no single (variant, block) wins at
every vertex count: small buckets want one big block (grid overhead), large
buckets want the triangular prefetch schedule or the MXU 'gram' path.  This
module turns that study into infrastructure: per vertex *bucket* (the
static padding cap from ``ops.vertex_bucket``) it sweeps the candidate
configurations once on the resolved backend, caches the winner in a JSON
file, and hands the cached choice to every later call -- the TPU analogue
of a CUDA occupancy/launch-bound autotuner.

Cache: one JSON object keyed ``"diameter/<backend>/M<bucket>"`` holding the
winning variant/block plus the full measured table (microseconds), so the
sweep is also a persisted perf trajectory.  The path comes from
``REPRO_AUTOTUNE_CACHE`` (default ``~/.cache/repro_autotune.json``); writes
are atomic (tmp + rename) so concurrent processes at worst re-measure.

Sweeping policy: measured sweeps run by default only on the compiled
``pallas`` backend.  ``interpret`` is a correctness backend -- Python timings
there are meaningless for TPU choices -- so it uses the default config
unless ``REPRO_AUTOTUNE=1`` forces a sweep (used by tests to exercise the
round-trip) ; ``REPRO_AUTOTUNE=0`` disables sweeping everywhere.  The
``ref`` backend has no (variant, block) axis at all.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

DEFAULT_VARIANTS = ("seqacc", "tri_prefetch", "nomask", "gram")
DEFAULT_BLOCKS = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class DiameterConfig:
    variant: str
    block: int


DEFAULT_CONFIG = DiameterConfig("seqacc", 256)


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_autotune.json")


class AutotuneCache:
    """Tiny JSON key->record store with atomic writes."""

    def __init__(self, path: str | None = None):
        self.path = path or cache_path()

    def _read(self) -> dict:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    def get(self, key: str):
        return self._read().get(key)

    def put(self, key: str, record: dict) -> None:
        data = self._read()
        data[key] = record
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:  # pragma: no cover - cache is best-effort
            try:
                os.unlink(tmp)
            except OSError:
                pass


def sweep_key(bucket: int, backend: str) -> str:
    return f"diameter/{backend}/M{int(bucket)}"


def measure_diameter_config(
    bucket: int,
    backend: str,
    variant: str,
    block: int,
    *,
    repeat: int = 2,
    warmup: int = 1,
    seed: int = 0,
) -> float:
    """Best-of-``repeat`` wall-clock seconds for one configuration."""
    from repro.core import dispatcher
    from repro.kernels import diameter as dk

    rng = np.random.default_rng(seed)
    verts = np.asarray(rng.normal(size=(bucket, 3)) * 10.0, np.float32)
    mask = np.ones((bucket,), np.float32)
    kw = dispatcher.kernel_kwargs(backend)

    def call():
        return dk.max_diameters_sq_pallas(
            verts, mask, block=block, variant=variant, **kw
        )

    for _ in range(warmup):
        jax.block_until_ready(call())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def sweep_diameter(
    bucket: int,
    backend: str,
    *,
    variants=DEFAULT_VARIANTS,
    blocks=DEFAULT_BLOCKS,
    repeat: int = 2,
):
    """Measure every (variant, block) candidate; returns (best, table).

    ``table`` maps ``"variant/block"`` to measured microseconds.  Blocks
    larger than the bucket only pad the grid, so they are dropped (the
    smallest candidate block is clamped in instead when all are too big).
    """
    usable = [b for b in blocks if b <= bucket] or [min(min(blocks), bucket)]
    table: dict[str, float] = {}
    best, best_t = None, float("inf")
    for variant in variants:
        for block in usable:
            t = measure_diameter_config(
                bucket, backend, variant, block, repeat=repeat
            )
            table[f"{variant}/{block}"] = t * 1e6
            if t < best_t:
                best, best_t = DiameterConfig(variant, block), t
    return best, table


def _sweep_allowed(backend: str) -> bool:
    flag = os.environ.get("REPRO_AUTOTUNE")
    if flag == "0":
        return False
    if flag == "1":
        return True
    return backend == "pallas"  # interpret timings don't transfer to TPU


def get_diameter_config(
    bucket: int,
    backend: str,
    *,
    cache: AutotuneCache | None = None,
    variants=DEFAULT_VARIANTS,
    blocks=DEFAULT_BLOCKS,
    repeat: int = 2,
) -> DiameterConfig:
    """Cached-or-swept best (variant, block) for a vertex bucket.

    The fast path is a cache hit -- no kernel runs at all.  A miss sweeps
    (when allowed, see module docstring), persists the winner + table, and
    returns it; when sweeping is disallowed the default config is returned
    without being cached (so a later TPU run can still measure).
    """
    from repro.kernels import diameter as dk

    if backend == "ref":
        return DEFAULT_CONFIG
    cache = cache or AutotuneCache()
    key = sweep_key(bucket, backend)
    hit = cache.get(key)
    if hit is not None:
        # validate: the persistent cache can outlive a rename/removal of a
        # variant (or be malformed) -- treat anything unusable as a miss
        try:
            cfg = DiameterConfig(str(hit["variant"]), int(hit["block"]))
        except (KeyError, TypeError, ValueError):
            cfg = None
        if cfg is not None and cfg.variant in dk.VARIANTS and cfg.block > 0:
            return cfg
    if not _sweep_allowed(backend):
        return DEFAULT_CONFIG
    best, table = sweep_diameter(
        bucket, backend, variants=variants, blocks=blocks, repeat=repeat
    )
    cache.put(
        key,
        {
            "variant": best.variant,
            "block": best.block,
            "us": table[f"{best.variant}/{best.block}"],
            "table": table,
            "swept_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    )
    return best
