"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json        tree structure, shapes, dtypes, step, extras
        <flat.key>.npy       one file per leaf (addressable data)
        _COMMITTED           written last; absence = partial checkpoint

Properties needed at cluster scale, all implemented here:
  * **atomicity** -- writes go to ``step_X.tmp-<pid>`` and are renamed into
    place after the commit marker; a crashed writer never corrupts the
    latest checkpoint (``latest_step`` ignores uncommitted dirs);
  * **async** -- ``save_async`` snapshots to host memory synchronously
    (cheap) and writes to disk on a worker thread, off the train loop;
  * **reshard-on-load** -- ``restore`` takes the *target* shardings, so a
    checkpoint written on one mesh loads onto any other mesh/topology
    (elastic restart after losing a pod);
  * **retention** -- ``keep`` newest k checkpoints are preserved.

On a multi-host deployment each process saves only the shards it owns
(``jax.experimental.multihost_utils`` handles the barrier); in this
single-process container that specialisation is a no-op.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=()):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (str(i),)))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), prefix + (k,)))
    else:
        out[_SEP.join(prefix)] = tree
    return out


def _unflatten_into(skeleton, flat, prefix=()):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, prefix + (str(k),))
                for k, v in skeleton.items()}
    if hasattr(skeleton, "_fields"):
        return type(skeleton)(*[
            _unflatten_into(getattr(skeleton, k), flat, prefix + (k,))
            for k in skeleton._fields
        ])
    if isinstance(skeleton, (list, tuple)):
        return type(skeleton)(
            _unflatten_into(v, flat, prefix + (str(i),))
            for i, v in enumerate(skeleton)
        )
    return flat[_SEP.join(prefix)]


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ---- write ----
    def save(self, step: int, tree, extras: dict | None = None):
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host, extras or {})

    def save_async(self, step: int, tree, extras: dict | None = None):
        """Snapshot now, write on a background thread."""
        self.wait()  # one in-flight write at a time
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # device->host now
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extras or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host_tree, extras):
        flat = _flatten(host_tree)
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extras": extras, "leaves": {}}
        for key, arr in flat.items():
            arr = np.asarray(arr)
            fname = key.replace(_SEP, ".") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "_COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- read ----
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and (p / "_COMMITTED").exists() and ".tmp-" not in p.name:
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, skeleton, shardings=None):
        """Load a checkpoint into the structure of ``skeleton``.

        ``shardings``: optional matching tree of NamedShardings -- the
        reshard-on-load path (checkpoint mesh need not equal target mesh).
        Returns (tree, extras).
        """
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        flat = {}
        for key, meta in manifest["leaves"].items():
            flat[key] = np.load(d / meta["file"])
        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest["extras"]

    def restore_latest(self, skeleton, shardings=None):
        """Load the newest readable checkpoint, walking back over torn ones.

        The ``_COMMITTED`` marker already screens out checkpoints whose
        writer died before the rename -- but a marker can survive while a
        leaf file is later truncated or lost (disk-full, partial rsync,
        bit-rot).  ``restore`` stays strict (a named step either loads or
        raises); ``restore_latest`` is the recovery path, so it falls
        back to the previous committed step when the newest fails to
        deserialize.  Returns ``None`` only when no step is readable.
        """
        last_err = None
        for step in reversed(self.all_steps()):
            try:
                tree, extras = self.restore(step, skeleton, shardings)
                return step, tree, extras
            except (OSError, ValueError, KeyError, json.JSONDecodeError,
                    EOFError) as e:
                last_err = e
                continue
        if last_err is not None:
            import warnings

            warnings.warn(
                f"no readable checkpoint (newest failed with: {last_err!r})",
                RuntimeWarning, stacklevel=2,
            )
        return None
