"""Int8 error-feedback gradient compression for cross-pod reduction.

At multi-pod scale the data-parallel gradient all-reduce crosses the slow
pod interconnect; 4x compression (f32 -> int8) cuts that traffic
proportionally.  Implementation (1-bit-Adam-family scheme, k=8 bits):

    residual e_t carried per leaf (error feedback)
    g' = g + e_t
    q  = clip(round(g' / scale), -127, 127), scale = max|g'| / 127  per leaf
    wire format int8; reduction upcasts to int32 (no overflow for <= 2^24
    participants); dequantised mean applied, e_{t+1} = g' - q * scale

Error feedback makes the quantisation noise telescope: the *accumulated*
applied update tracks the true gradient sum, so convergence matches
uncompressed SGD/Adam up to higher-order terms (tested in
tests/test_compression.py).

``compressed_psum_tree`` works under ``shard_map`` (axis_name present) or
as a pure single-process simulation (axis_name=None) for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _shared_scale(g32, axis_name=None):
    """One scale for ALL workers: quantising with per-worker scales and
    dequantising the wire-sum with any single scale is a biased reduction
    (q_i·(s−s_i) error terms); the scale must be agreed *before*
    quantising — one extra scalar pmax on the wire."""
    amax = jnp.max(jnp.abs(g32))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    return jnp.where(amax > 0, amax / 127.0, 1.0)


def compress_leaf(g, err, scale=None):
    """Returns (int8 payload, scale, new_error)."""
    g32 = g.astype(jnp.float32) + err
    if scale is None:
        scale = _shared_scale(g32)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def reduce_compressed(q, scale, axis_name=None):
    """Mean-reduce quantised gradients across data parallel workers.

    ``scale`` must be identical on every worker (see ``_shared_scale``).
    """
    qi = q.astype(jnp.int32)
    if axis_name is None:
        return qi.astype(jnp.float32) * scale
    total = jax.lax.psum(qi, axis_name)  # int32 wire-sum of int8 payloads
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32)


def compressed_psum_tree(grads, err_tree, axis_name=None):
    """Error-feedback int8 psum over a gradient pytree.

    Returns (reduced_grads, new_err_tree).
    """
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_tree)
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        g32 = g.astype(jnp.float32) + e
        scale = _shared_scale(g32, axis_name)
        q, scale, ne = compress_leaf(g, e, scale=scale)
        outs.append(reduce_compressed(q, scale, axis_name).astype(g.dtype))
        new_errs.append(ne)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, new_errs)


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
