"""Distribution substrate: logical-axis sharding, compression, pipeline."""
