"""Logical-axis sharding rules (MaxText-style) for params and activations.

Every parameter dimension carries a logical axis name (see models/params.P)
and every activation constraint site names its axes.  A *rule set* maps
logical names to mesh axes; the same model code then runs on the single-pod
(16, 16) = ('data', 'model') mesh, the multi-pod (2, 16, 16) =
('pod', 'data', 'model') mesh, or CPU (no mesh: constraints become no-ops).

Default ruleset = FSDP + TP (+ DP over pods):
  * batch       -> ('pod', 'data')        data parallelism
  * heads/mlp/vocab/kv_heads -> 'model'   tensor parallelism
  * embed       -> 'data'                 weight FSDP (ZeRO-3 style; GSPMD
                                          all-gathers at use sites)
  * expert      -> 'data'                 expert parallelism (all-to-all)
  * layers/seq/head_dim -> replicated

Per-arch overrides live in the arch config files.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class Ax:
    """Logical-axes annotation used as a *leaf* inside pytrees (e.g. the
    per-leaf axis names of a decode cache)."""

    axes: tuple

DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",  # FSDP on weight embed dims
    "embed_act": None,  # activation embed dim stays replicated
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "data",
    "layers": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict | None = None


_CTX = _Ctx()


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = True):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map`` (replication check kwarg
    ``check_vma``); 0.4.x has it under ``jax.experimental.shard_map`` with
    ``check_rep``.  All repo call sites go through this wrapper.  ``check``
    defaults to True like jax itself; pass False only where the checker
    rejects a legitimate program (e.g. the gpipe ppermute loop).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def data_parallel_map(fn, mesh: Mesh | None = None, axis: str = "data",
                      check: bool = True):
    """Shard a batched device function over ``axis`` of a mesh.

    ``fn`` maps arrays with a leading batch dimension to arrays with the
    same leading dimension (e.g. the pipeline's vmapped pass-1 pruning
    bound, the batched segmented compaction, or the staged pass-2a
    marching-cubes batch).  With a mesh the batch axis is split over
    ``axis`` via :func:`shard_map_compat`, so N devices process N slices
    concurrently; with no mesh (or a mesh without the axis) this is a
    plain ``jax.jit`` -- a strict no-op fallback, which is what lets the
    same pipeline code run on CPU and on a pod.  ``mesh`` defaults to the
    ambient :func:`use_mesh` context.  Callers pad the batch to a
    multiple of the axis size (:func:`pad_batch`; shard_map shapes are
    uniform).
    """
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None or axis not in mesh.shape:
        return jax.jit(fn)
    spec = PartitionSpec(axis)
    return jax.jit(
        shard_map_compat(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                         check=check)
    )


def axis_size(mesh: Mesh | None, axis: str = "data") -> int:
    """Size of ``axis`` on ``mesh`` (1 without a mesh or the axis)."""
    if mesh is None or axis not in mesh.shape:
        return 1
    return mesh.shape[axis]


def pad_batch(arrays, n: int, mesh: Mesh | None = None, axis: str = "data"):
    """Pad stacked leading dims to a data-axis multiple (first-row copies).

    The companion of :func:`data_parallel_map`: shard_map shapes must be
    uniform across shards, so a batch of ``n`` rows is padded up to the
    next multiple of the axis size by repeating row 0 (duplicate rows can
    never change a per-case result, and callers simply never read the
    padding rows back).  A no-op without a mesh.
    """
    n_data = axis_size(mesh, axis)
    np_ = int(math.ceil(max(n, 1) / n_data)) * n_data
    if np_ == n:
        return tuple(arrays)
    return tuple(
        jnp.concatenate([a, jnp.repeat(a[:1], np_ - n, axis=0)])
        for a in arrays
    )


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    """Activate a mesh + ruleset for logical constraints and pspec lookup."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(DEFAULT_RULES, **(rules or {}))
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old


def active_rules() -> dict:
    return _CTX.rules or DEFAULT_RULES


def active_mesh() -> Mesh | None:
    return _CTX.mesh


def _mesh_axes_for(logical: str, rules: dict, mesh: Mesh | None):
    ax = rules.get(logical, None)
    if ax is None:
        return None
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    if mesh is not None:
        axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def pspec(axes: tuple, rules: dict | None = None, mesh: Mesh | None = None,
          shape: tuple | None = None) -> PartitionSpec:
    """PartitionSpec for a tuple of logical axis names.

    Guarantees no mesh axis is used twice (later dims lose the conflict and
    stay replicated, matching GSPMD legality).  When ``shape`` is given,
    mesh axes that do not divide the dim are dropped greedily (e.g. 56
    attention heads on a 16-way 'model' axis stay replicated; a batch of 1
    drops the ('pod', 'data') sharding) -- uneven shardings are legal in
    GSPMD but pad silently, which we refuse at framework level.
    """
    rules = rules or active_rules()
    mesh = mesh or active_mesh()
    used: set = set()
    parts = []
    for i, name in enumerate(axes):
        m = None if name is None else _mesh_axes_for(name, rules, mesh)
        if m is None:
            parts.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used)
        if shape is not None and mesh is not None:
            dim = shape[i]
            kept = []
            prod = 1
            for a in ms:  # greedy prefix that divides the dim
                if dim % (prod * mesh.shape[a]) == 0:
                    kept.append(a)
                    prod *= mesh.shape[a]
                else:
                    break
            ms = tuple(kept)
        if not ms:
            parts.append(None)
            continue
        used.update(ms)
        parts.append(ms if len(ms) > 1 else ms[0])
    return PartitionSpec(*parts)


def constrain(x, *axes):
    """Sharding constraint by logical axes; no-op without an active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, pspec(tuple(axes), shape=x.shape))
    )


def named_sharding(axes: tuple, mesh: Mesh | None = None, rules=None) -> NamedSharding:
    mesh = mesh or active_mesh()
    assert mesh is not None, "named_sharding requires a mesh"
    return NamedSharding(mesh, pspec(tuple(axes), rules=rules, mesh=mesh))


def param_shardings(spec_tree, mesh: Mesh, rules=None):
    """Tree of NamedShardings matching a params spec tree."""
    from repro.models import params as pmod

    rules = dict(DEFAULT_RULES, **(rules or {}))

    def one(leaf):
        return NamedSharding(
            mesh, pspec(leaf.axes, rules=rules, mesh=mesh, shape=leaf.shape)
        )

    flat = {path: one(leaf) for path, leaf in pmod.tree_paths(spec_tree)}
    return pmod._unflatten(flat)


def tree_shardings(abstract_tree, axes_tree, mesh: Mesh, rules=None):
    """NamedShardings for an arbitrary pytree annotated with ``Ax`` leaves.

    ``axes_tree`` mirrors ``abstract_tree`` but each array leaf is replaced
    by an ``Ax(axes)`` annotation (treated as a leaf because Ax is not a
    registered pytree).
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def one(sds, ax):
        assert isinstance(ax, Ax), ax
        return NamedSharding(
            mesh, pspec(ax.axes, rules=rules, mesh=mesh, shape=sds.shape)
        )

    return jax.tree.map(one, abstract_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, Ax))
