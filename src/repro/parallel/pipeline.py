"""GPipe-style pipeline parallelism over the 'pod' mesh axis.

At multi-pod scale the cross-pod (DCN) link is the slowest in the system;
FSDP/TP traffic must stay inside a pod.  Two strategies compose in this
framework:

  * default: the 'pod' axis extends **data parallelism** — only gradient
    all-reduces cross pods (optionally int8-compressed,
    `parallel/compression.py`);
  * optional: the layer stack is split into one **pipeline stage per pod**
    (this module).  Only (microbatch, seq, d_model) activations cross the
    pod boundary once per microbatch per direction — orders of magnitude
    less DCN traffic than FSDP weight gathers would need.

Implementation: `shard_map` over the 'pod' axis; each pod holds
`n_layers / n_stages` layers' params (sharded inside the pod by the usual
TP/FSDP rules, which see only the remaining mesh axes).  The classic
GPipe schedule runs `n_micro + n_stages - 1` ticks; each tick every stage
processes one microbatch slot and hands its output to the next stage with
`jax.lax.ppermute`.  Bubble fraction = (S-1)/(M+S-1).

The schedule is expressed with `jax.lax.scan` over ticks so it lowers to
a single fused loop (no Python unrolling at trace time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding


def pipeline_stages(n_layers: int, n_stages: int):
    """Evenly partition layers into contiguous stages."""
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    per = n_layers // n_stages
    return [(s * per, (s + 1) * per) for s in range(n_stages)]


def gpipe(stage_fn, n_stages: int, *, axis: str = "pod"):
    """Build the per-shard GPipe schedule body.

    ``stage_fn(stage_params, x) -> x`` applies this stage's layer block to
    one microbatch of activations (B_micro, S, d).  Returns a function
    ``run(stage_params, micro_x) -> micro_y`` to be used under
    ``shard_map`` where ``axis`` indexes the stage:

        micro_x: (n_micro, B_micro, S, d)  on stage 0 (others ignore it)
        micro_y: (n_micro, B_micro, S, d)  from the last stage
    """

    def run(stage_params, micro_x):
        sid = jax.lax.axis_index(axis)
        n_micro = micro_x.shape[0]
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(micro_x)  # output slots (valid on last stage)

        def tick(carry, t):
            buf, inflight = carry
            # stage 0 injects microbatch t (if any); others take the
            # activation handed over by the previous stage
            x_in = jnp.where(
                sid == 0,
                micro_x[jnp.clip(t, 0, n_micro - 1)],
                inflight,
            )
            y = stage_fn(stage_params, x_in)
            # hand to next stage; the last stage's output goes to buf
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)]
            )
            out_slot = t - (n_stages - 1)
            land = (sid == n_stages - 1) & (out_slot >= 0)
            buf = jnp.where(
                land,
                buf.at[jnp.clip(out_slot, 0, n_micro - 1)].set(y),
                buf,
            )
            return (buf, nxt), None

        (buf, _), _ = jax.lax.scan(
            tick, (buf, jnp.zeros_like(micro_x[0])), jnp.arange(ticks)
        )
        # only the last stage holds outputs; psum replicates them to all
        # pods (zeros elsewhere), satisfying the replicated out_spec
        return jax.lax.psum(buf, axis)

    return run


def pipeline_forward(layer_fn, params_stacked, x, mesh, *, n_micro: int,
                     axis: str = "pod"):
    """Full pipeline forward: split batch into microbatches, run GPipe.

    ``layer_fn(layer_params, x) -> x``; ``params_stacked``: pytree with a
    leading (n_layers, ...) dim; layers are split into one stage per pod.
    ``x``: (B, S, d) with B % n_micro == 0.
    """
    n_stages = mesh.shape[axis]
    b, s, d = x.shape
    assert b % n_micro == 0
    micro = x.reshape(n_micro, b // n_micro, s, d)

    def stage_fn(stage_params, xm):
        # under shard_map the local view keeps a leading stage dim of 1
        stage_params = jax.tree.map(lambda p: p[0], stage_params)

        def body(c, lp):
            return layer_fn(lp, c), None
        out, _ = jax.lax.scan(body, xm, stage_params)
        return out

    run = gpipe(stage_fn, n_stages, axis=axis)

    n_layers = jax.tree.leaves(params_stacked)[0].shape[0]
    per = n_layers // n_stages
    # reshape layers to (n_stages, per, ...) so shard_map splits stages
    staged = jax.tree.map(
        lambda p: p.reshape(n_stages * per, *p.shape[1:]).reshape(
            n_stages, per, *p.shape[1:]
        ),
        params_stacked,
    )

    shmap = sharding.shard_map_compat(
        run,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: P(axis), staged),
            P(),  # microbatches replicated in; stage 0 reads them
        ),
        out_specs=P(),
        check=False,
    )
    out = shmap(jax.tree.map(lambda p: p, staged), micro)
    return out.reshape(b, s, d)
