"""Radiomics service CLI: ``python -m repro.launch.serve``.

Stands up the persistent extraction service (``serve/service``) over a
backend and drives it with mixed multi-tenant traffic -- many small ROIs
plus rare huge cases, the clinic-plus-research-cohort shape -- from
concurrent client threads, then prints p50/p99 request latency, case
throughput, and the service's window-fusion census.

    PYTHONPATH=src python -m repro.launch.serve --backend ref --smoke

``--deadline-ms`` attaches a deadline to every request (expired requests
complete with a ``DeadlineExceeded`` error row instead of occupying a
window slot); ``--queue-mb`` bounds the admission-control byte budget
(submitters block on a full queue).  The gated benchmark twin of this
demo is ``benchmarks/serve_latency.py``.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import mixed_traffic_stream


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="drive the radiomics extraction service with mixed "
                    "multi-tenant traffic")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--families", default=None)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per client")
    ap.add_argument("--batch", type=int, default=1,
                    help="cases per request")
    ap.add_argument("--huge-every", type=int, default=16,
                    help="every Nth case is a huge ROI (0: none)")
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--queue-mb", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run")
    args = ap.parse_args(argv)
    if args.smoke:
        args.clients, args.requests, args.huge_every = 2, 3, 5

    bx = BatchedExtractor(backend=args.backend, prep="hint",
                          schedule="static", families=args.families)
    n_cases = args.clients * args.requests * args.batch
    cases = list(mixed_traffic_stream(n_cases, seed=args.seed,
                                      huge_every=args.huge_every))

    latencies: list = []
    error_rows: list = []
    lock = threading.Lock()

    def client(cidx: int, svc):
        mine = cases[cidx::args.clients]
        for r in range(args.requests):
            chunk = mine[r * args.batch:(r + 1) * args.batch]
            if not chunk:
                break
            fut = svc.submit(
                [(img, msk, sp) for _, img, msk, sp in chunk],
                tenant=f"client-{cidx}",
                deadline_s=(None if args.deadline_ms is None
                            else args.deadline_ms / 1e3),
            )
            res = fut.result(timeout=600)
            with lock:
                latencies.append(res.latency_s)
                error_rows.extend(res.errors.values())

    with bx.serve(max_queue_bytes=(None if args.queue_mb is None
                                   else args.queue_mb * 2**20)) as svc:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c, svc))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        stats = svc.stats()

    lat = np.asarray(latencies)
    served = stats["served_cases"]
    fused = stats["window_cases"]
    cross = sum(1 for t in stats["window_tenants"] if t > 1)
    print(f"[serve] backend={bx.backend} families={bx.families} "
          f"clients={args.clients} requests/client={args.requests} "
          f"batch={args.batch}")
    print(f"[serve] {served} cases in {dt:.2f}s "
          f"({served / dt:.1f} cases/s), {stats['windows']} windows "
          f"(mean fused {np.mean(fused):.1f}, {cross} cross-tenant)")
    print(f"[serve] request latency p50 {np.percentile(lat, 50) * 1e3:.1f} ms, "
          f"p99 {np.percentile(lat, 99) * 1e3:.1f} ms "
          f"(max {lat.max() * 1e3:.1f} ms)")
    if stats["expired_cases"]:
        print(f"[serve] {stats['expired_cases']} cases expired at "
              f"deadline {args.deadline_ms} ms")
    if error_rows:
        print(f"[serve] {len(error_rows)} error rows "
              f"(deadline/quarantine)")


if __name__ == "__main__":
    main()
