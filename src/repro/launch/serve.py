"""Production serving launcher: ``python -m repro.launch.serve --arch <id>``.

Builds a mesh over available devices, shards params/caches by the serving
rules (KV caches seq-sharded over 'model' when the head count does not
divide it — §Perf/1), prefills a prompt batch, and runs the jitted decode
loop with throughput stats.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_config, get_model, list_archs
from repro.parallel import sharding as shd
from repro.serve.serve_step import make_serve_step

# flash-decode cache layout + head_dim TP + pure-TP weights (no FSDP:
# decode re-reads weights every step; see EXPERIMENTS.md §Perf/1)
SERVE_RULES = {"cache_seq": "model", "head_dim": "model", "embed": None}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_host_mesh(args.model_parallel) if jax.device_count() > 1 else None
    rules = SERVE_RULES if mesh is not None else None

    max_len = args.prompt_len + args.tokens
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32,
    )

    with shd.use_mesh(mesh, rules):
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(args.batch, max_len, dtype=jnp.float32)
        if mesh is not None:
            params = jax.tree.map(
                jax.device_put, params,
                shd.param_shardings(model.spec(), mesh, rules),
            )
            cache = jax.tree.map(
                jax.device_put, cache,
                shd.tree_shardings(cache, model.cache_axes(), mesh, rules),
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
            )
        step = jax.jit(make_serve_step(model, temperature=args.temperature),
                       donate_argnums=(1,))

        t0 = time.perf_counter()
        for i in range(args.prompt_len):
            _, _, cache = step(params, cache, prompts[:, i : i + 1],
                               jax.random.PRNGKey(i))
        jax.block_until_ready(cache["pos"])
        t_prefill = time.perf_counter() - t0

        tok = prompts[:, -1:]
        t0 = time.perf_counter()
        for i in range(args.tokens):
            tok, _, cache = step(params, cache, tok, jax.random.PRNGKey(10_000 + i))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    print(f"[serve] arch={cfg.name} devices={jax.device_count()} "
          f"mesh={dict(mesh.shape) if mesh else None}")
    print(f"[serve] prefill {args.prompt_len} tok: {t_prefill*1e3:.1f} ms; "
          f"decode {args.tokens} tok: {t_decode*1e3:.1f} ms "
          f"({args.batch*args.tokens/t_decode:.1f} tok/s)")


if __name__ == "__main__":
    main()
