"""Out-of-core tiled extraction smoke: ``python -m repro.launch.tiled_smoke``.

The CI ``tiled`` stage's executable half (the other half is the
``tests/test_tiled_pipeline.py`` tier-1 parity suite): runs one small
case through the tiled engine at a deliberately tiny staged-bytes
budget -- many single-granule tiles, every prune level -- and asserts
the rows against the in-core ``extract_one`` oracle; then streams a
128^3 analytic sphere that the budget could never materialize.  Fast
(seconds, ref backend) and loud: any parity break or budget breach is a
nonzero exit.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.pipeline import BatchedExtractor
from repro.core.tiled import TiledExtractor
from repro.data.tiles import FnSlabSource, TiledCase


def _blobby_case(shape=(36, 40, 150), seed=7):
    rng = np.random.default_rng(seed)
    X, Y, Z = shape
    mask = np.zeros(shape, np.float32)
    xs, ys, zs = np.meshgrid(np.arange(X), np.arange(Y), np.arange(Z),
                             indexing="ij")
    for cx, cy, cz, r in ((18, 20, 22, 11), (16, 19, 128, 9)):
        d2 = ((xs - cx) / r) ** 2 + ((ys - cy) / r) ** 2 + ((zs - cz) / r) ** 2
        mask[d2 < 1.0] = 1.0
    image = rng.normal(size=shape).astype(np.float32)
    spacing = np.asarray([1.0, 1.1, 0.9], np.float32)
    return image, mask, spacing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--budget-kb", type=int, default=192,
                    help="forced staged-bytes budget (tiny => many tiles)")
    args = ap.parse_args(argv)
    budget = args.budget_kb * 1024
    t_start = time.perf_counter()

    image, mask, spacing = _blobby_case()
    bx = BatchedExtractor(backend=args.backend,
                          families=["shape", "firstorder"])
    oracle = bx.extract_one(image, mask, spacing)
    case = TiledCase(mask, image=image, spacing=spacing)
    import warnings
    for level in ("none", "occupancy", "bounds"):
        tx = TiledExtractor(bx.executor, budget_bytes=budget,
                            tile_prune=level)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = tx.extract(case)
        bitwise = np.array_equal(oracle, res.row)
        close = np.allclose(oracle, res.row, rtol=1e-5, atol=1e-5)
        s = res.stats
        print(f"tiled_smoke {level:9s}: tiles={s['tiles']} "
              f"skipped={s['tiles_skipped']} "
              f"bounds_pruned={s['tiles_bounds_pruned']} "
              f"bitwise={bitwise} close={close}")
        # occupancy pruning is fully bitwise on every backend; bounds
        # relaxes only the ref diameters to f32 rounding
        ok = close if (level == "bounds" and args.backend == "ref") else bitwise
        if not ok:
            print(f"tiled_smoke FAIL: {level} parity broke "
                  f"(oracle={oracle!r} tiled={res.row!r})", file=sys.stderr)
            return 1

    # out-of-core: the sphere exists only as an analytic slab fn; the
    # materialized volume would be 8 MiB vs the ~192 KiB staged budget
    N = 128

    def sphere(z0, z1):
        ax = ((np.arange(N) - N / 2) / (N * 0.42)) ** 2
        az = ((np.arange(z0, z1) - N / 2) / (N * 0.42)) ** 2
        r2 = ax[:, None, None] + ax[None, :, None] + az[None, None, :]
        return (r2 < 1.0).astype(np.float32)

    ooc = TiledCase(FnSlabSource(sphere, (N, N, N)))
    # mc_chunk=4 shrinks the granule to 5 staged planes, so two tiles of
    # this frame genuinely fit the 1 MiB budget (8x below the volume)
    ooc_budget = 1 << 20
    tx = TiledExtractor(
        BatchedExtractor(backend=args.backend,
                         mc_chunk=4 if args.backend == "ref" else None)
        .executor,
        budget_bytes=ooc_budget, tile_prune="bounds",
    )
    res = tx.extract(ooc)
    if (args.backend == "ref"
            and res.stats["staged_bytes_peak"] > ooc_budget):
        print("tiled_smoke FAIL: staged peak "
              f"{res.stats['staged_bytes_peak']} B over the {ooc_budget} B "
              "budget", file=sys.stderr)
        return 1
    vol_bytes = 4 * N ** 3
    print(f"tiled_smoke out_of_core: {N}^3 volume ({vol_bytes >> 20} MiB) "
          f"through {res.stats['tiles']} tiles, staged peak "
          f"{res.stats['staged_bytes_peak'] / 2**10:.0f} KiB, "
          f"mesh volume {res.row[0]:.1f}")
    if not np.isfinite(res.row).all() or res.row[0] <= 0:
        print("tiled_smoke FAIL: degenerate out-of-core row", file=sys.stderr)
        return 1
    print(f"tiled_smoke OK in {time.perf_counter() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
