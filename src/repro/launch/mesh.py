"""Production meshes.

TPU v5e pods: single pod = 256 chips as (16, 16) = ('data', 'model');
multi-pod = 2 pods = 512 chips as (2, 16, 16) = ('pod', 'data', 'model')
with DCN/ICI over the 'pod' axis.  Functions (not module constants) so that
importing this module never touches jax device state -- the dry-run sets
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


HW = {
    # TPU v5e per-chip constants used by the roofline analysis
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}
