"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the scale proof: ``.lower().compile()`` must succeed for the
single-pod (16,16) and multi-pod (2,16,16) production meshes for all 40
assigned cells, with explicit shardings end to end.  The compiled artifact
feeds the roofline analysis (EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
# The force-host-device flag MUST precede any jax device initialisation.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import RunConfig  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.encdec import EncDec, enc_len_for  # noqa: E402
from repro.models.registry import ARCHS, get_config, get_model  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402
from repro.serve.serve_step import make_prefill_fn, make_serve_step  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402
from repro.utils import roofline  # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def skip_reason(arch: str, shape_name: str) -> str | None:
    """Cells excluded by the assignment rules."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{arch} is full-attention (see DESIGN.md §Arch-applicability)"
        )
    return None


def _abstract(tree_fn, *args, **kw):
    return jax.eval_shape(tree_fn, *args, **kw)


def input_specs(cfg, shape, mesh, rules=None):
    """ShapeDtypeStruct stand-ins + shardings for one cell's batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family in ("audio", "encdec"):
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, enc_len_for(s), cfg.d_model), jnp.bfloat16
        )
    elif cfg.frontend_tokens:
        specs["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    # shape-aware: batch may not divide (e.g. B=1) -> pspec handles it
    shardings = {
        k: jax.sharding.NamedSharding(
            mesh,
            shd.pspec(("batch",) + (None,) * (len(v.shape) - 1),
                      rules=rules, mesh=mesh, shape=v.shape),
        )
        for k, v in specs.items()
    }
    return specs, shardings


# Per-arch run overrides driven by per-chip HBM accounting (16 GB v5e):
#   * arctic-480b: f32 master + f32 moments = 22.5 GB/chip on one pod ->
#     bf16 master + bf16 moments (11.3 GB); deeper grad accumulation keeps
#     expert activations bounded.
ARCH_RUN_OVERRIDES = {
    # microbatch_multi: the multi-pod mesh has 32 batch-axis devices
    # (pod*data); a microbatch whose global batch is smaller than that
    # makes GSPMD pad/replicate samples (observed: arctic per-device FLOPs
    # doubled at microbatch=16 on 2x16x16).  Keep per-micro batch >= the
    # batch-axis size.
    "arctic-480b": dict(microbatch=16, microbatch_multi=8,
                        param_dtype="bfloat16", opt_dtype="bfloat16"),
    "nemotron-4-15b": dict(microbatch=8),
    "internvl2-26b": dict(microbatch=16, microbatch_multi=8),
    # train activation temps exceeded 16 GiB at microbatch=4 (42/34 GiB):
    # deeper accumulation keeps one microbatch's activations live
    "minicpm-2b": dict(microbatch=16, microbatch_multi=8),
    "hymba-1.5b": dict(microbatch=16, microbatch_multi=8),
}


def _build_cell(cfg, shape, mesh, rules=None, microbatch=4,
                serve_bf16=True, force_microbatch=None):
    """Assemble (fn, args, jit kwargs, model_flops) for one cell.

    Train cells default to 4 gradient-accumulation microbatches so peak
    activation memory stays within a v5e's 16 GB HBM (the accumulation scan
    keeps only one microbatch's activations live).  Decode/prefill cells
    serve in bf16 by default (§Perf/1 it.3); --baseline restores f32.
    """
    ov = ARCH_RUN_OVERRIDES.get(cfg.name, {})
    microbatch = ov.get("microbatch", microbatch)
    if "pod" in mesh.shape:
        microbatch = ov.get("microbatch_multi", microbatch)
    if force_microbatch is not None:
        microbatch = force_microbatch
    default_pdt = ("bfloat16" if serve_bf16 and shape.kind != "train"
                   else "float32")
    param_dtype = jnp.dtype(ov.get("param_dtype", default_pdt))
    opt_dtype = jnp.dtype(ov.get("opt_dtype", "float32"))
    model = get_model(cfg)
    run = RunConfig(microbatch=microbatch,
                    gather_weights_once=ov.get("gather_weights_once", False))
    with shd.use_mesh(mesh, rules):
        params_abs = model.abstract(param_dtype)
        p_sh = shd.param_shardings(model.spec(), mesh, rules)
        batch_abs, batch_sh = input_specs(cfg, shape, mesh, rules)

        if shape.kind == "train":
            opt_abs = _abstract(lambda p: opt.init_opt_state(p, opt_dtype),
                                params_abs)
            o_sh = opt.OptState(
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                p_sh, jax.tree.map(lambda x: x, p_sh),
            )
            fn = make_train_step(model, run)
            args = (params_abs, opt_abs, batch_abs)
            jit_kw = dict(
                in_shardings=(p_sh, o_sh, batch_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            tokens = shape.global_batch * shape.seq_len
            mflops = roofline.model_flops_train(cfg, tokens)
        elif shape.kind == "prefill":
            fn = make_prefill_fn(model)
            extra_keys = [k for k in batch_abs if k != "tokens"]
            args = (params_abs, batch_abs["tokens"],
                    *[batch_abs[k] for k in extra_keys])
            in_sh = [batch_sh["tokens"]] + [batch_sh[k] for k in extra_keys]
            jit_kw = dict(in_shardings=(p_sh, *in_sh))
            tokens = shape.global_batch * shape.seq_len
            mflops = roofline.model_flops_decode(cfg, tokens)
        else:  # decode
            b = shape.global_batch
            cache_abs = _abstract(
                lambda: model.init_cache(b, shape.seq_len, dtype=jnp.bfloat16)
            )
            c_sh = shd.tree_shardings(cache_abs, model.cache_axes(), mesh, rules)
            fn = make_serve_step(model)
            tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            tok_sh = jax.sharding.NamedSharding(
                mesh, shd.pspec(("batch", None), rules=rules, mesh=mesh,
                                shape=(b, 1)),
            )
            rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
            args = (params_abs, cache_abs, tok_abs, rng_abs)
            jit_kw = dict(in_shardings=(p_sh, c_sh, tok_sh, None),
                          donate_argnums=(1,))
            mflops = roofline.model_flops_decode(cfg, shape.global_batch)
    return fn, args, jit_kw, mflops


def _with_layers(cfg, n: int):
    """Same arch at n *unrolled* layers (per-layer cost extrapolation).

    Unrolling matters: a scanned stack lowers to the same while body at any
    trip count, so XLA's body-once cost counting would make an L-diff
    vacuous.  Unrolled 2- vs 3-layer programs contain genuinely distinct
    per-layer ops (including each layer's FSDP all-gathers), so their diff
    is one true layer.
    """
    kw = dict(n_layers=n, scan_layers=False)
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = n
    if cfg.global_attn_layers:
        kw["global_attn_layers"] = (0,)
    return dataclasses.replace(cfg, **kw)


def _costs_at(cfg, shape, mesh, rules=None, force_microbatch=None) -> dict:
    """(collective bytes, flops, bytes accessed) for a small-L variant."""
    fn, args, jit_kw, _ = _build_cell(cfg, shape, mesh, rules,
                                      force_microbatch=force_microbatch)
    with shd.use_mesh(mesh, rules):
        compiled = jax.jit(fn, **jit_kw).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "coll": roofline.collective_bytes(compiled.as_text())["total"],
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def _train_microbatch(cfg, mesh, microbatch=4) -> int:
    ov = ARCH_RUN_OVERRIDES.get(cfg.name, {})
    mb = ov.get("microbatch", microbatch)
    if "pod" in mesh.shape:
        mb = ov.get("microbatch_multi", mb)
    return mb


def lower_cell(arch: str, shape_name: str, mesh, rules=None, compile_=True,
               extrapolate_collectives=True, serve_bf16=True):
    """Lower (and optionally compile) one cell.  Returns the report dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    fn, args, jit_kw, mflops = _build_cell(cfg, shape, mesh, rules,
                                           serve_bf16=serve_bf16)
    with shd.use_mesh(mesh, rules):
        lowered = jax.jit(fn, **jit_kw).lower(*args)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "mesh_axes": dict(mesh.shape),
        "kind": shape.kind,
        "n_chips": n_chips,
        "lower_s": round(time.time() - t0, 2),
        "n_params": cfg.n_params,
        "n_active_params": cfg.n_active_params,
    }
    if not compile_:
        return report, lowered, None

    t1 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t1, 2)
    hlo = compiled.as_text()

    # loop-aware corrections (XLA counts while bodies once; see roofline.py)
    with shd.use_mesh(mesh, rules):
        fcorr, bcorr, detail = roofline.loop_corrections(fn, *args)

    # exact per-layer collectives by diffing 2- vs 3-layer compiles of the
    # same cell (covers the all-gathers/reduce-scatters inside the layer
    # scan, which the single-body HLO count misses)
    coll_override = None
    bytes_override = None
    uses_layer_scan = not (cfg.family == "hybrid" and shape.kind == "decode")
    if extrapolate_collectives and uses_layer_scan and cfg.n_layers > 3:
        a2 = _costs_at(_with_layers(cfg, 2), shape, mesh, rules)
        a3 = _costs_at(_with_layers(cfg, 3), shape, mesh, rules)
        L = cfg.n_layers
        ext = lambda k: a2[k] + (L - 2) * max(0.0, a3[k] - a2[k])
        coll_override = ext("coll")
        # Per-layer HBM-byte extrapolation.  Inner (attention/SSM) scan
        # bodies stay counted once, which matches TPU reality: a fused
        # flash-style kernel streams KV/chunks through VMEM, touching HBM
        # once per operand -- see DESIGN.md §Roofline-accounting.
        bytes_override = ext("bytes")
        report["layer_extrapolation"] = {
            "at_2_layers": a2,
            "at_3_layers": a3,
            "collective_total": coll_override,
            "bytes_total": bytes_override,
            "flops_total_xla": ext("flops"),
        }
        # Gradient-accumulation correction: the microbatch scan body is
        # counted ONCE by the HLO text parse, but weight gathers repeat
        # every micro-iteration.  Split collectives into a per-token part
        # A (microbatch-invariant) and a per-iteration part W by also
        # compiling at microbatch=1:  C1 = A + W,  Cb = A/b + W
        # => A = (C1-Cb)*b/(b-1), true total = A + b*W.
        b = _train_microbatch(cfg, mesh)
        if shape.kind == "train" and b > 1:
            c1_2 = _costs_at(_with_layers(cfg, 2), shape, mesh, rules,
                             force_microbatch=1)["coll"]
            c1_3 = _costs_at(_with_layers(cfg, 3), shape, mesh, rules,
                             force_microbatch=1)["coll"]
            C1 = c1_2 + (L - 2) * max(0.0, c1_3 - c1_2)
            Cb = coll_override
            A = max(0.0, (C1 - Cb) * b / (b - 1))
            W = max(0.0, C1 - A)
            coll_override = A + b * W
            report["layer_extrapolation"]["microbatch_correction"] = {
                "microbatch": b, "coll_mb1": C1, "coll_body_once": Cb,
                "per_token_bytes": A, "per_iteration_bytes": W,
                "collective_total": coll_override,
            }

    tp = mesh.shape.get("model", 1)
    dp = n_chips // tp
    cache_shard = 1
    if (rules or {}).get("cache_seq") == "model" and shape.kind == "decode":
        cache_shard = tp
    struct_bytes = roofline.structural_hbm_bytes(cfg, shape, n_chips, tp, dp,
                                                 cache_shard=cache_shard)
    report["roofline"] = roofline.cost_terms(
        compiled, n_chips, model_flops=mflops, hlo_text=hlo,
        flop_correction=fcorr, byte_correction=bcorr,
        bytes_override=bytes_override,
        collective_total_override=coll_override,
        structural_bytes=struct_bytes,
    )
    report["roofline"].update(detail)
    report["memory"] = roofline.memory_report(compiled)
    return report, lowered, compiled


# §Perf/1 serving rules: flash-decode cache layout + head_dim TP, and pure
# TP for the weights ("embed": None disables FSDP -- decode re-reads the
# same weights every step, so gathering them per step over 'data' was the
# whole collective term: 14x on nemotron/internvl2).  arctic-480b keeps
# FSDP: 960 GB of bf16 experts cannot replicate over the data axis.
OPT_DECODE_RULES = {"cache_seq": "model", "head_dim": "model", "embed": None}
FSDP_SERVE_ARCHS = {"arctic-480b"}


def run_cell(arch, shape_name, mesh_kind, rules=None, suffix="",
             serve_bf16=True):
    reason = skip_reason(arch, shape_name)
    name = f"{arch}__{shape_name}__{mesh_kind}{suffix}"
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{name}.json"
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "skipped": reason}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip] {name}: {reason}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        report, _, _ = lower_cell(arch, shape_name, mesh, rules,
                                  serve_bf16=serve_bf16)
        report["status"] = "ok"
    except Exception as e:  # pragma: no cover - failure reporting path
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {name}: {report['error']}")
        out_path.write_text(json.dumps(report, indent=2))
        return report
    out_path.write_text(json.dumps(report, indent=2))
    r = report.get("roofline", {})
    m = report.get("memory", {})
    print(
        f"[ok] {name}: compile {report.get('compile_s', '?')}s "
        f"dominant={r.get('dominant')} "
        f"compute={r.get('compute_s', 0):.3e}s "
        f"mem={r.get('memory_s', 0):.3e}s coll={r.get('collective_s', 0):.3e}s "
        f"hbm_args={m.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
        f"temp={m.get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
    )
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful pre-§Perf configuration: batch-only "
                         "cache sharding, FSDP attn weights, f32 serving")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="(kept for §Perf repro) same as the default opt "
                         "rules: cache seq-sharded + head_dim TP")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="(kept for §Perf repro) bf16 decode params for one "
                         "arch — now the default; see --baseline")
    ap.add_argument("--suffix", default="",
                    help="output filename suffix (keeps baselines intact)")
    args = ap.parse_args()

    if args.serve_bf16:
        ARCH_RUN_OVERRIDES.setdefault(args.arch, {})["param_dtype"] = "bfloat16"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    # --all is a convenience for "no filters"; explicit --arch/--shape
    # always narrow the sweep
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                # §Perf/1 optimized rules are the DECODE default (they
                # regress train cells: head_dim TP conflicts with the
                # kv-head layout inside blockwise attention); --baseline
                # reverts to the paper-faithful batch-only cache sharding.
                if args.cache_seq_shard and not args.baseline:
                    rules = dict(OPT_DECODE_RULES)  # forced (Perf repro)
                elif not args.baseline and SHAPES[shape_name].kind == "decode":
                    rules = dict(OPT_DECODE_RULES)
                else:
                    rules = None
                if rules is not None and arch in FSDP_SERVE_ARCHS:
                    rules.pop("embed", None)  # keep FSDP weights
                rec = run_cell(arch, shape_name, mesh_kind, rules=rules,
                               suffix=args.suffix,
                               serve_bf16=not args.baseline)
                if rec.get("status") == "FAILED":
                    n_fail += 1
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
