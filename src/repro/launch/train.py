"""Production training launcher: ``python -m repro.launch.train --arch <id>``.

Builds the mesh from whatever devices exist (or the production mesh under
the dry-run device flag), applies the per-arch sharding rules, and drives
the fault-tolerant Trainer.  On a real multi-host TPU deployment this
process runs per host under ``jax.distributed.initialize()``; everything
below that line is identical.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 10 --workdir /tmp/run1
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.launch.mesh import make_host_mesh
from repro.models.encdec import enc_len_for
from repro.models.registry import get_config, get_model, list_archs
from repro.train.trainer import Trainer


def synthetic_data(cfg, batch: int, seq: int, seed: int = 0):
    """Synthetic token stream (plus modality-stub inputs where required)."""
    rng = np.random.default_rng(seed)
    while True:
        out = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq + 1)), jnp.int32)}
        if cfg.family in ("audio", "encdec"):
            out["frames"] = jnp.asarray(
                rng.normal(size=(batch, enc_len_for(seq), cfg.d_model)),
                jnp.float32) * 0.1
        elif cfg.frontend_tokens:
            out["prefix"] = jnp.asarray(
                rng.normal(size=(batch, cfg.frontend_tokens, cfg.d_model)),
                jnp.float32) * 0.1
        yield out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--workdir", default="/tmp/repro_launch_train")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_host_mesh(args.model_parallel) if jax.device_count() > 1 else None
    run = RunConfig(steps=args.steps, microbatch=args.microbatch,
                    warmup_steps=max(2, args.steps // 10),
                    checkpoint_every=max(1, args.steps // 4))
    print(f"[launch] arch={cfg.name} params~{cfg.n_params/1e6:.1f}M "
          f"devices={jax.device_count()} mesh={dict(mesh.shape) if mesh else None}")
    trainer = Trainer(model, run, synthetic_data(cfg, args.batch, args.seq),
                      args.workdir, mesh=mesh)
    _, _, last = trainer.train(steps=args.steps)
    print(f"[launch] done: {last}")


if __name__ == "__main__":
    main()
