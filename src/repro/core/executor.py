"""Executor layer: runs :class:`~repro.core.plan.ExtractionPlan`s with a
device-resident data plane.

The planning/execution split (see ``core/plan``) gives this module a
simple contract: ``submit_window`` turns one window of cases into device
launches without data-dependent control flow, ``collect_window`` drains
the results.  Everything between -- device pools, the sync-free static
pass-1 chain, the double-buffered feeds, the streaming overlap -- lives
here, behind the thin :class:`~repro.core.pipeline.BatchedExtractor`
facade.

Data plane (both passes device-resident):

* **pass 0 (staging):** each case's cropped, bucket-padded mask goes to
  the device once during host prep (async ``device_put``-style transfer
  overlapping the next case's crop/pad); per shape bucket the staged
  masks are stacked into a bucket-keyed **device pool** that both pass 1
  (vertex fields) and pass 2a (MC) consume -- the per-chunk host
  ``np.stack`` of PR 2/3 is gone;
* **pass 1:** one (shard-able) bound + segmented-compaction chain per
  cap group.  Under ``schedule='counted'`` the survivor counts are
  fetched to size the ragged M' buckets (one small (B, 2) sync per cap
  group -- the PR 3 behaviour and the parity baseline).  Under
  ``schedule='static'`` the chain compacts straight into the plan's
  static target and the counts ride along **as a device array**: pass 1
  -> pass 2b is a single dispatch chain with ZERO host fetches (counted
  by ``transfer_log`` and locked by a tier-1 test);
* **pass 2a/2b:** grouped sub-batches sliced off the pools / pass-1
  output stacks; every launch of a window is submitted before any result
  is drained, so transfers and compute of chunk k+1 overlap chunk k.

Static-schedule collect: the deferred (B, 2) count fetch happens at
drain time, AFTER the diameter sweeps were dispatched.  Cases whose
counted-schedule decision would have been "keep the originals" (the
static target is exactly the counted win boundary -- ``core/plan``) are
then re-swept once at their original cap from the retained device
stacks; every other case's static result is already exact, because the
aligned target guarantees no survivor was dropped.

Streaming: ``extract_stream`` pipelines windows -- window k+1 is
prepped/submitted while the device still executes window k (jax dispatch
is async), then window k is drained and its rows yielded in input order.
Under ``schedule='static'`` the submit path never blocks on the device,
so the overlap is complete; under ``'counted'`` the pass-1 count fetch
re-serialises part of it (the measured trade-off is recorded in
ROADMAP.md).

Cost-model-driven knobs (PR 5, ``runtime/costmodel``): ``prep='hint'``
sizes pass-0 caps from ``plan.vertex_hint`` metadata alone -- the last
per-case host sync (``int(n)``) disappears; the true count rides to the
collector as a device future, and the rare hint-overflow case re-runs
count-sized at collect time (the same retry contract as the static
keep-originals re-sweep).  ``schedule='auto'`` resolves counted-vs-
static per window from the calibrated ``sync/<backend>`` probe and the
window's census; ``extract_stream(window='auto')`` closes windows at
census-decided boundaries.  ``prep='count'`` and fixed windows remain
the parity baselines, and every auto knob is bit-identical to them
(tier-1-locked).

Resilience (PR 6, ``runtime/resilience``): cases may be lazy loader
callables; any load/validation failure (incl. NaN-poisoned masks)
quarantines the case as an all-NaN row plus a window-stats error record
instead of killing the window (``_prep_case_safe``), and a ``retry``
policy turns a collect-time fault into a backed-off ``resubmit_window``
+ re-drain -- both pure host-side mechanisms that leave the sync-free
submit path's zero-fetch invariants untouched.

Feature families (PR 7, ``core/plan.FAMILIES``): the executor extracts
any requested subset of the registered families.  The intensity families
(first-order, GLCM) ride the same windows as the shape passes: pass 0
stages each case's cropped, bucket-padded intensity volume ONCE
alongside its mask, the per-shape-bucket intensity pools are built once
and SHARED by every intensity family, and one batched family launch per
(family, shape bucket) is submitted inside the same submit phase -- no
new host fetch happens before collect, so the sync-free invariants
(zero pass-0/pass-1 fetches under hint prep + static schedule) hold
unchanged with families enabled (tier-1-locked).  Feature rows are the
family-order concatenation ``plan.row_width(families)`` wide; quarantine
NaN rows and empty-mask zero rows derive their width from the same
registry, never from a hardcoded constant.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import math
import time
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import dispatcher
from repro.core import plan as planlib
from repro.core.shape_features import crop_to_roi
from repro.kernels import ops
from repro.kernels import prune as prune_kernels
from repro.parallel import sharding as psharding
from repro.runtime import autotune


@dataclasses.dataclass
class _Prepped:
    """Pass-0 state for one case (None mask = empty-mask case).

    ``mask`` is the bucket-padded mask, staged on device (the pool
    entry); ``verts``/``vmask`` stay device-resident on the device-
    compaction path and are host numpy on the legacy host path.
    """

    mask: object | None = None  # device-staged bucket-padded mask
    image: object | None = None  # device-staged bucket-padded intensity
    # volume (same crop/pad as the mask); None unless a family needs it
    spacing: np.ndarray | None = None
    shape: tuple | None = None  # padded shape bucket (MC group key)
    roi_shape: tuple | None = None  # pre-pad cropped shape (pad stats)
    verts: object | None = None
    vmask: object | None = None
    n_vertices: int = 0  # pre-prune dedup vertex count (a feature)
    vertex_cap: int = 0  # static M' bucket the diameter kernel compiles for
    prune_info: object | None = None
    n_fut: object | None = None  # hint prep: true dedup count, ON DEVICE
    prep_cap: int = 0  # hint prep: the pass-0 compaction cap (overflow ref;
    # vertex_cap is overwritten by pass 1 with the pass-2b bucket)
    error: str | None = None  # quarantined case: the row degrades to NaNs


@dataclasses.dataclass
class _Window:
    """One submitted window: every launch issued, nothing drained yet."""

    prepped: list
    plan: planlib.ExtractionPlan
    mc_futs: list
    diam_futs: list
    fused_futs: list
    static_aux: list  # [(cap, idxs, counts_fut, verts, masks)] to resolve
    t_prune: float
    family_futs: dict = dataclasses.field(default_factory=dict)
    # {family: [(idxs, future)]} -- the intensity-family launches


@jax.jit
def _fields_count(mask, spacing):
    """Pass-0 compute: dedup vertex fields + active count, one compile per
    shape bucket (the eager per-op path costs ~10x on a cold sweep)."""
    fields = ops.vertex_fields(mask, 0.5, spacing)
    return fields, ops.count_vertices(fields)


@functools.partial(jax.jit, static_argnames=("cap",))
def _compact_cap(fields, cap: int):
    verts, vmask, _ = ops.compact_vertices(fields, cap)
    return verts, vmask


def _features_one(mask, spacing, vertex_cap, backend, variant, block=None,
                  mc_block=None, mc_chunk=None):
    mc_kw = ({"block": mc_block, "chunk": mc_chunk} if mc_block is not None
             else {"chunk": mc_chunk} if mc_chunk is not None else {})
    vol, area = ops.mc_volume_area(mask, 0.5, spacing, backend=backend, **mc_kw)
    fields = ops.vertex_fields(mask, 0.5, spacing)
    verts, vmask, n = ops.compact_vertices(fields, vertex_cap)
    d = ops.max_diameters(
        verts, vmask, backend=backend, variant=variant, block=block
    )
    return jnp.concatenate(
        [jnp.stack([vol, area]), d, jnp.asarray([n], jnp.float32)]
    )  # (7,)


class PlanExecutor:
    """Plan-driven batched extraction engine (see module docstring).

    Owns the compiled-function cache, the device pools, the submit/
    collect drivers, and the ``transfer_log`` host-sync accounting.
    ``BatchedExtractor`` is the public facade.
    """

    N_FEATURES = 7  # the shape-family (default request) row width:
    # [vol, area, d3, dxy, dxz, dyz, n_vertices].  Per-instance widths
    # come from the family registry: see ``self.n_features``.

    SCHEDULES = (*planlib.SCHEDULES, "auto")
    PREPS = ("count", "hint")

    def __init__(self, backend=None, variant="auto", mesh: Mesh | None = None,
                 data_axis: str = "data", prune: bool = True,
                 mc_block="auto", mc_chunk: int | None = None,
                 k_dirs: int = 16, device_compact: bool = True,
                 compact_block="auto", schedule: str = "counted",
                 prep: str = "count", cost_model=None,
                 transfer_callback=None, retry=None,
                 families=None, n_bins: int = 32):
        self.backend = dispatcher.resolve_backend(backend)
        self.variant = variant
        self.families = planlib.resolve_families(families)
        self.n_features = planlib.row_width(self.families)
        self.n_bins = int(n_bins)
        self._shape_on = "shape" in self.families
        self._needs_intensity = planlib.needs_intensity(self.families)
        if mesh is None:
            # adopt the ambient use_mesh mesh only when it can actually
            # shard the batch: train/serve meshes without a data axis must
            # not turn a working CPU pipeline into a KeyError
            ambient = psharding.active_mesh()
            if ambient is not None and data_axis in ambient.shape:
                mesh = ambient
        self.mesh = mesh
        self.data_axis = data_axis
        self.prune = prune
        self.mc_block = mc_block
        self.mc_chunk = mc_chunk
        self.k_dirs = k_dirs
        self.device_compact = device_compact
        self.compact_block = compact_block
        if schedule not in self.SCHEDULES:
            raise ValueError(
                f"schedule must be one of {self.SCHEDULES}, got {schedule!r}"
            )
        if schedule in ("static", "auto") and not (prune and device_compact):
            raise ValueError(
                f"schedule={schedule!r} is (or may resolve to) a "
                "device-resident schedule: it requires prune=True and "
                "device_compact=True"
            )
        self.schedule = schedule
        if prep not in self.PREPS:
            raise ValueError(f"prep must be one of {self.PREPS}, got {prep!r}")
        if prep == "hint" and not (prune and device_compact):
            raise ValueError(
                "prep='hint' is a device-resident prep: it requires "
                "prune=True and device_compact=True"
            )
        self.prep = prep
        self._cost_model = cost_model
        self.transfer_log = collections.Counter()
        self._transfer_cb = transfer_callback
        self.retry = retry  # runtime/resilience.RetryPolicy (duck-typed)
        self.window_retries = 0  # collect retries performed (resilience census)
        self._compiled = {}

    @property
    def cost_model(self):
        """Lazily-built decision layer (``runtime/costmodel.CostModel``).

        Only the auto knobs (``schedule='auto'``, ``window='auto'``) read
        it, so plain fixed-knob runs never touch the autotune cache file
        through this path.
        """
        if self._cost_model is None:
            from repro.runtime import costmodel  # local: keep import light

            self._cost_model = costmodel.CostModel(self.backend)
        return self._cost_model

    # -- host-sync accounting ----------------------------------------------

    def _fetch(self, stage: str, x) -> np.ndarray:
        """The ONLY device->host fetch point of the executor.

        Every host materialisation of a device value routes through here
        so ``transfer_log`` is a complete per-stage sync census -- the
        counter the zero-pass-1-fetch contract of ``schedule='static'``
        is asserted against (tier-1).
        """
        self.transfer_log[stage] += 1
        if self._transfer_cb is not None:
            self._transfer_cb(stage, x)
        return np.asarray(x)

    # -- tuned-config resolution (outside any trace) ------------------------

    def _resolve_mc(self, shape, depth: int = 1):
        if self.backend == "ref":
            # no brick block on ref; mc_chunk doubles as the scan slab
            # depth (a memory lever the tiled engine shares)
            return None, self.mc_chunk
        return dispatcher.mc_config(
            self.backend, shape, self.mc_block, self.mc_chunk, batch=depth
        )

    def _resolve_diameter(self, cap, depth: int = 1):
        if self.backend == "ref":
            return self.variant, None
        return dispatcher.diameter_config(
            self.backend, cap, self.variant, batch=depth
        )

    def _resolve_compact(self, cap_in, depth: int = 1):
        if self.backend == "ref":
            return None
        return dispatcher.compact_config(
            self.backend, cap_in, self.compact_block, batch=depth
        )

    def _resolve_family_block(self, family: str, shape, depth: int = 1):
        """Tuned block for an intensity-family launch (None on 'ref')."""
        if self.backend == "ref":
            return None
        resolver = (dispatcher.firstorder_config if family == "firstorder"
                    else dispatcher.glcm_config)
        return resolver(self.backend, shape, "auto", batch=depth)

    # -- compiled-function cache -------------------------------------------

    def _dp_map(self, fn, check: bool = True):
        """Shard a batched fn over the data axis (plain jit without a mesh).

        ``check=False`` for batch fns that contain a ``pallas_call``:
        jax's shard_map replication checker has no rule for it (the
        documented workaround -- results are still bit-identical, locked
        by tests/test_pipeline_multidevice.py).
        """
        return psharding.data_parallel_map(
            fn, self.mesh, self.data_axis, check=check
        )

    def _pad_batch(self, arrays, n: int):
        return psharding.pad_batch(arrays, n, self.mesh, self.data_axis)

    def _bound_fn(self, cap: int, depth: int):
        """Pass 1 (counted): sharded vmapped pruning bound + survivor counts.

        Maps stacked ``(B, cap, 3)`` verts + ``(B, cap)`` masks to
        ``(keep, counts)``; with a mesh the batch shards over the data
        axis (``data_parallel_map`` is a plain jit without one).
        """
        key = ("prune_bound", cap, depth)
        if key in self._compiled:
            return self._compiled[key]
        k_dirs = self.k_dirs

        def batch(verts, masks):
            keep, _ = prune_kernels.keep_mask_batch(verts, masks, k_dirs)
            m_valid = jnp.sum(masks.astype(jnp.int32), axis=1)
            m_kept = jnp.sum(keep.astype(jnp.int32), axis=1)
            # counts ride out pre-stacked (B, 2) so the host fetch is one
            # transfer with no eager stitching (batch dim first: shardable)
            return keep, jnp.stack([m_valid, m_kept], axis=1)

        fn = self._dp_map(batch)
        self._compiled[key] = fn
        return fn

    def _compact_fn(self, cap_in: int, cap_out: int, depth: int):
        """Pass 1 (counted): sharded batched compaction into the M' bucket."""
        key = ("compact", cap_in, cap_out, depth)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        block = self._resolve_compact(cap_in, depth)

        def batch(verts, keep):
            v, m, _ = ops.compact_survivors_batch(
                verts, keep, cap_out, backend=backend, block=block
            )
            return v, m

        fn = self._dp_map(batch, check=False)
        self._compiled[key] = fn
        return fn

    def _static_fn(self, cap: int, target: int, depth: int):
        """Pass 1 (static): ONE fused bound -> compaction dispatch chain.

        Emits ``(compacted verts, compacted mask, (B, 2) counts)`` with
        the counts staying ON DEVICE -- the chain has no data-dependent
        decision, which is what makes static pass 1 sync-free.  The
        compaction target is the plan's aligned static bucket, so no
        survivor of a counted-schedule "compact" case can overflow it
        (``core/plan.static_bucket``).
        """
        key = ("static_chain", cap, target, depth)
        if key in self._compiled:
            return self._compiled[key]
        backend, k_dirs = self.backend, self.k_dirs
        block = self._resolve_compact(cap, depth)

        def batch(verts, masks):
            keep, _ = prune_kernels.keep_mask_batch(verts, masks, k_dirs)
            m_valid = jnp.sum(masks.astype(jnp.int32), axis=1)
            m_kept = jnp.sum(keep.astype(jnp.int32), axis=1)
            v, m, _ = ops.compact_survivors_batch(
                verts, keep, target, backend=backend, block=block
            )
            return v, m, jnp.stack([m_valid, m_kept], axis=1)

        fn = self._dp_map(batch, check=False)
        self._compiled[key] = fn
        return fn

    def _batch_fn(self, bucket: planlib.Bucket, depth: int):
        """Legacy one-pass fused per-case function (``prune=False``)."""
        key = ("one_pass", bucket, depth)
        if key in self._compiled:
            return self._compiled[key]
        backend, cap = self.backend, bucket.vertex_cap
        variant, block = self._resolve_diameter(cap, depth)
        mc_block, mc_chunk = self._resolve_mc(bucket.shape, depth)

        def one(args):
            mask, spacing = args
            return _features_one(mask, spacing, cap, backend, variant, block,
                                 mc_block, mc_chunk)

        def batch(masks, spacings):
            return jax.lax.map(one, (masks, spacings))

        fn = self._dp_map(batch, check=False)
        self._compiled[key] = fn
        return fn

    def _mc_fn(self, shape, depth: int):
        """Pass 2a: staged batched fused MC for one shape bucket.

        Consumes device-pool stacks directly (``ops.mc_volume_area_batch``)
        and shards over the data axis exactly like pass 1.
        """
        key = ("mc", shape, depth)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        mc_block, mc_chunk = self._resolve_mc(shape, depth)

        def batch(masks, spacings):
            return ops.mc_volume_area_batch(
                masks, 0.5, spacings, backend=backend,
                block=mc_block, chunk=mc_chunk,
            )

        fn = self._dp_map(batch, check=False)
        self._compiled[key] = fn
        return fn

    def _family_fn(self, family: str):
        """Compile-key resolver for one intensity family's batched launch.

        Returns the ``fn_for_key`` shape :meth:`_submit` expects: per
        (padded-volume bucket, depth) one sharded jitted function mapping
        the pooled (images, masks) stacks to per-case DEVICE payloads --
        packed stats rows (firstorder) or count matrices (glcm).  Feature
        rows finalise host-side at drain time (:meth:`_family_row`); only
        the payloads need cross-backend parity.  The tuned block resolves
        OUTSIDE the trace, exactly like the shape passes' configs.
        """
        def fn_for_key(shape, depth):
            key = (family, shape, depth)
            if key in self._compiled:
                return self._compiled[key]
            backend, n_bins = self.backend, self.n_bins
            block = self._resolve_family_block(family, shape, depth)
            op = (ops.firstorder_packed_batch if family == "firstorder"
                  else ops.glcm_matrix_batch)

            def batch(images, masks):
                return op(images, masks, backend=backend, n_bins=n_bins,
                          block=block)

            fn = self._dp_map(batch, check=False)
            self._compiled[key] = fn
            return fn

        return fn_for_key

    def _diam_fn(self, cap, depth: int):
        """Pass 2b: batched diameter sweep for one (pruned) vertex bucket."""
        key = ("diam", cap, depth)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        variant, block = self._resolve_diameter(cap, depth)

        def one(args):
            verts, vmask = args
            return ops.max_diameters(
                verts, vmask, backend=backend, variant=variant, block=block
            )

        def batch(verts, vmasks):
            return jax.lax.map(one, (verts, vmasks))

        fn = self._dp_map(batch, check=False)
        self._compiled[key] = fn
        return fn

    # -- submit/drain drivers ----------------------------------------------

    def _submit(self, entries, fn_for_key, make_chunk, batch_size=None):
        """Submit every chunk of every entry; returns ``[(idxs, future)]``.

        ``entries`` yields ``(compile key, case indices, payload)``;
        ``make_chunk(payload, start, chunk, bs)`` materialises the stacked
        input arrays for one chunk, padded up to ``bs`` rows (a multiple
        of the mesh's data-axis size, so shard_map shapes stay uniform).
        jax dispatch is async, so every launch of the window is queued
        before any result is fetched -- the transfer/compute of chunk k+1
        overlaps chunk k, and draining is the collector's job.
        """
        n_data = psharding.axis_size(self.mesh, self.data_axis)
        futs = []
        for gkey, idxs, payload in entries:
            bs = batch_size or max(n_data, len(idxs))
            bs = int(math.ceil(bs / n_data)) * n_data
            fn = fn_for_key(gkey, autotune.batch_bucket(bs))
            for s in range(0, len(idxs), bs):
                chunk = idxs[s : s + bs]
                futs.append((chunk, fn(*make_chunk(payload, s, chunk, bs))))
        return futs

    def _drain(self, futs, stage: str) -> dict:
        """Fetch submitted futures into ``{case index: np row}``."""
        out: dict[int, np.ndarray] = {}
        for idxs, fut in futs:
            o = self._fetch(stage, fut)
            for j, i in enumerate(idxs):
                out[i] = o[j]
        return out

    @staticmethod
    def _stacked_chunk(arrays, s, chunk, bs):
        """Chunk maker over PRE-STACKED device groups (pools / pass-1 out).

        Slices straight off the device stacks -- no host re-stacking;
        short trailing chunks pad with copies of their first row (mesh
        padding rows in the stacks themselves are simply never read).
        """
        sl = tuple(a[s : s + len(chunk)] for a in arrays)
        if len(chunk) < bs:
            sl = tuple(
                jnp.concatenate([a, jnp.repeat(a[:1], bs - len(chunk), axis=0)])
                for a in sl
            )
        return sl

    def _host_chunk(self, arrays_for_case):
        """Chunk maker over host per-case arrays (the legacy pass-2b feed)."""

        def make(_, s, chunk, bs):
            filled = chunk + [chunk[0]] * (bs - len(chunk))
            cols = zip(*(arrays_for_case(i) for i in filled))
            return tuple(jnp.asarray(np.stack(c)) for c in cols)

        return make

    def _pool(self, prepped, idxs):
        """Bucket-keyed device pool for one shape group: (masks, spacings).

        ``jnp.stack`` of the staged per-case device masks runs on device;
        the (B, 3) spacing sidecar is tiny host metadata.
        """
        return (
            jnp.stack([prepped[i].mask for i in idxs]),
            jnp.asarray(np.stack([prepped[i].spacing for i in idxs])),
        )

    def _ipool(self, prepped, idxs):
        """Intensity device pool for one shape group: (images, masks).

        Built once per shape group at submit and shared by EVERY
        intensity family of the window -- the staged per-case volumes are
        stacked on device, never re-transferred per family.
        """
        return (
            jnp.stack([prepped[i].image for i in idxs]),
            jnp.stack([prepped[i].mask for i in idxs]),
        )

    def _submit_families(self, plan, prepped, batch_size=None) -> dict:
        """Submit the intensity-family launches for one planned window.

        One launch chain per (family, shape bucket), every launch queued
        before anything is drained -- the families ride the same
        submit/collect window as the shape passes and add NO host fetch
        before collect (the sync-free invariants hold unchanged;
        tier-1-locked).
        """
        families = [f for f in plan.families if f != "shape"]
        if not families:
            return {}
        pools = {
            shape: self._ipool(prepped, idxs)
            for shape, idxs in plan.shape_groups.items()
        }
        futs = {}
        for family in families:
            entries = [
                (shape, idxs, pools[shape])
                for shape, idxs in plan.shape_groups.items()
            ]
            futs[family] = self._submit(
                entries, self._family_fn(family), self._stacked_chunk,
                batch_size,
            )
        return futs

    # -- pass 0: prep + device staging --------------------------------------

    def _prep_case(self, image, mask, spacing, fields: bool = True,
                   prep: str | None = None) -> _Prepped:
        """Crop, bucket-pad, device-stage, and compact one case (pass 0).

        ``fields=False`` (the legacy one-pass path, which recomputes the
        vertex field inside its fused kernel) skips the field/count
        launches and sizes the cap from the metadata hint
        (``plan.vertex_hint`` -- memoised, spacing-aware).

        ``prep`` (default: the executor's configured prep) sizes the M
        cap: ``'count'`` fetches the measured dedup count (one ``int(n)``
        host sync per case -- the parity baseline), ``'hint'`` sizes it
        from ``plan.vertex_hint`` metadata alone and leaves the true
        count ON DEVICE (``n_fut``) for the collector -- pass 0 becomes
        sync-free, at the cost of occasional over-allocation plus the
        rare hint-overflow retry (``_resolve_hint_counts``).
        """
        prep = prep or self.prep
        sp = np.asarray(spacing, np.float32)
        if not np.any(mask):
            return _Prepped(spacing=sp)  # empty mask: all-zero feature row
        if self._needs_intensity:
            img = None if image is None else np.asarray(image)
            if img is None or img.shape != np.shape(mask):
                raise ValueError(
                    "intensity families requested but the case has no "
                    "matching intensity image"
                )
            if (np.issubdtype(img.dtype, np.floating)
                    and not np.isfinite(img).all()):
                raise ValueError("non-finite intensity image (poisoned case)")
        if image is None:  # shape-only requests never read the image
            image = np.zeros_like(np.asarray(mask), dtype=np.float32)
        im, m, _ = crop_to_roi(image, mask)
        roi_shape = m.shape
        bshape = planlib.shape_bucket(tuple(s - 2 for s in roi_shape))
        pad = [(0, bs - ms) for bs, ms in zip(bshape, roi_shape)]
        mdev = jnp.asarray(np.pad(m, pad))  # staged once; pool entry
        idev = (jnp.asarray(np.pad(im, pad)) if self._needs_intensity
                else None)  # staged once; shared by every intensity family
        if not self._shape_on:
            # intensity-only request: no vertex stage runs at all -- the
            # shape bucket still keys the family launches
            return _Prepped(mask=mdev, image=idev, spacing=sp, shape=bshape,
                            roi_shape=roi_shape)
        if not fields:
            hint = planlib.vertex_hint(tuple(s - 2 for s in roi_shape), sp)
            return _Prepped(
                mask=mdev, image=idev, spacing=sp, shape=bshape,
                roi_shape=roi_shape,
                n_vertices=hint,  # pad-waste census only (the fused kernel
                vertex_cap=ops.vertex_bucket(hint),  # recounts for the row)
            )
        f, n = _fields_count(mdev, jnp.asarray(sp))
        if prep == "hint":
            # sync-free prep: the cap comes from metadata alone; the true
            # count stays a device future the collector drains.  A larger-
            # than-needed cap is harmless (pruning and the pair sweep are
            # padding-invariant, tier-1-locked); a SMALLER one drops
            # vertices, which the collector detects and retries count-sized.
            hint = planlib.vertex_hint(tuple(s - 2 for s in roi_shape), sp)
            cap = ops.vertex_bucket(hint)
            verts, vmask = _compact_cap(f, cap)
            return _Prepped(
                mask=mdev, image=idev, spacing=sp, shape=bshape,
                roi_shape=roi_shape, verts=verts, vmask=vmask,
                n_vertices=hint, vertex_cap=cap, n_fut=n, prep_cap=cap,
            )
        n = int(self._fetch("prep", n))
        cap = ops.vertex_bucket(n)
        verts, vmask = _compact_cap(f, cap)
        if not self.device_compact:  # PR 2 host path: pull to numpy per case
            verts = self._fetch("prep", verts)
            vmask = self._fetch("prep", vmask)
        return _Prepped(
            mask=mdev, image=idev, spacing=sp, shape=bshape,
            roi_shape=roi_shape, verts=verts, vmask=vmask, n_vertices=n,
            vertex_cap=cap,
        )

    def _prep_case_safe(self, case, fields: bool = True,
                        prep: str | None = None) -> _Prepped:
        """Quarantining wrapper around :meth:`_prep_case` (pass 0).

        ``case`` is an ``(image, mask, spacing)`` tuple or a zero-arg
        callable returning one (a lazy loader, so load failures are
        attributable to the case that raised them).  Any exception --
        loader I/O errors, non-finite (poisoned) masks or spacings, crop
        failures -- degrades to a QUARANTINED prepped case: its feature
        row is all-NaN, its error message rides the window stats, and the
        rest of the window is untouched.  A 40k-case sweep must not die
        on one poisoned segmentation (the row-level-error contract,
        tier-1-locked).  Validation and quarantine are pure host work:
        the sync-free submit path's zero-fetch invariants are untouched.
        """
        try:
            if callable(case):
                case = case()
            image, mask, spacing = case
            m = np.asarray(mask)
            if np.issubdtype(m.dtype, np.floating) and not np.isfinite(m).all():
                raise ValueError("non-finite mask (poisoned case)")
            sp = np.asarray(spacing, np.float64)
            if sp.shape != (3,) or not np.isfinite(sp).all() or (sp <= 0).any():
                raise ValueError(f"invalid spacing {spacing!r}")
            return self._prep_case(image, mask, spacing, fields=fields,
                                   prep=prep)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            return _Prepped(error=f"{type(e).__name__}: {e}")

    def _meta(self, p: _Prepped) -> planlib.CaseMeta:
        if p.mask is None:
            return planlib.CaseMeta(None, None, 0, 0)
        return planlib.CaseMeta(p.shape, p.roi_shape, p.vertex_cap,
                                p.n_vertices, intensity=p.image is not None)

    # -- public prep surface (the submit/collect reuse contract) -------------
    #
    # External drivers that window cases themselves -- the resilient
    # runner (runtime/resilience) and the serving tier (serve/service) --
    # prep each case through here, census its metadata, and hand the
    # prepped batch to submit_prepped/collect_window.  Everything they
    # need is these two names plus the window API; the underscore
    # internals stay private.

    def prep_case(self, case) -> _Prepped:
        """Pass-0 prep of one case, quarantining any load/validation
        failure (see :meth:`_prep_case_safe`); ``case`` is an
        ``(image, mask, spacing)`` tuple or a zero-arg loader callable."""
        return self._prep_case_safe(case, fields=self.prune)

    def case_meta(self, p: _Prepped) -> planlib.CaseMeta:
        """Planning metadata of a prepped case (feeds ``WindowCensus``)."""
        return self._meta(p)

    # -- pass 1 --------------------------------------------------------------

    def _prune_pass(self, plan, prepped):
        """Pass 1 (host path): vmapped bound + per-case host compaction."""
        for _, idxs in plan.cap_groups.items():
            batch = ops.prune_candidates_batch(
                np.stack([prepped[i].verts for i in idxs]),
                np.stack([prepped[i].vmask for i in idxs]),
                k_dirs=self.k_dirs,
            )
            for i, (v2, m2, info) in zip(idxs, batch):
                prepped[i].verts, prepped[i].vmask = v2, m2
                prepped[i].vertex_cap = len(v2)
                prepped[i].prune_info = info

    def _pass1_counted(self, plan, prepped):
        """Pass 1 (counted device path): sharded bound + device compaction.

        Per cap group, ONE (sharded) vmapped bound launch computes every
        keep mask, one small (B, 2) count fetch sizes the ragged M'
        buckets, and one (sharded) compaction launch per target bucket
        scatters the survivors -- the vertex data itself never leaves the
        device.  Decisions (pruned or keep-originals) come from
        ``prune.plan_compaction``, the same rule the host path composes,
        so the two paths stay bit-identical.  Returns the pass-2b feed:
        ``[(M' bucket, case indices, (verts, vmask) stacks)]``.
        """
        entries = []
        for cap, idxs in plan.cap_groups.items():
            b = len(idxs)
            depth = autotune.batch_bucket(b)
            verts, masks = self._pad_batch(
                (
                    jnp.stack([prepped[i].verts for i in idxs]),
                    jnp.stack([prepped[i].vmask for i in idxs]),
                ),
                b,
            )
            keep, counts = self._bound_fn(cap, depth)(verts, masks)
            # the one host sync of counted pass 1: a small (B, 2) matrix
            counts = self._fetch("pass1", counts)
            plans = [
                prune_kernels.plan_compaction(
                    cap, int(counts[j, 0]), int(counts[j, 1]),
                    ops.vertex_bucket,
                )
                for j in range(b)
            ]
            for j, i in enumerate(idxs):
                prepped[i].prune_info = plans[j][1]
                prepped[i].vertex_cap = plans[j][0] or cap
            # keep-originals cases feed pass 2 at their input cap
            groups = planlib.group_indices(
                [cap_out if cap_out else ("orig", cap) for cap_out, _ in plans]
            )
            for gkey, js in groups.items():
                # whole cap group agreeing on one target reuses the stacks
                take = (
                    None if len(js) == b
                    else jnp.asarray(np.asarray(js, np.int32))
                )

                def sub(*arrays):
                    if take is None:
                        return arrays
                    return self._pad_batch(
                        tuple(jnp.take(a, take, axis=0) for a in arrays),
                        len(js),
                    )

                gidxs = [idxs[j] for j in js]
                if isinstance(gkey, tuple):  # unpruned: originals, input cap
                    entries.append((cap, gidxs, sub(verts, masks)))
                    continue
                # the launch carries the SUBGROUP's depth, not the cap group's
                cv, cm = self._compact_fn(
                    cap, gkey, autotune.batch_bucket(len(js))
                )(*sub(verts, keep))
                entries.append((gkey, gidxs, (cv, cm)))
        return entries, []

    def _pass1_static(self, plan, prepped):
        """Pass 1 (static schedule): the sync-free dispatch chain.

        Per cap group ONE fused bound+compaction chain targets the plan's
        static bucket; the per-case counts stay on device and ride into
        the collector as ``static_aux`` -- no host fetch happens anywhere
        in this method (``transfer_log['pass1']`` stays 0, tier-1-locked).
        Floor-cap groups (no shrink possible -- exactly the groups the
        counted schedule always keeps at their original cap) skip the
        chain entirely and feed pass 2b their original stacks.
        """
        entries, aux = [], []
        for cap, idxs in plan.cap_groups.items():
            b = len(idxs)
            target = plan.static_targets[cap]
            verts, masks = self._pad_batch(
                (
                    jnp.stack([prepped[i].verts for i in idxs]),
                    jnp.stack([prepped[i].vmask for i in idxs]),
                ),
                b,
            )
            if target is None:
                # counted parity without the bound: a floor-cap group can
                # never re-bucket, so its PruneInfo is metadata-only
                for i in idxs:
                    n = prepped[i].n_vertices
                    prepped[i].prune_info = prune_kernels.PruneInfo(
                        cap, n, n, False
                    )
                    prepped[i].vertex_cap = cap
                entries.append((cap, idxs, (verts, masks)))
                continue
            depth = autotune.batch_bucket(b)
            cv, cm, counts = self._static_fn(cap, target, depth)(verts, masks)
            entries.append((target, idxs, (cv, cm)))
            aux.append((cap, idxs, counts, verts, masks))
        return entries, aux

    def _resolve_static_aux(self, window, d_out):
        """Static collect: deferred count fetch + keep-originals re-sweep.

        Fetches each cap group's (B, 2) counts (the sync the static
        schedule moved out of pass 1), derives the SAME
        ``plan_compaction`` decision the counted schedule makes, and for
        the keep-originals cases re-sweeps the retained original stacks
        at their input cap -- those rows' static-target results are the
        only ones discarded.
        """
        prepped = window.prepped
        retries = []
        for cap, idxs, counts_fut, verts, masks in window.static_aux:
            counts = self._fetch("pass2b_counts", counts_fut)
            retry_js = []
            for j, i in enumerate(idxs):
                cap_out, info = prune_kernels.plan_compaction(
                    cap, int(counts[j, 0]), int(counts[j, 1]),
                    ops.vertex_bucket,
                )
                prepped[i].prune_info = info
                prepped[i].vertex_cap = cap_out or cap
                if cap_out is None:
                    retry_js.append(j)
            if retry_js:
                take = jnp.asarray(np.asarray(retry_js, np.int32))
                sub = self._pad_batch(
                    tuple(jnp.take(a, take, axis=0) for a in (verts, masks)),
                    len(retry_js),
                )
                retries.append((cap, [idxs[j] for j in retry_js], sub))
        if retries:
            futs = self._submit(retries, self._diam_fn, self._stacked_chunk)
            d_out.update(self._drain(futs, "pass2b_retry"))

    def _resolve_hint_counts(self, window, d_out):
        """Hint-prep collect: deferred count fetch + hint-overflow retry.

        ``prep='hint'`` sized each cap from metadata and left the true
        dedup count on device; it is fetched here -- AFTER every launch
        of the window was submitted, so no prep/submit ever blocked on it
        -- both because the count is itself a feature of the row and to
        detect overflow.  A case whose true count exceeds its hint cap
        had vertices dropped by ``compact_vertices``: its pass-1/2b
        results are discarded and it re-runs count-sized through the
        single-case oracle stages (same kernels, same tuned configs --
        the same retry contract as the static keep-originals re-sweep).
        """
        prepped = window.prepped
        for i, p in enumerate(prepped):
            if p.n_fut is None:
                continue
            n = int(self._fetch("collect_counts", p.n_fut))
            overflow = n > p.prep_cap
            p.n_vertices = n
            p.n_fut = None
            if not overflow:
                continue
            cap = ops.vertex_bucket(n)
            f, _ = _fields_count(p.mask, jnp.asarray(p.spacing))
            verts, vmask = _compact_cap(f, cap)
            v2, m2, info = ops.prune_candidates(verts, vmask, k_dirs=self.k_dirs)
            variant, block = self._resolve_diameter(len(v2))
            d = ops.max_diameters(
                v2, m2, backend=self.backend, variant=variant, block=block
            )
            d_out[i] = self._fetch("hint_retry", d)
            p.verts, p.vmask = v2, m2
            p.prune_info = info
            p.vertex_cap = len(v2)

    # -- window API ----------------------------------------------------------

    def submit_window(self, cases, batch_size=None) -> _Window:
        """Prep one window and issue EVERY device launch for it (no drains).

        Each case is an ``(image, mask, spacing)`` tuple or a zero-arg
        loader callable; a case that fails to load or validate is
        quarantined (NaN row) instead of killing the window.
        """
        prepped = [self._prep_case_safe(c, fields=self.prune) for c in cases]
        return self.submit_prepped(prepped, batch_size)

    def submit_prepped(self, prepped, batch_size=None) -> _Window:
        """Plan + submit already-prepped cases (the adaptive stream preps
        case by case, so planning must be callable on pass-0 state alone).

        ``schedule='auto'`` resolves here, per window: the cost model
        weighs the modeled sync cost of the counted schedule against the
        static schedule's padded sweeps on this window's census
        (``runtime/costmodel.CostModel.choose_schedule``).
        """
        metas = [self._meta(p) for p in prepped]
        schedule = self.schedule
        if schedule == "auto":
            schedule = self.cost_model.choose_schedule(metas)
        plan = planlib.build_plan(metas, schedule, families=self.families)
        family_futs = self._submit_families(plan, prepped, batch_size)

        mc_futs, diam_futs, fused_futs, aux = [], [], [], []
        t_prune = 0.0
        if not self._shape_on:
            # intensity-only request: the family launches are the window
            return _Window(prepped, plan, mc_futs, diam_futs, fused_futs,
                           aux, t_prune, family_futs)
        if not self.prune:
            fused_entries = [
                (bucket, idxs, self._pool(prepped, idxs))
                for bucket, idxs in plan.fused_groups.items()
            ]
            fused_futs = self._submit(
                fused_entries, self._batch_fn, self._stacked_chunk, batch_size
            )
            return _Window(prepped, plan, mc_futs, diam_futs, fused_futs,
                           aux, t_prune, family_futs)

        # pass 1
        t1 = time.perf_counter()
        if self.device_compact:
            if plan.schedule == "static":
                entries, aux = self._pass1_static(plan, prepped)
            else:
                entries, aux = self._pass1_counted(plan, prepped)
        else:
            self._prune_pass(plan, prepped)
            entries = None
        t_prune = time.perf_counter() - t1

        # pass 2a: staged fused MC per shape bucket, straight off the pools
        mc_entries = [
            (shape, idxs, self._pool(prepped, idxs))
            for shape, idxs in plan.shape_groups.items()
        ]
        mc_futs = self._submit(
            mc_entries, self._mc_fn, self._stacked_chunk, batch_size
        )

        # pass 2b: diameter sweep per pruned vertex bucket
        if entries is not None:
            diam_futs = self._submit(
                entries, self._diam_fn, self._stacked_chunk, batch_size
            )
        else:
            groups = planlib.group_indices(
                [None if p.mask is None else len(p.verts) for p in prepped]
            )
            diam_futs = self._submit(
                ((k, idxs, None) for k, idxs in groups.items()),
                self._diam_fn,
                self._host_chunk(lambda i: (prepped[i].verts, prepped[i].vmask)),
                batch_size,
            )
        return _Window(prepped, plan, mc_futs, diam_futs, [], aux, t_prune,
                       family_futs)

    def resubmit_window(self, window: _Window) -> _Window:
        """Idempotently re-submit a window from its prepped device state.

        The retry path: pass 1 may have overwritten each case's
        ``vertex_cap`` with its pass-2b bucket and attached a
        ``PruneInfo``, so both are reset to the prep-time state (the cap
        is the length of the retained vertex stack) before re-planning --
        the stacks themselves were never mutated, so the re-run is
        bit-identical to a first run (padding invariance, tier-1-locked).
        Quarantined and empty cases pass through untouched.
        """
        for p in window.prepped:
            if p.mask is None or p.error is not None:
                continue
            if p.verts is not None:
                p.vertex_cap = int(p.verts.shape[0])
                p.prune_info = None
        return self.submit_prepped(window.prepped)

    def collect_window(self, window: _Window):
        """Drain one submitted window; returns ``(rows, stats)`` in order.

        With a ``retry`` policy configured (``runtime/resilience.
        RetryPolicy``), a collect failure re-submits the window from its
        prepped device state and re-drains after exponential backoff, up
        to ``max_retries`` times -- a transient device/link fault costs
        one window of recompute, not the run.  ``timeout_s`` is advisory:
        an over-deadline collect is flagged in the stats for the
        straggler census (a blocking fetch cannot be interrupted).
        """
        policy = self.retry
        if policy is None:
            return self._collect_window(window)
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                rows, stats = self._collect_window(window)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                if attempt >= policy.max_retries:
                    raise
                self.window_retries += 1
                time.sleep(policy.delay(attempt))
                window = self.resubmit_window(window)
                attempt += 1
                continue
            dt = time.perf_counter() - t0
            if policy.timeout_s is not None and dt > policy.timeout_s:
                stats["collect_timeout"] = dt
            if attempt:
                stats["window_retries"] = attempt
            return rows, stats

    def _collect_window(self, window: _Window):
        prepped = window.prepped
        # intensity families drain first (they were submitted first);
        # stage names match the family names so transfer_log keeps a
        # per-family sync census and the shape stages' counts are
        # untouched by enabling families
        fam_out = {
            family: self._drain(futs, family)
            for family, futs in window.family_futs.items()
        }

        if window.fused_futs:  # legacy one-pass path
            out = self._drain(window.fused_futs, "pass2")
            rows = [
                self._degenerate_row(p) if p.mask is None
                else self._assemble_row(i, p, np.asarray(out[i], np.float32),
                                        fam_out)
                for i, p in enumerate(prepped)
            ]
            return rows, self._window_stats(window)

        shape_on = self._shape_on
        mc_out = self._drain(window.mc_futs, "pass2a")
        d_out = self._drain(window.diam_futs, "pass2b")
        if window.static_aux:
            self._resolve_static_aux(window, d_out)
        if any(p.n_fut is not None for p in prepped):
            # hint prep: drain the deferred counts, retry overflow cases
            # (AFTER the static aux so a retried row wins over both)
            self._resolve_hint_counts(window, d_out)

        rows = []
        for i, p in enumerate(prepped):
            if p.mask is None:
                rows.append(self._degenerate_row(p))
                continue
            shape_row = None
            if shape_on:
                shape_row = np.concatenate(
                    [np.asarray(mc_out[i], np.float32),
                     np.asarray(d_out[i], np.float32),
                     np.asarray([p.n_vertices], np.float32)]
                )
            rows.append(self._assemble_row(i, p, shape_row, fam_out))
        return rows, self._window_stats(window)

    def _family_row(self, family: str, payload) -> np.ndarray:
        """Finalise one case's fetched device payload into a feature row.

        The shared host-side derivations (numpy, deterministic): packed
        stats -> 9 first-order features, count matrix -> 4 Haralick
        features.  Kept out of the traced launches so batched and
        single-case rows stay bit-identical (see kernels/firstorder.py).
        """
        if family == "firstorder":
            from repro.kernels import firstorder as _fo

            return _fo.features_from_packed_np(payload, self.n_bins)
        from repro.kernels import glcm as _glcm

        return _glcm.glcm_features_from_matrix_np(payload, self.n_bins)

    def _assemble_row(self, i, p, shape_row, fam_out) -> np.ndarray:
        """Concatenate one case's family parts in canonical family order."""
        parts = []
        for family in self.families:
            if family == "shape":
                parts.append(shape_row)
            else:
                parts.append(self._family_row(family, fam_out[family][i]))
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts)

    def _degenerate_row(self, p: _Prepped) -> np.ndarray:
        """Row for a case that ran no launches: zeros (empty mask, the
        degenerate-segmentation contract) or NaNs (quarantined -- the
        row-level error record; the message rides the window stats)."""
        # width derives from the RESOLVED family set, not the shape-only
        # class constant -- a quarantined case in a multi-family run must
        # produce a full-width NaN row or np.stack on the results breaks
        if p.error is not None:
            return np.full(self.n_features, np.nan, np.float32)
        return np.zeros(self.n_features, np.float32)

    def _window_stats(self, window: _Window) -> dict:
        prepped = window.prepped
        infos = [p.prune_info for p in prepped if p.prune_info is not None]
        pruned = [inf for inf in infos if inf.pruned]
        return {
            "families": list(self.families),
            "buckets": len(window.plan.shape_groups),
            "vertex_buckets": len(
                {p.vertex_cap for p in prepped if p.vertex_cap}
            ),
            "pruned_cases": len(pruned),
            "empty_cases": sum(
                1 for p in prepped if p.mask is None and p.error is None
            ),
            "quarantined_cases": sum(1 for p in prepped if p.error is not None),
            "errors": {
                i: p.error for i, p in enumerate(prepped) if p.error is not None
            },
            "mean_keep_fraction": (
                float(np.mean([inf.keep_fraction for inf in infos]))
                if infos else 1.0
            ),
            "prune_seconds": window.t_prune,
            "plan": window.plan.stats(),
        }

    # -- public driving ------------------------------------------------------

    def run(self, cases: Sequence, batch_size: int | None = None):
        """Extract features for (image, mask, spacing) cases (one window).

        Returns a list of ``(row_width(families),)`` rows in input order
        plus throughput stats -- (7,) for the default shape-only request,
        wider when intensity families are enabled (``plan.family_slices``
        maps each family to its columns).
        """
        t0 = time.perf_counter()
        fetches0 = dict(self.transfer_log)
        window = self.submit_window(list(cases), batch_size)
        results, stats = self.collect_window(window)
        dt = time.perf_counter() - t0
        stats.update(
            cases=window.plan.n_cases,
            seconds=dt,
            cases_per_second=window.plan.n_cases / dt if dt > 0 else float("inf"),
            data_parallel=psharding.axis_size(self.mesh, self.data_axis),
            two_pass=self.prune,
            device_compact=self.prune and self.device_compact,
            schedule=self.schedule,  # 'auto' here; plan.schedule = resolved
            prep=self.prep,
            host_fetches={
                k: v - fetches0.get(k, 0)
                for k, v in self.transfer_log.items()
                if v - fetches0.get(k, 0)
            },
        )
        return results, stats

    def extract_stream(self, cases: Iterable, window: int | str = 32,
                       batch_size: int | None = None, stats_callback=None):
        """Streaming front-end: overlap window k+1's prep with window k.

        Consumes an iterator of (image, mask, spacing) cases and yields
        feature rows in input order.  Window k+1 is prepped and its
        launches submitted while the device still executes window k (jax
        dispatch is async); only then is window k drained and yielded.
        ``stats_callback(window_index, plan_stats)`` fires at each
        window's submit with its plan census (buckets, pad waste).

        ``window='auto'`` sizes the windows adaptively from the running
        bucket census and the cost model (``runtime/costmodel``): a new
        shape/cap bucket closes a window early once its current
        sub-batches are all past break-even depth, and homogeneous runs
        extend up to the memory-budgeted cap -- bit-identical rows to any
        fixed window (windowing never changes a feature, tier-1-locked).
        """
        if window == "auto":
            yield from self._stream_auto(cases, batch_size, stats_callback)
            return
        if not isinstance(window, int) or window < 1:
            raise ValueError(
                f"window must be a positive int or 'auto', got {window!r}"
            )
        it = iter(cases)
        pending = None
        widx = 0
        while True:
            chunk = list(itertools.islice(it, window))
            state = None
            if chunk:
                state = self.submit_window(chunk, batch_size)
                if stats_callback is not None:
                    stats_callback(widx, state.plan.stats())
                widx += 1
            if pending is not None:
                rows, _ = self.collect_window(pending)
                yield from rows
            if state is None:
                return
            pending = state

    def _stream_auto(self, cases: Iterable, batch_size=None,
                     stats_callback=None):
        """Adaptive-window streaming: cost-model-decided window boundaries.

        Cases are prepped one by one (prep is per-case work regardless of
        windowing) into an open buffer whose bucket census
        (``plan.WindowCensus``) feeds the close-early decision
        (``CostModel.should_close``).  Submit/collect overlap is the same
        as the fixed-window path: the closed window is submitted BEFORE
        the previous one is drained.
        """
        cm = self.cost_model
        pending = None
        widx = 0
        buf: list = []
        census = planlib.WindowCensus()
        for case in cases:
            p = self._prep_case_safe(case, fields=self.prune)
            meta = self._meta(p)
            if buf and cm.should_close(census, meta):
                state = self.submit_prepped(buf, batch_size)
                if stats_callback is not None:
                    stats_callback(widx, state.plan.stats())
                widx += 1
                buf, census = [], planlib.WindowCensus()
                if pending is not None:
                    rows, _ = self.collect_window(pending)
                    yield from rows
                pending = state
            buf.append(p)
            census.add(meta)
        if buf:
            state = self.submit_prepped(buf, batch_size)
            if stats_callback is not None:
                stats_callback(widx, state.plan.stats())
            if pending is not None:
                rows, _ = self.collect_window(pending)
                yield from rows
            pending = state
        if pending is not None:
            rows, _ = self.collect_window(pending)
            yield from rows

    def extract_one(self, image, mask, spacing):
        """Single-case pruned path: the batched pipeline's parity oracle.

        Runs the identical stages (same bucket padding, pruning, tuned
        configs, kernels) without any batching; returns a
        ``(row_width(families),)`` row -- (7,) for the default shape-only
        request.  Intensity families run at batch depth 1 through the
        same ``ops`` entry points as the batched pipeline (canonical-chunk
        contract: B=1 rows are bit-identical to any batched depth).  An
        empty mask yields zeros, matching the batched contract.  Always
        count-sized: the oracle is the baseline the hint prep must match.
        """
        p = self._prep_case(image, mask, spacing, prep="count")
        if p.mask is None:
            return np.zeros(self.n_features, np.float32)
        parts = []
        for family in self.families:
            if family == "shape":
                if self.prune:
                    p.verts, p.vmask, p.prune_info = ops.prune_candidates(
                        p.verts, p.vmask, k_dirs=self.k_dirs
                    )
                mc_block, mc_chunk = self._resolve_mc(p.shape)
                mc_kw = ({"block": mc_block, "chunk": mc_chunk}
                         if mc_block is not None
                         else {"chunk": mc_chunk}
                         if mc_chunk is not None else {})
                vol, area = ops.mc_volume_area(
                    p.mask, 0.5, p.spacing, backend=self.backend, **mc_kw
                )
                variant, block = self._resolve_diameter(len(p.verts))
                d = ops.max_diameters(
                    p.verts, p.vmask, backend=self.backend, variant=variant,
                    block=block
                )
                parts.append(np.concatenate(
                    [np.asarray([vol, area], np.float32),
                     np.asarray(d, np.float32),
                     np.asarray([p.n_vertices], np.float32)]
                ))
                continue
            blk = self._resolve_family_block(family, p.shape)
            op = (ops.firstorder_packed_batch if family == "firstorder"
                  else ops.glcm_matrix_batch)
            r = op(p.image[None], p.mask[None], backend=self.backend,
                   n_bins=self.n_bins, block=blk)
            parts.append(self._family_row(family, self._fetch(family, r)[0]))
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts)
