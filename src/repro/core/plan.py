"""Plan layer: static extraction plans built from case metadata alone.

The batched pipeline's planning decisions -- shape buckets, vertex-cap
groups, the pass-2b compaction targets -- are pure functions of per-case
*metadata* (ROI shape, spacing, vertex count).  This module isolates them
from execution (``core/executor``): an :class:`ExtractionPlan` is a fully
static description of one window's launches that never touches a device
array, which is what lets the executor dispatch a whole window without
data-dependent control flow.

Two pass-2b bucket schedules:

``schedule='counted'`` (default)
    The exact PR 2/3 behaviour: pass 1 fetches the per-case survivor
    counts ``(m_valid, m_kept)`` and re-buckets each case into
    ``vertex_bucket(m_kept)`` -- the tightest pad, at the cost of ONE
    host sync per cap group sitting between pass 1 and pass 2b.

``schedule='static'``
    The plan picks every cap group's pass-2b target up front:
    :func:`static_bucket` -- the next power-of-two below the cap.  This
    target is *exactly aligned* with the counted path's re-bucketing
    rule: for a power-of-two cap, ``vertex_bucket(m_kept) < cap`` iff
    ``m_kept <= cap // 2``, so every case the counted schedule would
    compact fits the static target with no survivor dropped, and every
    case that would overflow it is precisely a case the counted schedule
    keeps at its original cap anyway.  Pass 1 therefore needs NO
    survivor-count fetch: the executor compacts into the static target
    unconditionally, ships the counts along as a device array, and
    resolves the (rare) keep-originals cases at collect time.  The cost
    is padding: survivors sweep at ``cap // 2`` instead of the tight
    ``vertex_bucket(m_kept)`` bucket.

The module also owns the metadata-only vertex-count hint
(:func:`vertex_hint`): spacing-aware (anisotropic volumes cut more voxel
planes per unit of physical surface), memoised (the hint for a repeated
ROI shape is computed once per process, not per case), and capped at the
volume's total edge count so a degenerate estimate can never allocate a
cap group past what the mesh could physically produce.

Feature-family registry (PR 7): shape is one family of several.  A
:class:`FamilySpec` declares everything the planner and executor need to
schedule a family as a first-class stage -- its feature-row columns (and
therefore its width), whether it consumes the intensity volume, and the
autotune-cache namespace its kernel configurations live under.  A plan
carries the resolved family tuple (:func:`resolve_families`: validated,
canonical registry order, so the row layout is deterministic regardless
of request order), and :func:`row_width` / :func:`family_slices` are the
single source of the feature-row layout -- the quarantine NaN row, the
manifest column names, and every collector concatenation derive from
them instead of hardcoding a width.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

MIN_VERTEX_BUCKET = 512  # the vertex_bucket ladder floor


def vertex_bucket(n: int, minimum: int = MIN_VERTEX_BUCKET) -> int:
    """Static padding cap for a vertex count (limits recompilation).

    The single source of the M-bucket ladder; ``kernels.ops`` re-exports
    it for the kernel-side callers (the plan layer must stay importable
    without touching the kernel modules).
    """
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Static compilation key: padded shape + vertex cap."""

    shape: tuple[int, int, int]
    vertex_cap: int


def _bucket_dim(n: int, step: int = 32) -> int:
    return max(step, int(math.ceil(n / step)) * step)


def shape_bucket(mask_shape, step: int = 32) -> tuple[int, int, int]:
    """Padded shape bucket for an ROI shape (one compile per bucket)."""
    return tuple(_bucket_dim(s + 2, step) for s in mask_shape)


@functools.lru_cache(maxsize=4096)
def _vertex_hint(shape: tuple, spacing: tuple | None) -> int:
    n = 1
    edges = 3
    for s in shape:
        n *= int(s)
        edges *= int(s) + 2
    # ~12 active edges per surface cell; surface cells ~ N^(2/3) for a
    # compact ROI filling a constant fraction of its bounding box
    hint = float(n) ** (2.0 / 3.0) * 12.0
    if spacing is not None:
        # anisotropic spacing: a physical surface patch crosses more voxel
        # planes along the finely-sampled axes.  Scale by the mean
        # per-orientation cell-face density normalised to the isotropic
        # equivalent (AM-GM: >= 1, == 1 for isotropic spacing).
        sx, sy, sz = (float(s) for s in spacing)
        iso2 = (sx * sy * sz) ** (2.0 / 3.0)
        hint *= iso2 * (1.0 / (sy * sz) + 1.0 / (sx * sz) + 1.0 / (sx * sy)) / 3.0
    # a mesh cannot have more vertices than the volume has grid edges
    # (~3 per voxel of the +2-padded field): degenerate hints must not
    # allocate a cap group past that ceiling
    return int(min(hint, edges))


def vertex_hint(mask_shape, spacing=None) -> int:
    """Conservative, memoised active-edge estimate for an ROI shape.

    Used when a plan must be built before the real vertex count exists
    (metadata-only planning); the executor's prep pass replaces it with
    the measured count.  Spacing-aware and capped at the volume's total
    edge count -- see the module docstring.
    """
    sp = None if spacing is None else tuple(round(float(s), 6) for s in spacing)
    return _vertex_hint(tuple(int(s) for s in mask_shape), sp)


def assign_bucket(mask_shape, n_vertices_hint=None, step: int = 32,
                  spacing=None) -> Bucket:
    """(shape bucket, vertex cap) for an ROI shape; hint defaults to
    :func:`vertex_hint` (memoised, spacing-aware)."""
    if n_vertices_hint is None:
        n_vertices_hint = vertex_hint(mask_shape, spacing)
    return Bucket(shape_bucket(mask_shape, step), vertex_bucket(n_vertices_hint))


def static_bucket(cap: int, minimum: int = MIN_VERTEX_BUCKET) -> int | None:
    """Static pass-2b target for a cap group: next power-of-two below it.

    Returns ``None`` when no shrink is possible (the cap is already at
    the bucket floor).  For power-of-two caps this target is exactly the
    counted schedule's win boundary: ``vertex_bucket(m) < cap`` iff
    ``m <= cap // 2`` -- see the module docstring.
    """
    t = cap // 2
    return t if t >= minimum else None


def group_indices(keys: Sequence) -> dict:
    """Partition ``range(len(keys))`` by key, preserving input order.

    The re-bucketing primitive of both passes: every index lands in exactly
    one group (no drops, no duplicates -- property-tested).  ``None`` keys
    (degenerate cases) are excluded from the grouping.
    """
    groups: dict = {}
    for i, k in enumerate(keys):
        if k is not None:
            groups.setdefault(k, []).append(i)
    return groups


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Everything the planner/executor need to schedule one feature family.

    ``features`` fixes the family's feature-row columns (and width);
    ``needs_intensity`` tells prep whether the case must stage an
    intensity volume alongside the mask; ``cache_ns`` is the autotune
    namespace the family's kernel configurations are swept/cached under
    (``<cache_ns>/<backend>/...`` keys -- see ``runtime/autotune``).
    """

    name: str
    features: tuple
    needs_intensity: bool
    cache_ns: str

    @property
    def n_features(self) -> int:
        return len(self.features)


#: Registry order is canonical row order: shape columns always precede
#: first-order columns precede GLCM columns in a multi-family row.
FAMILIES: dict = {
    "shape": FamilySpec(
        name="shape",
        features=(
            "MeshVolume", "SurfaceArea", "Maximum3DDiameter",
            "Maximum2DDiameterSlice", "Maximum2DDiameterRow",
            "Maximum2DDiameterColumn", "n_vertices",
        ),
        needs_intensity=False,
        cache_ns="diameter",  # the shape passes predate the registry; their
        # configs live in the diameter/mc/compact namespaces
    ),
    "firstorder": FamilySpec(
        name="firstorder",
        features=(
            "Mean", "StdDev", "Minimum", "Maximum", "Percentile10",
            "Median", "Percentile90", "Energy", "Entropy",
        ),
        needs_intensity=True,
        cache_ns="firstorder",
    ),
    "glcm": FamilySpec(
        name="glcm",
        features=("Contrast", "Correlation", "Idm", "JointEnergy"),
        needs_intensity=True,
        cache_ns="glcm",
    ),
}

DEFAULT_FAMILIES = ("shape",)


def resolve_families(families=None) -> tuple:
    """Validate a family request and return it in canonical registry order.

    Canonicalising here makes the feature-row layout deterministic
    regardless of request order -- ``("glcm", "shape")`` and
    ``("shape", "glcm")`` produce identical rows.
    """
    if families is None:
        return DEFAULT_FAMILIES
    if isinstance(families, str):
        families = (families,)
    requested = set()
    for f in families:
        if f not in FAMILIES:
            raise ValueError(
                f"unknown feature family {f!r}; registered families: "
                f"{tuple(FAMILIES)}"
            )
        requested.add(f)
    if not requested:
        raise ValueError("at least one feature family is required")
    return tuple(f for f in FAMILIES if f in requested)


def row_width(families=DEFAULT_FAMILIES) -> int:
    """Total feature-row width for a family request."""
    return sum(FAMILIES[f].n_features for f in resolve_families(families))


def family_slices(families=DEFAULT_FAMILIES) -> dict:
    """``{family: slice}`` giving each family's columns in the row."""
    slices, offset = {}, 0
    for f in resolve_families(families):
        n = FAMILIES[f].n_features
        slices[f] = slice(offset, offset + n)
        offset += n
    return slices


def feature_names(families=DEFAULT_FAMILIES) -> tuple:
    """Feature-row column names, in row order, for a family request."""
    return tuple(
        name for f in resolve_families(families) for name in FAMILIES[f].features
    )


def needs_intensity(families=DEFAULT_FAMILIES) -> bool:
    """Does any requested family consume the intensity volume?"""
    return any(FAMILIES[f].needs_intensity for f in resolve_families(families))


@dataclasses.dataclass(frozen=True)
class CaseMeta:
    """Per-case planning metadata (no device data).

    ``shape`` is the padded shape bucket (``None`` marks an empty-mask
    case -- it takes part in no pass and yields a zero feature row);
    ``roi_shape`` the cropped-ROI shape before bucket padding (pad-waste
    accounting); ``vertex_cap`` the pass-1 compaction cap;
    ``n_vertices`` the dedup vertex count (measured, or a
    :func:`vertex_hint` for metadata-only plans); ``intensity`` whether
    the case stages an intensity volume alongside the mask (doubles the
    voxel footprint in :func:`meta_bytes`).
    """

    shape: tuple | None
    roi_shape: tuple | None
    vertex_cap: int
    n_vertices: int
    intensity: bool = False

    @property
    def empty(self) -> bool:
        return self.shape is None


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One planned kernel launch, described structurally (no device data).

    The plan layer's side of the roofline contract: a plan can enumerate
    every launch it implies -- kind, batch depth, vertex bucket / target,
    padded shape -- without importing a kernel module.  Pricing the items
    (FLOPs, bytes, microseconds) is ``repro.runtime.roofline``'s job; the
    split keeps this module importable in metadata-only contexts exactly
    like the rest of the plan layer.

    ``m`` is the launch's vertex bucket (pass-1 input cap for prune and
    compaction, the sweep bucket for the diameter item); ``cap`` the
    compaction OUTPUT bucket (compaction items only); ``shape`` the
    padded volume bucket (MC and intensity-family items only).
    """

    kind: str
    depth: int
    m: int | None = None
    cap: int | None = None
    shape: tuple | None = None


#: WorkItem kinds, one per launch family the executor dispatches.
WORK_KINDS = ("prune", "compact", "diameter", "mc", "firstorder", "glcm")


@dataclasses.dataclass(frozen=True)
class ExtractionPlan:
    """Fully static execution plan for one window of cases.

    ``shape_groups`` keys pass 2a (one fused-MC sub-batch per padded
    shape), ``cap_groups`` keys pass 1 (one bound+compaction chain per
    vertex cap), ``static_targets`` maps each cap group to its pass-2b
    bucket under the static schedule (``None`` target = feed originals;
    empty dict under the counted schedule, where targets come from the
    fetched survivor counts at run time).  ``families`` is the resolved
    (canonical-order) feature-family tuple the window extracts; the
    intensity families launch one batched kernel per shape group,
    sharing the pass-2a shape buckets.
    """

    schedule: str
    metas: tuple
    shape_groups: dict
    cap_groups: dict
    static_targets: dict
    families: tuple = DEFAULT_FAMILIES

    @property
    def n_cases(self) -> int:
        return len(self.metas)

    @property
    def fused_groups(self) -> dict:
        """(shape, cap) ``Bucket`` grouping for the legacy one-pass path."""
        return group_indices(
            [None if m.empty else Bucket(m.shape, m.vertex_cap)
             for m in self.metas]
        )

    def work_census(self) -> tuple:
        """Every kernel launch this plan implies, as :class:`WorkItem` rows.

        Pass 2a contributes one MC item per shape group (plus one item
        per requested intensity family, which shares the shape buckets);
        pass 1 contributes a prune + compaction item per cap group; pass
        2b one diameter item per cap group.  Under the static schedule
        the diameter item sweeps at the plan's aligned target; under the
        counted schedule the survivor buckets are not known until the
        count fetch, so the census prices the conservative pre-compaction
        cap -- an upper bound, which is the useful direction for both the
        window-cost and deadline decisions.
        """
        items = []
        for shape, idxs in self.shape_groups.items():
            if shape is None:
                continue
            depth = len(idxs)
            items.append(WorkItem(kind="mc", depth=depth, shape=shape))
            for fam in self.families:
                if FAMILIES[fam].needs_intensity:
                    items.append(WorkItem(kind=fam, depth=depth, shape=shape))
        for cap, idxs in self.cap_groups.items():
            depth = len(idxs)
            target = self.static_targets.get(cap) or cap
            items.append(WorkItem(kind="prune", depth=depth, m=cap))
            items.append(WorkItem(kind="compact", depth=depth, m=cap,
                                  cap=target))
            sweep = target if self.schedule == "static" else cap
            items.append(WorkItem(kind="diameter", depth=depth, m=sweep))
        return tuple(items)

    def stats(self) -> dict:
        """Plan-level stats: bucket counts + pad-waste fractions.

        ``mask_pad_waste`` is the fraction of padded pass-2a voxels that
        are bucket padding; ``vertex_pad_waste`` the same for pass-1
        vertex slots -- the quantities the static-vs-counted trade-off
        moves (see ROADMAP).
        """
        roi_vox = pad_vox = 0
        n_verts = cap_slots = 0
        for m in self.metas:
            if m.empty:
                continue
            roi_vox += math.prod(m.roi_shape)
            pad_vox += math.prod(m.shape)
            n_verts += m.n_vertices
            cap_slots += m.vertex_cap
        return {
            "schedule": self.schedule,
            "families": list(self.families),
            "cases": self.n_cases,
            "empty_cases": sum(1 for m in self.metas if m.empty),
            "shape_buckets": len(self.shape_groups),
            "cap_buckets": len(self.cap_groups),
            "mask_pad_waste": 1.0 - roi_vox / pad_vox if pad_vox else 0.0,
            "vertex_pad_waste": 1.0 - n_verts / cap_slots if cap_slots else 0.0,
        }


def meta_bytes(meta: CaseMeta) -> int:
    """Device footprint of one planned case: staged mask + vertex stacks.

    f32 mask at the padded shape bucket, plus the (cap, 3) vertex
    coordinates and the (cap,) validity mask -- the arrays pass 0 stages
    and pass 1 consumes.  Metadata-only, so the streaming window budget
    (``runtime/costmodel``) can be enforced before anything is staged.
    """
    if meta.empty:
        return 0
    vox = 4 * math.prod(meta.shape)
    if meta.intensity:
        vox *= 2  # staged f32 intensity volume alongside the mask
    return vox + 16 * meta.vertex_cap


@dataclasses.dataclass
class WindowCensus:
    """Incremental bucket census of an OPEN streaming window.

    The per-window :meth:`ExtractionPlan.stats` census is retrospective;
    this is its running counterpart, updated case by case as the adaptive
    window (``extract_stream(window='auto')``) grows, so the close-early
    decision (``runtime/costmodel.CostModel.should_close``) reads group
    depths and the memory footprint in O(1) per case.  Metadata only --
    a census never touches a device array.
    """

    shape_depths: dict = dataclasses.field(default_factory=dict)
    cap_depths: dict = dataclasses.field(default_factory=dict)
    cases: int = 0
    bytes: int = 0

    def add(self, meta: CaseMeta) -> None:
        self.cases += 1
        self.bytes += meta_bytes(meta)
        if meta.empty:
            return  # empty cases join no pass group (build_plan drops them)
        self.shape_depths[meta.shape] = self.shape_depths.get(meta.shape, 0) + 1
        self.cap_depths[meta.vertex_cap] = (
            self.cap_depths.get(meta.vertex_cap, 0) + 1
        )

    def fragments(self, meta: CaseMeta) -> bool:
        """Would admitting ``meta`` open a NEW shape or cap sub-batch?"""
        if meta.empty:
            return False
        return (meta.shape not in self.shape_depths
                or meta.vertex_cap not in self.cap_depths)


SCHEDULES = ("counted", "static")


def build_plan(metas: Sequence[CaseMeta], schedule: str = "counted",
               families=DEFAULT_FAMILIES) -> ExtractionPlan:
    """Build the static plan for one window from case metadata alone."""
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, got {schedule!r}")
    metas = tuple(metas)
    cap_groups = group_indices([None if m.empty else m.vertex_cap for m in metas])
    return ExtractionPlan(
        schedule=schedule,
        metas=metas,
        shape_groups=group_indices([m.shape for m in metas]),
        cap_groups=cap_groups,
        static_targets=(
            {cap: static_bucket(cap) for cap in cap_groups}
            if schedule == "static" else {}
        ),
        families=resolve_families(families),
    )


def plan_from_metadata(case_shapes, spacings=None, schedule: str = "counted") -> ExtractionPlan:
    """Metadata-only plan: caps come from :func:`vertex_hint`, not counts.

    For sizing/forecasting (pad waste, bucket census) before any mask is
    materialised -- the executor always re-plans from measured counts.
    """
    metas = []
    for i, shp in enumerate(case_shapes):
        sp = None if spacings is None else spacings[i]
        shp = tuple(int(s) for s in shp)
        hint = vertex_hint(shp, sp)
        metas.append(
            CaseMeta(
                shape=shape_bucket(shp),
                roi_shape=tuple(s + 2 for s in shp),
                vertex_cap=vertex_bucket(hint),
                n_vertices=hint,
            )
        )
    return build_plan(metas, schedule)
