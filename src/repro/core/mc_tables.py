"""Marching-cubes lookup tables, generated programmatically.

Instead of transcribing the classic Lorensen-Cline 256x16 triangle table (and
risking silent transcription errors that corrupt volume/area results), we
*derive* the table from first principles with a face-consistent pairing
convention:

  * cube corners / edges use the standard MC numbering,
  * on every cube face the isosurface crosses the face boundary an even number
    of times; crossings are paired so that each connection "hugs" only
    *negative* (outside) corners along the CCW walk of the face boundary
    (CCW w.r.t. the outward face normal).  This rule depends only on the
    face's own corner signs, so the two cells sharing a face always agree
    => the global mesh is watertight by construction.
  * connections are *directed* so the inside region lies on the left when
    walking the face with its outward normal up; tracing the directed
    connections yields oriented polygon loops whose fan triangulation has
    outward-pointing normals (verified at generation time).

The ambiguous-face resolution ("separate the positive corners") matches the
behaviour required for closed meshes; it may differ from PyRadiomics' fixed
table on ambiguous configurations (diagonally-touching voxels), which is a
documented implementation choice, not an error -- PyRadiomics' own table is
known to produce non-watertight meshes on those cases.

Exports
-------
CORNERS : (8,3) int  corner offsets within a cell
EDGES   : (12,2) int corner pairs per edge
TRI_TABLE : (256, 3*MAX_TRIS) int32, edge ids per triangle slot, -1 padded
N_TRIS  : (256,) int32 number of triangles per case
MAX_TRIS : int
EDGE_CELL_OFFSET / EDGE_CELL_AXIS : canonical-edge mapping used to dedupe
    mesh vertices into three dense per-axis vertex fields.
"""
from __future__ import annotations

import numpy as np

# Standard MC corner numbering: bottom z=0 ring 0-1-2-3, top z=1 ring 4-5-6-7.
CORNERS = np.array(
    [
        [0, 0, 0],
        [1, 0, 0],
        [1, 1, 0],
        [0, 1, 0],
        [0, 0, 1],
        [1, 0, 1],
        [1, 1, 1],
        [0, 1, 1],
    ],
    dtype=np.int32,
)

EDGES = np.array(
    [
        [0, 1], [1, 2], [2, 3], [3, 0],          # bottom ring
        [4, 5], [5, 6], [6, 7], [7, 4],          # top ring
        [0, 4], [1, 5], [2, 6], [3, 7],          # verticals
    ],
    dtype=np.int32,
)

# Canonical ("owned") edge mapping: every cube edge of cell (i,j,k) is the
# x/y/z-directed grid edge anchored at a grid point.  EDGE_CELL_AXIS[e] gives
# the direction (0=x,1=y,2=z); EDGE_CELL_OFFSET[e] the anchor offset from the
# cell origin.  Used to build dense, duplicate-free vertex fields.
EDGE_CELL_AXIS = np.array([0, 1, 0, 1, 0, 1, 0, 1, 2, 2, 2, 2], dtype=np.int32)
EDGE_CELL_OFFSET = np.array(
    [
        [0, 0, 0],  # e0  x-edge @ (i,j,k)
        [1, 0, 0],  # e1  y-edge @ (i+1,j,k)
        [0, 1, 0],  # e2  x-edge @ (i,j+1,k)
        [0, 0, 0],  # e3  y-edge @ (i,j,k)
        [0, 0, 1],  # e4  x-edge @ (i,j,k+1)
        [1, 0, 1],  # e5  y-edge @ (i+1,j,k+1)
        [0, 1, 1],  # e6  x-edge @ (i,j+1,k+1)
        [0, 0, 1],  # e7  y-edge @ (i,j,k+1)
        [0, 0, 0],  # e8  z-edge @ (i,j,k)
        [1, 0, 0],  # e9  z-edge @ (i+1,j,k)
        [1, 1, 0],  # e10 z-edge @ (i+1,j+1,k)
        [0, 1, 0],  # e11 z-edge @ (i,j+1,k)
    ],
    dtype=np.int32,
)


def _edge_id(c0: int, c1: int) -> int:
    for e, (a, b) in enumerate(EDGES):
        if (a, b) == (c0, c1) or (a, b) == (c1, c0):
            return e
    raise ValueError(f"no edge between corners {c0},{c1}")


def _faces():
    """Yield (corner ids CCW w.r.t outward normal, outward normal)."""
    faces = []
    for axis in range(3):
        for side in (0, 1):
            ids = [c for c in range(8) if CORNERS[c][axis] == side]
            normal = np.zeros(3)
            normal[axis] = 1.0 if side == 1 else -1.0
            center = CORNERS[ids].mean(axis=0)
            # build right-handed (u, v, normal) basis
            u = np.zeros(3)
            u[(axis + 1) % 3] = 1.0
            v = np.cross(normal, u)
            ang = []
            for c in ids:
                d = CORNERS[c] - center
                ang.append(np.arctan2(np.dot(d, v), np.dot(d, u)))
            order = [ids[i] for i in np.argsort(ang)]
            faces.append((order, normal))
    return faces


_FACES = _faces()


def _case_connections(inside: np.ndarray):
    """Directed (edge_from -> edge_to) connections for one sign case."""
    conns = []
    for order, _normal in _FACES:
        s = [bool(inside[c]) for c in order]
        # boundary slot i = edge between corner order[i] and order[i+1]
        crossings = [i for i in range(4) if s[i] != s[(i + 1) % 4]]
        if not crossings:
            continue
        eids = [_edge_id(order[i], order[(i + 1) % 4]) for i in range(4)]
        if len(crossings) == 2:
            a, b = crossings
            # corners strictly inside the CCW arc a->b are order[a+1..b]
            arc_ab = [(a + t) % 4 for t in range(1, (b - a) % 4 + 1)]
            if all(not s[i] for i in arc_ab):
                conns.append((eids[a], eids[b]))
            else:
                conns.append((eids[b], eids[a]))
        elif len(crossings) == 4:
            # Alternating signs (ambiguous face).  Pair the crossings that
            # hug each *positive* corner, isolating the positive corners --
            # the 'separate the positives' resolution.  Applied to the face
            # values it is symmetric between the two sharing cells, so the
            # global mesh stays watertight, and unlike the opposite choice it
            # produces no degenerate in-plane neck triangles.  Direction per
            # the general rule: the CCW arc of the directed connection
            # contains only negative corners, i.e. walk the long way around.
            for i in range(4):
                hugged = (i + 1) % 4
                if s[hugged]:
                    conns.append((eids[(i + 1) % 4], eids[i]))
        else:  # pragma: no cover - impossible for a 4-cycle of signs
            raise AssertionError("odd number of face crossings")
    return conns


def _edge_midpoint(e: int) -> np.ndarray:
    a, b = EDGES[e]
    return (CORNERS[a] + CORNERS[b]) / 2.0


# face membership of each cube edge (set of face indices), used to avoid
# fan-triangulating a loop into triangles that lie flat inside a cube face
# (those can coincide with the neighbour cell's triangles).
_EDGE_FACES = [
    frozenset(
        fi
        for fi, (order, _n) in enumerate(_FACES)
        if set(EDGES[e]).issubset(set(order))
    )
    for e in range(12)
]


def _fan(loop):
    """Fan-triangulate a loop, choosing the root that avoids in-face tris."""

    def tris_for_root(r):
        n = len(loop)
        rot = loop[r:] + loop[:r]
        return [(rot[0], rot[i], rot[i + 1]) for i in range(1, n - 1)]

    def n_coplanar(tris):
        return sum(
            1
            for (a, b, c) in tris
            if _EDGE_FACES[a] & _EDGE_FACES[b] & _EDGE_FACES[c]
        )

    best = min((tris_for_root(r) for r in range(len(loop))), key=n_coplanar)
    return best


def _generate():
    tri_lists = []
    for case in range(256):
        inside = np.array([(case >> c) & 1 for c in range(8)], dtype=bool)
        conns = _case_connections(inside)
        succ = {}
        heads = set()
        for f, t in conns:
            assert f not in succ, f"case {case}: edge {f} has two outgoing"
            assert t not in heads, f"case {case}: edge {t} has two incoming"
            succ[f] = t
            heads.add(t)
        assert set(succ) == heads, f"case {case}: open curve"
        # trace directed loops
        tris = []
        remaining = dict(succ)
        while remaining:
            start = min(remaining)
            loop = [start]
            nxt = remaining.pop(start)
            while nxt != start:
                loop.append(nxt)
                nxt = remaining.pop(nxt)
            assert len(loop) >= 3, f"case {case}: degenerate loop {loop}"
            tris.extend(_fan(loop))
        tri_lists.append(tris)

    # Fix global orientation sign using the 8 single-corner cases: the fan
    # normal must point away from the inside corner.
    flips = []
    for c in range(8):
        case = 1 << c
        (a, b, d) = tri_lists[case][0]
        pa, pb, pd = _edge_midpoint(a), _edge_midpoint(b), _edge_midpoint(d)
        n = np.cross(pb - pa, pd - pa)
        outward = pa - CORNERS[c]  # from inside corner toward the patch
        flips.append(float(np.dot(n, outward)) < 0)
    assert len(set(flips)) == 1, "inconsistent orientation across corner cases"
    if flips[0]:
        tri_lists = [[(a, d, b) for (a, b, d) in tris] for tris in tri_lists]

    max_tris = max(len(t) for t in tri_lists)
    table = np.full((256, max_tris * 3), -1, dtype=np.int32)
    ntris = np.zeros(256, dtype=np.int32)
    for case, tris in enumerate(tri_lists):
        ntris[case] = len(tris)
        for i, (a, b, d) in enumerate(tris):
            table[case, 3 * i : 3 * i + 3] = (a, b, d)
    return table, ntris, max_tris


TRI_TABLE, N_TRIS, MAX_TRIS = _generate()

# Bitmask of active edges per case (edge crossed by the isosurface).
EDGE_ACTIVE = np.zeros((256, 12), dtype=bool)
for _case in range(256):
    _ins = [( _case >> c) & 1 for c in range(8)]
    for _e, (_a, _b) in enumerate(EDGES):
        EDGE_ACTIVE[_case, _e] = _ins[_a] != _ins[_b]
