"""Batched, device-parallel radiomics feature pipeline (the HPC story).

The paper's motivating workload is extracting features from ~40 000 CT scans
on a cluster (xLUNGS).  Single-case GPU offload (Table 2) is step one; this
module is step two: **throughput across cases**.

Design:
  * cases are bucketed by padded volume shape and vertex cap, so each bucket
    compiles once;
  * inside a bucket, cases are stacked and mapped with ``jax.lax.map`` over
    the batch (sequential per device, the kernels already saturate a chip);
  * with a mesh, the batch axis is sharded over the ``data`` axis via
    ``shard_map`` -- N chips process N cases concurrently, the multi-pod
    extension the paper's conclusion calls for;
  * host->device feeding is double-buffered with ``jax.device_put`` so the
    transfer of batch i+1 overlaps the compute of batch i (the paper notes
    DMA/transfer overlap as the open opportunity).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dispatcher
from repro.core.shape_features import crop_to_roi
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Static compilation key: padded shape + vertex cap."""

    shape: tuple[int, int, int]
    vertex_cap: int


def _bucket_dim(n: int, step: int = 32) -> int:
    return max(step, int(math.ceil(n / step)) * step)


def assign_bucket(mask_shape, n_vertices_hint=None, step=32) -> Bucket:
    shape = tuple(_bucket_dim(s + 2, step) for s in mask_shape)
    if n_vertices_hint is None:
        # conservative: active edges ~ surface cells; cap by total edges
        n_vertices_hint = int(np.prod(mask_shape) ** (2 / 3) * 12)
    return Bucket(shape, ops.vertex_bucket(n_vertices_hint))


def _features_one(mask, spacing, vertex_cap, backend, variant, block=None):
    vol, area = ops.mc_volume_area(mask, 0.5, spacing, backend=backend)
    fields = ops.vertex_fields(mask, 0.5, spacing)
    verts, vmask, n = ops.compact_vertices(fields, vertex_cap)
    d = ops.max_diameters(
        verts, vmask, backend=backend, variant=variant, block=block
    )
    return jnp.concatenate(
        [jnp.stack([vol, area]), d, jnp.asarray([n], jnp.float32)]
    )  # (7,)


class BatchedExtractor:
    """Vectorised multi-case extraction, optionally sharded over a mesh.

    ``variant='auto'`` (default) resolves the measured-best diameter
    (variant, block) once per bucket from the autotune cache -- the whole
    batch then compiles against the tuned configuration.  (Exact vertex
    pruning is a single-case optimisation: batched shapes are static, so
    the O(M'^2) saving cannot be realised inside ``lax.map``.)
    """

    N_FEATURES = 7  # [vol, area, d3, dxy, dxz, dyz, n_vertices]

    def __init__(self, backend=None, variant="auto", mesh: Mesh | None = None,
                 data_axis: str = "data"):
        self.backend = dispatcher.resolve_backend(backend)
        self.variant = variant
        self.mesh = mesh
        self.data_axis = data_axis
        self._compiled = {}

    def _batch_fn(self, bucket: Bucket):
        if bucket in self._compiled:
            return self._compiled[bucket]
        backend, variant = self.backend, self.variant
        cap = bucket.vertex_cap
        block = None
        if backend != "ref":
            # resolve the tuned config OUTSIDE the traced function: the
            # sweep runs real kernels and must not happen mid-trace
            variant, block = dispatcher.diameter_config(backend, cap, variant)

        def one(args):
            mask, spacing = args
            return _features_one(mask, spacing, cap, backend, variant, block)

        def batch(masks, spacings):
            return jax.lax.map(one, (masks, spacings))

        if self.mesh is not None:
            axis = self.data_axis
            mesh = self.mesh
            batch_sharded = jax.jit(
                batch,
                in_shardings=(
                    NamedSharding(mesh, P(axis)),
                    NamedSharding(mesh, P(axis)),
                ),
                out_shardings=NamedSharding(mesh, P(axis)),
            )
            fn = batch_sharded
        else:
            fn = jax.jit(batch)
        self._compiled[bucket] = fn
        return fn

    def run(self, cases: Sequence[tuple[np.ndarray, np.ndarray, np.ndarray]],
            batch_size: int | None = None):
        """Extract features for (image, mask, spacing) cases.

        Returns a list of (7,) arrays in input order plus throughput stats.
        Cases are grouped per bucket; each group is padded to a multiple of
        the mesh's data-axis size so shard_map shapes stay uniform.
        """
        n_data = 1
        if self.mesh is not None:
            n_data = self.mesh.shape[self.data_axis]
        groups: dict[Bucket, list[int]] = {}
        prepped = []
        for i, (img, mask, spacing) in enumerate(cases):
            _, m, _ = crop_to_roi(img, mask)
            b = assign_bucket(tuple(s - 2 for s in m.shape))
            pad = [(0, bs - ms) for bs, ms in zip(b.shape, m.shape)]
            prepped.append((np.pad(m, pad), np.asarray(spacing, np.float32)))
            groups.setdefault(b, []).append(i)

        results: list[np.ndarray | None] = [None] * len(cases)
        t0 = time.perf_counter()
        for bucket, idxs in groups.items():
            fn = self._batch_fn(bucket)
            bs = batch_size or max(n_data, len(idxs))
            bs = int(math.ceil(bs / n_data)) * n_data
            # double-buffered feeding: device_put batch k+1 while k computes
            pending = None
            for s in range(0, len(idxs), bs):
                chunk = idxs[s : s + bs]
                masks = np.stack(
                    [prepped[i][0] for i in chunk]
                    + [prepped[chunk[0]][0]] * (bs - len(chunk))
                )
                sps = np.stack(
                    [prepped[i][1] for i in chunk]
                    + [prepped[chunk[0]][1]] * (bs - len(chunk))
                )
                fut = fn(jnp.asarray(masks), jnp.asarray(sps))
                if pending is not None:
                    done_idx, done_fut = pending
                    out = np.asarray(done_fut)
                    for j, i in enumerate(done_idx):
                        results[i] = out[j]
                pending = (chunk, fut)
            if pending is not None:
                done_idx, done_fut = pending
                out = np.asarray(done_fut)
                for j, i in enumerate(done_idx):
                    results[i] = out[j]
        dt = time.perf_counter() - t0
        stats = {
            "cases": len(cases),
            "seconds": dt,
            "cases_per_second": len(cases) / dt if dt > 0 else float("inf"),
            "buckets": len(groups),
            "data_parallel": n_data,
        }
        return results, stats
