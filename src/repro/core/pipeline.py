"""Batched, device-parallel radiomics feature pipeline (the HPC story).

The paper's motivating workload is extracting features from ~40 000 CT scans
on a cluster (xLUNGS).  Single-case GPU offload (Table 2) is step one; this
module is step two: **throughput across cases**.

Design (the two-pass pruned pipeline, ``prune=True``, the default):

  * **pass 1 (one vmapped bound kernel + one compaction kernel per cap
    group):** every case is cropped, padded to its shape bucket, and its
    deduplicated vertex field compacted to the static vertex cap; cases
    sharing a cap are then stacked and the *exact* pruning bound
    (``kernels/prune``) runs as a single vmapped kernel over the stack,
    shrinking each candidate set M -> M' (typically 10-30x) with
    guaranteed-identical maxima.  With ``device_compact=True`` (the
    default) the survivors are then compacted into their M' buckets ON
    DEVICE by the batched segmented-compaction kernel
    (``kernels/compact``): the only host traffic pass 1 produces is one
    small (B,) count fetch per cap group (to size the ragged M' buckets),
    and the bucketed ``(verts, vmask)`` stacks stay device-resident all
    the way into pass 2b -- no per-case ``np.asarray``/``np.nonzero``
    round trip between the passes.  ``device_compact=False`` keeps the
    PR 2 host-side compaction (bit-identical features; the parity
    baseline).  With a mesh, the bound + compaction launches shard over
    the ``data`` axis (``parallel.sharding.data_parallel_map``), so pass 1
    scales over devices exactly like pass 2;
  * **pass 2 (re-bucketed batched kernels):** cases are re-grouped twice --
    by padded volume shape for the fused marching-cubes kernel and by the
    *pruned* vertex bucket M' for the O(M'^2) diameter kernel -- so each
    sub-batch compiles once against the pruned candidate set.  This brings
    the single-case pruning win to the batch: the pair sweep costs
    (M'/M)^2 of the unpruned batched pipeline's dominant stage;
  * both passes resolve the measured-best kernel configuration per bucket
    from the autotune cache (``runtime/autotune``): the diameter
    (variant, block) for the M' bucket and the marching-cubes
    (brick, chunk) for the shape bucket, resolved OUTSIDE the traced
    functions;
  * inside a sub-batch, cases are stacked and mapped with ``jax.lax.map``
    (sequential per device, the kernels already saturate a chip); with a
    mesh, the batch axis is sharded over the ``data`` axis -- N chips
    process N cases concurrently, the multi-pod extension the paper's
    conclusion calls for;
  * host->device feeding is double-buffered with ``jax.device_put`` so the
    transfer of batch i+1 overlaps the compute of batch i (the paper notes
    DMA/transfer overlap as the open opportunity);
  * empty-mask cases yield an all-zero feature row instead of raising: a
    40k-case sweep must not die on one degenerate segmentation (the
    single-case ``ShapeFeatureExtractor`` keeps its strict ValueError).

``prune=False`` selects the legacy one-pass pipeline (one fused per-case
function per bucket, no pruning) -- kept as the benchmark baseline.

Parity contract: ``extract_one`` runs the identical stages case-by-case
(same padding, same pruning bound, same tuned configs, same kernels) and is
the oracle the batched path is property-tested against -- batching may
never change a feature value.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dispatcher
from repro.core.shape_features import crop_to_roi
from repro.kernels import ops
from repro.kernels import prune as prune_kernels
from repro.parallel import sharding as psharding


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Static compilation key: padded shape + vertex cap."""

    shape: tuple[int, int, int]
    vertex_cap: int


def _bucket_dim(n: int, step: int = 32) -> int:
    return max(step, int(math.ceil(n / step)) * step)


def assign_bucket(mask_shape, n_vertices_hint=None, step=32) -> Bucket:
    shape = tuple(_bucket_dim(s + 2, step) for s in mask_shape)
    if n_vertices_hint is None:
        # conservative: active edges ~ surface cells; cap by total edges
        n_vertices_hint = int(np.prod(mask_shape) ** (2 / 3) * 12)
    return Bucket(shape, ops.vertex_bucket(n_vertices_hint))


def group_indices(keys: Sequence) -> dict:
    """Partition ``range(len(keys))`` by key, preserving input order.

    The re-bucketing primitive of both passes: every index lands in exactly
    one group (no drops, no duplicates -- property-tested).  ``None`` keys
    (degenerate cases) are excluded from the grouping.
    """
    groups: dict = {}
    for i, k in enumerate(keys):
        if k is not None:
            groups.setdefault(k, []).append(i)
    return groups


@dataclasses.dataclass
class _Prepped:
    """Pass-1 host-side state for one case (None mask = empty-mask case)."""

    mask: np.ndarray | None = None  # bucket-padded mask
    spacing: np.ndarray | None = None
    shape: tuple | None = None  # padded shape bucket (MC group key)
    verts: object | None = None  # (pruned) candidates; jax.Array when the
    vmask: object | None = None  # device-compaction path keeps them resident
    n_vertices: int = 0  # pre-prune dedup vertex count (a feature)
    vertex_cap: int = 0  # static M' bucket the diameter kernel compiles for
    prune_info: object | None = None


@jax.jit
def _fields_count(mask, spacing):
    """Pass-1a compute: dedup vertex fields + active count, one compile per
    shape bucket (the eager per-op path costs ~10x on a cold sweep)."""
    fields = ops.vertex_fields(mask, 0.5, spacing)
    return fields, ops.count_vertices(fields)


@functools.partial(jax.jit, static_argnames=("cap",))
def _compact_cap(fields, cap: int):
    verts, vmask, _ = ops.compact_vertices(fields, cap)
    return verts, vmask


def _features_one(mask, spacing, vertex_cap, backend, variant, block=None,
                  mc_block=None, mc_chunk=None):
    mc_kw = {} if mc_block is None else {"block": mc_block, "chunk": mc_chunk}
    vol, area = ops.mc_volume_area(mask, 0.5, spacing, backend=backend, **mc_kw)
    fields = ops.vertex_fields(mask, 0.5, spacing)
    verts, vmask, n = ops.compact_vertices(fields, vertex_cap)
    d = ops.max_diameters(
        verts, vmask, backend=backend, variant=variant, block=block
    )
    return jnp.concatenate(
        [jnp.stack([vol, area]), d, jnp.asarray([n], jnp.float32)]
    )  # (7,)


class BatchedExtractor:
    """Vectorised multi-case extraction, optionally sharded over a mesh.

    ``prune=True`` (default) runs the two-pass pruned pipeline described in
    the module docstring; ``prune=False`` the legacy one-pass path.
    ``device_compact=True`` (default) keeps pass 1's survivor compaction on
    device (``kernels/compact``); ``device_compact=False`` selects the PR 2
    host-side compaction -- bit-identical features, kept as the parity
    baseline.  ``variant='auto'`` / ``mc_block='auto'`` /
    ``compact_block='auto'`` resolve the measured-best diameter
    (variant, block), MC (brick, chunk), and compaction scatter block once
    per bucket from the autotune cache -- each sub-batch then compiles
    against the tuned configuration.  ``mesh`` defaults to the ambient
    ``parallel.sharding.use_mesh`` context.
    """

    N_FEATURES = 7  # [vol, area, d3, dxy, dxz, dyz, n_vertices]

    def __init__(self, backend=None, variant="auto", mesh: Mesh | None = None,
                 data_axis: str = "data", prune: bool = True,
                 mc_block="auto", mc_chunk: int | None = None,
                 k_dirs: int = 16, device_compact: bool = True,
                 compact_block="auto"):
        self.backend = dispatcher.resolve_backend(backend)
        self.variant = variant
        if mesh is None:
            # adopt the ambient use_mesh mesh only when it can actually
            # shard the batch: train/serve meshes without a data axis must
            # not turn a working CPU pipeline into a KeyError
            ambient = psharding.active_mesh()
            if ambient is not None and data_axis in ambient.shape:
                mesh = ambient
        self.mesh = mesh
        self.data_axis = data_axis
        self.prune = prune
        self.mc_block = mc_block
        self.mc_chunk = mc_chunk
        self.k_dirs = k_dirs
        self.device_compact = device_compact
        self.compact_block = compact_block
        self._compiled = {}

    # -- compiled-function cache -------------------------------------------

    def _shard_jit(self, batch_fn):
        if self.mesh is None:
            return jax.jit(batch_fn)
        sh = NamedSharding(self.mesh, P(self.data_axis))
        return jax.jit(batch_fn, in_shardings=(sh, sh), out_shardings=sh)

    def _resolve_mc(self, shape):
        """Tuned MC (brick, chunk) for a shape bucket, outside any trace."""
        if self.backend == "ref":
            return None, None
        return dispatcher.mc_config(
            self.backend, shape, self.mc_block, self.mc_chunk
        )

    def _resolve_diameter(self, cap):
        """Tuned diameter (variant, block) for a vertex cap, outside traces."""
        if self.backend == "ref":
            return self.variant, None
        return dispatcher.diameter_config(self.backend, cap, self.variant)

    def _bound_fn(self, cap: int):
        """Pass 1b: sharded vmapped pruning bound + survivor counts.

        Maps stacked ``(B, cap, 3)`` verts + ``(B, cap)`` masks to
        ``(keep, m_valid, m_kept)``; with a mesh the batch shards over the
        data axis (``data_parallel_map`` is a plain jit without one).
        """
        key = ("prune_bound", cap)
        if key in self._compiled:
            return self._compiled[key]
        k_dirs = self.k_dirs

        def batch(verts, masks):
            keep, _ = prune_kernels.keep_mask_batch(verts, masks, k_dirs)
            m_valid = jnp.sum(masks.astype(jnp.int32), axis=1)
            m_kept = jnp.sum(keep.astype(jnp.int32), axis=1)
            # counts ride out pre-stacked (B, 2) so the host fetch is one
            # transfer with no eager stitching (batch dim first: shardable)
            return keep, jnp.stack([m_valid, m_kept], axis=1)

        fn = psharding.data_parallel_map(batch, self.mesh, self.data_axis)
        self._compiled[key] = fn
        return fn

    def _compact_fn(self, cap_in: int, cap_out: int):
        """Pass 1c: sharded batched segmented compaction into the M' bucket."""
        key = ("compact", cap_in, cap_out)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        # resolve the tuned scatter block OUTSIDE the traced function
        block = (
            None if backend == "ref"
            else dispatcher.compact_config(backend, cap_in, self.compact_block)
        )

        def batch(verts, keep):
            v, m, _ = ops.compact_survivors_batch(
                verts, keep, cap_out, backend=backend, block=block
            )
            return v, m

        fn = psharding.data_parallel_map(batch, self.mesh, self.data_axis)
        self._compiled[key] = fn
        return fn

    def _pad_batch(self, arrays, n: int):
        """Pad stacked leading dims to a data-axis multiple (first-row copies)."""
        n_data = 1 if self.mesh is None else self.mesh.shape[self.data_axis]
        np_ = int(math.ceil(max(n, 1) / n_data)) * n_data
        if np_ == n:
            return arrays
        return tuple(
            jnp.concatenate([a, jnp.repeat(a[:1], np_ - n, axis=0)])
            for a in arrays
        )

    def _batch_fn(self, bucket: Bucket):
        """Legacy one-pass fused per-case function (``prune=False``)."""
        key = ("one_pass", bucket)
        if key in self._compiled:
            return self._compiled[key]
        backend, cap = self.backend, bucket.vertex_cap
        variant, block = self._resolve_diameter(cap)
        mc_block, mc_chunk = self._resolve_mc(bucket.shape)

        def one(args):
            mask, spacing = args
            return _features_one(mask, spacing, cap, backend, variant, block,
                                 mc_block, mc_chunk)

        def batch(masks, spacings):
            return jax.lax.map(one, (masks, spacings))

        fn = self._shard_jit(batch)
        self._compiled[key] = fn
        return fn

    def _mc_fn(self, shape):
        """Pass-2a: batched fused MC volume+area for one shape bucket."""
        key = ("mc", shape)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        mc_block, mc_chunk = self._resolve_mc(shape)
        mc_kw = {} if mc_block is None else {"block": mc_block, "chunk": mc_chunk}

        def one(args):
            mask, spacing = args
            vol, area = ops.mc_volume_area(
                mask, 0.5, spacing, backend=backend, **mc_kw
            )
            return jnp.stack([vol, area])

        def batch(masks, spacings):
            return jax.lax.map(one, (masks, spacings))

        fn = self._shard_jit(batch)
        self._compiled[key] = fn
        return fn

    def _diam_fn(self, cap):
        """Pass-2b: batched diameter sweep for one (pruned) vertex bucket."""
        key = ("diam", cap)
        if key in self._compiled:
            return self._compiled[key]
        backend = self.backend
        variant, block = self._resolve_diameter(cap)

        def one(args):
            verts, vmask = args
            return ops.max_diameters(
                verts, vmask, backend=backend, variant=variant, block=block
            )

        def batch(verts, vmasks):
            return jax.lax.map(one, (verts, vmasks))

        fn = self._shard_jit(batch)
        self._compiled[key] = fn
        return fn

    # -- batching driver ----------------------------------------------------

    def _drive(self, entries, fn_for_key, make_chunk, batch_size=None):
        """Shared double-buffered batch driver for both pass-2 feeds.

        ``entries`` yields ``(compile key, case indices, payload)``;
        ``make_chunk(payload, start, chunk, bs)`` materialises the stacked
        input arrays for one chunk, padded up to ``bs`` rows.  Batch sizes
        are rounded to a multiple of the mesh's data-axis size so
        shard_map shapes stay uniform; the submit of batch k+1 overlaps
        the compute of batch k.  Returns ``{case index: np row}`` -- each
        input index exactly once.
        """
        n_data = 1
        if self.mesh is not None:
            n_data = self.mesh.shape[self.data_axis]
        out: dict[int, np.ndarray] = {}

        def drain(pending):
            idx, fut = pending
            o = np.asarray(fut)
            for j, i in enumerate(idx):
                out[i] = o[j]

        for gkey, idxs, payload in entries:
            fn = fn_for_key(gkey)
            bs = batch_size or max(n_data, len(idxs))
            bs = int(math.ceil(bs / n_data)) * n_data
            pending = None
            for s in range(0, len(idxs), bs):
                chunk = idxs[s : s + bs]
                fut = fn(*make_chunk(payload, s, chunk, bs))
                if pending is not None:
                    drain(pending)
                pending = (chunk, fut)
            if pending is not None:
                drain(pending)
        return out

    def _run_grouped(self, groups, fn_for_key, arrays_for_case,
                     batch_size=None):
        """Grouped batch driver over host per-case arrays.

        ``groups`` maps a compile key to case indices; ``arrays_for_case``
        returns the per-case input arrays to stack.  Chunks are padded
        with copies of their first element.
        """

        def make_chunk(_, s, chunk, bs):
            filled = chunk + [chunk[0]] * (bs - len(chunk))
            cols = zip(*(arrays_for_case(i) for i in filled))
            return tuple(jnp.asarray(np.stack(c)) for c in cols)

        return self._drive(
            ((k, idxs, None) for k, idxs in groups.items()),
            fn_for_key, make_chunk, batch_size,
        )

    def _run_stacked(self, entries, fn_for_key, batch_size=None):
        """Driver over PRE-STACKED device groups (the device pass-2b feed).

        ``entries`` is the pass-1 device output: ``(key, idxs, arrays)``
        tuples whose ``arrays`` are stacked device arrays with leading dim
        >= len(idxs) (mesh padding rows, if any, are simply never read).
        Chunks are sliced straight off the device stacks -- no host
        re-stacking between the passes.
        """

        def make_chunk(arrays, s, chunk, bs):
            sl = tuple(a[s : s + len(chunk)] for a in arrays)
            if len(chunk) < bs:
                sl = tuple(
                    jnp.concatenate(
                        [a, jnp.repeat(a[:1], bs - len(chunk), axis=0)]
                    )
                    for a in sl
                )
            return sl

        return self._drive(entries, fn_for_key, make_chunk, batch_size)

    # -- pass 1 -------------------------------------------------------------

    def _prep_case(self, image, mask, spacing) -> _Prepped:
        """Crop, bucket-pad, and compact one case's vertex field (pass 1a)."""
        sp = np.asarray(spacing, np.float32)
        if not np.any(mask):
            return _Prepped(spacing=sp)  # empty mask: all-zero feature row
        _, m, _ = crop_to_roi(image, mask)
        b = assign_bucket(tuple(s - 2 for s in m.shape))
        pad = [(0, bs - ms) for bs, ms in zip(b.shape, m.shape)]
        mp = np.pad(m, pad)
        fields, n = _fields_count(jnp.asarray(mp), jnp.asarray(sp))
        n = int(n)
        cap = ops.vertex_bucket(n)
        verts, vmask = _compact_cap(fields, cap)
        if not self.device_compact:  # PR 2 host path: pull to numpy per case
            verts, vmask = np.asarray(verts), np.asarray(vmask)
        return _Prepped(
            mask=mp, spacing=sp, shape=b.shape,
            verts=verts, vmask=vmask, n_vertices=n, vertex_cap=cap,
        )

    def _prune_pass(self, prepped: list[_Prepped]):
        """Pass 1b (host path): vmapped bound + per-case host compaction."""
        cap_groups = group_indices(
            [None if p.mask is None else len(p.verts) for p in prepped]
        )
        for _, idxs in cap_groups.items():
            batch = ops.prune_candidates_batch(
                np.stack([prepped[i].verts for i in idxs]),
                np.stack([prepped[i].vmask for i in idxs]),
                k_dirs=self.k_dirs,
            )
            for i, (v2, m2, info) in zip(idxs, batch):
                prepped[i].verts, prepped[i].vmask = v2, m2
                prepped[i].vertex_cap = len(v2)
                prepped[i].prune_info = info

    def _prune_pass_device(self, prepped: list[_Prepped]):
        """Pass 1b+1c (device path): sharded bound + on-device compaction.

        Per original-cap group, ONE (sharded) vmapped bound launch computes
        every keep mask, one small (B,) count fetch sizes the ragged M'
        buckets, and one (sharded) batched segmented-compaction launch per
        target bucket scatters the survivors -- the vertex data itself
        never leaves the device.  Decisions (pruned or keep-originals) come
        from ``prune.plan_compaction``, the same rule the host path
        composes, so the two paths stay bit-identical.

        Returns the pass-2b feed: ``[(M' bucket, case indices, (verts,
        vmask) stacks)]`` -- already-bucketed device stacks the diameter
        sweep consumes directly (``_run_stacked``), which is what lets the
        two passes pipeline with no host re-stacking in between.
        """
        entries = []
        cap_groups = group_indices(
            [None if p.mask is None else len(p.verts) for p in prepped]
        )
        for cap, idxs in cap_groups.items():
            b = len(idxs)
            verts, masks = self._pad_batch(
                (
                    jnp.stack([prepped[i].verts for i in idxs]),
                    jnp.stack([prepped[i].vmask for i in idxs]),
                ),
                b,
            )
            keep, counts = self._bound_fn(cap)(verts, masks)
            # the one host sync of pass 1: a small (B, 2) count matrix
            counts = np.asarray(counts)
            plans = [
                prune_kernels.plan_compaction(
                    cap, int(counts[j, 0]), int(counts[j, 1]),
                    ops.vertex_bucket,
                )
                for j in range(b)
            ]
            for j, i in enumerate(idxs):
                prepped[i].prune_info = plans[j][1]
                prepped[i].vertex_cap = plans[j][0] or cap
            # keep-originals cases feed pass 2 at their input cap
            groups = group_indices(
                [cap_out if cap_out else ("orig", cap) for cap_out, _ in plans]
            )
            for gkey, js in groups.items():
                # whole cap group agreeing on one target reuses the stacks
                take = (
                    None if len(js) == b
                    else jnp.asarray(np.asarray(js, np.int32))
                )

                def sub(*arrays):
                    if take is None:
                        return arrays
                    return self._pad_batch(
                        tuple(jnp.take(a, take, axis=0) for a in arrays),
                        len(js),
                    )

                gidxs = [idxs[j] for j in js]
                if isinstance(gkey, tuple):  # unpruned: originals, input cap
                    entries.append((cap, gidxs, sub(verts, masks)))
                    continue
                cv, cm = self._compact_fn(cap, gkey)(*sub(verts, keep))
                entries.append((gkey, gidxs, (cv, cm)))
        return entries

    # -- public API ---------------------------------------------------------

    def extract_one(self, image, mask, spacing):
        """Single-case pruned path: the batched pipeline's parity oracle.

        Runs the identical stages (same bucket padding, pruning, tuned
        configs, kernels) without any batching; returns a (7,) row.  An
        empty mask yields zeros, matching the batched contract.
        """
        p = self._prep_case(image, mask, spacing)
        if p.mask is None:
            return np.zeros(self.N_FEATURES, np.float32)
        if self.prune:
            p.verts, p.vmask, p.prune_info = ops.prune_candidates(
                p.verts, p.vmask, k_dirs=self.k_dirs
            )
        mc_block, mc_chunk = self._resolve_mc(p.shape)
        mc_kw = {} if mc_block is None else {"block": mc_block, "chunk": mc_chunk}
        vol, area = ops.mc_volume_area(
            p.mask, 0.5, p.spacing, backend=self.backend, **mc_kw
        )
        variant, block = self._resolve_diameter(len(p.verts))
        d = ops.max_diameters(
            p.verts, p.vmask, backend=self.backend, variant=variant, block=block
        )
        return np.concatenate(
            [np.asarray([vol, area], np.float32), np.asarray(d, np.float32),
             np.asarray([p.n_vertices], np.float32)]
        )

    def run(self, cases: Sequence[tuple[np.ndarray, np.ndarray, np.ndarray]],
            batch_size: int | None = None):
        """Extract features for (image, mask, spacing) cases.

        Returns a list of (7,) arrays in input order plus throughput stats.
        """
        t0 = time.perf_counter()
        if self.prune:
            results, stats = self._run_two_pass(cases, batch_size)
        else:
            results, stats = self._run_one_pass(cases, batch_size)
        dt = time.perf_counter() - t0
        n_data = 1
        if self.mesh is not None:
            n_data = self.mesh.shape[self.data_axis]
        stats.update(
            cases=len(cases),
            seconds=dt,
            cases_per_second=len(cases) / dt if dt > 0 else float("inf"),
            data_parallel=n_data,
            two_pass=self.prune,
            device_compact=self.prune and self.device_compact,
        )
        return results, stats

    def _run_two_pass(self, cases, batch_size):
        # pass 1: prep + vmapped pruning bound + (device) compaction
        prepped = [self._prep_case(*c) for c in cases]
        t1 = time.perf_counter()
        if self.device_compact:
            entries = self._prune_pass_device(prepped)
        else:
            self._prune_pass(prepped)
        t_prune = time.perf_counter() - t1

        # pass 2a: fused MC per shape bucket
        mc_out = self._run_grouped(
            group_indices([p.shape for p in prepped]),
            self._mc_fn,
            lambda i: (prepped[i].mask, prepped[i].spacing),
            batch_size,
        )
        # pass 2b: diameter sweep per pruned vertex bucket -- the device
        # path consumes pass 1's already-bucketed stacks directly
        if self.device_compact:
            d_out = self._run_stacked(entries, self._diam_fn, batch_size)
        else:
            d_out = self._run_grouped(
                group_indices(
                    [None if p.mask is None else len(p.verts) for p in prepped]
                ),
                self._diam_fn,
                lambda i: (prepped[i].verts, prepped[i].vmask),
                batch_size,
            )

        results = []
        for i, p in enumerate(prepped):
            if p.mask is None:
                results.append(np.zeros(self.N_FEATURES, np.float32))
                continue
            results.append(
                np.concatenate(
                    [np.asarray(mc_out[i], np.float32),
                     np.asarray(d_out[i], np.float32),
                     np.asarray([p.n_vertices], np.float32)]
                )
            )
        infos = [p.prune_info for p in prepped if p.prune_info is not None]
        pruned = [inf for inf in infos if inf.pruned]
        stats = {
            "buckets": len({p.shape for p in prepped if p.shape is not None}),
            "vertex_buckets": len(
                {p.vertex_cap for p in prepped if p.vertex_cap}
            ),
            "pruned_cases": len(pruned),
            "empty_cases": sum(1 for p in prepped if p.mask is None),
            "mean_keep_fraction": (
                float(np.mean([inf.keep_fraction for inf in infos]))
                if infos else 1.0
            ),
            "prune_seconds": t_prune,
        }
        return results, stats

    def _run_one_pass(self, cases, batch_size):
        prepped = []
        buckets = []
        for img, mask, spacing in cases:
            sp = np.asarray(spacing, np.float32)
            if not np.any(mask):
                prepped.append((None, sp))
                buckets.append(None)
                continue
            _, m, _ = crop_to_roi(img, mask)
            b = assign_bucket(tuple(s - 2 for s in m.shape))
            pad = [(0, bs - ms) for bs, ms in zip(b.shape, m.shape)]
            prepped.append((np.pad(m, pad), sp))
            buckets.append(b)

        out = self._run_grouped(
            group_indices(buckets),
            self._batch_fn,
            lambda i: prepped[i],
            batch_size,
        )
        results = [
            np.zeros(self.N_FEATURES, np.float32) if buckets[i] is None
            else np.asarray(out[i], np.float32)
            for i in range(len(cases))
        ]
        stats = {
            "buckets": len({b for b in buckets if b is not None}),
            "vertex_buckets": len(
                {b.vertex_cap for b in buckets if b is not None}
            ),
            "pruned_cases": 0,
            "empty_cases": sum(1 for b in buckets if b is None),
            "mean_keep_fraction": 1.0,
            "prune_seconds": 0.0,
        }
        return results, stats
