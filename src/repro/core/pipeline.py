"""Batched, device-parallel radiomics feature pipeline: the public facade.

The paper's motivating workload is extracting features from ~40 000 CT
scans on a cluster (xLUNGS).  Single-case GPU offload (Table 2) is step
one; this layer is the throughput story -- and since PR 4 it is split in
two, with this module as the thin public surface:

* ``core/plan``     -- the PLAN layer: shape buckets, cap groups, the
  pass schedule and the static pass-2b targets, all pure functions of
  per-case metadata (never touches a device array);
* ``core/executor`` -- the EXECUTOR layer: runs a plan with a
  device-resident data plane for both passes, plus the streaming
  front-end.

Data flow of one window (``PlanExecutor.submit_window`` /
``collect_window``)::

      cases ──► pass 0: crop + bucket-pad + STAGE mask on device ──┐
                (dedup vertex fields + count; cap = M bucket)      │
                                                                   ▼
                       ┌──────────────── bucket-keyed device pools ┐
                       │  masks (per shape bucket)    verts/vmask  │
                       └───────┬───────────────────────────┬───────┘
                               │                           │
              pass 2a ◄────────┘            pass 1 ────────┘
          fused MC batch                sharded bound + segmented
        (device stacks, no       compaction per cap group
         host re-stacking)          │ 'counted': (B,2) count fetch
                               │    │   sizes ragged M' buckets
                               │    │ 'static': counts stay ON DEVICE,
                               │    │   compact into cap//2 target
                               │    ▼
                               │  pass 2b: diameter sweep per M' bucket
                               ▼    (device stacks from pass 1)
                            collect: drain rows; static schedule resolves
                            its deferred counts here and re-sweeps the
                            rare keep-originals cases at their input cap

Schedules (``schedule=``):

* ``'counted'`` (default): the PR 3 behaviour -- tightest M' buckets,
  one (B, 2) host sync per cap group between pass 1 and pass 2b;
* ``'static'``: sync-free pass 1 -> 2b dispatch chain.  The plan picks
  each cap group's target as the next power-of-two below the cap, which
  is *exactly* the counted schedule's re-bucketing win boundary
  (``plan.static_bucket``), so the two schedules are bit-identical
  (tier-1-locked) -- static trades padded pair-sweep work (cap//2 vs
  the tight bucket) for zero pass-1 syncs, the right trade for
  streaming and for high-latency links (measured numbers in ROADMAP);
* ``'auto'``: resolved per window by the cost model
  (``runtime/costmodel``) from the calibrated ``sync/<backend>`` d2h
  probe and the window's bucket census -- counted on a zero-latency
  local device, static when the modeled sync cost outweighs the
  padding (either way bit-identical, since the schedules are).

Prep (``prep=``): ``'count'`` (default) fetches each case's dedup vertex
count to size its M cap -- one ``int(n)`` host sync per case;
``'hint'`` sizes caps from ``plan.vertex_hint`` metadata alone (pass 0
becomes sync-free; the true count rides to the collector on device, and
a hint-overflow case re-runs count-sized at collect time).  Bit-identical
to ``'count'``, tier-1-locked.

Front-ends:

* ``run(cases)`` / ``extract_batch(cases)`` -- one window, results +
  stats;
* ``extract_stream(cases, window=...)`` -- dataset-level streaming:
  host prep of window k+1 overlaps device execution of window k, rows
  yielded in input order (the cluster scenario of the paper's
  conclusion; see ``examples/cluster_pipeline.py``);
* ``extract_one`` -- the single-case parity oracle: identical stages,
  no batching; batching may never change a feature value (tier-1).

Feature families (PR 7) -- the multi-family registry
(``plan.FAMILIES``): a feature row is the canonical-order concatenation
of the requested families' parts, selected with ``families=``:

* ``'shape'`` (default) -- the 7 mesh features above (MC volume/area,
  diameters, vertex count);
* ``'firstorder'`` -- 9 intensity statistics (``kernels/firstorder``):
  the case's IMAGE volume rides pass 0 to the device next to its mask,
  and one batched stats launch per shape bucket joins the submit window
  (sync-free: it drains with its own ``'firstorder'`` transfer stage,
  never adding a prep/pass-1 sync);
* ``'glcm'`` -- 4 Haralick texture features (``kernels/glcm``) off the
  same staged intensity pool (one matrix launch per bucket, its own
  ``'glcm'`` drain stage).

Each family ships a reference oracle and a Pallas kernel with a locked
parity contract (first-order: bitwise via the canonical-chunk fold;
GLCM: integer-exact count matrices), and an ``<family>/<backend>``
autotune namespace for its launch block.  Row layout is a pure function
of the requested set (``plan.family_slices`` / ``plan.feature_names``);
batched, streamed, and single-case extraction stay bit-identical per
family.  Quarantined cases degrade to full-width NaN rows.

Legacy paths kept as parity baselines: ``prune=False`` (one-pass fused
pipeline), ``device_compact=False`` (PR 2 host-side compaction).
Empty-mask cases yield all-zero rows instead of raising: a 40k-case
sweep must not die on one degenerate segmentation.

Resilience (``runtime/resilience``) -- the layer that makes the 40k-case
cluster run *survivable*, not just fast:

* **manifest format**: ``RunManifest`` is an atomic append-only JSONL
  file, one record per case, keyed by a CONTENT hash of the mask bytes +
  spacing (``{"id", "name", "status": "done"|"error", "features"|
  "error", "window"}``).  ``resume()`` rebuilds the done-set, repairing
  a torn tail (a record cut mid-write by a kill) by truncating back to
  the last complete line; ``record`` is idempotent (an id already done
  is never written twice).
* **quarantine semantics**: every case entering ``submit_window`` /
  ``extract_stream`` may be a tuple or a lazy loader callable; a case
  that fails to load or validate (e.g. a NaN-poisoned mask) degrades to
  a row-level error -- an all-NaN feature row plus an ``errors`` entry
  in the window stats -- and the remaining cases of the window are
  bit-identical to a run without it (tier-1-locked).  Empty masks stay
  all-zero ``done`` rows.  With a ``retry`` policy, a collect-time
  fault re-submits the window from its prepped device state with
  exponential backoff (``resubmit_window``; bit-identical re-run).
* **resume guarantees**: a run preempted mid-stream (SIGTERM via
  ``PreemptionHandler``) and resumed produces a manifest record-set
  bit-identical to an uninterrupted run, with zero lost and zero
  duplicated ids, redoing at most ONE window of work (the in-flight
  window; rows already committed are skipped by the done-set).  Proved
  by ``tests/test_resilience.py`` (tier-1) and soaked at scale by
  ``benchmarks/soak.py``.

Out-of-core tiling (``core/tiled``, PR 9) -- the path for volumes that
do not fit the device (or even the host): a case may be a
``data.tiles.TiledCase`` -- a pair of z-slab SOURCES (windowed NIfTI
reads, in-memory arrays, or analytic generators) instead of materialized
volumes.  The tiled engine runs the census prepass, cuts the padded
frame into halo-exchanged z-tiles of whole marching-cubes granules, and
re-folds per-tile partials in the in-core accumulation order, so the
row is bit-identical to ``extract_one`` on any size both paths can run
(tier-1-locked; ``tile_prune='bounds'`` relaxes only the ref-backend
diameters to f32 rounding, the same contract as vertex pruning).
Hierarchical tile pruning skips empty tiles outright and skips vertex
work for tiles provably excluded from every farthest-pair combo.
Routing: a ``TiledCase`` always takes this path; with ``tiled=True``,
ordinary tuple cases whose staged frame would exceed the tile budget
(``tile_mem_mb`` / ``REPRO_TILE_MEM_MB``) are converted and routed too.
``run`` merges tiled rows back in input order; ``extract_stream`` flushes
the surrounding in-core segments around each tiled case (inter-segment
prep overlap is sacrificed -- tiled cases are assumed rare and huge;
within a tiled case, tile k+1's device work is dispatched before tile
k's partials are drained).  Surviving-tile metadata feeds the same
``plan.WindowCensus`` machinery the cost model reads.

Serving (``serve/service``, PR 8) -- the persistent multi-tenant front
door over the same windows (``serve()`` below returns the service):

* **API**: concurrent clients call ``submit(cases, tenant=...,
  deadline_s=..., block=...)`` (single or batch; tuples or loader
  callables) and get a ``ServeFuture``; ``future.result()`` returns the
  request's rows in ITS OWN input order plus a per-case ``errors`` map.
  One driver thread owns all device work and fuses queued cases across
  tenants into shared windows with the same ``plan.WindowCensus`` +
  ``CostModel.should_close`` the stream uses -- served rows are
  bit-identical to ``extract_stream`` on the same cases (tier-1).
* **deadline semantics**: ``deadline_s`` is relative to submit.  While a
  case is still QUEUED its request may expire: it then completes with a
  ``DeadlineExceeded`` error row and never occupies a window slot, and
  co-tenant cases sharing its windows are untouched.  Once a case is
  admitted to a window it is always delivered (``ServeResult.late``
  marks overruns); ``CostModel.deadline_at_risk`` -- the first
  latency-vs-throughput decision -- closes the open window early when
  its modeled cost (sync + diameter tables, x2 safety) threatens the
  oldest pending deadline, making late delivery rare.
* **backpressure**: admission is bounded by estimated queued bytes
  (``plan.meta_bytes`` over uncropped metadata, a conservative
  over-estimate); a full queue blocks the submitter or raises
  ``ServiceOverloaded`` (``block=False``), so bursts cannot OOM the
  staging host.  Quarantine semantics are the executor's, reported per
  request index.  ``benchmarks/serve_latency.py`` gates mixed-traffic
  p50/p99 + throughput; ``python -m repro.launch.serve`` is the CLI.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np
from jax.sharding import Mesh

# re-exported planning primitives (public API since PR 1-3)
from repro.core import plan as planlib
from repro.core.executor import PlanExecutor
from repro.core.plan import (  # noqa: F401  (re-exports)
    Bucket,
    assign_bucket,
    group_indices,
)
from repro.core.tiled import TiledExtractor
from repro.data.tiles import TiledCase


class BatchedExtractor:
    """Vectorised multi-case extraction, optionally sharded over a mesh.

    The public facade over ``plan.build_plan`` + ``executor.PlanExecutor``
    (see the module docstring for the architecture).  ``prune=True``
    (default) runs the two-pass pruned pipeline; ``prune=False`` the
    legacy one-pass path.  ``device_compact=True`` (default) keeps pass
    1's survivor compaction on device; ``device_compact=False`` selects
    the PR 2 host-side compaction -- bit-identical features, kept as the
    parity baseline.  ``schedule='static'`` removes the pass-1 count
    sync (bit-identical to ``'counted'``, tier-1-locked);
    ``schedule='auto'`` lets the cost model pick per window.
    ``prep='hint'`` removes the last per-case pass-0 sync (hint-sized
    caps, overflow retried at collect; bit-identical to ``'count'``).
    ``variant='auto'`` / ``mc_block='auto'`` / ``compact_block='auto'``
    resolve the measured-best kernel configurations per (bucket,
    batch-depth) from the autotune cache.  ``mesh`` defaults to the
    ambient ``parallel.sharding.use_mesh`` context.  ``retry`` takes a
    ``runtime/resilience.RetryPolicy`` for backed-off per-window retry;
    failed/poisoned cases quarantine as NaN rows (see the module
    docstring's Resilience section).  ``families`` selects the feature
    families (name, sequence of names, or None for shape-only; see the
    module docstring) and sets the row width ``self.n_features``;
    ``n_bins`` is the intensity discretisation the firstorder/glcm
    families share.
    """

    N_FEATURES = PlanExecutor.N_FEATURES

    def __init__(self, backend=None, variant="auto", mesh: Mesh | None = None,
                 data_axis: str = "data", prune: bool = True,
                 mc_block="auto", mc_chunk: int | None = None,
                 k_dirs: int = 16, device_compact: bool = True,
                 compact_block="auto", schedule: str = "counted",
                 prep: str = "count", transfer_callback=None, retry=None,
                 families=None, n_bins: int = 32, tiled: bool = False,
                 tile_prune: str = "bounds",
                 tile_mem_mb: float | None = None):
        self.executor = PlanExecutor(
            backend=backend, variant=variant, mesh=mesh, data_axis=data_axis,
            prune=prune, mc_block=mc_block, mc_chunk=mc_chunk, k_dirs=k_dirs,
            device_compact=device_compact, compact_block=compact_block,
            schedule=schedule, prep=prep, transfer_callback=transfer_callback,
            retry=retry, families=families, n_bins=n_bins,
        )
        ex = self.executor
        self.tiled = bool(tiled)
        self.tile_prune = tile_prune
        self._tile_budget = (None if tile_mem_mb is None
                             else int(tile_mem_mb * 2**20))
        self._tiledx = None  # built on first tiled case (family-validated)
        self.families = ex.families
        self.n_features = ex.n_features
        self.n_bins = ex.n_bins
        self.backend = ex.backend
        self.variant = ex.variant
        self.mesh = ex.mesh
        self.data_axis = ex.data_axis
        self.prune = ex.prune
        self.device_compact = ex.device_compact
        self.schedule = ex.schedule
        self.prep = ex.prep

    @property
    def cost_model(self):
        """The executor's decision layer (``runtime/costmodel.CostModel``)."""
        return self.executor.cost_model

    @property
    def tiled_extractor(self) -> TiledExtractor:
        """The lazily-built out-of-core engine (``core/tiled``)."""
        if self._tiledx is None:
            self._tiledx = TiledExtractor(
                self.executor, budget_bytes=self._tile_budget,
                tile_prune=self.tile_prune,
            )
        return self._tiledx

    def _route_tiled(self, case) -> bool:
        """Should ``case`` take the out-of-core path?

        A ``TiledCase`` always does (constructing one is the opt-in).
        With ``tiled=True``, a materialized tuple whose staged frame
        (mask + optional intensity, f32) would exceed the tile budget is
        converted too; loader callables stay in-core -- their shape is
        unknown until loaded (the serving layer's header peek handles
        byte estimation separately).
        """
        if isinstance(case, TiledCase):
            return True
        if not self.tiled:
            return False
        if not (isinstance(case, (tuple, list)) and len(case) == 3):
            return False
        mask = np.asarray(case[1])
        if mask.ndim != 3:
            return False
        staged = 4 * mask.size * (1 + int(self.executor._needs_intensity))
        return staged > self.tiled_extractor.budget_bytes

    def _as_tiled(self, case) -> TiledCase:
        if isinstance(case, TiledCase):
            return case
        image, mask, spacing = case
        return TiledCase(mask, image=image, spacing=spacing)

    def extract_tiled(self, case):
        """Run one case through the out-of-core tiled engine.

        Accepts a ``TiledCase`` or an ``(image, mask, spacing)`` tuple;
        returns its ``core.tiled.TiledResult`` (row + census metadata +
        tile stats).
        """
        return self.tiled_extractor.extract(self._as_tiled(case))

    def run(self, cases: Sequence, batch_size: int | None = None):
        """Extract features for (image, mask, spacing) cases (one window).

        Returns a list of ``(self.n_features,)`` arrays in input order
        plus throughput stats ((7,) for the default shape-only request).
        Cases routed out-of-core (see ``_route_tiled``) run through the
        tiled engine and merge back in input order; their surviving-tile
        metadata joins the stats as a ``plan.WindowCensus``.
        """
        cases = list(cases)
        tiled_idx = [i for i, c in enumerate(cases) if self._route_tiled(c)]
        if not tiled_idx:
            return self.executor.run(cases, batch_size)
        incore = [c for i, c in enumerate(cases) if i not in set(tiled_idx)]
        if incore:
            rows, stats = self.executor.run(incore, batch_size)
        else:
            rows, stats = [], {"cases": 0}
        rows = list(rows)
        census = planlib.WindowCensus()
        tile_stats = []
        for i in tiled_idx:
            res = self.tiled_extractor.extract(self._as_tiled(cases[i]))
            rows.insert(i, res.row)
            census.add(res.meta)
            tile_stats.append(res.stats)
        stats = dict(stats)
        stats["tiled"] = {
            "cases": len(tiled_idx),
            "census": census,
            "tiles": sum(s.get("tiles", 0) for s in tile_stats),
            "tiles_skipped": sum(s.get("tiles_skipped", 0)
                                 for s in tile_stats),
            "tiles_bounds_pruned": sum(s.get("tiles_bounds_pruned", 0)
                                       for s in tile_stats),
        }
        return rows, stats

    def extract_batch(self, cases: Sequence, batch_size: int | None = None):
        """Alias of :meth:`run`: one window of the streaming machinery."""
        return self.run(cases, batch_size)

    def extract_stream(self, cases: Iterable, window: int | str = 32,
                       batch_size: int | None = None, stats_callback=None):
        """Stream (image, mask, spacing) cases; yield rows in input order.

        Host prep (load + crop + pad + bucket) of window k+1 overlaps
        device execution of window k; ``stats_callback(i, plan_stats)``
        reports each window's plan census (buckets, pad waste) at submit
        time.  ``run`` is one window of this machinery.
        ``window='auto'`` sizes windows adaptively from the running
        bucket census and the cost model (bit-identical rows to any
        fixed window).

        Out-of-core cases (``TiledCase`` instances, or oversized tuples
        with ``tiled=True``) are handled between in-core segments: the
        preceding segment is flushed through the windowed machinery,
        then the tiled case runs (tile-level submit/collect overlap),
        then streaming resumes.  Rows still arrive in input order;
        prep overlap ACROSS a tiled boundary is sacrificed.
        """
        # validate eagerly: an all-tiled (or empty) stream would otherwise
        # never reach the executor's own check
        if window != "auto" and (not isinstance(window, int) or window < 1):
            raise ValueError(
                f"window must be a positive int or 'auto', got {window!r}"
            )

        def _segments():
            seg = []
            for case in cases:
                if self._route_tiled(case):
                    if seg:
                        yield False, seg
                        seg = []
                    yield True, case
                else:
                    seg.append(case)
            if seg:
                yield False, seg

        def _gen():
            for is_tiled, item in _segments():
                if is_tiled:
                    yield self.tiled_extractor.extract(
                        self._as_tiled(item)).row
                else:
                    yield from self.executor.extract_stream(
                        item, window=window, batch_size=batch_size,
                        stats_callback=stats_callback,
                    )

        return _gen()

    def extract_one(self, image, mask, spacing):
        """Single-case parity oracle (identical stages, no batching)."""
        return self.executor.extract_one(image, mask, spacing)

    def serve(self, *, max_queue_bytes: float | None = None,
              idle_tick_s: float = 0.002):
        """Start the persistent multi-tenant service over this extractor.

        Returns a running ``serve.service.ExtractionService`` (also a
        context manager): concurrent clients ``submit()`` cases and the
        driver fuses them across tenants into shared windows, honouring
        per-request deadlines and the queue-byte backpressure budget.
        See the module docstring's Serving section for the semantics.
        """
        from repro.serve.service import ExtractionService

        return ExtractionService(
            self, max_queue_bytes=max_queue_bytes, idle_tick_s=idle_tick_s,
        )
