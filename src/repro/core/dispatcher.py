"""Backend dispatch: the TPU analogue of PyRadiomics-cuda's GPU probe.

The paper's C extension replaces one call site with a dispatcher that
queries for a CUDA device at runtime and falls back to the original CPU
implementation when none is found (or the driver fails).  Here:

    'pallas'    -- compiled Pallas TPU kernels (requires a TPU backend)
    'interpret' -- the same kernels executed in Pallas interpret mode
                   (Python/CPU; used for validation in this container)
    'ref'       -- the pure-jnp reference path (the 'original CPU
                   implementation' role)
    'auto'      -- probe: TPU present -> 'pallas', else 'ref'

``REPRO_BACKEND`` overrides 'auto' (like CUDA_VISIBLE_DEVICES-style control).
Every backend returns identical features (tested), so switching is
transparent to callers -- the paper's key compatibility property.
"""
from __future__ import annotations

import os
from typing import Literal

import jax

Backend = Literal["auto", "pallas", "interpret", "ref"]
_VALID = ("auto", "pallas", "interpret", "ref")


def has_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover - no backend at all
        return False


def resolve_backend(backend: Backend | None = None) -> str:
    """Resolve 'auto' to a concrete backend, honouring REPRO_BACKEND."""
    if backend is None:
        backend = os.environ.get("REPRO_BACKEND", "auto")  # type: ignore
    if backend not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {backend!r}")
    if backend != "auto":
        return backend
    return "pallas" if has_tpu() else "ref"


def kernel_kwargs(backend: str) -> dict:
    """kwargs forwarded to the Pallas wrappers for a resolved backend."""
    if backend == "pallas":
        return {"interpret": False}
    if backend == "interpret":
        return {"interpret": True}
    raise ValueError(f"not a kernel backend: {backend!r}")


def diameter_config(backend: str, bucket: int, variant: str = "auto",
                    block: int | None = None, batch: int = 1):
    """Resolve the (variant, block) the diameter kernel should run with.

    ``variant='auto'`` consults the measured autotune cache for the
    (vertex bucket, batch-depth bucket) pair -- the plan-aware key: the
    executor passes the sub-batch depth a launch will actually carry
    (``repro.runtime.autotune``).  Explicit values pass through, and an
    explicitly passed ``block`` always wins over the tuned one.  For the
    'ref' backend the choice is moot and defaults are returned.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    if variant != "auto":
        return variant, (block or autotune.DEFAULT_CONFIG.block)
    cfg = autotune.get_diameter_config(int(bucket), backend, batch=batch)
    return cfg.variant, (block or cfg.block)


def compact_config(backend: str, bucket: int, block="auto",
                   batch: int = 1) -> int:
    """Resolve the segmented-compaction scatter block for an M bucket.

    ``block='auto'`` consults the measured autotune cache for the (input
    vertex bucket, batch-depth bucket) pair (``repro.runtime.autotune``);
    explicit values pass through.  For the 'ref' backend the choice is
    moot and the default is returned.  Like the other config resolvers
    this may run a measuring sweep, so call it OUTSIDE any traced
    function.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    if block is not None and block != "auto":
        return int(block)
    if backend == "ref":
        return autotune.DEFAULT_COMPACT_CONFIG.block
    return autotune.get_compact_config(int(bucket), backend, batch=batch).block


def firstorder_config(backend: str, shape, block="auto",
                      batch: int = 1) -> int:
    """Resolve the first-order reduction block for a padded-volume bucket.

    ``block='auto'`` consults the ``firstorder/<backend>`` autotune-cache
    namespace for the (volume bucket, batch-depth bucket) pair; explicit
    values pass through.  For the 'ref' backend the choice is moot and
    the default is returned.  May run a measuring sweep, so call it
    OUTSIDE any traced function.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    if block is not None and block != "auto":
        return int(block)
    if backend == "ref":
        return autotune.DEFAULT_FIRSTORDER_CONFIG.block
    return autotune.get_family_config(
        "firstorder", autotune.mc_shape_bucket(shape), backend, batch=batch
    ).block


def glcm_config(backend: str, shape, block="auto", batch: int = 1) -> int:
    """Resolve the GLCM pair-scatter block for a padded-volume bucket.

    Same contract as :func:`firstorder_config`, against the
    ``glcm/<backend>`` autotune-cache namespace.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    if block is not None and block != "auto":
        return int(block)
    if backend == "ref":
        return autotune.DEFAULT_GLCM_CONFIG.block
    return autotune.get_family_config(
        "glcm", autotune.mc_shape_bucket(shape), backend, batch=batch
    ).block


def sync_cost(backend: str, cache=None) -> float:
    """Resolve the modeled per-fetch d2h latency (microseconds).

    Consults the ``sync/<backend>`` autotune-cache entry (the one-time
    measured probe; ``repro.runtime.autotune.get_sync_cost``), falling
    back to the documented default when no calibration exists and
    probing is disallowed.  Unlike the kernel-config resolvers this is
    meaningful for EVERY backend including 'ref' -- the sync cost
    belongs to the device link, not to a kernel.  May run the measuring
    probe, so call it OUTSIDE any traced function.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    return autotune.get_sync_cost(backend, cache=cache)


def hw_profile(backend: str, cache=None) -> dict | None:
    """Resolve the backend's hardware roofline profile (or ``None``).

    Consults the ``hw/<backend>`` autotune-cache entry (the one-time
    measured peak-FLOP/s + memory-bandwidth probe;
    ``repro.runtime.autotune.get_hw_profile``), falling back to the
    static per-backend default when no calibration exists and probing is
    disallowed.  ``None`` means no profile exists at all -- an unknown
    backend string, or ``REPRO_ROOFLINE=0`` -- and the cost model then
    uses its analytic constant.  Like :func:`sync_cost` this is
    meaningful for every backend, and may run the measuring probe, so
    call it OUTSIDE any traced function.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    return autotune.get_hw_profile(backend, cache=cache)


def mc_config(backend: str, shape, block="auto", chunk: int | None = None,
              batch: int = 1):
    """Resolve the (brick, chunk) the marching-cubes kernel should run with.

    ``block='auto'`` consults the measured autotune cache for the
    (padded-volume bucket of ``shape``, batch-depth bucket) pair
    (``repro.runtime.autotune``); explicit values pass through, and an
    explicitly passed ``chunk`` always wins over the tuned one.  For the
    'ref' backend the choice is moot and defaults are returned.  Like
    ``diameter_config`` this may run a measuring sweep, so call it
    OUTSIDE any traced function.
    """
    from repro.runtime import autotune  # local import: avoid cycle

    if block is not None and block != "auto":
        return tuple(block), int(chunk or autotune.DEFAULT_MC_CONFIG.chunk)
    if backend == "ref":
        cfg = autotune.DEFAULT_MC_CONFIG
    else:
        cfg = autotune.get_mc_config(
            autotune.mc_shape_bucket(shape), backend, batch=batch
        )
    return cfg.block, int(chunk or cfg.chunk)
