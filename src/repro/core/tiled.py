"""Out-of-core tiled extraction: halo tiles, tile pruning, streamed diameter.

The layer between the slab loaders (``data/tiles.py``) and the
plan/executor: extracts the same feature row as the in-core pipeline for
a volume that never materializes on host or device.  The executor still
owns backends, tuned configs and the oracle sequence -- this engine only
re-partitions pass 0..2 into z-tiles and re-folds the partials in the
in-core order.

Data flow (one case)
--------------------
1. **Census prepass** (host, streamed): global nonzero/inside bounding
   boxes, per-plane occupancy + xy boxes, the masked intensity range
   (exact min/max -- order-invariant), and for ``tile_prune='bounds'``
   the K-direction extreme inside-voxels the tile bound needs.
2. **Frame replication**: the in-core pipeline crops to the mask bbox,
   pads by one zero plane (``crop_to_roi``) and bucket-pads to
   ``plan.shape_bucket``.  The census gives the same frame geometry
   without materializing anything: frame index = original - lo + 1.
3. **Tile sweep**: the frame is cut into z-tiles of whole MC granules
   (ref: ``chunk_z`` slabs, kernel backends: brick rows), each staged
   with a +1-plane halo so every marching-cubes cell and vertex edge on
   a tile face sees the same neighbour values as in-core.  Edge
   ownership partitions the three vertex fields exactly: a tile owns
   x/y-edges on its frame planes and z-edge slots starting there, so no
   vertex is emitted twice.  Per tile: MC partial sums
   (``ops.mc_tile_partials``), owned-vertex positions (device fields on
   an xy-subcrop, ``index_offset`` keeps coordinates in the global
   frame -- exact, see ``kernels/ref.vertex_fields``), and the
   first-order voxel gather.  Submit-(k+1)/collect-k: tile k+1's device
   work is dispatched before tile k's futures are drained.
4. **Hierarchical pruning**: ``'occupancy'`` skips all-zero tiles (their
   MC partials are exactly +0.0 and they own no vertices -- fully
   bitwise on every backend); ``'bounds'`` additionally lifts the
   ``kernels/prune`` vertex bound one level and skips the VERTEX work of
   tiles whose inflated AABB provably cannot contain a farthest-pair
   endpoint for any of the 4 diameter combos (bit-identical on the gram
   Pallas variants, ~1 ulp on the ref diameter path -- the same
   contract ``prune_candidates`` documents).  ``'none'`` stages every
   tile (the naive baseline the bench row beats).
5. **Re-fold**: MC partials are re-assembled in global slab/brick order
   (skipped tiles contribute exact +0.0) and folded with the in-core
   reduction order; owned vertices from all surviving tiles are sorted
   by their global field rank -- reproducing the in-core compacted
   buffer -- then run the UNCHANGED oracle tail: ``prune_candidates``
   -> tuned diameter kernel.  First-order stats fold the mask-touched
   canonical chunks through ``kernels/firstorder.fold_packed_chunks``.

Budget: ``REPRO_TILE_MEM_MB`` (default 256) bounds the STAGED bytes --
two tiles' slabs (the submit/collect overlap holds at most two alive),
mask + intensity.  Like ``plan.meta_bytes`` it deliberately counts
staged arrays, not transient XLA temporaries.  GLCM needs neighbour
pairs across tile faces and is not offered tiled (``ValueError``).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import plan as planlib
from repro.kernels import firstorder as _fo
from repro.kernels import ops

DEFAULT_TILE_MEM_MB = 256.0
TILE_PRUNE_LEVELS = ("none", "occupancy", "bounds")

_SUBCROP_STEP = 16  # xy-subcrop dims bucket (bounds fields compiles)


def tile_budget_bytes() -> int:
    """The configured staged-bytes budget (``REPRO_TILE_MEM_MB``)."""
    from repro.runtime import costmodel

    return int(costmodel._env_float("REPRO_TILE_MEM_MB",
                                    DEFAULT_TILE_MEM_MB) * 2**20)


@dataclasses.dataclass
class TiledResult:
    """One tiled case's row + the census the cost model consumes."""

    row: np.ndarray
    meta: planlib.CaseMeta
    stats: dict


@dataclasses.dataclass
class _Census:
    """Host prepass summary (see module docstring, step 1)."""

    empty: bool
    lo: np.ndarray = None          # (3,) nonzero bbox lower corner (orig)
    hi: np.ndarray = None          # (3,) nonzero bbox upper corner (orig)
    plane_any: np.ndarray = None   # (Z,) any nonzero mask on orig plane z
    plane_box: np.ndarray = None   # (Z, 4) inside-voxel xlo,xhi,ylo,yhi
    int_lo: float = 0.0            # masked intensity range (exact min/max)
    int_hi: float = 0.0
    witnesses: np.ndarray = None   # (W, 3) extreme inside-voxel coords (orig)


class TiledExtractor:
    """Drives one :class:`~repro.data.tiles.TiledCase` through the tiled
    pipeline using an executor's backend/config/oracle machinery."""

    def __init__(self, executor, budget_bytes: int | None = None,
                 tile_prune: str = "bounds"):
        if tile_prune not in TILE_PRUNE_LEVELS:
            raise ValueError(
                f"tile_prune must be one of {TILE_PRUNE_LEVELS}, got "
                f"{tile_prune!r}"
            )
        for fam in executor.families:
            if fam not in ("shape", "firstorder"):
                raise ValueError(
                    f"feature family {fam!r} is not supported in tiled mode "
                    "(GLCM needs neighbour pairs across tile faces); run it "
                    "in-core or request shape/firstorder only"
                )
        self.ex = executor
        self.budget_bytes = (tile_budget_bytes() if budget_bytes is None
                             else int(budget_bytes))
        self.tile_prune = tile_prune

    # -- census prepass -----------------------------------------------------

    def _census(self, case) -> _Census:
        X, Y, Z = case.shape
        need_int = self.ex._needs_intensity
        need_wit = self.tile_prune == "bounds" and self.ex._shape_on
        dirs = None
        if need_wit:
            from repro.kernels import prune as _prune

            dirs = _prune._directions((0, 1, 2), self.ex.k_dirs)  # (K, 3)
            pmax = np.full(len(dirs), -np.inf)
            pmin = np.full(len(dirs), np.inf)
            wmax = np.zeros((len(dirs), 3), np.int64)
            wmin = np.zeros((len(dirs), 3), np.int64)
        plane_any = np.zeros(Z, bool)
        plane_box = np.full((Z, 4), -1, np.int64)
        lo = np.array([X, Y, Z], np.int64)
        hi = np.array([-1, -1, -1], np.int64)
        int_lo, int_hi = np.inf, -np.inf
        sp64 = np.asarray(case.spacing, np.float64)

        # census chunk: a slab the budget could stage (mask only, f32)
        step = max(1, min(Z, self.budget_bytes // max(1, X * Y * 4)))
        for z0 in range(0, Z, step):
            z1 = min(z0 + step, Z)
            sl = np.asarray(case.mask_slab(z0, z1))
            nz = sl != 0
            anyz = nz.any(axis=(0, 1))
            if not anyz.any():
                continue
            plane_any[z0:z1] = anyz
            xs, ys, zs = np.nonzero(nz)
            lo = np.minimum(lo, [xs.min(), ys.min(), z0 + zs.min()])
            hi = np.maximum(hi, [xs.max(), ys.max(), z0 + zs.max()])
            ins = sl > 0.5  # iso-inside voxels: what vertices attach to
            ixs, iys, izs = np.nonzero(ins)
            for k, zz in enumerate(range(z0, z1)):
                pm = izs == k
                if pm.any():
                    px, py = ixs[pm], iys[pm]
                    plane_box[zz] = (px.min(), px.max(), py.min(), py.max())
            if need_wit and len(ixs):
                pts = np.stack([ixs, iys, izs + z0], 1).astype(np.float64)
                proj = (pts * sp64) @ dirs.T  # (V, K)
                jmax, jmin = proj.argmax(0), proj.argmin(0)
                for d in range(len(dirs)):
                    if proj[jmax[d], d] > pmax[d]:
                        pmax[d] = proj[jmax[d], d]
                        wmax[d] = pts[jmax[d]]
                    if proj[jmin[d], d] < pmin[d]:
                        pmin[d] = proj[jmin[d], d]
                        wmin[d] = pts[jmin[d]]
            if need_int and len(xs):
                pos = sl > 0  # the intensity-family mask rule (mask > 0)
                if pos.any():
                    img = np.asarray(case.image_slab(z0, z1),
                                     np.float32)[pos]
                    int_lo = min(int_lo, float(img.min()))
                    int_hi = max(int_hi, float(img.max()))
        if hi[0] < 0:
            return _Census(empty=True)
        wit = None
        if need_wit:
            wit = np.unique(np.concatenate([wmax, wmin]), axis=0)
        return _Census(
            empty=False, lo=lo, hi=hi, plane_any=plane_any,
            plane_box=plane_box,
            int_lo=0.0 if np.isinf(int_lo) else int_lo,
            int_hi=0.0 if np.isinf(int_hi) else int_hi,
            witnesses=wit,
        )

    # -- tile-level bounds pruning ------------------------------------------

    @staticmethod
    def _combo_lowers(witnesses, sp64):
        """(4,) conservative lower bounds on the combo diameters (f64).

        Max pairwise distance among the direction-extreme INSIDE-voxel
        centres, per combo projection, minus ``2*max(spacing)``: every
        inside extreme voxel has an outside axis-neighbour (otherwise a
        farther projection would exist), so a mesh vertex lies within
        ``max(spacing)`` of its centre.
        """
        combos = ((0, 1, 2), (0, 1), (0, 2), (1, 2))
        pts = witnesses * sp64  # physical centres, shift-invariant below
        slack = 2.0 * sp64.max()
        out = np.zeros(4)
        for ci, combo in enumerate(combos):
            p = pts[:, combo]
            d2 = ((p[:, None, :] - p[None, :, :]) ** 2).sum(-1)
            out[ci] = max(np.sqrt(d2.max()) - slack, 0.0)
        return out

    @staticmethod
    def _tile_upper(tbox_lo, tbox_hi, gbox_lo, gbox_hi, sp64):
        """(4,) upper bounds on any tile-vertex-to-anywhere distance.

        Boxes are inside-voxel index bboxes inflated by one voxel (a
        vertex sits on an edge of an inside voxel, within one index step
        per axis), mapped to physical space per axis.
        """
        t_lo = (tbox_lo - 1.0) * sp64
        t_hi = (tbox_hi + 1.0) * sp64
        g_lo = (gbox_lo - 1.0) * sp64
        g_hi = (gbox_hi + 1.0) * sp64
        per_axis = np.maximum(g_hi - t_lo, t_hi - g_lo)
        per_axis = np.maximum(per_axis, 0.0)
        combos = ((0, 1, 2), (0, 1), (0, 2), (1, 2))
        return np.array([
            np.sqrt((per_axis[list(c)] ** 2).sum()) for c in combos
        ])

    # -- the main sweep ------------------------------------------------------

    def extract(self, case) -> TiledResult:
        ex = self.ex
        cen = self._census(case)
        sp = np.asarray(case.spacing, np.float32)
        if cen.empty:
            meta = planlib.CaseMeta(shape=None, roi_shape=None,
                                    vertex_cap=0, n_vertices=0,
                                    intensity=ex._needs_intensity)
            return TiledResult(np.zeros(ex.n_features, np.float32), meta,
                               {"tiles": 0, "tiles_skipped": 0,
                                "tiles_bounds_pruned": 0})
        if ex._needs_intensity and case.image_source is None:
            raise ValueError(
                "intensity families requested but the TiledCase has no "
                "image source"
            )

        # frame geometry: crop_to_roi pad=1 + shape_bucket, from metadata
        lo, hi = cen.lo, cen.hi
        extent = hi - lo + 1
        roi_shape = tuple(int(e) + 2 for e in extent)
        bshape = planlib.shape_bucket(tuple(int(e) for e in extent))
        Xb, Yb, Zb = bshape
        fo = lo - 1  # frame index = original - fo
        ext_x, ext_y, ext_z = (int(e) for e in extent)

        # frame-plane census (frame plane p holds original plane p + fo[2])
        f_any = np.zeros(Zb, bool)
        f_box = np.full((Zb, 4), -1, np.int64)
        f_any[1:ext_z + 1] = cen.plane_any[lo[2]:hi[2] + 1]
        fb = cen.plane_box[lo[2]:hi[2] + 1].copy()
        has = fb[:, 1] >= 0
        fb[has, 0] -= fo[0]
        fb[has, 1] -= fo[0]
        fb[has, 2] -= fo[1]
        fb[has, 3] -= fo[1]
        f_box[1:ext_z + 1] = fb

        # MC granule + tile sizing under the staged-bytes budget
        n_cells = Zb - 1
        if ex.backend == "ref":
            cz = min(ex.mc_chunk or 32, n_cells)
            mc_block = mc_chunk = None
        else:
            mc_block, mc_chunk = ex._resolve_mc(bshape)
            cz = min(int(mc_block[2]), n_cells)
        n_slabs = -(-n_cells // cz)
        n_int = 1 + int(ex._needs_intensity)
        plane_bytes = Xb * Yb * 4 * n_int
        # two tiles alive at once (submit k+1 / collect k overlap)
        g = max(1, int((self.budget_bytes / 2 / plane_bytes - 1) // cz))
        tile_bytes = plane_bytes * (g * cz + 1)
        if 2 * tile_bytes > self.budget_bytes:
            warnings.warn(
                f"tile budget {self.budget_bytes} B cannot hold two minimal "
                f"{tile_bytes} B tiles of frame {bshape}; proceeding with "
                "1-granule tiles over budget",
                RuntimeWarning, stacklevel=2,
            )
        n_tiles = -(-n_slabs // g)

        # global bounds-pruning threshold
        do_bounds = (self.tile_prune == "bounds" and ex._shape_on
                     and cen.witnesses is not None)
        sp64 = np.asarray(sp, np.float64)
        if do_bounds:
            lowers = self._combo_lowers(cen.witnesses - fo, sp64)
            g_ins_lo = np.array([
                f_box[f_box[:, 1] >= 0, 0].min(),
                f_box[f_box[:, 3] >= 0, 2].min(),
                int(np.nonzero(f_box[:, 1] >= 0)[0].min()),
            ], np.float64)
            g_ins_hi = np.array([
                f_box[:, 1].max(), f_box[:, 3].max(),
                int(np.nonzero(f_box[:, 1] >= 0)[0].max()),
            ], np.float64)

        shape_on = ex._shape_on
        needs_int = ex._needs_intensity
        iso = jnp.float32(0.5)
        sp_dev = jnp.asarray(sp)

        vol_parts = np.zeros(n_slabs, np.float32)   # ref: per-slab deltas
        area_parts = np.zeros(n_slabs, np.float32)
        brick_vol = brick_area = None               # kernel backends
        rank_list, pos_futs = [], []
        fo_chunks: dict[int, list] = {}
        n_total = 0
        skipped = bounds_pruned = 0
        pending = None  # previous tile's futures (collect-k)
        results = []

        def _drain(p):
            if p is not None:
                results.append({k: np.asarray(v) for k, v in p.items()})

        for t in range(n_tiles):
            k0, k1 = t * g, min((t + 1) * g, n_slabs)
            pz0 = k0 * cz
            pz_halo = min(k1 * cz + 1, Zb)          # planes with frame data
            own_end = k1 * cz if t < n_tiles - 1 else Zb  # x/y-edge planes
            dz = (k1 - k0) * cz + 1                 # staged depth (padded)

            if self.tile_prune != "none" and not f_any[pz0:pz_halo].any():
                skipped += 1
                continue

            # stage the frame slab (zeros frame + source window paste)
            slab = np.zeros((Xb, Yb, dz), np.float32)
            a, b = max(pz0, 1), min(pz_halo, ext_z + 1)
            if a < b:
                src = np.asarray(case.mask_slab(a + fo[2], b + fo[2]))
                slab[1:ext_x + 1, 1:ext_y + 1, a - pz0:b - pz0] = (
                    src[lo[0]:hi[0] + 1, lo[1]:hi[1] + 1].astype(np.float32)
                )
            futs = {}

            # MC partials for every staged tile
            if shape_on:
                part = ops.mc_tile_partials(
                    jnp.asarray(slab), iso, sp_dev, backend=ex.backend,
                    k0=k0, chunk_z=cz, full_shape=bshape,
                    block=mc_block, chunk=mc_chunk,
                )
                futs["mc"] = part
                futs["_mc_range"] = (k0, k1)

            # owned active edges (host): counts always, positions unless
            # the tile bound proves it holds no farthest-pair endpoint
            if shape_on:
                inside = slab > 0.5
                ax = inside[:-1, :, :] != inside[1:, :, :]
                ay = inside[:, :-1, :] != inside[:, 1:, :]
                az = inside[:, :, :-1] != inside[:, :, 1:]
                o = own_end - pz0
                if t < n_tiles - 1:
                    ax, ay = ax[:, :, :o], ay[:, :, :o]
                n_tile = int(ax.sum()) + int(ay.sum()) + int(az.sum())
                n_total += n_tile

                pruned = False
                if do_bounds and n_tile:
                    tb = f_box[pz0:pz_halo]
                    thas = tb[:, 1] >= 0
                    t_lo = np.array([
                        tb[thas, 0].min(), tb[thas, 2].min(),
                        pz0 + int(np.nonzero(thas)[0].min()),
                    ], np.float64)
                    t_hi = np.array([
                        tb[thas, 1].max(), tb[thas, 3].max(),
                        pz0 + int(np.nonzero(thas)[0].max()),
                    ], np.float64)
                    ups = self._tile_upper(t_lo, t_hi, g_ins_lo, g_ins_hi,
                                           sp64)
                    pruned = bool((ups * (1.0 + 1e-9) < lowers).all())
                if pruned:
                    bounds_pruned += 1
                elif n_tile:
                    futs.update(self._emit_vertices(
                        slab, ax, ay, az, f_box, pz0, pz_halo, sp_dev,
                        bshape, rank_list,
                    ))

            # first-order voxel gather over OWNED planes
            if needs_int:
                o1 = min(own_end, Zb) - pz0
                mm = slab[:, :, :o1] > 0
                if mm.any():
                    img = np.zeros((Xb, Yb, dz), np.float32)
                    if a < b:
                        isrc = np.asarray(
                            case.image_slab(a + fo[2], b + fo[2]))
                        img[1:ext_x + 1, 1:ext_y + 1, a - pz0:b - pz0] = (
                            isrc[lo[0]:hi[0] + 1, lo[1]:hi[1] + 1]
                            .astype(np.float32)
                        )
                    xs, ys, zs = np.nonzero(mm)
                    flat = ((xs.astype(np.int64) * Yb + ys) * Zb
                            + (zs + pz0))
                    self._scatter_chunks(fo_chunks, flat,
                                         img[xs, ys, zs])

            _drain(pending)
            pending = futs
        _drain(pending)

        # -- re-fold ---------------------------------------------------------
        parts = []
        for family in ex.families:
            if family == "shape":
                parts.append(self._finish_shape(
                    results, vol_parts, area_parts, n_slabs, bshape,
                    rank_list, n_total,
                ))
            else:
                parts.append(self._finish_firstorder(fo_chunks, cen))
        row = parts[0] if len(parts) == 1 else np.concatenate(parts)

        cap = ops.vertex_bucket(max(n_total, 1)) if shape_on else 0
        meta = planlib.CaseMeta(shape=bshape, roi_shape=roi_shape,
                                vertex_cap=cap, n_vertices=n_total,
                                intensity=needs_int)
        stats = {
            "tiles": n_tiles, "tiles_skipped": skipped,
            "tiles_bounds_pruned": bounds_pruned,
            "granule_cz": cz, "granules_per_tile": g,
            "tile_bytes": tile_bytes, "budget_bytes": self.budget_bytes,
            "staged_bytes_peak": 2 * tile_bytes,
            "n_vertices": n_total,
            "emitted_vertices": sum(len(r) for r in rank_list),
        }
        return TiledResult(row.astype(np.float32), meta, stats)

    # -- per-tile helpers ----------------------------------------------------

    def _emit_vertices(self, slab, ax, ay, az, f_box, pz0, pz_halo, sp_dev,
                       bshape, rank_list):
        """Device vertex fields on the xy-subcrop; returns position futures.

        The subcrop spans the tile's inside-voxel xy bbox inflated by one
        (every active edge has an iso-inside endpoint, and the frame
        border is all-zero by construction), bucketed to bound the
        fields-kernel compile count; the excess is zero-extended, which
        activates nothing.  Owned active indices come from the HOST edge
        masks (the same exact comparisons the device performs), so the
        only device round trip is the gather of the active positions.
        """
        Xb, Yb, Zb = bshape
        dz = slab.shape[2]
        tb = f_box[pz0:pz_halo]
        thas = tb[:, 1] >= 0
        sx0 = max(int(tb[thas, 0].min()) - 1, 0)
        sy0 = max(int(tb[thas, 2].min()) - 1, 0)
        sx1 = min(int(tb[thas, 1].max()) + 2, Xb)
        sy1 = min(int(tb[thas, 3].max()) + 2, Yb)
        sxb = -(-(sx1 - sx0) // _SUBCROP_STEP) * _SUBCROP_STEP
        syb = -(-(sy1 - sy0) // _SUBCROP_STEP) * _SUBCROP_STEP
        sub = np.zeros((sxb, syb, dz), np.float32)
        cx, cy = min(sx0 + sxb, Xb) - sx0, min(sy0 + syb, Yb) - sy0
        sub[:cx, :cy] = slab[sx0:sx0 + cx, sy0:sy0 + cy]

        fields = ops.tile_vertex_fields(
            jnp.asarray(sub), jnp.float32(0.5), sp_dev,
            jnp.asarray([sx0, sy0, pz0], jnp.float32),
        )
        futs = {}
        off_y = (Xb - 1) * Yb * Zb
        off_z = off_y + Xb * (Yb - 1) * Zb
        specs = [
            (ax, fields.vx, (sxb - 1, syb, dz), 0, Yb, Zb),
            (ay, fields.vy, (sxb, syb - 1, dz), off_y, Yb - 1, Zb),
            (az, fields.vz, (sxb, syb, dz - 1), off_z, Yb, Zb - 1),
        ]
        for fi, (act, pos, fshape, roff, ry, rz) in enumerate(specs):
            ii, jj, ll = np.nonzero(act)
            if not len(ii):
                continue
            gx, gy, gz = ii + 0, jj + 0, ll + pz0  # global frame coords
            rank = roff + ((gx.astype(np.int64) * ry + gy) * rz + gz)
            # local indices into the subcrop field
            li, lj = ii - sx0, jj - sy0
            flat = (li.astype(np.int64) * fshape[1] + lj) * fshape[2] + ll
            rank_list.append(rank)
            futs[f"pos{fi}"] = jnp.take(
                pos.reshape(-1, 3), jnp.asarray(flat), axis=0
            )
        return futs

    @staticmethod
    def _scatter_chunks(chunks: dict, flat: np.ndarray, vals: np.ndarray):
        """Accumulate masked voxels into canonical-chunk buffers."""
        C = _fo.CANON_CHUNK
        cids = flat // C
        offs = flat % C
        uniq, starts = np.unique(cids, return_index=True)
        bounds = list(starts) + [len(flat)]
        for u, s, e in zip(uniq, bounds[:-1], bounds[1:]):
            buf = chunks.get(int(u))
            if buf is None:
                buf = chunks[int(u)] = [np.zeros(C, np.float32),
                                        np.zeros(C, np.float32)]
            buf[0][offs[s:e]] = vals[s:e]
            buf[1][offs[s:e]] = 1.0

    # -- re-fold helpers -----------------------------------------------------

    def _finish_shape(self, results, vol_parts, area_parts, n_slabs, bshape,
                      rank_list, n_total):
        ex = self.ex
        if ex.backend == "ref":
            for r in results:
                if "mc" in r:
                    k0, k1 = r["_mc_range"]
                    dvs, das = r["mc"]
                    vol_parts[k0:k1] = dvs
                    area_parts[k0:k1] = das
            vol, area = ops.mc_tile_finalize(vol_parts, area_parts,
                                             backend=ex.backend)
        else:
            # assemble the full brick grid; pruned tiles stay exact zeros
            first = next((r for r in results if "mc" in r), None)
            if first is None:
                vol = area = np.float32(0.0)
            else:
                nbx, nby = first["mc"][0].shape[:2]
                bv = np.zeros((nbx, nby, n_slabs), np.float32)
                ba = np.zeros((nbx, nby, n_slabs), np.float32)
                for r in results:
                    if "mc" in r:
                        k0, k1 = r["_mc_range"]
                        bv[:, :, k0:k1], ba[:, :, k0:k1] = r["mc"]
                vol, area = ops.mc_tile_finalize(bv, ba, backend=ex.backend)

        # streamed farthest pair: global-rank sort reproduces the in-core
        # compacted buffer; then the unchanged oracle tail
        pos = [r[k] for r in results for k in sorted(r)
               if k.startswith("pos")]
        if not pos:
            d = np.zeros(4, np.float32)
            return np.concatenate([
                np.asarray([vol, area], np.float32), d,
                np.asarray([n_total], np.float32),
            ])
        ranks = np.concatenate(rank_list)
        verts_sorted = np.concatenate(pos)[np.argsort(ranks, kind="stable")]
        n_emitted = len(verts_sorted)
        cap = ops.vertex_bucket(n_emitted)
        verts = np.zeros((cap, 3), np.float32)
        verts[:n_emitted] = verts_sorted
        vmask = np.zeros(cap, bool)
        vmask[:n_emitted] = True
        if ex.prune:
            verts, vmask, _ = ops.prune_candidates(verts, vmask,
                                                   k_dirs=ex.k_dirs)
        variant, block = ex._resolve_diameter(len(verts))
        d = ops.max_diameters(verts, vmask, backend=ex.backend,
                              variant=variant, block=block)
        return np.concatenate([
            np.asarray([vol, area], np.float32),
            np.asarray(d, np.float32),
            np.asarray([n_total], np.float32),
        ])

    def _finish_firstorder(self, chunks: dict, cen: _Census):
        ex = self.ex
        if not chunks:
            return np.zeros(_fo.N_FEATURES, np.float32)
        cids = sorted(chunks)
        nt = len(cids)
        ntb = 1 << (nt - 1).bit_length()  # pad with exact-+0 chunks
        C = _fo.CANON_CHUNK
        x = np.zeros((ntb, C), np.float32)
        m = np.zeros((ntb, C), np.float32)
        for i, cid in enumerate(cids):
            x[i], m[i] = chunks[cid]
        packed = _fo.fold_packed_chunks(
            jnp.asarray(x), jnp.asarray(m),
            jnp.float32(cen.int_lo), jnp.float32(cen.int_hi),
            n_bins=ex.n_bins,
        )
        return ex._family_row("firstorder", np.asarray(packed))
