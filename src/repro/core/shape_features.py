"""PyRadiomics-compatible 3D shape feature extraction.

The user-facing API mirrors the paper's usage:

    from repro.core.shape_features import ShapeFeatureExtractor
    ext = ShapeFeatureExtractor()
    res = ext.execute(image, mask, spacing=(1.0, 1.0, 1.0))
    res['MeshVolume'], res['SurfaceArea'], res['Maximum3DDiameter'], ...

Feature names and definitions follow the PyRadiomics shape(3D) class:
MeshVolume, VoxelVolume, SurfaceArea, SurfaceVolumeRatio, Sphericity,
Compactness1, Compactness2, SphericalDisproportion, Maximum3DDiameter,
Maximum2DDiameterSlice (x-y plane), Maximum2DDiameterColumn (y-z plane),
Maximum2DDiameterRow (x-z plane), MajorAxisLength, MinorAxisLength,
LeastAxisLength, Elongation, Flatness.

Axis convention: volumes are indexed (x, y, z) with ``spacing`` in the same
order.  (PyRadiomics uses (z, y, x) numpy order; the plane features map as
Slice = in-plane (x, y), Column = (y, z), Row = (x, z).)

The two expensive stages (fused marching cubes and the O(M^2) diameter
search) run on the backend chosen by ``repro.core.dispatcher`` -- this class
is the integration shim the paper implements in C: same inputs, same
outputs, accelerator decided at runtime.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatcher
from repro.kernels import ops


@dataclasses.dataclass
class StageTimes:
    """Wall-clock breakdown mirroring the paper's Table 2 columns."""

    preprocess_ms: float = 0.0  # crop/pad/mask ('File reading' analogue)
    transfer_ms: float = 0.0  # host->device ('D. tran.')
    mesh_ms: float = 0.0  # fused MC volume+area ('M.C.')
    diameter_ms: float = 0.0  # pairwise search ('Diam.')

    @property
    def total_ms(self) -> float:
        return self.preprocess_ms + self.transfer_ms + self.mesh_ms + self.diameter_ms


def crop_to_roi(image: np.ndarray, mask: np.ndarray, pad: int = 1):
    """Crop image/mask to the ROI bounding box and zero-pad by ``pad``.

    PyRadiomics crops to the bounding box before feature extraction; the
    1-voxel zero pad closes the isosurface at the volume boundary.
    Host-side numpy: this is part of the 'data loading' stage in the paper's
    breakdown, not the accelerated region.
    """
    idx = np.nonzero(mask)
    if len(idx[0]) == 0:
        raise ValueError("mask is empty")
    lo = [int(i.min()) for i in idx]
    hi = [int(i.max()) + 1 for i in idx]
    sl = tuple(slice(l, h) for l, h in zip(lo, hi))
    m = np.ascontiguousarray(mask[sl]).astype(np.float32)
    im = np.ascontiguousarray(image[sl]).astype(np.float32)
    m = np.pad(m, pad)
    im = np.pad(im, pad)
    return im, m, lo


@jax.jit
def _voxel_stats(mask, spacing):
    """Voxel-count volume and PCA axis lengths (physical coordinates)."""
    n = jnp.sum(mask)
    voxel_volume = n * jnp.prod(spacing)
    nx, ny, nz = mask.shape
    ii, jj, kk = jnp.meshgrid(
        jnp.arange(nx, dtype=jnp.float32),
        jnp.arange(ny, dtype=jnp.float32),
        jnp.arange(nz, dtype=jnp.float32),
        indexing="ij",
    )
    coords = jnp.stack([ii, jj, kk], -1) * spacing  # physical
    w = mask[..., None]
    mean = jnp.sum(coords * w, axis=(0, 1, 2)) / jnp.maximum(n, 1.0)
    d = (coords - mean) * mask[..., None]
    cov = jnp.einsum("xyzi,xyzj->ij", d, d) / jnp.maximum(n, 1.0)
    eig = jnp.linalg.eigvalsh(cov)  # ascending
    eig = jnp.maximum(eig, 0.0)
    return voxel_volume, eig


class ShapeFeatureExtractor:
    """Drop-in 3D shape feature extractor with accelerator dispatch.

    ``diameter_variant='auto'`` and ``mc_block='auto'`` (the defaults) pick
    the measured-best diameter (variant, block) for the case's vertex
    bucket and the measured-best marching-cubes (brick, chunk) for the
    case's padded-volume bucket from the autotune cache
    (``repro.runtime.autotune``); pass concrete values to pin them.
    ``prune=True`` runs the exact candidate pruning stage
    (``repro.kernels.prune``) before the O(M^2) pair sweep -- identical
    diameters (bit-for-bit on the Pallas variants, up to f32 rounding on
    the ref path), usually at a fraction of the pair work.
    """

    def __init__(self, backend: str | None = None, diameter_variant: str = "auto",
                 mc_block="auto", mc_chunk: int | None = None,
                 diam_block: int | None = None, prune: bool = True):
        self.backend = dispatcher.resolve_backend(backend)
        self.diameter_variant = diameter_variant
        self.mc_block = mc_block if mc_block == "auto" else tuple(mc_block)
        self.mc_chunk = mc_chunk
        self.diam_block = diam_block
        self.prune = prune
        self.last_prune_info = None  # PruneInfo of the most recent case

    # -- staged API (used by the Table-2 benchmark harness) ----------------
    def mesh_features(self, mask_padded, spacing):
        v, a = ops.mc_volume_area(
            mask_padded, 0.5, spacing, backend=self.backend,
            block=self.mc_block, chunk=self.mc_chunk,
        )
        return v, a

    def diameter_features(self, mask_padded, spacing):
        fields = ops.vertex_fields(mask_padded, 0.5, spacing)
        n = int(ops.count_vertices(fields))
        cap = ops.vertex_bucket(n)
        verts, vmask, _ = ops.compact_vertices(fields, cap)
        self.last_prune_info = None
        if self.prune:
            verts, vmask, self.last_prune_info = ops.prune_candidates(
                np.asarray(verts), np.asarray(vmask)
            )
        d = ops.max_diameters(
            verts, vmask, backend=self.backend,
            variant=self.diameter_variant, block=self.diam_block,
        )
        return d, n

    # -- public API ---------------------------------------------------------
    def execute(
        self,
        image: np.ndarray,
        mask: np.ndarray,
        spacing=(1.0, 1.0, 1.0),
        with_times: bool = False,
    ) -> Mapping[str, float]:
        times = StageTimes()
        sp = np.asarray(spacing, np.float32)

        t0 = time.perf_counter()
        _, m, _ = crop_to_roi(image, mask)
        times.preprocess_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        m_dev = jax.device_put(jnp.asarray(m))
        sp_dev = jax.device_put(jnp.asarray(sp))
        jax.block_until_ready(m_dev)
        times.transfer_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        mesh_volume, surface_area = self.mesh_features(m_dev, sp_dev)
        jax.block_until_ready(surface_area)
        times.mesh_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        diam, n_verts = self.diameter_features(m_dev, sp_dev)
        jax.block_until_ready(diam)
        times.diameter_ms = (time.perf_counter() - t0) * 1e3

        voxel_volume, eig = _voxel_stats(m_dev, sp_dev)

        V = float(mesh_volume)
        A = float(surface_area)
        d3, dxy, dxz, dyz = (float(x) for x in diam)
        e0, e1, e2 = (float(x) for x in eig)  # ascending: least, minor, major
        pi = float(np.pi)
        feats = {
            "MeshVolume": V,
            "VoxelVolume": float(voxel_volume),
            "SurfaceArea": A,
            "SurfaceVolumeRatio": A / V if V > 0 else float("nan"),
            "Sphericity": (36.0 * pi * V * V) ** (1.0 / 3.0) / A if A > 0 else float("nan"),
            "Compactness1": V / (pi ** 0.5 * A ** 1.5) if A > 0 else float("nan"),
            "Compactness2": 36.0 * pi * V * V / (A ** 3) if A > 0 else float("nan"),
            "SphericalDisproportion": A / (36.0 * pi * V * V) ** (1.0 / 3.0) if V > 0 else float("nan"),
            "Maximum3DDiameter": d3,
            "Maximum2DDiameterSlice": dxy,
            "Maximum2DDiameterRow": dxz,
            "Maximum2DDiameterColumn": dyz,
            "MajorAxisLength": 4.0 * e2 ** 0.5,
            "MinorAxisLength": 4.0 * e1 ** 0.5,
            "LeastAxisLength": 4.0 * e0 ** 0.5,
            "Elongation": (e1 / e2) ** 0.5 if e2 > 0 else float("nan"),
            "Flatness": (e0 / e2) ** 0.5 if e2 > 0 else float("nan"),
            "_n_mesh_vertices": float(n_verts),
        }
        if with_times:
            return feats, times
        return feats
