"""Core: the paper's contribution -- accelerated 3D shape feature extraction.

Public API:
    ShapeFeatureExtractor   -- PyRadiomics-compatible single-case extractor
    BatchedExtractor        -- multi-case, mesh-sharded pipeline (facade over
                               the plan/executor split)
    ExtractionPlan          -- static per-window plan (repro.core.plan)
    PlanExecutor            -- device-resident plan runner (repro.core.executor)
    resolve_backend         -- accelerator probe / CPU fallback (dispatcher)
"""
from repro.core.dispatcher import resolve_backend, has_tpu
from repro.core.shape_features import ShapeFeatureExtractor, StageTimes, crop_to_roi
from repro.core.pipeline import BatchedExtractor, Bucket, assign_bucket
from repro.core.plan import ExtractionPlan, build_plan, plan_from_metadata
from repro.core.executor import PlanExecutor

__all__ = [
    "ShapeFeatureExtractor",
    "StageTimes",
    "BatchedExtractor",
    "Bucket",
    "assign_bucket",
    "crop_to_roi",
    "resolve_backend",
    "has_tpu",
    "ExtractionPlan",
    "build_plan",
    "plan_from_metadata",
    "PlanExecutor",
]
