"""Core: the paper's contribution -- accelerated 3D shape feature extraction.

Public API:
    ShapeFeatureExtractor   -- PyRadiomics-compatible single-case extractor
    BatchedExtractor        -- multi-case, mesh-sharded pipeline
    resolve_backend         -- accelerator probe / CPU fallback (dispatcher)
"""
from repro.core.dispatcher import resolve_backend, has_tpu
from repro.core.shape_features import ShapeFeatureExtractor, StageTimes, crop_to_roi
from repro.core.pipeline import BatchedExtractor, Bucket, assign_bucket

__all__ = [
    "ShapeFeatureExtractor",
    "StageTimes",
    "BatchedExtractor",
    "Bucket",
    "assign_bucket",
    "crop_to_roi",
    "resolve_backend",
    "has_tpu",
]
