"""internvl2-26b -- InternViT (stubbed patch frontend) + InternLM2 backbone.
[arXiv:2404.16821; hf]
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The vision frontend is a stub: input_specs() provides precomputed patch
embeddings (B, 1024, d) prepended to the text sequence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="patch",
    frontend_tokens=1024,
)
