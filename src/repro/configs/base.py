"""Model/run configuration system.

One frozen dataclass covers all 10 assigned architecture families (dense,
MoE, SSM, hybrid, enc-dec, VLM/audio backbones).  Architecture configs live
in ``repro/configs/<arch>.py`` (exact public hyper-parameters); input-shape
configs in ``repro/configs/shapes.py``; ``registry.get_config`` resolves
``--arch`` names.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- layer variations -------------------------------------------------
    mlp_act: str = "swiglu"  # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_residual_ff: int = 0  # arctic-style parallel dense FFN
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    moe_group_size: int = 512  # tokens per dispatch group (cost ~ linear)

    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    attn_window: int = 0  # 0 = full attention
    global_attn_layers: tuple = ()  # hybrid: layers with full attention

    # --- enc-dec ------------------------------------------------------------
    n_encoder_layers: int = 0

    # --- modality frontend (STUB: precomputed embeddings via input_specs) ---
    frontend: str = "none"  # none | patch(vision) | frames(audio)
    frontend_tokens: int = 0

    # --- numerics / training ------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    zloss: float = 1e-4

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (self.name, "GQA group")

    @property
    def vocab_padded(self) -> int:
        """Embedding-table size padded for even sharding (512 | 16*32)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state => can run the long_500k shape."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_window > 0:
            return True
        return False

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,w,g,o ~ 6 d^2) + channel-mix
            attn = 6 * d * d
        mlp_mult = 3 if self.mlp_act == "swiglu" else 2
        dense_mlp = mlp_mult * d * self.d_ff
        per_layer = attn + dense_mlp
        if self.n_experts:
            expert = mlp_mult * d * self.moe_d_ff
            per_layer = attn + self.n_experts * expert + self.n_shared_experts * expert
            if self.dense_residual_ff:
                per_layer += mlp_mult * d * self.dense_residual_ff
            per_layer += d * self.n_experts  # router
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + di * (2 * self.ssm_state + 1)
        total = L * per_layer + self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + dense_mlp + attn // 2)
        return int(total)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k only)."""
        if not self.n_experts:
            return self.n_params
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp_mult = 3 if self.mlp_act == "swiglu" else 2
        expert = mlp_mult * d * self.moe_d_ff
        per_layer = attn + (self.n_experts_per_token + self.n_shared_experts) * expert
        if self.dense_residual_ff:
            per_layer += mlp_mult * d * self.dense_residual_ff
        per_layer += d * self.n_experts
        total = L * per_layer + 2 * self.vocab_size * d
        return int(total)

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads % 2 == 0 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
            scan_layers=self.scan_layers,
            dtype="float32",  # CPU smoke tests stay in f32
        )
        if self.n_experts:
            small.update(n_experts=4, n_experts_per_token=min(2, self.n_experts_per_token),
                         n_shared_experts=min(1, self.n_shared_experts), moe_d_ff=64,
                         dense_residual_ff=64 if self.dense_residual_ff else 0)
        if self.ssm_state:
            small.update(ssm_state=4)
        if self.n_encoder_layers:
            small.update(n_encoder_layers=2)
        if self.attn_window:
            small.update(attn_window=16)
        if self.global_attn_layers:
            small.update(global_attn_layers=(0,))
        if self.frontend_tokens:
            small.update(frontend_tokens=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclass(frozen=True)
class RunConfig:
    """Launcher-level knobs (mesh, optimizer, fault tolerance)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    schedule: str = "wsd"  # wsd | cosine | constant
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 = no gradient accumulation
    steps: int = 100
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    grad_compression: str = "none"  # none | int8
    async_checkpoint: bool = True
    # Hoist the FSDP weight all-gather out of the gradient-accumulation
    # loop: constrain params to a data-replicated layout ONCE before the
    # microbatch scan; the constraint's transpose is a single grad
    # reduce-scatter after it.  Collectives go from A + b*W to A + W
    # (see EXPERIMENTS.md §Perf/2 it.3).  Costs one replicated f32 copy of
    # the weights + grads in HBM, so off for memory-tight giants.
    gather_weights_once: bool = False
