"""deepseek-moe-16b -- 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]
28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    capacity_factor=1.25,
)
