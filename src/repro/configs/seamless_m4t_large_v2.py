"""seamless-m4t-large-v2 -- enc-dec multimodal (audio frontend stubbed).
[arXiv:2308.11596; hf]
24L decoder + 24L encoder, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The speech frontend is a stub: input_specs() provides precomputed frame
embeddings (B, seq//4, d)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_act="gelu",
    frontend="frames",
)
