"""Architecture + shape configs.  ``registry.get_config('<arch>')`` resolves
the 10 assigned architectures; ``shapes.SHAPES`` the 4 assigned input
shapes."""
