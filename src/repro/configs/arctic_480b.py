"""arctic-480b -- 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    n_experts_per_token=2,
    moe_d_ff=4864,
    dense_residual_ff=4864,  # arctic's parallel dense residual path
    capacity_factor=1.25,
)
