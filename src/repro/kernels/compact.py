"""Device-resident segmented survivor compaction (pass 1b of the pipeline).

PR 2's two-pass pipeline computes the exact pruning bound as one vmapped
kernel but then compacts each case's survivors HOST-side (``np.nonzero`` +
``np.pad`` per case) -- the last CPU<->device round trip between pass 1 and
pass 2, exactly the ping-pong PyRadiomics-cuda exists to eliminate.  This
module is the device-side replacement: a **segmented compaction** primitive
that scatters the survivors of a keep mask into the first M' slots of a
static M'-bucket, batched over a stack of same-cap cases, so pass 1 emits
already-bucketed ``(verts, vmask)`` device arrays that feed pass 2 directly.

Semantics (shared by both paths, and by the host path they replace):

  * survivors keep their original relative order (stable compaction);
  * slot ``j`` of the output holds the j-th survivor; slots ``>= M'`` are
    zero with a False mask -- bit-identical to the host path's
    ``verts[np.nonzero(keep)]`` + zero ``np.pad``;
  * survivors beyond the cap are dropped (callers size the cap from the
    survivor count, so this only happens under a deliberately small cap);
  * the returned count ``n`` is the TOTAL survivor count (pre-drop),
    matching ``ref.compact_vertices``.

Two implementations:

``compact_batch_ref``
    jnp reference/oracle: exclusive prefix sum over the mask gives each
    survivor its output slot; a ``mode='drop'`` scatter writes them.  Runs
    on any backend; this is also the 'ref' dispatch target.

``compact_batch_pallas``
    Pallas TPU kernel.  The grid walks ``(case, block)``; an SMEM scalar
    carries the running survivor count across a case's sequential blocks
    (the same revisited-accumulator idiom as the diameter 'seqacc'
    variant), and the per-block scatter is realised as a one-hot matmul:
    ``out += verts_block (3, B) @ onehot (B, cap)`` where
    ``onehot[i, j] = keep_i & (prefix_i == j)``.  A 0/1 matmul copies
    floats exactly (x * 1.0 + 0.0 terms), so the result is bit-identical
    to the reference path.  Scatter-by-matmul keeps the store pattern
    static -- the MXU-native way to compact on TPU, where per-element
    dynamic stores are not an option.  ``block`` is the autotuned axis
    (``runtime/autotune`` sweeps it per M bucket).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 256


def _compact_one_ref(verts, keep, cap: int):
    """Single-case jnp compaction: (M, 3), (M,) -> (cap, 3), (cap,), n."""
    k = keep.astype(bool)
    ki = k.astype(jnp.int32)
    pos = jnp.cumsum(ki) - 1  # exclusive prefix sum = output slot
    # non-survivors (and survivors past the cap) land out of bounds: dropped
    idx = jnp.where(k, pos, cap)
    out = jnp.zeros((cap, 3), jnp.float32).at[idx].set(verts, mode="drop")
    n = jnp.sum(ki)
    mask = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n, cap)
    return out, mask, n


@functools.partial(jax.jit, static_argnames=("cap",))
def compact_batch_ref(verts, keep, cap: int):
    """Batched reference compaction.

    ``verts``: (B, M, 3), ``keep``: (B, M) -> ``(out, mask, n)`` with
    ``out``: (B, cap, 3) float32, ``mask``: (B, cap) bool, ``n``: (B,) int32.
    """
    verts = jnp.asarray(verts, jnp.float32)
    keep = jnp.asarray(keep)
    return jax.vmap(lambda v, k: _compact_one_ref(v, k, cap))(verts, keep)


def _compact_kernel(kref, vref, vout, base, *, block: int, cap: int):
    b, t = pl.program_id(0), pl.program_id(1)
    del b  # the grid's case axis is routed entirely by the BlockSpecs

    @pl.when(t == 0)
    def _():  # new case: reset the accumulator block + running offset
        vout[...] = jnp.zeros_like(vout)
        base[0] = 0

    ki = (kref[0, 0, :] > 0.0).astype(jnp.int32)  # (block,)
    pos = jnp.cumsum(ki) - 1 + base[0]  # global output slot per survivor
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, cap), 1)
    onehot = ((pos[:, None] == cols) & (ki[:, None] > 0)).astype(jnp.float32)
    # scatter-by-matmul: each output column receives exactly one survivor
    # (slots are unique), every other term is x * 0.0 -- exact in f32
    vout[0] += jax.lax.dot_general(
        vref[0],
        onehot,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    base[0] = base[0] + jnp.sum(ki)


@functools.partial(
    jax.jit, static_argnames=("cap", "block", "interpret")
)
def compact_batch_pallas(
    verts, keep, cap: int, *, block: int = DEFAULT_BLOCK,
    interpret: bool = False
):
    """Batched Pallas segmented compaction; same contract as the ref path.

    ``verts``: (B, M, 3), ``keep``: (B, M) -> ``(out, mask, n)``.  The grid
    is ``(B, M/block)``; case ``b``'s blocks run sequentially, carrying the
    survivor offset in SMEM, and revisit one (3, cap) output accumulator.
    """
    verts = jnp.asarray(verts, jnp.float32)
    kf = jnp.asarray(keep).astype(jnp.float32)
    B, M, _ = verts.shape
    nb = max(1, -(-M // block))
    pad = nb * block - M
    v = jnp.pad(verts, ((0, 0), (0, pad), (0, 0))).transpose(0, 2, 1)
    km = jnp.pad(kf, ((0, 0), (0, pad)))[:, None, :]  # (B, 1, nb*block)

    out = pl.pallas_call(
        functools.partial(_compact_kernel, block=block, cap=cap),
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, 1, block), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, 3, block), lambda b, t: (b, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, 3, cap), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 3, cap), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(km, v)

    n = jnp.sum(kf > 0.0, axis=1).astype(jnp.int32)  # (B,)
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (B, cap), 1)
        < jnp.minimum(n, cap)[:, None]
    )
    return out.transpose(0, 2, 1), mask, n
