"""Pallas TPU kernel: fused Marching Cubes volume + surface area.

PyRadiomics-cuda's first kernel walks every voxel with one CUDA thread,
emitting triangles and atomically accumulating mesh volume and surface area.
The TPU adaptation:

* the volume is restacked host-side into **overlapping (BX+1, BY+1, CZ+1)
  bricks** (the +1 halo shares one plane with the neighbour -- the analogue
  of staging tiles in CUDA shared memory).  Memory overhead is
  (1+1/BX)(1+1/BY)(1+1/CZ) ~ 1.2-1.4x, streamed HBM->VMEM by the Pallas
  pipeline;
* the per-voxel triangle-table *gather* (which TPUs dislike) becomes a
  **one-hot matmul on the MXU**: ``onehot(cube_index, 256) @ TRI_TABLE`` --
  data-dependent lookup expressed as dense systolic compute;
* CUDA atomic accumulation becomes per-brick partial sums written to their
  own output cells and reduced outside (deterministic, Megacore-safe);
* triangle *vertices* are not appended to a global list at all: the
  deduplicated vertex field is a dense per-grid-edge structure computed in a
  single fused elementwise XLA pass (see ``kernels/ref.vertex_fields``) --
  on TPU a dense masked write beats an atomic append.

Signed tetrahedron volumes are accumulated against the volume centre to keep
f32 cancellation error small; the global sum is origin-independent because
the generated MC table yields closed, consistently oriented meshes (property-
tested in tests/test_mc_tables.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mc_tables as mct

_NSLOTS = mct.MAX_TRIS * 3  # 15 table slots per case


def _brick_cells(s, iso, x0, y0, z0, spacing, origin):
    """Per-cell edge-vertex positions + cube index for one brick.

    s: (BX+1, BY+1, CZ+1) corner values.  Returns (E, idx) with
    E: (12, BX*BY*CZ, 3) physical positions, idx: (BX*BY*CZ,) int32.
    """
    bx, by, cz = s.shape[0] - 1, s.shape[1] - 1, s.shape[2] - 1
    inside = (s > iso).astype(jnp.int32)

    idx = jnp.zeros((bx, by, cz), jnp.int32)
    for c, (dx, dy, dz) in enumerate(np.asarray(mct.CORNERS)):
        idx = idx + (inside[dx : dx + bx, dy : dy + by, dz : dz + cz] << c)

    def interp(v0, v1):
        den = v1 - v0
        den = jnp.where(jnp.abs(den) < 1e-30, 1.0, den)
        return jnp.clip((iso - v0) / den, 0.0, 1.0)

    tx = interp(s[:-1, :, :], s[1:, :, :])  # (BX, BY+1, CZ+1)
    ty = interp(s[:, :-1, :], s[:, 1:, :])  # (BX+1, BY, CZ+1)
    tz = interp(s[:, :, :-1], s[:, :, 1:])  # (BX+1, BY+1, CZ)

    spx, spy, spz = spacing
    ox, oy, oz = origin

    def coords(shape, fx, fy, fz):
        ii = jax.lax.broadcasted_iota(jnp.float32, shape, 0)
        jj = jax.lax.broadcasted_iota(jnp.float32, shape, 1)
        kk = jax.lax.broadcasted_iota(jnp.float32, shape, 2)
        px = (x0 + ii + fx) * spx + ox
        py = (y0 + jj + fy) * spy + oy
        pz = (z0 + kk + fz) * spz + oz
        return jnp.stack([px, py, pz], axis=-1)

    # Vertex positions on the three canonical edge families.
    px = coords(tx.shape, tx, 0.0, 0.0)  # x-directed edges
    py = coords(ty.shape, 0.0, ty, 0.0)
    pz = coords(tz.shape, 0.0, 0.0, tz)

    e = [None] * 12
    e[0] = px[:, :-1, :-1]
    e[2] = px[:, 1:, :-1]
    e[4] = px[:, :-1, 1:]
    e[6] = px[:, 1:, 1:]
    e[3] = py[:-1, :, :-1]
    e[1] = py[1:, :, :-1]
    e[7] = py[:-1, :, 1:]
    e[5] = py[1:, :, 1:]
    e[8] = pz[:-1, :-1, :]
    e[9] = pz[1:, :-1, :]
    e[10] = pz[1:, 1:, :]
    e[11] = pz[:-1, 1:, :]
    E = jnp.stack([x.reshape(-1, 3) for x in e])  # (12, cells, 3)
    return E, idx.reshape(-1)


def _mc_kernel(scal, table_ref, brick, vol_out, area_out, *, chunk,
               z_scal=False):
    """One brick: fused table lookup (MXU one-hot matmul) + vol/area sums.

    With ``z_scal`` (the tiled entry) ``scal`` carries an 8th element:
    the window's global z offset in cells, added to the brick-local z
    base.  Both are integer-valued f32 < 2^24, so the add is exact and
    the brick computes with the SAME coordinates as the in-core grid.
    """
    iso = scal[0]
    spacing = (scal[1], scal[2], scal[3])
    origin = (scal[4], scal[5], scal[6])
    bx1 = brick.shape[3]
    by1 = brick.shape[4]
    cz1 = brick.shape[5]
    bx, by, cz = bx1 - 1, by1 - 1, cz1 - 1

    px_id = pl.program_id(0)
    py_id = pl.program_id(1)
    pz_id = pl.program_id(2)
    x0 = (px_id * bx).astype(jnp.float32)
    y0 = (py_id * by).astype(jnp.float32)
    z0 = (pz_id * cz).astype(jnp.float32)
    if z_scal:
        z0 = z0 + scal[7]

    s = brick[0, 0, 0]
    E, idx = _brick_cells(s, iso, x0, y0, z0, spacing, origin)
    cells = bx * by * cz

    table = table_ref[:]  # (256, 15) f32 triangle table, resident in VMEM

    def chunk_body(c0, acc):
        sv, sa = acc
        idx_c = jax.lax.dynamic_slice_in_dim(idx, c0 * chunk, chunk)
        E_c = jax.lax.dynamic_slice_in_dim(E, c0 * chunk, chunk, axis=1)
        # --- one-hot matmul gather (MXU) ---
        oh = (idx_c[:, None] == jax.lax.broadcasted_iota(jnp.int32, (chunk, 256), 1)).astype(jnp.float32)
        ids = jax.lax.dot_general(
            oh, table, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (chunk, 15) float edge ids, exact small ints
        sel = (
            ids[:, :, None]
            == jax.lax.broadcasted_iota(jnp.float32, (chunk, _NSLOTS, 12), 2)
        ).astype(jnp.float32)  # (chunk, 15, 12)
        Ec = jnp.transpose(E_c, (1, 0, 2))  # (chunk, 12, 3)
        verts = jax.lax.dot_general(
            sel, Ec, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )  # (chunk, 15, 3)
        tri = verts.reshape(chunk, mct.MAX_TRIS, 3, 3)
        valid = (ids.reshape(chunk, mct.MAX_TRIS, 3)[:, :, 0] >= 0.0).astype(jnp.float32)
        a, b, c = tri[:, :, 0, :], tri[:, :, 1, :], tri[:, :, 2, :]
        ab, ac = b - a, c - a
        cr = jnp.cross(ab, ac)
        area = 0.5 * jnp.sqrt(jnp.sum(cr * cr, axis=-1) + 1e-30) * valid
        svol = jnp.sum(a * jnp.cross(b, c), axis=-1) / 6.0 * valid
        return sv + jnp.sum(svol), sa + jnp.sum(area)

    nchunks = cells // chunk
    sv, sa = jax.lax.fori_loop(0, nchunks, chunk_body, (jnp.float32(0), jnp.float32(0)))
    vol_out[0, 0, 0] = sv
    area_out[0, 0, 0] = sa


def normalize_chunk(block, chunk: int) -> int:
    """Clamp ``chunk`` to a valid in-kernel chunk length for ``block``.

    The kernel slices each brick's ``bx*by*bz`` cells into equal chunks, so
    a valid chunk divides the cell count; oversized chunks clamp to it.
    Shared by the kernel entry point, the autotune sweep's candidate
    enumeration and its cache-record validation (``runtime.autotune``).

    Raises ``ValueError`` when no clamp can make ``chunk`` valid.
    """
    bx, by, cz = block
    cells = bx * by * cz
    if cells % chunk:
        chunk = min(chunk, cells)
        if cells % chunk:
            raise ValueError(f"chunk {chunk} must divide cells/brick {cells}")
    return chunk


def _restack(vol, bx, by, cz):
    """Host-side overlapping brick view: (nbx, nby, nbz, BX+1, BY+1, CZ+1)."""
    nx, ny, nz = vol.shape
    nbx = max(1, -(-(nx - 1) // bx))
    nby = max(1, -(-(ny - 1) // by))
    nbz = max(1, -(-(nz - 1) // cz))
    volp = jnp.pad(
        vol,
        ((0, nbx * bx + 1 - nx), (0, nby * by + 1 - ny), (0, nbz * cz + 1 - nz)),
        constant_values=0.0,
    )
    ix = (np.arange(nbx)[:, None] * bx + np.arange(bx + 1)[None, :]).reshape(-1)
    iy = (np.arange(nby)[:, None] * by + np.arange(by + 1)[None, :]).reshape(-1)
    iz = (np.arange(nbz)[:, None] * cz + np.arange(cz + 1)[None, :]).reshape(-1)
    v = volp[ix][:, iy][:, :, iz]
    v = v.reshape(nbx, bx + 1, nby, by + 1, nbz, cz + 1)
    return jnp.transpose(v, (0, 2, 4, 1, 3, 5)), (nbx, nby, nbz)


@functools.partial(
    jax.jit, static_argnames=("block", "chunk", "interpret")
)
def mc_volume_area_pallas(
    vol,
    iso=0.5,
    spacing=(1.0, 1.0, 1.0),
    *,
    block=(8, 8, 8),
    chunk=512,
    interpret=False,
):
    """Mesh volume + surface area via the fused Pallas MC kernel.

    Matches ``kernels.ref.mc_volume_area`` (same table, same interpolation).
    """
    vol = jnp.asarray(vol, jnp.float32)
    bx, by, cz = block
    chunk = normalize_chunk(block, chunk)
    bricks, (nbx, nby, nbz) = _restack(vol, bx, by, cz)

    # centre the coordinate origin to minimise f32 cancellation
    nx, ny, nz = vol.shape
    sp = jnp.asarray(spacing, jnp.float32)
    origin = -0.5 * jnp.asarray([nx, ny, nz], jnp.float32) * sp
    scal = jnp.concatenate([jnp.asarray([iso], jnp.float32), sp, origin])

    out_spec = pl.BlockSpec((1, 1, 1), lambda i, j, k: (i, j, k))
    vol_p, area_p = pl.pallas_call(
        functools.partial(_mc_kernel, chunk=chunk),
        grid=(nbx, nby, nbz),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((256, _NSLOTS), lambda i, j, k: (0, 0)),
            pl.BlockSpec(
                (1, 1, 1, bx + 1, by + 1, cz + 1),
                lambda i, j, k: (i, j, k, 0, 0, 0),
            ),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nbx, nby, nbz), jnp.float32),
            jax.ShapeDtypeStruct((nbx, nby, nbz), jnp.float32),
        ],
        interpret=interpret,
    )(scal, jnp.asarray(mct.TRI_TABLE, jnp.float32), bricks)
    return jnp.abs(jnp.sum(vol_p)), jnp.sum(area_p)


@functools.partial(
    jax.jit, static_argnames=("full_shape", "block", "chunk", "interpret")
)
def mc_brick_partials_pallas(
    slab,
    iso=0.5,
    spacing=(1.0, 1.0, 1.0),
    *,
    full_shape,
    z_cell_offset=0.0,
    block=(8, 8, 8),
    chunk=512,
    interpret=False,
):
    """Per-brick (signed volume, area) partials for one z-window of a volume.

    The tiled-extraction entry: runs the SAME brick kernel as
    :func:`mc_volume_area_pallas` over a window of ``z_cell_offset``-shifted
    bricks, with the coordinate origin computed from ``full_shape`` (the
    whole volume's centred origin), and returns the per-brick partial
    arrays UNREDUCED.  The caller assembles the windows' partials into
    the full (nbx, nby, nbz) brick grid -- zeros for windows that were
    pruned away (a skipped empty brick contributes exactly +0.0) -- and
    reduces once via :func:`mc_partials_finalize`, reproducing the
    in-core reduction shape bit-for-bit.  ``z_cell_offset`` is traced
    (f32, exact small integer): tiles at different depths share one
    compiled kernel.

    The window must span whole bricks: ``slab.shape[2] == k*cz + 1``.
    """
    slab = jnp.asarray(slab, jnp.float32)
    bx, by, cz = block
    chunk = normalize_chunk(block, chunk)
    bricks, (nbx, nby, nbz) = _restack(slab, bx, by, cz)

    sp = jnp.asarray(spacing, jnp.float32)
    origin = -0.5 * jnp.asarray(list(full_shape), jnp.float32) * sp
    scal = jnp.concatenate([
        jnp.asarray([iso], jnp.float32), sp, origin,
        jnp.asarray([z_cell_offset], jnp.float32),
    ])

    out_spec = pl.BlockSpec((1, 1, 1), lambda i, j, k: (i, j, k))
    return pl.pallas_call(
        functools.partial(_mc_kernel, chunk=chunk, z_scal=True),
        grid=(nbx, nby, nbz),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((256, _NSLOTS), lambda i, j, k: (0, 0)),
            pl.BlockSpec(
                (1, 1, 1, bx + 1, by + 1, cz + 1),
                lambda i, j, k: (i, j, k, 0, 0, 0),
            ),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nbx, nby, nbz), jnp.float32),
            jax.ShapeDtypeStruct((nbx, nby, nbz), jnp.float32),
        ],
        interpret=interpret,
    )(scal, jnp.asarray(mct.TRI_TABLE, jnp.float32), bricks)


@jax.jit
def mc_partials_finalize(vol_p, area_p):
    """Reduce assembled full-grid brick partials: (|sum vol|, sum area).

    The same two reductions :func:`mc_volume_area_pallas` ends with, over
    an array of the same (nbx, nby, nbz) shape -- the reduction-tree
    shape is what fixes the f32 accumulation order, so assembling tile
    partials into the full grid first keeps the result bit-identical to
    the in-core pass.
    """
    return jnp.abs(jnp.sum(vol_p)), jnp.sum(area_p)


@functools.partial(
    jax.jit, static_argnames=("block", "chunk", "interpret")
)
def mc_volume_area_batch_pallas(
    vols,
    iso=0.5,
    spacings=None,
    *,
    block=(8, 8, 8),
    chunk=512,
    interpret=False,
):
    """Device-stack MC: ``(B, nx, ny, nz)`` masks -> ``(B, 2)`` [vol, area].

    The batched entry point of the device-resident pass-2a data plane:
    the executor stages bucket-padded masks into a device pool and feeds
    stacked slices straight here -- no host re-stacking per chunk.  Cases
    are mapped sequentially per device (``lax.map``; the brick grid of a
    single case already saturates a chip) with per-case physical spacing
    ``spacings``: ``(B, 3)``.
    """
    vols = jnp.asarray(vols, jnp.float32)
    if spacings is None:
        spacings = jnp.ones((vols.shape[0], 3), jnp.float32)

    def one(args):
        vol, sp = args
        v, a = mc_volume_area_pallas(
            vol, iso, sp, block=block, chunk=chunk, interpret=interpret
        )
        return jnp.stack([v, a])

    return jax.lax.map(one, (vols, jnp.asarray(spacings, jnp.float32)))


def flop_estimate(shape, block=(8, 8, 8), chunk=512) -> float:
    """Structural FLOP count: dominated by the one-hot MXU matmul."""
    nx, ny, nz = shape
    bx, by, cz = block
    nbricks = (-(-(nx - 1) // bx)) * (-(-(ny - 1) // by)) * (-(-(nz - 1) // cz))
    cells = bx * by * cz
    per_cell = 2 * 256 * _NSLOTS + _NSLOTS * 12 * (1 + 2 * 3) + mct.MAX_TRIS * 60
    return float(nbricks) * cells * per_cell
