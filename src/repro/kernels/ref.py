"""Pure-jnp reference oracles for the two PyRadiomics-cuda hot spots.

These are the numerical ground truth the Pallas kernels are validated against
(``tests/test_kernels_*``) and the CPU fallback path of the dispatcher -- the
role the original C implementation plays in PyRadiomics-cuda.

Conventions
-----------
* volumes are ``(nx, ny, nz)`` float arrays; a voxel is *inside* iff
  ``value > iso`` (binary masks with ``iso=0.5``, as PyRadiomics uses).
* ``spacing``/``origin`` map index space to physical space:
  ``pos_phys = origin + index * spacing``.
* mesh vertices are deduplicated by construction: every *grid edge* owns at
  most one vertex, stored in three dense per-axis fields (VX, VY, VZ).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mc_tables as mct

_TRI_TABLE = jnp.asarray(mct.TRI_TABLE)  # (256, 15) int32, -1 padded
_NSLOTS = mct.MAX_TRIS * 3


class VertexFields(NamedTuple):
    """Dense per-axis vertex fields (the TPU-native 'triangle append')."""

    vx: jax.Array  # (nx-1, ny, nz, 3) positions on x-directed edges
    vy: jax.Array  # (nx, ny-1, nz, 3)
    vz: jax.Array  # (nx, ny, nz-1, 3)
    ax: jax.Array  # (nx-1, ny, nz) bool, edge active
    ay: jax.Array
    az: jax.Array


def _interp(v0, v1, iso):
    """Interpolation parameter of the iso crossing along an edge."""
    denom = v1 - v0
    safe = jnp.where(jnp.abs(denom) < 1e-30, 1.0, denom)
    t = (iso - v0) / safe
    return jnp.clip(t, 0.0, 1.0)


def vertex_fields(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), origin=(0.0, 0.0, 0.0),
                  index_offset=None):
    """Compute the deduplicated mesh-vertex fields (pure elementwise pass).

    ``index_offset`` (default: none -- the graph is unchanged) shifts the
    per-axis grid indices before the physical mapping, so a sub-window of
    a larger volume emits positions in the FULL volume's index frame.
    The offsets are integers (< 2^24) added to integer-valued f32 iotas:
    the add is exact, so ``(local + offset) + t`` is bit-identical to the
    full volume's ``global + t`` -- the key to tiled/in-core vertex
    bit-parity (``core/tiled.py``).
    """
    vol = jnp.asarray(vol, jnp.float32)
    sp = jnp.asarray(spacing, jnp.float32)
    og = jnp.asarray(origin, jnp.float32)
    off = None if index_offset is None else jnp.asarray(index_offset, jnp.float32)
    nx, ny, nz = vol.shape
    inside = vol > iso

    def axis_field(axis, n_axis):
        sl0 = [slice(None)] * 3
        sl1 = [slice(None)] * 3
        sl0[axis] = slice(0, -1)
        sl1[axis] = slice(1, None)
        v0, v1 = vol[tuple(sl0)], vol[tuple(sl1)]
        act = inside[tuple(sl0)] != inside[tuple(sl1)]
        t = _interp(v0, v1, iso)
        shape = v0.shape
        ii, jj, kk = jnp.meshgrid(
            jnp.arange(shape[0], dtype=jnp.float32),
            jnp.arange(shape[1], dtype=jnp.float32),
            jnp.arange(shape[2], dtype=jnp.float32),
            indexing="ij",
        )
        idx = [ii, jj, kk]
        if off is not None:
            idx = [ii + off[0], jj + off[1], kk + off[2]]
        idx[axis] = idx[axis] + t
        pos = jnp.stack(idx, axis=-1) * sp + og
        return pos, act

    vx, ax = axis_field(0, nx)
    vy, ay = axis_field(1, ny)
    vz, az = axis_field(2, nz)
    return VertexFields(vx, vy, vz, ax, ay, az)


def _cell_cube_index(vol, iso):
    """(nx-1,ny-1,nz-1) int32 MC case index per cell."""
    inside = (vol > iso).astype(jnp.int32)
    idx = 0
    for c, (dx, dy, dz) in enumerate(np.asarray(mct.CORNERS)):
        sl = (
            slice(dx, dx + vol.shape[0] - 1),
            slice(dy, dy + vol.shape[1] - 1),
            slice(dz, dz + vol.shape[2] - 1),
        )
        idx = idx + (inside[sl] << c)
    return idx


def _cell_edge_positions(f: VertexFields):
    """Stack the 12 per-cell edge-vertex positions from the dense fields.

    Returns (cx, cy, cz, 12, 3).  Pure slicing -- no dynamic gather.
    """
    vx, vy, vz = f.vx, f.vy, f.vz
    e = [None] * 12
    e[0] = vx[:, :-1, :-1]
    e[2] = vx[:, 1:, :-1]
    e[4] = vx[:, :-1, 1:]
    e[6] = vx[:, 1:, 1:]
    e[3] = vy[:-1, :, :-1]
    e[1] = vy[1:, :, :-1]
    e[7] = vy[:-1, :, 1:]
    e[5] = vy[1:, :, 1:]
    e[8] = vz[:-1, :-1, :]
    e[9] = vz[1:, :-1, :]
    e[10] = vz[1:, 1:, :]
    e[11] = vz[:-1, 1:, :]
    return jnp.stack(e, axis=-2)


def _slab_volume_area(slab, iso, spacing, origin):
    """Signed mesh volume + surface area for the cells of one volume slab."""
    f = vertex_fields(slab, iso, spacing, origin)
    e = _cell_edge_positions(f)  # (cx,cy,cz,12,3)
    idx = _cell_cube_index(slab, iso)  # (cx,cy,cz)
    tids = _TRI_TABLE[idx]  # (cx,cy,cz,15) via jnp.take - oracle only
    safe = jnp.maximum(tids, 0)
    verts = jnp.take_along_axis(e, safe[..., None], axis=-2)
    # verts: (cx,cy,cz,15,3); group into triangles
    tri = verts.reshape(*verts.shape[:-2], mct.MAX_TRIS, 3, 3)
    valid = (tids.reshape(*tids.shape[:-1], mct.MAX_TRIS, 3)[..., 0] >= 0).astype(
        jnp.float32
    )
    a, b, c = tri[..., 0, :], tri[..., 1, :], tri[..., 2, :]
    cr = jnp.cross(b - a, c - a)
    area = 0.5 * jnp.linalg.norm(cr, axis=-1) * valid
    svol = jnp.einsum("...d,...d->...", a, jnp.cross(b, c)) / 6.0 * valid
    return jnp.sum(svol), jnp.sum(area)


@functools.partial(jax.jit, static_argnames=("chunk_z",))
def _mc_volume_area_jit(vol, iso, spacing, origin, chunk_z):
    nz = vol.shape[2]
    n_cells_z = nz - 1
    cz = min(chunk_z, n_cells_z)
    n_slabs = -(-n_cells_z // cz)
    pad_z = n_slabs * cz + 1 - nz
    volp = jnp.pad(vol, ((0, 0), (0, 0), (0, pad_z)), constant_values=0.0)

    def body(carry, k):
        sv, sa = carry
        slab = jax.lax.dynamic_slice_in_dim(volp, k * cz, cz + 1, axis=2)
        og = jnp.asarray(origin, jnp.float32).at[2].add(
            k * cz * jnp.asarray(spacing, jnp.float32)[2]
        )
        dv, da = _slab_volume_area(slab, iso, spacing, og)
        return (sv + dv, sa + da), None

    (sv, sa), _ = jax.lax.scan(body, (0.0, 0.0), jnp.arange(n_slabs))
    return jnp.abs(sv), sa


@functools.partial(jax.jit, static_argnames=("chunk_z",))
def _mc_slab_partials_jit(vol, iso, spacing, origin, k0, chunk_z):
    n_slabs = (vol.shape[2] - 1) // chunk_z

    def body(carry, k):
        sv, sa = carry
        slab = jax.lax.dynamic_slice_in_dim(vol, k * chunk_z, chunk_z + 1, axis=2)
        og = jnp.asarray(origin, jnp.float32).at[2].add(
            (k + k0) * chunk_z * jnp.asarray(spacing, jnp.float32)[2]
        )
        dv, da = _slab_volume_area(slab, iso, spacing, og)
        return (sv + dv, sa + da), (dv, da)

    _, (dvs, das) = jax.lax.scan(body, (0.0, 0.0), jnp.arange(n_slabs))
    return dvs, das


def mc_slab_partials(vol, iso=0.5, spacing=(1.0, 1.0, 1.0),
                     origin=(0.0, 0.0, 0.0), chunk_z=32, k0=0):
    """Per-slab (signed volume, area) partial sums for one z-window.

    The tiled-extraction building block: the scan body is the SAME
    ``_slab_volume_area`` + origin-advance as :func:`_mc_volume_area_jit`
    (slab shapes identical -- the caller pads the window to a whole
    number of ``chunk_z`` granules plus the closing plane), but the
    per-slab deltas are emitted instead of only the folded carry.  ``k0``
    is the window's first GLOBAL slab index: ``(k + k0)`` is an exact
    int add, so each slab's origin is bit-identical to the one the
    in-core scan computes for that global slab.  The host re-folds the
    collected deltas in global slab order with np.float32 adds (IEEE-754
    single, the same op the in-core carry performs) -- see
    ``core/tiled.py``.
    """
    vol = jnp.asarray(vol, jnp.float32)
    if (vol.shape[2] - 1) % chunk_z:
        raise ValueError(
            f"window depth {vol.shape[2]} is not a whole number of "
            f"chunk_z={chunk_z} slabs plus the closing plane"
        )
    return _mc_slab_partials_jit(
        vol, jnp.float32(iso), jnp.asarray(spacing, jnp.float32),
        jnp.asarray(origin, jnp.float32), jnp.int32(k0), chunk_z
    )


@jax.jit
def tile_vertex_fields(slab, iso, spacing, index_offset):
    """Jitted vertex-field pass for one halo-padded tile sub-window.

    ``index_offset`` is traced (one compile per sub-window shape bucket,
    not per tile position).  Positions land in the full volume's index
    frame -- see :func:`vertex_fields` on why this is bit-exact.
    """
    return vertex_fields(slab, iso, spacing, index_offset=index_offset)


def mc_volume_area(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), origin=(0.0, 0.0, 0.0), chunk_z=32):
    """Mesh volume and surface area of the iso-surface (reference path).

    Pads nothing: callers pad masks by one voxel (as PyRadiomics does) so the
    surface closes.  Volume is the absolute signed-tetrahedron sum; with the
    outward-oriented table the sign is positive already.
    """
    vol = jnp.asarray(vol, jnp.float32)
    iso = jnp.float32(iso)
    spacing = jnp.asarray(spacing, jnp.float32)
    origin = jnp.asarray(origin, jnp.float32)
    return _mc_volume_area_jit(vol, iso, spacing, origin, chunk_z)


# ---------------------------------------------------------------------------
# Vertex compaction: dense per-edge fields -> padded (M,3) vertex list
# ---------------------------------------------------------------------------

def compact_vertices(f: VertexFields, max_vertices: int):
    """Gather active-edge vertices into a padded (max_vertices, 3) array.

    Returns (verts, mask, n_active).  Deterministic order (x-field, y-field,
    z-field, row-major).  If there are more active vertices than
    ``max_vertices`` the excess is dropped (callers size the cap from
    ``count_vertices``).
    """
    pos = jnp.concatenate([f.vx.reshape(-1, 3), f.vy.reshape(-1, 3), f.vz.reshape(-1, 3)])
    act = jnp.concatenate([f.ax.reshape(-1), f.ay.reshape(-1), f.az.reshape(-1)])
    n = jnp.sum(act.astype(jnp.int32))
    # stable order: active first, original order preserved among actives
    order = jnp.argsort(~act, stable=True)[:max_vertices]
    verts = pos[order]
    mask = act[order]
    return verts, mask, n


def count_vertices(f: VertexFields):
    return (
        jnp.sum(f.ax.astype(jnp.int32))
        + jnp.sum(f.ay.astype(jnp.int32))
        + jnp.sum(f.az.astype(jnp.int32))
    )


# ---------------------------------------------------------------------------
# Diameters: max pairwise distances (3D + three coordinate-plane projections)
# ---------------------------------------------------------------------------

NEG = jnp.float32(-1e30)


@functools.partial(jax.jit, static_argnames=("row_block",))
def max_diameters_sq(verts, mask, row_block=128):
    """Maximum squared pairwise distances over valid vertex pairs.

    Returns (4,) float32: [3D, xy-plane, xz-plane, yz-plane] squared maxima.
    Blocked over rows so memory is O(row_block * M).

    Masking trick (big CPU speedup): every *invalid* vertex is replaced by
    the first valid vertex before the pair sweep.  A duplicated point can
    never increase the maximum pairwise distance, so the sweep needs no
    per-pair mask/where at all -- the inner loop is pure sub/mul/add/max,
    SoA over axes, which XLA fuses into one vectorised pass.
    """
    verts = jnp.asarray(verts, jnp.float32)
    m = jnp.asarray(mask).astype(bool)
    M = verts.shape[0]
    R = min(row_block, M)
    nb = -(-M // R)
    pad = nb * R - M

    v0 = verts[jnp.argmax(m)]  # first valid vertex (callers reject empty)
    vfill = jnp.where(m[:, None], verts, v0[None, :])
    # centre to keep f32 magnitudes small (cancellation control)
    centre = 0.5 * (jnp.min(vfill, axis=0) + jnp.max(vfill, axis=0))
    vfill = vfill - centre
    # pad rows duplicate the last vertex -- duplicates cannot raise the max
    vp = jnp.pad(vfill, ((0, pad), (0, 0)), mode="edge") if pad else vfill
    cx, cy, cz = vp[:, 0], vp[:, 1], vp[:, 2]  # SoA (M,)

    def body(best, i):
        rows = jax.lax.dynamic_slice_in_dim(vp, i * R, R, axis=0)
        dx = rows[:, 0][:, None] - cx[None, :]
        dy = rows[:, 1][:, None] - cy[None, :]
        dz = rows[:, 2][:, None] - cz[None, :]
        qx, qy, qz = dx * dx, dy * dy, dz * dz
        qxy = qx + qy
        m3 = jnp.max(qxy + qz)
        mxy = jnp.max(qxy)
        mxz = jnp.max(qx + qz)
        myz = jnp.max(qy + qz)
        return jnp.maximum(best, jnp.stack([m3, mxy, mxz, myz])), None

    best, _ = jax.lax.scan(body, jnp.full((4,), NEG), jnp.arange(nb))
    return jnp.maximum(best, 0.0)


def max_diameters(verts, mask, row_block=128):
    """(4,) float32 diameters: [max 3D, xy(Slice), xz(Row), yz(Column)]."""
    return jnp.sqrt(max_diameters_sq(verts, mask, row_block=row_block))


# ---------------------------------------------------------------------------
# intensity-family helpers (first-order / GLCM): shared quantization contract
# ---------------------------------------------------------------------------

def intensity_range(image, mask):
    """Masked intensity ``(lo, hi)`` -- order-invariant (pure min/max).

    Min/max are exact under any reduction order, so every backend computes
    bit-identical ranges (and therefore bit-identical bin edges) without a
    canonical-order contract.  An empty mask yields ``(0, 0)``.
    """
    img = jnp.asarray(image, jnp.float32)
    m = jnp.asarray(mask) > 0
    any_ = jnp.any(m)
    lo = jnp.where(any_, jnp.min(jnp.where(m, img, jnp.inf)), 0.0)
    hi = jnp.where(any_, jnp.max(jnp.where(m, img, -jnp.inf)), 0.0)
    return lo, hi


def quantize_intensity(image, mask, lo, hi, n_bins: int):
    """Fixed-bin-count discretization: f32 bin ids in ``[0, n_bins)``.

    Returns ``(q, width)`` where ``q`` is float32 (one-hot comparisons in
    the kernels stay in the native MXU dtype) and masked-out voxels are
    forced to bin 0.  A degenerate range (constant intensity, empty mask)
    has ``width == 0`` and every voxel in bin 0.  Purely elementwise, so
    ``lo``/``hi`` may be scalars or broadcastable per-case columns.
    """
    img = jnp.asarray(image, jnp.float32)
    width = (hi - lo) / n_bins
    safe = jnp.where(width > 0, width, 1.0)
    q = jnp.clip(jnp.floor((img - lo) / safe), 0.0, float(n_bins - 1))
    return jnp.where(jnp.asarray(mask) > 0, q, 0.0), width
