"""First-order intensity statistics as a batched plan-stage family.

Nine features over the masked voxels of an intensity volume: mean, std,
min, max, three histogram percentiles (P10/median/P90 over the fixed
``n_bins`` discretization), energy (sum of squares), and histogram
entropy.  Everything reduces to one accumulated statistics vector per
case -- ``[count, sum, sum_sq, histogram]`` -- plus the order-invariant
intensity range, packed into one ``(B, packed_width)`` device row per
case.  The feature row is derived HOST-SIDE by a single shared numpy
function (:func:`features_from_packed_np`): deriving in-graph is a trap,
because XLA fuses/contracts ``s2/n - mean*mean`` differently at
different batch shapes, silently breaking batched-equals-single at the
last bit.  Host derivation is one tiny deterministic code path, so
backend and batch parity only ever have to hold on the packed stats.

Bitwise parity contract (mirrors the diameter suite, but for sums):
f32 addition is not associative, so a "sum the masked voxels" spec does
not pin the result -- the ADDITION ORDER is part of the contract.  The
canonical order is a left fold over fixed :data:`CANON_CHUNK`-voxel
chunks of the flattened (zero-padded) volume, where each chunk's partial
is computed by ``jnp.sum`` over a ``(CANON_CHUNK,)`` slice
(:func:`_chunk_stats`).  The reference oracle IS that fold
(``lax.scan``); the Pallas kernel performs exactly one accumulator
update per canonical chunk (``for j in range(block // CANON_CHUNK)``),
so its global accumulation is the same left fold for ANY block size --
the autotuned ``block`` is a pure performance axis, never a numerics
axis, and block-sweep winners cannot flip feature bits.

Zero padding is exact: padded lanes have ``mask == 0``, contributing
``+0.0`` to every statistic (and bin 0 of the histogram only via the
``mask > 0`` guard, i.e. not at all).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

N_BINS = 32          # default fixed-bin-count discretization
CANON_CHUNK = 1024   # canonical accumulation granule (see module docstring)
DEFAULT_BLOCK = 2048

FEATURES = ("Mean", "StdDev", "Minimum", "Maximum", "Percentile10",
            "Median", "Percentile90", "Energy", "Entropy")
N_FEATURES = len(FEATURES)


def stats_width(n_bins: int = N_BINS) -> int:
    """Width of the accumulated stats vector: [count, sum, sum_sq, hist]."""
    return 3 + n_bins


def packed_width(n_bins: int = N_BINS) -> int:
    """Width of the per-case device row: stats ++ [lo, hi, bin_width]."""
    return stats_width(n_bins) + 3


def _pack(stats, lo, hi, width):
    return jnp.concatenate(
        [stats, lo[:, None], hi[:, None], width[:, None]], axis=1
    )


def _chunk_stats(x, m, q, n_bins: int):
    """``(3 + n_bins,)`` partial statistics of ONE canonical chunk.

    THE shared numerical contract: the reference fold and the Pallas
    kernel both call this on identically-shaped ``(CANON_CHUNK,)``
    slices, so per-chunk partials lower to the same reductions and match
    bitwise across backends.
    """
    cols = jax.lax.broadcasted_iota(jnp.float32, (CANON_CHUNK, n_bins), 1)
    onehot = ((q[:, None] == cols) & (m[:, None] > 0)).astype(jnp.float32)
    return jnp.concatenate([
        jnp.stack([jnp.sum(m), jnp.sum(x), jnp.sum(x * x)]),
        jnp.sum(onehot, axis=0),
    ])


def _padded_len(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


def _flatten_batch(images, masks, n_bins, multiple):
    """Flatten + mask + quantize a ``(B, *vol)`` stack, padded to ``multiple``.

    Returns ``(x, m, q, lo, hi, width)`` with the first three shaped
    ``(B, Lp)`` (masked values are zeroed; pads are zero) and the last
    three shaped ``(B,)``.
    """
    imgs = jnp.asarray(images, jnp.float32)
    B = imgs.shape[0]
    imgs = imgs.reshape(B, -1)
    m = (jnp.asarray(masks).reshape(B, -1) > 0).astype(jnp.float32)
    lo, hi = jax.vmap(_ref.intensity_range)(imgs, m)
    q, width = _ref.quantize_intensity(
        imgs, m, lo[:, None], hi[:, None], n_bins
    )
    x = jnp.where(m > 0, imgs, 0.0)
    pad = _padded_len(imgs.shape[1], multiple) - imgs.shape[1]
    pad2 = ((0, 0), (0, pad))
    return (jnp.pad(x, pad2), jnp.pad(m, pad2), jnp.pad(q, pad2),
            lo, hi, width[:, 0])


def features_from_packed_np(packed, n_bins: int = N_BINS) -> np.ndarray:
    """``(..., N_FEATURES)`` rows from packed stats, on the HOST in numpy.

    The single derivation shared by every backend and every batch depth:
    parity only has to hold on the packed stats vector (see module
    docstring for why this must not run in-graph).  An empty case
    (count 0) yields an all-zero row; a constant-intensity case has
    ``bin_width == 0`` so every bin centre collapses to ``lo`` and
    std/entropy are exactly 0.
    """
    p = np.asarray(packed, np.float32)
    n, s1, s2 = p[..., 0], p[..., 1], p[..., 2]
    hist = p[..., 3:3 + n_bins]
    lo, hi = p[..., 3 + n_bins], p[..., 4 + n_bins]
    width = p[..., 5 + n_bins]
    nsafe = np.maximum(n, 1.0)
    mean = s1 / nsafe
    var = np.maximum(s2 / nsafe - mean * mean, 0.0)
    prob = hist / nsafe[..., None]
    entropy = -np.sum(
        np.where(prob > 0,
                 prob * np.log2(np.where(prob > 0, prob, 1.0)), 0.0),
        axis=-1,
    )
    centers = (lo[..., None]
               + (np.arange(n_bins, dtype=np.float32) + 0.5)
               * width[..., None])
    cum = np.cumsum(hist, axis=-1)

    def pct(frac):
        # first bin whose cumulative count reaches the frac-quantile rank
        idx = np.argmax(cum >= np.float32(frac) * n[..., None], axis=-1)
        return np.take_along_axis(centers, idx[..., None], axis=-1)[..., 0]

    row = np.stack([
        mean, np.sqrt(var), lo, hi,
        pct(0.1), pct(0.5), pct(0.9), s2, entropy,
    ], axis=-1)
    return np.where(n[..., None] > 0, row, 0.0).astype(np.float32)


def firstorder_stats_ref(image, mask, n_bins: int = N_BINS):
    """Single-case oracle stats: the canonical left fold over chunks."""
    x, m, q, lo, hi, width = _flatten_batch(
        jnp.asarray(image)[None], jnp.asarray(mask)[None], n_bins, CANON_CHUNK
    )
    nc = x.shape[1] // CANON_CHUNK
    chunks = (x.reshape(nc, CANON_CHUNK), m.reshape(nc, CANON_CHUNK),
              q.reshape(nc, CANON_CHUNK))

    def body(acc, ch):
        cx, cm, cq = ch
        return acc + _chunk_stats(cx, cm, cq, n_bins), None

    acc, _ = jax.lax.scan(
        body, jnp.zeros((stats_width(n_bins),), jnp.float32), chunks
    )
    return acc, lo[0], hi[0], width[0]


@functools.partial(jax.jit, static_argnames=("n_bins",))
def fold_packed_chunks(x, m, lo, hi, n_bins: int = N_BINS):
    """Packed stats from a stack of TOUCHED canonical chunks (tiled path).

    ``x``/``m``: (nt, CANON_CHUNK) masked values / mask lanes of the
    mask-touched chunks of the padded frame, in ascending global chunk
    order; ``lo``/``hi`` the order-invariant masked intensity range
    (exact min/max, so a streaming census computes the same bits).  An
    untouched chunk's :func:`_chunk_stats` partial is an exact +0.0
    vector (zero lanes, ``m > 0`` nowhere), so folding ONLY the touched
    chunks -- same body, same ascending order -- accumulates bit-
    identically to the in-core full scan.  Quantization happens in-graph
    from the same ``lo``/``hi`` (elementwise, shape-independent).
    """
    q, width = _ref.quantize_intensity(x, m, lo, hi, n_bins)

    def body(acc, ch):
        cx, cm, cq = ch
        return acc + _chunk_stats(cx, cm, cq, n_bins), None

    acc, _ = jax.lax.scan(
        body, jnp.zeros((stats_width(n_bins),), jnp.float32), (x, m, q)
    )
    return jnp.concatenate([acc, jnp.stack([lo, hi, width])])


@functools.partial(jax.jit, static_argnames=("n_bins",))
def firstorder_packed_batch_ref(images, masks, n_bins: int = N_BINS):
    """``(B, packed_width)`` oracle stats via the single-case fold, mapped.

    ``lax.map`` (not vmap): each case runs the exact single-case fold, so
    batched rows are bit-identical to one-at-a-time extraction.
    """
    def one(args):
        img, m = args
        acc, lo, hi, width = firstorder_stats_ref(img, m, n_bins)
        return jnp.concatenate([acc, jnp.stack([lo, hi, width])])

    return jax.lax.map(
        one,
        (jnp.asarray(images, jnp.float32), jnp.asarray(masks, jnp.float32)),
    )


def firstorder_features_batch_ref(images, masks, n_bins: int = N_BINS):
    """``(B, N_FEATURES)`` rows: oracle stats + host derivation.

    NOT traceable (the derivation is host-side numpy by design); traced
    callers consume :func:`firstorder_packed_batch_ref` and finalise
    after the fetch.
    """
    return features_from_packed_np(
        firstorder_packed_batch_ref(images, masks, n_bins), n_bins
    )


def _fo_kernel(xref, mref, qref, out, *, block: int, n_bins: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out[...] = jnp.zeros_like(out)

    # one accumulator update PER CANONICAL CHUNK: the global add order is
    # the module-contract left fold for any block size
    for j in range(block // CANON_CHUNK):
        sl = slice(j * CANON_CHUNK, (j + 1) * CANON_CHUNK)
        vec = _chunk_stats(xref[0, 0, sl], mref[0, 0, sl], qref[0, 0, sl],
                           n_bins)
        out[...] += vec[None, :]


@functools.partial(jax.jit,
                   static_argnames=("n_bins", "block", "interpret"))
def firstorder_packed_batch_pallas(images, masks, *, n_bins: int = N_BINS,
                                   block: int = DEFAULT_BLOCK,
                                   interpret: bool = False):
    """``(B, packed_width)`` stats via the Pallas left-fold kernel."""
    if block % CANON_CHUNK:
        raise ValueError(
            f"firstorder block must be a multiple of CANON_CHUNK="
            f"{CANON_CHUNK}, got {block}"
        )
    x, m, q, lo, hi, width = _flatten_batch(images, masks, n_bins, block)
    B, Lp = x.shape
    grid = (B, Lp // block)
    spec = pl.BlockSpec((1, 1, block), lambda b, t: (b, 0, t))
    w = stats_width(n_bins)
    stats = pl.pallas_call(
        functools.partial(_fo_kernel, block=block, n_bins=n_bins),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, w), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, w), jnp.float32),
        interpret=interpret,
    )(x[:, None, :], m[:, None, :], q[:, None, :])
    return _pack(stats, lo, hi, width)


def firstorder_features_batch_pallas(images, masks, *, n_bins: int = N_BINS,
                                     block: int = DEFAULT_BLOCK,
                                     interpret: bool = False):
    """``(B, N_FEATURES)`` rows: Pallas stats kernel + host derivation.

    NOT traceable (see :func:`firstorder_features_batch_ref`)."""
    return features_from_packed_np(
        firstorder_packed_batch_pallas(
            images, masks, n_bins=n_bins, block=block, interpret=interpret
        ),
        n_bins,
    )
