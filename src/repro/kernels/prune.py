"""Exact candidate pruning for the O(M^2) diameter search.

The farthest-pair search dominates shape-feature time (paper Table 2:
95.7%-99.9%), so shrinking the candidate set M -> M' before the quadratic
pass is the biggest structural lever: pair work drops by (M/M')^2.  This
stage is O(M*K), fully vectorised, and **exact** -- the pruned search
returns bit-identical maxima for every feature combo on the Pallas
variants (see the composition note below for the ref path's ulp caveat).

Method (per combo c in {3D, xy, xz, yz}, restricted to c's axes):

1. *Lower bound* L_c: project the vertices onto K sampled unit directions
   (always including the coordinate axes), take the arg-min/arg-max vertex
   per direction, and brute-force the <= 2K extreme points.  Every extreme
   is a real valid vertex, so L_c <= D_c (the true combo diameter).
2. *Upper bound* ub_c(p) per vertex: distance from p to the farthest point
   of the candidate bounding box.  ``x -> |p - x|`` is convex, so its max
   over a box is attained at a corner -- the corner sweep is exact.  We
   additionally intersect with the triangle-inequality bound
   ``|p - centre| + max_q |q - centre|`` and keep the smaller of the two.
3. Discard p for combo c iff ub_c(p) < L_c: p can then not be an endpoint
   of any pair reaching L_c, in particular not of the farthest pair.

A vertex survives if ANY combo keeps it; the union keeps every potential
endpoint of all four maxima, which is what makes running a single 4-combo
kernel on the pruned set sound.

Exactness of the composition (prune + any Pallas kernel variant): the
achieving pair (p*, q*) of combo c has real distance D_c >= L_c and
ub_c >= D_c, so both endpoints survive; per-pair tile arithmetic is
shape-independent, so a max over a subset that contains the arg-max pair
is the same float -- **bit-identical** for every Pallas variant.  The
extreme witnesses themselves are force-kept (axis directions are always
in the sample), so the candidate bounding box is pruning-invariant.  The
pure-jnp reference path is the one exception to bit-identity: XLA fuses
its sweep shape-dependently (FMA/vectorization choices change with M),
so ref results can differ by ~1 ulp across pruning -- identical up to
f32 rounding, not bit-for-bit.

Float safety: bounds are compared with a small relative slack so f32
rounding in ub/L can never discard a borderline true endpoint.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

COMBOS = ((0, 1, 2), (0, 1), (0, 2), (1, 2))  # 3D, xy, xz, yz

# relative slack on the squared upper bound; >> f32 rounding, prunes
# a negligible shell of borderline candidates less aggressively
_SLACK = np.float32(1.0 + 1e-4)


def _directions(combo: tuple, k: int) -> np.ndarray:
    """(K', 3) unit directions spanning ``combo``'s axes.

    Always starts with the coordinate axes and the subspace diagonals;
    extra directions come from a deterministic golden-ratio sweep (2D:
    half-circle angles, 3D: spiral hemisphere).  Min/max projections are
    both taken per direction, so antipodes are covered for free.
    """
    dirs = []
    for a in combo:
        e = np.zeros(3)
        e[a] = 1.0
        dirs.append(e)
    if len(combo) == 2:
        a0, a1 = combo
        for s in (1.0, -1.0):
            d = np.zeros(3)
            d[a0], d[a1] = 1.0, s
            dirs.append(d)
        for i in range(max(0, k - len(dirs))):
            th = np.pi * (i + 0.5) / max(1, k - 4)
            d = np.zeros(3)
            d[a0], d[a1] = np.cos(th), np.sin(th)
            dirs.append(d)
    else:
        for sx in (1.0, -1.0):
            for sy in (1.0, -1.0):
                dirs.append(np.array([1.0, sx, sy]))
        golden = (1.0 + 5.0 ** 0.5) / 2.0
        n_extra = max(0, k - len(dirs))
        for i in range(n_extra):
            z = (i + 0.5) / n_extra
            r = (1.0 - z * z) ** 0.5
            th = 2.0 * np.pi * i / golden
            dirs.append(np.array([r * np.cos(th), r * np.sin(th), z]))
    d = np.stack(dirs)
    d /= np.linalg.norm(d, axis=1, keepdims=True)
    return d.astype(np.float32)


@functools.partial(jax.jit, static_argnames=("k_dirs",))
def candidate_keep_mask(verts, mask, k_dirs: int = 16):
    """Exact per-vertex keep mask for the 4-combo diameter search.

    Returns ``(keep, lower_sq)``: ``keep`` is a (M,) bool mask (False =
    provably not an endpoint of any of the 4 maxima, or invalid), and
    ``lower_sq`` the (4,) squared lower bounds found per combo.
    """
    verts = jnp.asarray(verts, jnp.float32)
    m = jnp.asarray(mask).astype(bool)
    v0 = verts[jnp.argmax(m)]  # first valid vertex (callers reject empty)
    vfill = jnp.where(m[:, None], verts, v0[None, :])

    keep_any = jnp.zeros(m.shape, bool)
    lower_sq = []
    for combo in COMBOS:
        axes = jnp.zeros((3,), jnp.float32).at[jnp.asarray(combo)].set(1.0)
        pc = vfill * axes[None, :]  # off-combo axes zeroed
        d = jnp.asarray(_directions(combo, k_dirs))  # (K, 3) constants
        proj = pc @ d.T  # (M, K)
        # bias invalid (duplicated-fill) slots out of the extreme search so
        # an argmax/argmin tie can never land on a slot that '& m' would
        # then drop -- the witnesses must be real valid vertices
        inf = jnp.float32(np.inf)
        pmax = jnp.where(m[:, None], proj, -inf)
        pmin = jnp.where(m[:, None], proj, inf)
        ext = jnp.concatenate([jnp.argmax(pmax, 0), jnp.argmin(pmin, 0)])
        e = pc[ext]  # (2K, 3) extreme points -- real valid vertices
        de = e[:, None, :] - e[None, :, :]
        l2 = jnp.max(jnp.sum(de * de, -1))  # squared lower bound

        lo = jnp.min(pc, axis=0)
        hi = jnp.max(pc, axis=0)
        signs = jnp.asarray(
            [[sx, sy, sz] for sx in (0, 1) for sy in (0, 1) for sz in (0, 1)],
            jnp.float32,
        )  # (8, 3); degenerate/duplicate corners are harmless
        corners = lo[None, :] + signs * (hi - lo)[None, :]
        dc = pc[:, None, :] - corners[None, :, :]
        ub_corner2 = jnp.max(jnp.sum(dc * dc, -1), axis=1)  # (M,)
        centre = 0.5 * (lo + hi)
        r = jnp.sqrt(jnp.sum((pc - centre) ** 2, -1))
        ub_centre2 = (r + jnp.max(r)) ** 2
        ub2 = jnp.minimum(ub_corner2, ub_centre2)
        keep_any = keep_any | (ub2 * _SLACK >= l2)
        # force-keep the extreme witnesses: an extreme can itself be a
        # provable non-endpoint, but dropping it would move the candidate
        # bounding box and break the pruning-invariance of the reference
        # path's centring (bit-identity).  <= 2K extra vertices.
        keep_any = keep_any.at[ext].set(True)
        lower_sq.append(l2)
    return keep_any & m, jnp.stack(lower_sq)


@dataclasses.dataclass(frozen=True)
class PruneInfo:
    """Host-side pruning statistics (fed to benchmarks / BENCH records)."""

    m_total: int  # input rows (incl. padding)
    m_valid: int  # valid vertices before pruning
    m_kept: int  # surviving candidates (M')
    pruned: bool  # False when pruning was skipped (degenerate input)

    @property
    def keep_fraction(self) -> float:
        return self.m_kept / self.m_valid if self.m_valid else 1.0


def _compact_survivors(verts_np, mask_np, keep):
    """Host-side compaction shared by the single and batched prune paths."""
    m_valid = int(mask_np.sum())
    if m_valid < 2:
        return verts_np, mask_np, PruneInfo(len(verts_np), m_valid, m_valid, False)
    keep = np.asarray(keep)
    m_kept = int(keep.sum())
    if m_kept < 2 or m_kept >= m_valid:
        return verts_np, mask_np, PruneInfo(len(verts_np), m_valid, m_valid, False)
    idx = np.nonzero(keep)[0]
    return (
        np.ascontiguousarray(verts_np[idx]),
        np.ones((m_kept,), bool),
        PruneInfo(len(verts_np), m_valid, m_kept, True),
    )


def prune_vertices(verts, mask, k_dirs: int = 16):
    """Host-side pruning: compact survivors into a dense candidate list.

    Returns ``(verts', mask', info)`` as numpy arrays with
    ``verts'.shape == (M', 3)`` and an all-true mask.  Degenerate inputs
    (fewer than 2 survivors, or nothing pruned) fall back to the originals
    so callers never lose the empty/single-vertex semantics of the kernels.
    """
    verts_np = np.asarray(verts, np.float32)
    mask_np = np.asarray(mask).astype(bool)
    if int(mask_np.sum()) < 2:  # callers reject empty; skip the kernel
        keep = np.zeros(len(verts_np), bool)
    else:
        keep, _ = candidate_keep_mask(verts_np, mask_np, k_dirs=k_dirs)
    return _compact_survivors(verts_np, mask_np, keep)


@functools.partial(jax.jit, static_argnames=("k_dirs",))
def keep_mask_batch(verts, masks, k_dirs: int = 16):
    """Vmapped :func:`candidate_keep_mask` over a (B, M, 3) stack.

    The two-pass pipeline's pass-1 bound: ONE launch computes every case's
    keep mask.  Device in/out -- both the host compaction path
    (:func:`prune_vertices_batch`) and the device compaction path
    (``kernels/compact``) consume the same masks.
    """
    keep, lower = jax.vmap(
        lambda v, m: candidate_keep_mask(v, m, k_dirs=k_dirs)
    )(verts, masks)
    return keep, lower


def plan_compaction(m_total: int, m_valid: int, m_kept: int, bucket_fn):
    """Shared pruned/kept decision for both compaction paths.

    Composes the degenerate-input rule of :func:`_compact_survivors`
    (fewer than 2 valid or surviving vertices, or nothing pruned -> keep
    the originals) with the re-bucketing rule of
    ``ops._rebucket_pruned`` (a survivor bucket no smaller than the input
    wins nothing -> keep the originals).  Returns ``(cap, info)`` where
    ``cap`` is the M' bucket to compact into, or ``None`` when the case
    keeps its original arrays.  Both the host path and the device path
    derive their ``PruneInfo`` from this single function, so the two can
    never drift.
    """
    if m_valid < 2 or m_kept < 2 or m_kept >= m_valid:
        return None, PruneInfo(m_total, m_valid, m_valid, False)
    cap = int(bucket_fn(m_kept))
    if cap >= m_total:
        return None, PruneInfo(m_total, m_valid, m_valid, False)
    return cap, PruneInfo(m_total, m_valid, m_kept, True)


def prune_vertices_batch(verts, masks, k_dirs: int = 16):
    """Batched pass-1 pruning bound for a stack of same-cap cases.

    ``verts``: (B, M, 3), ``masks``: (B, M).  One vmapped keep-mask kernel
    computes every case's bound in a single launch (the batched pipeline's
    pass 1); compaction stays host-side per case because the surviving
    counts M' are ragged.  Returns a list of B ``(verts', mask', info)``
    triples with the same degenerate-input semantics as
    :func:`prune_vertices`.  Tie-breaks in the vmapped extreme search can
    differ from the single-case path, so the surviving *sets* may differ --
    both always contain every true farthest-pair endpoint, which is the
    property the downstream diameters depend on.
    """
    verts_np = np.asarray(verts, np.float32)
    masks_np = np.asarray(masks).astype(bool)
    keep, _ = keep_mask_batch(verts_np, masks_np, k_dirs)
    keep = np.asarray(keep)
    return [
        _compact_survivors(v, m, k)
        for v, m, k in zip(verts_np, masks_np, keep)
    ]
