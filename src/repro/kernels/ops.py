"""Jitted, backend-dispatched wrappers around the shape-feature kernels.

Public entry points used by ``repro.core`` -- each takes a ``backend``
keyword resolved by ``repro.core.dispatcher`` and routes to the Pallas TPU
kernel, its interpret-mode twin, or the pure-jnp reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dispatcher
from repro.kernels import diameter as _diam
from repro.kernels import marching_cubes as _mc
from repro.kernels import ref as _ref


def mc_volume_area(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), *, backend=None, **kw):
    """(mesh_volume, surface_area) of the isosurface of ``vol``."""
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _ref.mc_volume_area(vol, iso, spacing, chunk_z=kw.get("chunk_z", 32))
    return _mc.mc_volume_area_pallas(
        vol,
        iso,
        spacing,
        block=kw.get("block", (8, 8, 8)),
        chunk=kw.get("chunk", 512),
        **dispatcher.kernel_kwargs(b),
    )


def max_diameters(verts, mask, *, backend=None, **kw):
    """(4,) [3D, Slice(xy), Row(xz), Column(yz)] max diameters."""
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _ref.max_diameters(verts, mask, row_block=kw.get("row_block", 128))
    return _diam.max_diameters_pallas(
        verts,
        mask,
        block=kw.get("block", 256),
        variant=kw.get("variant", "seqacc"),
        **dispatcher.kernel_kwargs(b),
    )


def vertex_fields(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), origin=(0.0, 0.0, 0.0)):
    """Dense dedup vertex fields (elementwise; same path on all backends)."""
    return _ref.vertex_fields(vol, iso, spacing, origin)


def count_vertices(fields):
    return _ref.count_vertices(fields)


def compact_vertices(fields, max_vertices):
    return _ref.compact_vertices(fields, max_vertices)


def vertex_bucket(n: int, minimum: int = 512) -> int:
    """Static padding cap for a vertex count (limits recompilation)."""
    b = minimum
    while b < n:
        b *= 2
    return b
