"""Jitted, backend-dispatched wrappers around the shape-feature kernels.

Public entry points used by ``repro.core`` -- each takes a ``backend``
keyword resolved by ``repro.core.dispatcher`` and routes to the Pallas TPU
kernel, its interpret-mode twin, or the pure-jnp reference path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatcher
from repro.kernels import diameter as _diam
from repro.kernels import marching_cubes as _mc
from repro.kernels import ref as _ref


def mc_volume_area(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), *, backend=None, **kw):
    """(mesh_volume, surface_area) of the isosurface of ``vol``.

    ``block='auto'`` (the default) resolves the measured-best MC
    (brick, chunk) for the padded-volume bucket from the autotune cache
    (see ``repro.runtime.autotune``).  Resolution may sweep, so traced
    callers must pass a concrete ``block`` AND ``chunk`` (resolved outside
    the trace via ``dispatcher.mc_config``).
    """
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        # the ref path's only configuration axis is the scan slab depth;
        # honour a kernel-style ``chunk`` too so the executor's mc_chunk
        # becomes the device-budget lever on every backend (tiled path)
        chunk_z = kw.get("chunk_z", kw.get("chunk") or 32)
        return _ref.mc_volume_area(vol, iso, spacing, chunk_z=chunk_z)
    block, chunk = kw.get("block", "auto"), kw.get("chunk")
    if block is None or block == "auto" or chunk is None:
        block, chunk = dispatcher.mc_config(b, np.shape(vol), block, chunk)
    return _mc.mc_volume_area_pallas(
        vol,
        iso,
        spacing,
        block=tuple(block),
        chunk=chunk,
        **dispatcher.kernel_kwargs(b),
    )


def mc_volume_area_batch(vols, iso=0.5, spacings=None, *, backend=None,
                         block=None, chunk=None):
    """Batched :func:`mc_volume_area` over a device stack (pass 2a).

    ``vols``: (B, nx, ny, nz) bucket-padded masks, ``spacings``: (B, 3)
    -> (B, 2) [volume, area] rows.  The device-resident MC feed: callers
    (the executor's staged pass 2a) slice stacks straight off a
    bucket-keyed device pool, so no host re-stacking happens per chunk.
    This entry point is designed to be TRACED (it sits under the
    executor's sharded jit), so ``block``/``chunk`` must already be
    concrete for kernel backends -- resolve them outside the trace via
    ``dispatcher.mc_config``; the 'ref' backend has no configuration axis.
    """
    b = dispatcher.resolve_backend(backend)
    vols = jnp.asarray(vols, jnp.float32)
    if spacings is None:
        spacings = jnp.ones((vols.shape[0], 3), jnp.float32)
    spacings = jnp.asarray(spacings, jnp.float32)
    if b == "ref":
        chunk_z = chunk if isinstance(chunk, int) else 32

        def one(args):
            vol, sp = args
            v, a = _ref.mc_volume_area(vol, iso, sp, chunk_z=chunk_z)
            return jnp.stack([v, a])

        return jax.lax.map(one, (vols, spacings))
    if block is None or block == "auto" or chunk is None:
        raise ValueError(
            "mc_volume_area_batch is traced: resolve (block, chunk) outside "
            "the trace via dispatcher.mc_config"
        )
    return _mc.mc_volume_area_batch_pallas(
        vols,
        iso,
        spacings,
        block=tuple(block),
        chunk=chunk,
        **dispatcher.kernel_kwargs(b),
    )


def mc_tile_partials(slab, iso=0.5, spacing=(1.0, 1.0, 1.0), *, backend=None,
                     k0=0, chunk_z=32, full_shape=None, block=None,
                     chunk=None):
    """Tile accumulator: MC partial sums for one halo-closed z-window.

    The tiled pipeline's per-tile reduction entry (``core/tiled.py``).
    ``slab`` spans the window's cells plus the closing plane
    (``k * chunk_z + 1`` deep for ref, ``k * block[2] + 1`` for kernel
    backends); ``k0`` is the window's first global slab/brick-row index.
    Returns per-slab ``(dvol, darea)`` 1-D arrays on the ref backend and
    per-brick ``(vol_p, area_p)`` (nbx, nby, nbz_window) arrays on the
    kernel backends.  Partials are NOT reduced here: the caller re-folds
    them in the in-core path's global order so the f32 accumulation is
    bit-identical (see :func:`repro.kernels.ref.mc_slab_partials` and
    :func:`repro.kernels.marching_cubes.mc_brick_partials_pallas`).
    """
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _ref.mc_slab_partials(slab, iso, spacing, chunk_z=chunk_z, k0=k0)
    if full_shape is None:
        raise ValueError("kernel backends need full_shape for the centred "
                         "origin")
    if block is None or block == "auto" or chunk is None:
        block, chunk = dispatcher.mc_config(b, tuple(full_shape), block, chunk)
    cz = int(block[2])
    return _mc.mc_brick_partials_pallas(
        slab, iso, spacing,
        full_shape=tuple(full_shape),
        z_cell_offset=np.float32(k0 * cz),
        block=tuple(block), chunk=chunk,
        **dispatcher.kernel_kwargs(b),
    )


def mc_tile_finalize(vol_partials, area_partials, *, backend=None):
    """Fold assembled tile partials into ``(volume, area)``.

    ref: a host ``np.float32`` left fold over the global-slab-order
    deltas -- IEEE-754 single adds, the same op sequence as the in-core
    scan carry.  Kernel backends: one jitted reduce over the assembled
    full brick grid (:func:`mc_partials_finalize` -- the same reduction
    shape the in-core kernel entry ends with).
    """
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        sv = np.float32(0.0)
        sa = np.float32(0.0)
        for dv, da in zip(np.asarray(vol_partials, np.float32),
                          np.asarray(area_partials, np.float32)):
            sv = np.float32(sv + dv)
            sa = np.float32(sa + da)
        return np.abs(sv), sa
    v, a = _mc.mc_partials_finalize(jnp.asarray(vol_partials, jnp.float32),
                                    jnp.asarray(area_partials, jnp.float32))
    return np.float32(v), np.float32(a)


def max_diameters(verts, mask, *, backend=None, **kw):
    """(4,) [3D, Slice(xy), Row(xz), Column(yz)] max diameters.

    ``variant='auto'`` resolves (variant, block) from the autotune cache
    for this vertex bucket (see ``repro.runtime.autotune``).
    """
    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _ref.max_diameters(verts, mask, row_block=kw.get("row_block", 128))
    variant, block = dispatcher.diameter_config(
        b, verts.shape[0], kw.get("variant", "seqacc"), kw.get("block")
    )
    return _diam.max_diameters_pallas(
        verts,
        mask,
        block=block,
        variant=variant,
        **dispatcher.kernel_kwargs(b),
    )


def _rebucket_pruned(orig_verts, orig_mask, v2, m2, info):
    """Pad a pruned candidate list back up to its M' vertex bucket."""
    if not info.pruned:
        return v2, m2, info
    cap = vertex_bucket(info.m_kept)
    if cap >= info.m_total:
        # the survivor bucket (>= 512 floor) is no smaller than the input,
        # so re-bucketing would not shrink the padded pair sweep -- keep
        # the originals and report the stage as a no-op
        return (
            np.asarray(orig_verts, np.float32),
            np.asarray(orig_mask).astype(bool),
            dataclasses.replace(info, m_kept=info.m_valid, pruned=False),
        )
    pad = cap - len(v2)
    if pad > 0:
        v2 = np.pad(v2, ((0, pad), (0, 0)))
        m2 = np.pad(m2, (0, pad))
    return v2, m2, info


def prune_candidates(verts, mask, k_dirs: int = 16):
    """Exact host-side candidate pruning + re-bucketing for the pair sweep.

    Shrinks the vertex list to the provably-sufficient candidate set
    (identical diameters: bit-for-bit on the Pallas variants, up to f32
    rounding on the ref path -- see ``repro.kernels.prune``), then
    pads it back up to the M' vertex bucket.  Returns
    ``(verts', mask', info)``; on degenerate inputs the originals come
    back unchanged.
    """
    from repro.kernels import prune as _prune

    v2, m2, info = _prune.prune_vertices(verts, mask, k_dirs=k_dirs)
    return _rebucket_pruned(verts, mask, v2, m2, info)


def compact_survivors_batch(verts, keep, cap: int, *, backend=None,
                            block="auto"):
    """Batched device-resident segmented compaction (pass 1b).

    Scatters each case's keep-mask survivors into the first M' slots of a
    static ``cap`` bucket (stable order, zero padding -- bit-identical to
    the host ``np.nonzero`` + ``np.pad`` path it replaces).  ``verts``:
    (B, M, 3), ``keep``: (B, M) -> ``(out, mask, n)`` device arrays with
    ``out``: (B, cap, 3), ``mask``: (B, cap) bool, ``n``: (B,) int32 total
    survivor counts.  ``block='auto'`` resolves the measured-best scatter
    block for the M bucket from the autotune cache; resolution may sweep,
    so traced callers must resolve it first via ``dispatcher.compact_config``.
    """
    from repro.kernels import compact as _compact

    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _compact.compact_batch_ref(verts, keep, cap)
    blk = dispatcher.compact_config(b, np.shape(verts)[1], block)
    return _compact.compact_batch_pallas(
        verts, keep, cap, block=blk, **dispatcher.kernel_kwargs(b)
    )


def prune_candidates_batch(verts, masks, k_dirs: int = 16):
    """Batched :func:`prune_candidates` for a (B, M, 3) stack of cases.

    The keep-mask bound runs as ONE vmapped kernel over the whole stack
    (the two-pass pipeline's pass 1); compaction + re-bucketing are per
    case HOST-side because the pruned counts M' are ragged.  Returns a
    list of B ``(verts', mask', info)`` triples.  This is the
    ``device_compact=False`` path of the batched pipeline; the default
    device-resident path pairs :func:`repro.kernels.prune.keep_mask_batch`
    with :func:`compact_survivors_batch` instead.
    """
    from repro.kernels import prune as _prune

    verts_np = np.asarray(verts, np.float32)
    masks_np = np.asarray(masks)
    return [
        _rebucket_pruned(v, m, v2, m2, info)
        for (v, m), (v2, m2, info) in zip(
            zip(verts_np, masks_np),
            _prune.prune_vertices_batch(verts_np, masks_np, k_dirs=k_dirs),
        )
    ]


def firstorder_packed_batch(images, masks, *, backend=None, n_bins=32,
                            block=None):
    """Batched packed first-order stats over bucket-padded stacks.

    ``images``/``masks``: (B, nx, ny, nz) device stacks ->
    (B, packed_width) stats rows ([count, sum, sum_sq, hist, lo, hi,
    bin_width]; see ``repro.kernels.firstorder``).  Designed to be
    TRACED (it runs under the executor's sharded jit), so ``block`` must
    already be concrete for kernel backends -- resolve it outside the
    trace via ``dispatcher.firstorder_config``; the 'ref' backend has no
    configuration axis.  Batched rows are bit-identical to single-case
    extraction on every backend (canonical-chunk contract); the feature
    row derives host-side via ``firstorder.features_from_packed_np``.
    """
    from repro.kernels import firstorder as _fo

    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _fo.firstorder_packed_batch_ref(images, masks, n_bins=n_bins)
    if block is None or block == "auto":
        raise ValueError(
            "firstorder_packed_batch is traced: resolve block outside the "
            "trace via dispatcher.firstorder_config"
        )
    return _fo.firstorder_packed_batch_pallas(
        images, masks, n_bins=n_bins, block=int(block),
        **dispatcher.kernel_kwargs(b),
    )


def firstorder_features_batch(images, masks, *, backend=None, n_bins=32,
                              block=None):
    """Batched first-order intensity rows: (B, 9) (host-finalised).

    Convenience wrapper: :func:`firstorder_packed_batch` + the shared
    host derivation.  NOT traceable -- traced callers (the executor)
    consume the packed entry and finalise after the fetch.
    """
    from repro.kernels import firstorder as _fo

    return _fo.features_from_packed_np(
        firstorder_packed_batch(images, masks, backend=backend,
                                n_bins=n_bins, block=block),
        n_bins,
    )


def glcm_matrix_batch(images, masks, *, backend=None, n_bins=32, block=None):
    """Batched symmetric GLCM count matrices: (B, n_bins, n_bins).

    Counts are integer-valued f32 and exactly equal across backends and
    block sizes (0/1 contributions; see ``repro.kernels.glcm``).  Traced
    callers must resolve ``block`` via ``dispatcher.glcm_config``.
    """
    from repro.kernels import glcm as _glcm

    b = dispatcher.resolve_backend(backend)
    if b == "ref":
        return _glcm.glcm_matrix_batch_ref(images, masks, n_bins=n_bins)
    if block is None or block == "auto":
        raise ValueError(
            "glcm_matrix_batch is traced: resolve block outside the trace "
            "via dispatcher.glcm_config"
        )
    return _glcm.glcm_matrix_batch_pallas(
        images, masks, n_bins=n_bins, block=int(block),
        **dispatcher.kernel_kwargs(b),
    )


def glcm_features_batch(images, masks, *, backend=None, n_bins=32,
                        block=None):
    """Batched Haralick GLCM rows: (B, 4) [contrast, corr, idm, energy].

    Convenience wrapper: :func:`glcm_matrix_batch` + the shared host
    derivation.  NOT traceable -- traced callers (the executor) consume
    the matrix entry and finalise after the fetch.
    """
    from repro.kernels import glcm as _glcm

    return _glcm.glcm_features_from_matrix_np(
        glcm_matrix_batch(images, masks, backend=backend, n_bins=n_bins,
                          block=block),
        n_bins,
    )


def vertex_fields(vol, iso=0.5, spacing=(1.0, 1.0, 1.0), origin=(0.0, 0.0, 0.0),
                  index_offset=None):
    """Dense dedup vertex fields (elementwise; same path on all backends)."""
    return _ref.vertex_fields(vol, iso, spacing, origin,
                              index_offset=index_offset)


def tile_vertex_fields(slab, iso, spacing, index_offset):
    """Jitted per-tile vertex fields in the full volume's index frame."""
    return _ref.tile_vertex_fields(slab, iso, spacing, index_offset)


def count_vertices(fields):
    return _ref.count_vertices(fields)


def compact_vertices(fields, max_vertices):
    return _ref.compact_vertices(fields, max_vertices)


# Single-source M-bucket ladder: defined in the (kernel-free) plan layer,
# re-exported here for the kernel-side callers that predate the split.
from repro.core.plan import vertex_bucket  # noqa: E402, F401
