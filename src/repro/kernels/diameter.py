"""Pallas TPU kernel: maximum pairwise vertex distances (3D + 3 planes).

This is the PyRadiomics-cuda hot spot: 95.7%-99.9% of shape-feature time is
spent finding the farthest vertex pair (paper Table 2).  The CUDA version
assigns vertex-pair subsets to threads with per-thread max accumulators and a
final reduction; on TPU we tile the O(M^2) pair space into (B x B) VMEM
blocks walked by the Pallas grid.

Per block-pair (I, J):
    q_a[i, j] = (a_i - a_j)^2          per axis a in {x, y, z}   (VPU)
    d3  = qx + qy + qz                  max 3D diameter
    dxy = qx + qy                       'Slice'  plane (ignore z)
    dxz = qx + qz                       'Row'    plane (ignore y)
    dyz = qy + qz                       'Column' plane (ignore x)
masked by valid_i * valid_j, max-reduced into per-block partials (or an
in-kernel accumulator -- see variants).

Optimization variants (the TPU analogue of the paper's Fig. 1 study):
    'naive'  : one pass per combo (4 separate kernel launches), full grid.
    'fused'  : all 4 combos in one pass, full grid.          [mem-access opt]
    'tri'    : fused + predicated skip of lower-triangle blocks (j < i).
               DMA still runs; compute is skipped.            [load balance]
    'seqacc' : fused + triangular + single in-kernel accumulator block that
               is revisited across the sequential TPU grid -- the analogue of
               the paper's per-thread local accumulators (vs. the partial-
               output blocks, which are its 'block-based reduction').
    'tri_prefetch': fused + a 1-D grid over only the nb*(nb+1)/2 upper-
               triangle block pairs, with the (i, j) schedule delivered via
               scalar prefetch so skipped blocks cost neither DMA nor compute
               -- the TPU-native version of CUDA early-exit load balancing.
    'nomask' : tri_prefetch without the mask streams: invalid slots are
               pre-filled with the first valid vertex, so the mask DMA and
               the per-pair select disappear.
    'gram'   : tri_prefetch schedule, but the per-tile pair distances are
               computed on the MXU via the (augmented) Gram identity
                   |r_i - c_j|^2 = |r_i|^2 + |c_j|^2 - 2 <r_i, c_j>
               realised per axis as [r^2, 1, -2r] @ [1, c^2, c]^T -- the
               rank-1 cross term and both norm terms ride in one per-axis
               (B,3)x(3,B) product, batched over the 3 axes into a single
               ``dot_general``.  The per-axis products stay separate, so
               all 4 combos (3D/xy/xz/yz) are served from the same 3 MXU
               products; the VPU only does combo adds + select + max, not
               the subtract-square sweep.

Exact candidate pruning (``repro.kernels.prune``) can shrink M -> M' before
any variant runs; the result is guaranteed identical (the farthest pair per
combo always survives).  ``repro.runtime.autotune`` sweeps (variant, block)
per vertex bucket and caches the measured winner.

Coordinates are stored SoA as (3, M) (the paper's '1D arrays' layout): the
lane dimension is the vertex index, so loads are contiguous 128-lane vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = np.float32(-1e30)
VARIANTS = ("naive", "fused", "tri", "seqacc", "tri_prefetch", "nomask", "gram")

# variants scheduled on the triangular scalar-prefetch 1-D grid
_PREFETCH_VARIANTS = ("tri_prefetch", "gram")


def _pairwise_combos(rows, cols, rmask, cmask, combos):
    """(len(combos),) partial maxima for one (B, B) tile."""
    qs = []
    for a in range(3):
        d = rows[a][:, None] - cols[a][None, :]
        qs.append(d * d)
    valid = (rmask[0][:, None] > 0.0) & (cmask[0][None, :] > 0.0)
    outs = []
    for combo in combos:
        s = functools.reduce(lambda x, y: x + y, [qs[a] for a in combo])
        s = jnp.where(valid, s, NEG)
        outs.append(jnp.max(s))
    return jnp.stack(outs)


_ALL_COMBOS = ((0, 1, 2), (0, 1), (0, 2), (1, 2))  # 3D, xy, xz, yz


def _pairwise_combos_gram(rows, cols, rmask, cmask, combos):
    """(len(combos),) tile maxima via the augmented Gram identity (MXU).

    Per axis a, the whole (B, B) squared-difference matrix is ONE K=3
    matrix product: with l = [r^2, 1, -2r] (B, 3) and m = [1, c^2, c]^T
    (3, B),

        (l @ m)[i, j] = r_i^2 + c_j^2 - 2 r_i c_j = (r_i - c_j)^2,

    i.e. the norm terms of |r|^2 + |c|^2 - 2<r, c> ride in the same
    per-axis (B,3)x(3,B) ``dot_general`` as the rank-1 cross term.  The
    three axis products are batched into a single call and kept separate,
    so all 4 combos (3D/xy/xz/yz) are served from the same 3 MXU products;
    the VPU only does the per-combo adds + select + max, not the
    subtract-square sweep.
    """
    ones = jnp.ones_like(rows)
    lhs = jnp.stack([rows * rows, ones, -2.0 * rows], axis=-1)  # (3, B, 3)
    rhs = jnp.stack([ones, cols * cols, cols], axis=1)  # (3, 3, B)
    q = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (3, B, B): per-axis squared differences
    valid = (rmask[0][:, None] > 0.0) & (cmask[0][None, :] > 0.0)
    outs = []
    for combo in combos:
        s = functools.reduce(lambda x, y: x + y, [q[a] for a in combo])
        s = jnp.where(valid, s, NEG)
        outs.append(jnp.max(s))
    return jnp.stack(outs)


def _kernel_partial(vr, mr, vc, mc, out, *, combos, triangular):
    i, j = pl.program_id(0), pl.program_id(1)

    if triangular:
        @pl.when(j >= i)
        def _():
            out[0, 0, :] = _pairwise_combos(vr[:], vc[:], mr[:], mc[:], combos)

        @pl.when(j < i)
        def _():
            out[0, 0, :] = jnp.full((len(combos),), NEG)
    else:
        out[0, 0, :] = _pairwise_combos(vr[:], vc[:], mr[:], mc[:], combos)


def _kernel_seqacc(vr, mr, vc, mc, out, *, combos):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _():
        out[0, :] = jnp.full((len(combos),), NEG)

    @pl.when(j >= i)
    def _():
        part = _pairwise_combos(vr[:], vc[:], mr[:], mc[:], combos)
        out[0, :] = jnp.maximum(out[0, :], part)


def _kernel_tri_prefetch(ij_ref, vr, mr, vc, mc, out, *, combos, tile_fn):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        out[0, :] = jnp.full((len(combos),), NEG)

    part = tile_fn(vr[:], vc[:], mr[:], mc[:], combos)
    out[0, :] = jnp.maximum(out[0, :], part)


def _combos_nomask(rows, cols, combos):
    """Mask-free tile maxima: inputs are pre-filled so every slot is valid."""
    qs = []
    for a in range(3):
        d = rows[a][:, None] - cols[a][None, :]
        qs.append(d * d)
    outs = []
    for combo in combos:
        s = functools.reduce(lambda x, y: x + y, [qs[a] for a in combo])
        outs.append(jnp.max(s))
    return jnp.stack(outs)


def _kernel_nomask(ij_ref, vr, vc, out, *, combos):
    """Beyond-paper variant (§Perf/3): triangular scalar-prefetch schedule
    with NO mask streams.  Invalid slots were pre-filled with the first
    valid vertex (a duplicated point can never raise the max), so the mask
    DMA (2 of 8 input streams) and the per-pair select disappear."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        out[0, :] = jnp.full((len(combos),), NEG)

    part = _combos_nomask(vr[:], vc[:], combos)
    out[0, :] = jnp.maximum(out[0, :], part)


def _pad_inputs(verts, mask, block):
    """SoA-transpose and pad to a block multiple; padding is invalid."""
    verts = jnp.asarray(verts, jnp.float32)
    mask = jnp.asarray(mask).astype(jnp.float32)
    M = verts.shape[0]
    nb = max(1, -(-M // block))
    pad = nb * block - M
    v = jnp.pad(verts, ((0, pad), (0, 0))).T  # (3, nb*B)
    m = jnp.pad(mask, (0, pad))[None, :]  # (1, nb*B)
    return v, m, nb


@functools.partial(
    jax.jit, static_argnames=("block", "variant", "interpret", "combos")
)
def max_diameters_sq_pallas(
    verts,
    mask,
    *,
    block: int = 256,
    variant: str = "fused",
    interpret: bool = False,
    combos=_ALL_COMBOS,
):
    """Maximum squared pairwise distances, Pallas TPU kernel.

    Returns (len(combos),) float32 squared maxima, default
    [3D, xy(Slice), xz(Row), yz(Column)].
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    if variant == "naive":
        outs = [
            max_diameters_sq_pallas(
                verts, mask, block=block, variant="fused",
                interpret=interpret, combos=(c,),
            )
            for c in combos
        ]
        return jnp.concatenate(outs)

    v, m, nb = _pad_inputs(verts, mask, block)
    nc = len(combos)

    if variant == "nomask":
        # pre-fill invalid slots with the first valid vertex; padding from
        # _pad_inputs is masked-out, so it is filled too
        first = jnp.argmax(m[0] > 0.0)
        v = jnp.where(m > 0.0, v, v[:, first][:, None])
        ii, jj = np.triu_indices(nb)
        ij = jnp.asarray(np.stack([ii, jj]).astype(np.int32))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(len(ii),),
            in_specs=[
                pl.BlockSpec((3, block), lambda t, ij: (0, ij[0, t])),
                pl.BlockSpec((3, block), lambda t, ij: (0, ij[1, t])),
            ],
            out_specs=pl.BlockSpec((1, nc), lambda t, ij: (0, 0)),
        )
        out = pl.pallas_call(
            functools.partial(_kernel_nomask, combos=combos),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((1, nc), jnp.float32),
            interpret=interpret,
        )(ij, v, v)
        return jnp.maximum(out[0], 0.0)

    row_spec = pl.BlockSpec((3, block), lambda i, j: (0, i))
    col_spec = pl.BlockSpec((3, block), lambda i, j: (0, j))
    rmask_spec = pl.BlockSpec((1, block), lambda i, j: (0, i))
    cmask_spec = pl.BlockSpec((1, block), lambda i, j: (0, j))

    if variant in ("fused", "tri"):
        out = pl.pallas_call(
            functools.partial(
                _kernel_partial, combos=combos, triangular=(variant == "tri")
            ),
            grid=(nb, nb),
            in_specs=[row_spec, rmask_spec, col_spec, cmask_spec],
            out_specs=pl.BlockSpec((1, 1, nc), lambda i, j: (i, j, 0)),
            out_shape=jax.ShapeDtypeStruct((nb, nb, nc), jnp.float32),
            interpret=interpret,
        )(v, m, v, m)
        best = jnp.max(out, axis=(0, 1))
    elif variant == "seqacc":
        out = pl.pallas_call(
            functools.partial(_kernel_seqacc, combos=combos),
            grid=(nb, nb),
            in_specs=[row_spec, rmask_spec, col_spec, cmask_spec],
            out_specs=pl.BlockSpec((1, nc), lambda i, j: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, nc), jnp.float32),
            interpret=interpret,
        )(v, m, v, m)
        best = out[0]
    else:  # tri_prefetch / gram: triangular scalar-prefetch schedule
        ii, jj = np.triu_indices(nb)
        nsteps = len(ii)
        ij = jnp.asarray(np.stack([ii, jj]).astype(np.int32))  # (2, T)
        tile_fn = (
            _pairwise_combos_gram if variant == "gram" else _pairwise_combos
        )

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nsteps,),
            in_specs=[
                pl.BlockSpec((3, block), lambda t, ij: (0, ij[0, t])),
                pl.BlockSpec((1, block), lambda t, ij: (0, ij[0, t])),
                pl.BlockSpec((3, block), lambda t, ij: (0, ij[1, t])),
                pl.BlockSpec((1, block), lambda t, ij: (0, ij[1, t])),
            ],
            out_specs=pl.BlockSpec((1, nc), lambda t, ij: (0, 0)),
        )
        out = pl.pallas_call(
            functools.partial(
                _kernel_tri_prefetch, combos=combos, tile_fn=tile_fn
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((1, nc), jnp.float32),
            interpret=interpret,
        )(ij, v, m, v, m)
        best = out[0]
    return jnp.maximum(best, 0.0)


def max_diameters_pallas(verts, mask, **kw):
    """(4,) float32 diameters [3D, Slice(xy), Row(xz), Column(yz)]."""
    return jnp.sqrt(max_diameters_sq_pallas(verts, mask, **kw))


def flop_estimate(M: int, block: int, variant: str) -> float:
    """Structural VPU cost model used by the §Perf iteration log.

    For 'gram' this counts only the vector-unit work (combo assembly, mask
    select, max-reduce); the subtract-square sweep moved to the matrix unit
    and is reported separately by :func:`mxu_flop_estimate`.
    """
    nb = -(-M // block)
    if variant in ("naive",):
        tiles = nb * nb * 4
        per_tile = block * block * (3 * 2 + 3 + 2)
    elif variant == "fused":
        tiles = nb * nb
        per_tile = block * block * (3 * 2 + 5 + 1 + 4 + 4)
    elif variant == "nomask":  # no valid-mask compare/select per combo
        tiles = nb * (nb + 1) // 2
        per_tile = block * block * (3 * 2 + 5 + 4)
    elif variant == "gram":  # per-pair: combo adds + select + max only
        tiles = nb * (nb + 1) // 2
        per_tile = block * block * (5 + 4 + 4)
    else:  # tri / seqacc / tri_prefetch
        tiles = nb * (nb + 1) // 2
        per_tile = block * block * (3 * 2 + 5 + 1 + 4 + 4)
    return float(tiles) * per_tile


def mxu_flop_estimate(M: int, block: int, variant: str) -> float:
    """Matrix-unit FLOPs: 3 axis-batched K=3 (B,3)x(3,B) products per tile
    ('gram' only): 3 * 2*3*B^2."""
    if variant != "gram":
        return 0.0
    nb = -(-M // block)
    tiles = nb * (nb + 1) // 2
    return float(tiles) * (3 * 2.0 * 3 * block * block)


def bytes_estimate(M: int, block: int, variant: str) -> float:
    nb = -(-M // block)
    if variant in ("naive", "fused", "tri"):
        tiles = nb * nb  # 'tri' skips compute but still DMAs the block
    else:
        tiles = nb * (nb + 1) // 2
    streams = 3 if variant == "nomask" else (3 + 1)  # coords (+ mask)
    scale = 4 if variant == "naive" else 1
    return float(tiles) * (2 * streams * block * 4) * scale
