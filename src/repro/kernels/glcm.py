"""GLCM texture family: co-occurrence accumulation as one-hot matmuls.

The gray-level co-occurrence matrix counts ordered pairs of quantized
intensities at the distance-1 axial offsets (:data:`OFFSETS`), restricted
to pairs whose BOTH voxels are inside the mask.  Accumulating it is a
scatter-add over ``(q1, q2)`` index pairs -- the exact shape of problem
``kernels/compact.py`` already solved with the one-hot-matmul trick: a
0/1 matrix product performs the scatter on the MXU, and because every
contribution is 0 or 1 the accumulated counts are INTEGERS stored in
f32, exact up to 2**24.  Integer-exact addition is associative, so the
blocked Pallas accumulation equals the reference scatter bit-for-bit and
the autotuned ``block`` is a pure performance axis.

Feature derivation (Haralick contrast / correlation / inverse difference
moment (homogeneity) / joint energy) happens OUTSIDE the kernel, on the
HOST in numpy, from the symmetrised count matrix via one shared function
(:func:`glcm_features_from_matrix_np`) -- in-graph derivation would let
XLA contract the f32 arithmetic differently per batch shape (see
``kernels/firstorder.py``), whereas the count matrix is integer-exact,
so host derivation makes the feature rows bitwise identical across
backends AND batch depths.  A case with no valid pairs (single voxel,
empty mask) yields an all-zero feature row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

N_BINS = 32
DEFAULT_BLOCK = 2048
#: distance-1 axial co-occurrence offsets (symmetrised afterwards, so the
#: opposite directions are covered by the transpose)
OFFSETS = ((1, 0, 0), (0, 1, 0), (0, 0, 1))

FEATURES = ("Contrast", "Correlation", "Idm", "JointEnergy")
N_FEATURES = len(FEATURES)


def pair_arrays(q, m):
    """Flatten one case's co-occurrence pairs: ``(q1, q2, valid)``.

    ``q`` is the f32 bin-id volume, ``m`` the f32 mask; each offset in
    :data:`OFFSETS` contributes the overlapping slab of (voxel, neighbour)
    pairs.  The concatenated length is static given the volume shape, so
    the executor's shape buckets key the pair length too.
    """
    q1s, q2s, vs = [], [], []
    for off in OFFSETS:
        a = tuple(slice(None, -o) if o else slice(None) for o in off)
        b = tuple(slice(o, None) for o in off)
        q1s.append(q[a].reshape(-1))
        q2s.append(q[b].reshape(-1))
        vs.append((m[a] * m[b]).reshape(-1))
    return jnp.concatenate(q1s), jnp.concatenate(q2s), jnp.concatenate(vs)


def _quantize_batch(images, masks, n_bins):
    imgs = jnp.asarray(images, jnp.float32)
    m = (jnp.asarray(masks) > 0).astype(jnp.float32)
    B = imgs.shape[0]
    lo, hi = jax.vmap(_ref.intensity_range)(
        imgs.reshape(B, -1), m.reshape(B, -1)
    )
    bcast = (B,) + (1,) * (imgs.ndim - 1)
    q, _ = _ref.quantize_intensity(
        imgs, m, lo.reshape(bcast), hi.reshape(bcast), n_bins
    )
    return q, m


def glcm_matrix_ref(image, mask, n_bins: int = N_BINS):
    """Single-case symmetric co-occurrence counts via ``.at[].add`` scatter."""
    q, m = _quantize_batch(jnp.asarray(image)[None], jnp.asarray(mask)[None],
                           n_bins)
    q1, q2, v = pair_arrays(q[0], m[0])
    idx = q1.astype(jnp.int32) * n_bins + q2.astype(jnp.int32)
    counts = jnp.zeros((n_bins * n_bins,), jnp.float32).at[idx].add(v)
    g = counts.reshape(n_bins, n_bins)
    return g + g.T


def glcm_features_from_matrix_np(mat, n_bins: int = N_BINS) -> np.ndarray:
    """``(..., N_FEATURES)`` Haralick rows from symmetric count matrices.

    HOST-side numpy, shared by every backend (see module docstring).
    ``correlation`` of a zero-variance (single gray level) matrix is
    defined as 1.0, matching PyRadiomics; a matrix with no pairs at all
    yields an all-zero row.
    """
    mat = np.asarray(mat, np.float32)
    total = np.sum(mat, axis=(-2, -1))
    P = mat / np.maximum(total, 1.0)[..., None, None]
    i = np.arange(n_bins, dtype=np.float32)[:, None]
    j = np.arange(n_bins, dtype=np.float32)[None, :]
    diff2 = (i - j) * (i - j)
    contrast = np.sum(diff2 * P, axis=(-2, -1))
    idm = np.sum(P / (1.0 + diff2), axis=(-2, -1))
    energy = np.sum(P * P, axis=(-2, -1))
    # marginal stats (symmetric matrix: px == py)
    px = np.sum(P, axis=-1)
    levels = np.arange(n_bins, dtype=np.float32)
    mu = np.sum(levels * px, axis=-1)
    sig2 = np.sum(
        (levels - mu[..., None]) * (levels - mu[..., None]) * px, axis=-1
    )
    corr = np.where(
        sig2 > 0,
        (np.sum(i * j * P, axis=(-2, -1)) - mu * mu)
        / np.where(sig2 > 0, sig2, 1.0),
        1.0,
    )
    row = np.stack([contrast, corr, idm, energy], axis=-1)
    return np.where(total[..., None] > 0, row, 0.0).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("n_bins",))
def glcm_matrix_batch_ref(images, masks, n_bins: int = N_BINS):
    """``(B, n_bins, n_bins)`` symmetric count matrices (scatter path)."""
    def one(args):
        img, m = args
        return glcm_matrix_ref(img, m, n_bins)

    return jax.lax.map(
        one,
        (jnp.asarray(images, jnp.float32), jnp.asarray(masks, jnp.float32)),
    )


def glcm_features_batch_ref(images, masks, n_bins: int = N_BINS):
    """``(B, N_FEATURES)`` rows: scatter matrices + host derivation.

    NOT traceable (host-side numpy derivation by design); traced callers
    consume :func:`glcm_matrix_batch_ref` and finalise after the fetch.
    """
    return glcm_features_from_matrix_np(
        glcm_matrix_batch_ref(images, masks, n_bins), n_bins
    )


def _glcm_kernel(q1ref, q2ref, vref, out, *, block: int, n_bins: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out[...] = jnp.zeros_like(out)

    q1 = q1ref[0, 0, :]
    q2 = q2ref[0, 0, :]
    v = vref[0, 0, :]
    cols = jax.lax.broadcasted_iota(jnp.float32, (block, n_bins), 1)
    # invalid/padded pairs are zeroed on the LEFT factor only: one dead
    # row in oh1 kills the whole pair
    oh1 = ((q1[:, None] == cols) & (v[:, None] > 0)).astype(jnp.float32)
    oh2 = (q2[:, None] == cols).astype(jnp.float32)
    # scatter-by-matmul: counts[a, b] += sum_p oh1[p, a] * oh2[p, b];
    # 0/1 contributions -> integer-valued f32, exact
    out[0] += jax.lax.dot_general(
        oh1, oh2,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit,
                   static_argnames=("n_bins", "block", "interpret"))
def glcm_matrix_batch_pallas(images, masks, *, n_bins: int = N_BINS,
                             block: int = DEFAULT_BLOCK,
                             interpret: bool = False):
    """Batched symmetric count matrices via the one-hot-matmul kernel."""
    q, m = _quantize_batch(images, masks, n_bins)
    q1, q2, v = jax.vmap(pair_arrays)(q, m)
    B, P = q1.shape
    Pp = -(-P // block) * block
    pad = ((0, 0), (0, Pp - P))
    q1 = jnp.pad(q1, pad)[:, None, :]
    q2 = jnp.pad(q2, pad)[:, None, :]
    v = jnp.pad(v, pad)[:, None, :]  # zero validity: pads contribute nothing
    spec = pl.BlockSpec((1, 1, block), lambda b, t: (b, 0, t))
    g = pl.pallas_call(
        functools.partial(_glcm_kernel, block=block, n_bins=n_bins),
        grid=(B, Pp // block),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, n_bins, n_bins), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_bins, n_bins), jnp.float32),
        interpret=interpret,
    )(q1, q2, v)
    return g + jnp.transpose(g, (0, 2, 1))


def glcm_features_batch_pallas(images, masks, *, n_bins: int = N_BINS,
                               block: int = DEFAULT_BLOCK,
                               interpret: bool = False):
    """``(B, N_FEATURES)`` rows: one-hot-matmul matrices + host derivation.

    NOT traceable (see :func:`glcm_features_batch_ref`)."""
    return glcm_features_from_matrix_np(
        glcm_matrix_batch_pallas(images, masks, n_bins=n_bins, block=block,
                                 interpret=interpret),
        n_bins,
    )
