"""Minimal parameter-spec system (no flax): shapes + logical axes + init.

A model is described by a nested dict of ``P`` leaves.  From the same spec
tree we derive:
  * materialised parameters  (``init_params``)
  * abstract parameters      (``abstract_params`` -- ShapeDtypeStructs for
    the dry-run; no allocation)
  * PartitionSpecs           (``partition_specs`` via logical-axis rules)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class P(NamedTuple):
    shape: tuple
    axes: tuple  # logical axis name per dim (or None)
    init: str = "normal"  # normal | zeros | ones

    def with_leading(self, n: int, axis_name: str | None = "layers"):
        return P((n, *self.shape), (axis_name, *self.axes), self.init)


def is_leaf(x):
    return isinstance(x, P)


def tree_paths(spec):
    """Deterministic (path, leaf) list."""
    out = []

    def rec(node, path):
        if is_leaf(node):
            out.append((path, node))
            return
        for k in sorted(node):
            rec(node[k], path + (k,))

    rec(spec, ())
    return out


def _init_one(leaf: P, key, dtype):
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
    std = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, leaf.shape, jnp.float32) * std).astype(dtype)


def init_params(spec, key, dtype=jnp.float32):
    leaves = tree_paths(spec)
    keys = jax.random.split(key, max(1, len(leaves)))
    flat = {path: _init_one(leaf, k, dtype) for (path, leaf), k in zip(leaves, keys)}
    return _unflatten(flat)


def abstract_params(spec, dtype=jnp.float32):
    flat = {
        path: jax.ShapeDtypeStruct(leaf.shape, dtype)
        for path, leaf in tree_paths(spec)
    }
    return _unflatten(flat)


def axes_tree(spec):
    flat = {path: leaf.axes for path, leaf in tree_paths(spec)}
    return _unflatten(flat)


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        node = root
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = v
    return root


def map_with_axes(fn, params, spec):
    """Map ``fn(param_leaf, logical_axes)`` over a params tree."""
    flat = {}
    for path, leaf in tree_paths(spec):
        node = params
        for k in path:
            node = node[k]
        flat[path] = fn(node, leaf.axes)
    return _unflatten(flat)
