"""RWKV6 ("Finch"): attention-free decoder with data-dependent decay.

Structure per layer (faithful to arXiv:2404.05892 at the block level):
  * time-mix: token-shift lerps feed r/k/v/g/w projections; the decay
    w_t = exp(-softplus(lora_w(x_t))) is *data-dependent per channel* (the
    paper's headline mechanism); recurrence runs through the shared chunked
    diagonal-decay scan (models/ssm.py) with the current-token bonus u.
  * channel-mix: token-shifted squared-ReLU FFN with a sigmoid receptance
    gate (d_ff = 7168).

Head size is fixed at 64 (d_model 2048 -> 32 heads).  Decode state per
layer: (time-shift x, channel-shift x, per-head (64, 64) state matrix) --
O(1) in sequence length, which is why this arch runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.params import P, init_params, abstract_params
from repro.parallel.sharding import Ax, constrain

HEAD_SIZE = 64


def _tm_spec(cfg):
    d = cfg.d_model
    nh = d // HEAD_SIZE
    return {
        "mu": P((5, d), (None, "embed"), "zeros"),  # r,k,v,w,g lerp factors
        "wr": P((d, d), ("embed", "heads")),
        "wk": P((d, d), ("embed", "heads")),
        "wv": P((d, d), ("embed", "heads")),
        "wg": P((d, d), ("embed", "heads")),
        "ww": P((d, d), ("embed", "heads")),
        "w0": P((d,), ("heads",), "zeros"),
        "u": P((nh, HEAD_SIZE), ("ssm_heads", None), "zeros"),
        "ln_x": P((d,), ("heads",), "ones"),  # per-head group norm scale
        "wo": P((d, d), ("heads", "embed")),
    }


def _cm_spec(cfg):
    d = cfg.d_model
    return {
        "mu": P((2, d), (None, "embed"), "zeros"),  # k, r lerp factors
        "wk": P((d, cfg.d_ff), ("embed", "mlp")),
        "wv": P((cfg.d_ff, d), ("mlp", "embed")),
        "wr": P((d, d), ("embed", "embed_act")),
    }


def _lerp(x, xprev, mu):
    return x + (xprev - x) * jax.nn.sigmoid(mu).astype(x.dtype)


def _time_mix_project(p, x, xprev, cfg):
    nh = cfg.d_model // HEAD_SIZE
    mu = p["mu"]
    xr = _lerp(x, xprev, mu[0])
    xk = _lerp(x, xprev, mu[1])
    xv = _lerp(x, xprev, mu[2])
    xw = _lerp(x, xprev, mu[3])
    xg = _lerp(x, xprev, mu[4])
    shp = (*x.shape[:-1], nh, HEAD_SIZE)
    r = jnp.einsum("...d,de->...e", xr, p["wr"].astype(x.dtype)).reshape(shp)
    k = jnp.einsum("...d,de->...e", xk, p["wk"].astype(x.dtype)).reshape(shp)
    v = jnp.einsum("...d,de->...e", xv, p["wv"].astype(x.dtype)).reshape(shp)
    g = jax.nn.silu(jnp.einsum("...d,de->...e", xg, p["wg"].astype(x.dtype)))
    logw = -jax.nn.softplus(
        jnp.einsum("...d,de->...e", xw, p["ww"].astype(x.dtype)).astype(jnp.float32)
        + p["w0"].astype(jnp.float32)
    ).reshape(*x.shape[:-1], nh, HEAD_SIZE)
    return r, k, v, g, logw


def _time_mix_out(p, wkv, g, cfg, x_dtype):
    """Per-head group norm, gate, output projection."""
    d = cfg.d_model
    y = wkv.astype(jnp.float32)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(*y.shape[:-2], d) * p["ln_x"].astype(jnp.float32)
    y = y.astype(x_dtype) * g.astype(x_dtype)
    return jnp.einsum("...e,ed->...d", y, p["wo"].astype(x_dtype))


def _channel_mix(p, x, xprev, cfg):
    xk = _lerp(x, xprev, p["mu"][0])
    xr = _lerp(x, xprev, p["mu"][1])
    k = jnp.einsum("...d,df->...f", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("...f,fd->...d", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["wr"].astype(x.dtype)))
    return r.astype(x.dtype) * kv


def _shift(x):
    """(B, S, d) -> previous-token tensor (zero for t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


class RWKV6:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.d_model % HEAD_SIZE == 0

    def spec(self):
        cfg = self.cfg
        one = {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "ln2": L.rmsnorm_spec(cfg.d_model),
            "tm": _tm_spec(cfg),
            "cm": _cm_spec(cfg),
        }
        stacked = jax.tree.map(
            lambda p: p.with_leading(cfg.n_layers),
            one,
            is_leaf=lambda x: isinstance(x, P),
        )
        return {
            "embed": L.embed_spec(cfg),
            "layers": stacked,
            "final_norm": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }

    def init(self, key, dtype=jnp.float32):
        return init_params(self.spec(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.spec(), dtype)

    def forward(self, params, tokens, prefix_embeds=None, ssm_chunk=64):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        x = constrain(x, "batch", "seq", "embed_act")

        def body(carry, lp):
            xc, aux = carry
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            r, k, v, g, logw = _time_mix_project(lp["tm"], h, _shift(h), cfg)
            wkv, _ = S.chunked_decay_attention(
                r, k, v, logw, u=lp["tm"]["u"], chunk=ssm_chunk, inclusive=False
            )
            xc = xc + _time_mix_out(lp["tm"], wkv, g, cfg, xc.dtype)
            h = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            xc = xc + _channel_mix(lp["cm"], h, _shift(h), cfg)
            xc = constrain(xc, "batch", "seq", "embed_act")
            return (xc, aux), None

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, _), _ = L.scan_or_unroll(
            body_fn, (x, 0.0), params["layers"], cfg.n_layers, cfg.scan_layers
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)
        return constrain(logits, "batch", "seq", "vocab"), 0.0

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        nh = cfg.d_model // HEAD_SIZE
        lshape = (cfg.n_layers, batch)
        return {
            "tm_shift": jnp.zeros((*lshape, cfg.d_model), dtype),
            "cm_shift": jnp.zeros((*lshape, cfg.d_model), dtype),
            "state": jnp.zeros((*lshape, nh, HEAD_SIZE, HEAD_SIZE), jnp.float32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self):
        return {
            "tm_shift": Ax(("layers", "cache_batch", "embed_act")),
            "cm_shift": Ax(("layers", "cache_batch", "embed_act")),
            "state": Ax(("layers", "cache_batch", "ssm_heads", None, None)),
            "pos": Ax(("cache_batch",)),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)[:, 0]  # (B, d)

        def body(xc, xs):
            lp, tm_s, cm_s, st = xs
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            r, k, v, g, logw = _time_mix_project(lp["tm"], h, tm_s.astype(h.dtype), cfg)
            wkv, st2 = S.decay_attention_step(r, k, v, logw, lp["tm"]["u"], st)
            xc = xc + _time_mix_out(lp["tm"], wkv, g, cfg, xc.dtype)
            h2 = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            xc = xc + _channel_mix(lp["cm"], h2, cm_s.astype(h2.dtype), cfg)
            return xc, (h.astype(tm_s.dtype), h2.astype(cm_s.dtype), st2)

        x, (tm_new, cm_new, st_new) = L.scan_or_unroll(
            body, x,
            (params["layers"], cache["tm_shift"], cache["cm_shift"],
             cache["state"]),
            cfg.n_layers, cfg.scan_layers,
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x[:, None])
        return logits, {
            "tm_shift": tm_new,
            "cm_shift": cm_new,
            "state": st_new,
            "pos": cache["pos"] + 1,
        }
