"""Chunked linear attention with data-dependent diagonal decay.

One primitive covers both assigned recurrent families:
  * RWKV6 ("Finch") time-mix: per-key-channel data-dependent decay w_t plus
    a current-token bonus u  --  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T).
  * Mamba2-style SSD heads (Hymba's parallel-SSM branch): scalar-per-head
    decay == the same recurrence with w_t broadcast across key channels.

Sequential scans are O(T) steps; this implements the standard chunked
decomposition (GLA/SSD style) where a chunk of C steps becomes three
matmuls.  All exponents are differences of cumulative log-decays along
*forward* spans, hence <= 0: everything stays in (0, 1] -- numerically
stable without secondary chunking.

    la_t   = sum_{tau<=t} log w_tau           (cumulative, inclusive)
    inter  : out_t += (r_t * exp(la_{t-1})) @ S_0
    intra  : out_t += sum_{tau<t} [sum_i r_ti k_taui exp(la_{t-1,i}-la_tau,i)] v_tau
    bonus  : out_t += (sum_i r_ti u_i k_ti) v_t
    carry  : S_C = diag(exp(la_C)) S_0 + sum_tau (k_tau exp(la_C-la_tau))^T v_tau
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def decay_attention_step(r, k, v, logw, u, state):
    """One decode step.

    r/k/logw: (B, H, Dk); v: (B, H, Dv); u: (H, Dk) or None;
    state: (B, H, Dk, Dv).  Returns (out (B, H, Dv), new_state).
    """
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    out = jnp.einsum("bhi,bhiv->bhv", r, state)
    if u is not None:
        out = out + jnp.einsum("bhi,hi,bhi,bhv->bhv", r, u.astype(jnp.float32), k, v)
        new_state = jnp.exp(logw)[..., None] * state + k[..., None] * v[..., None, :]
    else:
        # SSD convention: output reads the *updated* state (inclusive)
        new_state = jnp.exp(logw)[..., None] * state + k[..., None] * v[..., None, :]
        out = jnp.einsum("bhi,bhiv->bhv", r, new_state)
    return out, new_state


@functools.partial(jax.jit, static_argnames=("chunk", "inclusive"))
def chunked_decay_attention(r, k, v, logw, u=None, state0=None, chunk=64,
                            inclusive=False):
    """Full-sequence chunked scan.

    r/k: (B, T, H, Dk); v: (B, T, H, Dv); logw: (B, T, H, Dk) (<= 0,
    broadcastable over Dk for scalar-per-head decay); u: (H, Dk) or None.
    ``inclusive``: out_t reads the state including step t (SSD convention,
    used when u is None).  Returns (out (B, T, H, Dv), state (B,H,Dk,Dv)).
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    logw = jnp.broadcast_to(logw, (b, t, h, dk)).astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    c = min(chunk, t)
    t_orig = t
    if t % c:
        # Pad to a chunk multiple with neutral steps: logw=0 (exp(0)=1 keeps
        # the state unchanged), k=0 (no contribution), r=0 (no output read).
        # The scan's final state therefore equals the state at t_orig; padded
        # outputs are sliced off below.
        pad = c - t % c
        padt = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = padt(r), padt(k), padt(v), padt(logw)
        t = t + pad
    n = t // c

    rc = r.reshape(b, n, c, h, dk).astype(jnp.float32)
    kc = k.reshape(b, n, c, h, dk).astype(jnp.float32)
    vc = v.reshape(b, n, c, h, dv).astype(jnp.float32)
    lw = logw.reshape(b, n, c, h, dk)

    tri = jnp.tril(jnp.ones((c, c), jnp.float32), -1 if not inclusive else 0)

    def body(state, xs):
        rr, kk, vv, ww = xs  # (b,c,h,dk/(dv))
        la = jnp.cumsum(ww, axis=1)  # (b,c,h,dk) inclusive
        a = la if inclusive else la - ww  # exponent used by queries
        q_eff = rr * jnp.exp(a)
        k_dec = kk * jnp.exp(-la + la[:, -1:, :, :])  # k * exp(la_C - la_tau)
        # inter-chunk
        out = jnp.einsum("bchi,bhiv->bchv", q_eff, state)
        # intra-chunk: scores_ttau = sum_i r_ti k_taui exp(a_t - la_tau).
        # On the valid region (tau < t for exclusive, tau <= t inclusive)
        # the exponent is a sum of log-decays over a forward span, so it is
        # <= 0 *pairwise*.  Any factored form (q*e^a)(k*e^-la) has one
        # unbounded side under strong decay, so we form the exact pairwise
        # exponent tensor, clamp the (masked-out) upper triangle, and pay
        # the (C, C, Dk) workspace -- chunk size keeps it modest.
        expo = a[:, :, None, :, :] - la[:, None, :, :, :]  # (b,c,c,h,dk)
        dmat = jnp.exp(jnp.minimum(expo, 0.0))
        scores = jnp.einsum("bchi,bdhi,bcdhi->bhcd", rr, kk, dmat)
        mask = tri[None, None]
        scores = scores * mask
        out = out + jnp.einsum("bhcd,bdhv->bchv", scores, vv)
        if u is not None:
            bonus = jnp.einsum("bchi,hi,bchi->bch", rr, u.astype(jnp.float32), kk)
            out = out + bonus[..., None] * vv
        new_state = jnp.exp(la[:, -1])[..., None] * state + jnp.einsum(
            "bchi,bchv->bhiv", k_dec, vv
        )
        return new_state, out

    xs = (
        jnp.moveaxis(rc, 1, 0),
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(lw, 1, 0),
    )
    state, out = jax.lax.scan(body, state0, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, t, h, dv)
    if t != t_orig:
        out = out[:, :t_orig]
    return out, state
