"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

GSPMD-friendly dense dispatch (Mesh-TF/Switch style): tokens are grouped
per sequence; each group independently routes its tokens into per-expert
capacity slots via one-hot dispatch/combine einsums.  With experts sharded
over the 'expert' logical axis (mapped to the mesh 'data' axis) and tokens
sharded over 'batch', XLA inserts the canonical all-to-alls.

Supports the two assigned MoE architectures:
  * arctic-480b    : 128 experts top-2 + a parallel dense residual FFN
  * deepseek-moe-16b: 64 fine-grained experts top-6 + 2 shared experts
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import P
from repro.models import layers


def moe_spec(cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    spec = {
        "router": P((d, e), ("embed", "expert")),
        "wi": P((e, d, f), ("expert", "embed", "mlp")),
        "wo": P((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        spec["wg"] = P((e, d, f), ("expert", "embed", "mlp"))
    if cfg.n_shared_experts:
        spec["shared"] = layers.mlp_spec(cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    if cfg.dense_residual_ff:
        spec["dense"] = layers.mlp_spec(cfg, d_ff=cfg.dense_residual_ff)
    return spec


def _capacity(s_tokens: int, k: int, e: int, factor: float) -> int:
    c = int(np.ceil(s_tokens * k * factor / e))
    return max(4, min(c, s_tokens))


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are regrouped into dispatch groups of ``cfg.moe_group_size``:
    the dense dispatch/combine einsums cost O(group_size) FLOPs *per
    token*, so small groups keep routing overhead a few percent of expert
    compute (full-sequence groups at 4k tokens made dispatch dominate).
    """
    b_in, s_in, d = x.shape
    gs = min(cfg.moe_group_size, b_in * s_in)
    pad = (-(b_in * s_in)) % gs
    flat = x.reshape(-1, d)
    valid_flat = jnp.ones((flat.shape[0],), x.dtype)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
        valid_flat = jnp.pad(valid_flat, (0, pad))
    x = flat.reshape(-1, gs, d)
    valid = valid_flat.reshape(-1, gs)  # (g, s) 1 for real tokens
    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    cap = _capacity(s, k, e, cfg.capacity_factor)

    logits = jnp.einsum("gsd,de->gse", x, params["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (g,s,e)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (g,s,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )  # renormalise among selected (deepseek convention)

    # load-balancing auxiliary loss (Switch): e * sum(frac_tokens * frac_prob)
    assign1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    frac_tokens = jnp.mean(assign1, axis=1)  # (g,e)
    frac_probs = jnp.mean(probs, axis=1)  # (g,e)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # capacity slots: position of each (token, choice) in its expert queue;
    # padded tokens neither claim slots nor contribute output
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (g,s,k,e)
    onehot = onehot * valid[:, :, None, None].astype(jnp.int32)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # slots used before this entry
    pos = pos.reshape(b, s, k, e)
    keep = (pos < cap) & (onehot > 0)
    slot_oh = jax.nn.one_hot(
        jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype
    )[..., :cap]  # (g,s,k,e,cap); overflow tokens land in the dropped bucket

    dispatch = jnp.einsum("gske,gskec->gsec", onehot.astype(x.dtype), slot_oh)
    combine = jnp.einsum(
        "gsk,gske,gskec->gsec", gate_vals.astype(x.dtype),
        onehot.astype(x.dtype), slot_oh,
    )

    # NOTE (§Perf/2 it.2, refuted): explicitly constraining xe/h/ye to an
    # expert-sharded layout forced GSPMD to replicate the group dim (a full
    # all-gather per layer) and made the collective term 2.7x WORSE
    # (3.19 s -> 8.63 s).  GSPMD's own choice — expert weights gathered to
    # the token shards — is the better schedule at this batch size because
    # weight bytes/layer (~3.2 GB) < top-6 capacity-inflated token bytes.
    # Left unconstrained deliberately.
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, x)  # (g,e,cap,d)
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(x.dtype))
    if cfg.mlp_act == "swiglu":
        gte = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(x.dtype))
        h = jax.nn.silu(gte) * h
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine, ye)

    if cfg.n_shared_experts:
        out = out + layers.mlp(params["shared"], x, cfg.mlp_act)
    if cfg.dense_residual_ff:
        out = out + layers.mlp(params["dense"], x, cfg.mlp_act)
    out = out.reshape(-1, d)
    if pad:
        out = out[: b_in * s_in]
    return out.reshape(b_in, s_in, d), aux * cfg.router_aux_loss
