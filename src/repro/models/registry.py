"""Architecture registry: ``--arch <id>`` -> (config, model)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCHS = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "arctic-480b": "arctic_480b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "hymba-1.5b": "hymba_1p5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-1.7b": "qwen3_1p7b",
    "minicpm-2b": "minicpm_2b",
    "granite-3-2b": "granite_3_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-26b": "internvl2_26b",
}


def list_archs():
    return sorted(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def get_model(cfg: ModelConfig):
    from repro.models.encdec import EncDec
    from repro.models.rwkv6 import RWKV6
    from repro.models.transformer import Decoder

    if cfg.family == "ssm":
        return RWKV6(cfg)
    if cfg.family in ("audio", "encdec"):
        return EncDec(cfg)
    return Decoder(cfg)  # dense | moe | hybrid | vlm
