"""Decoder-only transformer assembly: dense, MoE, and hybrid families.

One config-driven implementation covers 8 of the 10 assigned architectures
(arctic, deepseek-moe, nemotron, qwen3, minicpm, granite, hymba, and the
internvl2 language backbone).  Layers are stacked with ``lax.scan`` (fast
compiles at 28-48 layers) and optionally rematerialised.

Hybrid (Hymba): each layer runs attention and a Mamba2-style SSD branch in
parallel on the same normed input and averages the outputs; a static
per-layer window vector selects full vs sliding-window attention.  Decode
for hybrids is unrolled so SWA layers keep ring-buffer caches of window
size while global layers keep full caches (this asymmetry is the point of
the architecture).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import P, init_params, abstract_params
from repro.parallel.sharding import Ax, constrain


# --------------------------------------------------------------------------
# Hybrid SSD branch (Mamba2-style scalar-per-head decay)
# --------------------------------------------------------------------------

def ssd_spec(cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.head_dim
    n = cfg.ssm_state
    return {
        "wx": P((d, di), ("embed", "mlp")),
        "wz": P((d, di), ("embed", "mlp")),
        "wb": P((d, nh, n), ("embed", "ssm_heads", "ssm_state")),
        "wc": P((d, nh, n), ("embed", "ssm_heads", "ssm_state")),
        "wdt": P((d, nh), ("embed", "ssm_heads")),
        "dt0": P((nh,), ("ssm_heads",), "zeros"),
        "norm": P((di,), ("mlp",), "ones"),
        "wo": P((di, d), ("mlp", "embed")),
    }


def _ssd_project(params, x, cfg):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.head_dim
    xv = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(x.dtype))
    bts = jnp.einsum("bsd,dhn->bshn", x, params["wb"].astype(x.dtype))
    cts = jnp.einsum("bsd,dhn->bshn", x, params["wc"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(x.dtype))
    logw = -jax.nn.softplus(dt.astype(jnp.float32) + params["dt0"].astype(jnp.float32))
    v = xv.reshape(*xv.shape[:-1], nh, cfg.head_dim)
    return v, z, bts, cts, logw


def _ssd_out(params, y, z, cfg, x_dtype):
    di = cfg.ssm_expand * cfg.d_model
    y = y.reshape(*y.shape[:-2], di)
    dt = y.dtype
    yn = y.astype(jnp.float32)
    yn = yn * jax.lax.rsqrt(jnp.mean(yn * yn, -1, keepdims=True) + 1e-5)
    y = (yn * params["norm"].astype(jnp.float32)).astype(x_dtype)
    y = y * jax.nn.silu(z).astype(x_dtype)
    return jnp.einsum("bse,ed->bsd", y, params["wo"].astype(x_dtype))


def ssd_apply(params, x, cfg, state0=None, chunk=64):
    """Full-sequence SSD branch.  Returns (out, final_state)."""
    v, z, bts, cts, logw = _ssd_project(params, x, cfg)
    out, state = S.chunked_decay_attention(
        cts, bts, v, logw[..., None], u=None, state0=state0, chunk=chunk,
        inclusive=True,
    )
    return _ssd_out(params, out, z, cfg, x.dtype), state


def ssd_step(params, x, cfg, state):
    """Single-token decode.  x: (B,1,d)."""
    v, z, bts, cts, logw = _ssd_project(params, x, cfg)
    out, state = S.decay_attention_step(
        cts[:, 0], bts[:, 0], v[:, 0],
        jnp.broadcast_to(logw[:, 0, :, None], bts[:, 0].shape),
        None, state,
    )
    return _ssd_out(params, out[:, None], z, cfg, x.dtype), state


# --------------------------------------------------------------------------
# Layer spec / apply
# --------------------------------------------------------------------------

def layer_spec(cfg):
    spec = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
    }
    if cfg.n_experts:
        spec["moe"] = M.moe_spec(cfg)
    else:
        spec["mlp"] = L.mlp_spec(cfg)
    if cfg.family == "hybrid":
        spec["ssd"] = ssd_spec(cfg)
    return spec


def _ffn(params, h, cfg):
    if cfg.n_experts:
        out, aux = M.moe_apply(params["moe"], h, cfg)
        return out, aux
    return L.mlp(params["mlp"], h, cfg.mlp_act), 0.0


def layer_apply(params, x, positions, cfg, window, ssm_chunk=64):
    """Training/prefill layer.  window: per-layer scalar (0 = full)."""
    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
    attn = L.self_attention(params["attn"], h, positions, cfg, window=window)
    if cfg.family == "hybrid":
        ssm_out, _ = ssd_apply(params["ssd"], h, cfg, chunk=ssm_chunk)
        attn = (attn + ssm_out) * 0.5
    x = x + attn
    x = constrain(x, "batch", "seq", "embed_act")
    h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
    out, aux = _ffn(params, h, cfg)
    x = x + out
    x = constrain(x, "batch", "seq", "embed_act")
    return x, aux


# --------------------------------------------------------------------------
# Decoder model
# --------------------------------------------------------------------------

class Decoder:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---- params ----
    def spec(self):
        cfg = self.cfg
        one = layer_spec(cfg)
        stacked = jax.tree.map(
            lambda p: p.with_leading(cfg.n_layers),
            one,
            is_leaf=lambda x: isinstance(x, P),
        )
        spec = {
            "embed": L.embed_spec(cfg),
            "layers": stacked,
            "final_norm": L.rmsnorm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            spec["unembed"] = L.unembed_spec(cfg)
        return spec

    def init(self, key, dtype=jnp.float32):
        return init_params(self.spec(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.spec(), dtype)

    def windows(self):
        cfg = self.cfg
        if cfg.family == "hybrid" and cfg.attn_window:
            w = [
                0 if i in cfg.global_attn_layers else cfg.attn_window
                for i in range(cfg.n_layers)
            ]
        else:
            w = [cfg.attn_window] * cfg.n_layers
        return np.asarray(w, np.int32)

    # ---- forward (train / full-sequence) ----
    def forward(self, params, tokens, prefix_embeds=None):
        """tokens: (B, S) int32; prefix_embeds: (B, P, d) or None.

        Returns (logits (B, S_total, V), aux_loss).
        """
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = constrain(x, "batch", "seq", "embed_act")
        windows = jnp.asarray(self.windows())

        def body(carry, xs):
            xc, aux = carry
            lp, w = xs
            xc, a = layer_apply(lp, xc, positions, cfg, w)
            return (xc, aux + a), None

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, aux), _ = L.scan_or_unroll(
            body_fn, (x, 0.0), (params["layers"], windows),
            cfg.n_layers, cfg.scan_layers,
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum(
                "bsd,vd->bsv", x, params["embed"]["embedding"].astype(x.dtype)
            )
        else:
            logits = L.unembed(params["unembed"], x)
        logits = constrain(logits, "batch", "seq", "vocab")
        return logits, aux

    # ---- decode ----
    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        if cfg.family == "hybrid":
            di = cfg.ssm_expand * cfg.d_model
            nh = di // hd
            caches = []
            for i in range(cfg.n_layers):
                w = int(self.windows()[i])
                slots = max_len if w == 0 else min(w, max_len)
                caches.append(
                    {
                        "k": jnp.zeros((batch, slots, kvh, hd), dtype),
                        "v": jnp.zeros((batch, slots, kvh, hd), dtype),
                        "kpos": jnp.full((batch, slots), -1, jnp.int32),
                        "state": jnp.zeros((batch, nh, cfg.ssm_state, hd), jnp.float32),
                    }
                )
            return {"layers": caches, "pos": jnp.zeros((batch,), jnp.int32)}
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, kvh, hd), dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self):
        """Logical axes for each cache leaf (for dry-run shardings)."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            per_layer = {
                "k": Ax(("cache_batch", "cache_seq", "kv_heads", "head_dim")),
                "v": Ax(("cache_batch", "cache_seq", "kv_heads", "head_dim")),
                "kpos": Ax(("cache_batch", "cache_seq")),
                "state": Ax(("cache_batch", "ssm_heads", "ssm_state", "head_dim")),
            }
            return {
                "layers": [dict(per_layer) for _ in range(cfg.n_layers)],
                "pos": Ax(("cache_batch",)),
            }
        kv = Ax(("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim"))
        return {"k": kv, "v": kv, "pos": Ax(("cache_batch",))}

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B, 1, V), cache)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
        x = constrain(x, "batch", "seq", "embed_act")
        pos = cache["pos"]
        if cfg.family == "hybrid":
            new_layers = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda p: p[i], params["layers"])
                lc = cache["layers"][i]
                w = int(self.windows()[i])
                x, nlc = self._hybrid_step(lp, x, lc, pos, w)
                new_layers.append(nlc)
            x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            logits = self._unembed(params, x)
            return logits, {"layers": new_layers, "pos": pos + 1}

        def body(carry, xs):
            xc = carry
            lp, ck, cv = xs
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            attn, nk, nv = L.decode_attention(
                lp["attn"], h, ck, cv, pos, cfg, window=cfg.attn_window
            )
            xc = xc + attn
            h = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            out, _ = _ffn(lp, h, cfg)
            return xc + out, (nk, nv)

        x, (nk, nv) = L.scan_or_unroll(
            body, x, (params["layers"], cache["k"], cache["v"]),
            cfg.n_layers, cfg.scan_layers,
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x)
        return logits, {"k": nk, "v": nv, "pos": pos + 1}

    def _unembed(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return jnp.einsum(
                "bsd,vd->bsv", x, params["embed"]["embedding"].astype(x.dtype)
            )
        return L.unembed(params["unembed"], x)

    def _hybrid_step(self, lp, x, lc, pos, window):
        """One hybrid layer, single token, ring-buffer SWA cache."""
        cfg = self.cfg
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, kv = L.attention_qkv(lp["attn"], h, pos[:, None], cfg)
        slots = lc["k"].shape[1]
        slot = pos % slots
        oh = jax.nn.one_hot(slot, slots, dtype=lc["k"].dtype)
        nk = lc["k"] * (1 - oh[..., None, None]) + oh[..., None, None] * kv.k
        nv = lc["v"] * (1 - oh[..., None, None]) + oh[..., None, None] * kv.v
        kpos = jnp.where(oh > 0, pos[:, None], lc["kpos"])
        # attend over ring buffer using stored absolute positions
        b = x.shape[0]
        kh, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim
        qg = (q / np.sqrt(hd)).reshape(b, 1, kh, g, hd)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, nk,
                       preferred_element_type=jnp.float32)
        valid = (kpos >= 0) & (kpos <= pos[:, None])
        if window:
            valid = valid & (kpos > pos[:, None] - window)
        s = jnp.where(valid[:, None, None, None, :], s, L.NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(nv.dtype)
        o = jnp.einsum("bqkgs,bskd->bqkgd", p, nv).reshape(b, 1, cfg.n_heads, hd)
        attn = L.attention_out(lp["attn"], o, x.dtype)
        ssm_out, nstate = ssd_step(lp["ssd"], h, cfg, lc["state"])
        x = x + (attn + ssm_out) * 0.5
        h2 = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        out, _ = _ffn(lp, h2, cfg)
        return x + out, {"k": nk, "v": nv, "kpos": kpos, "state": nstate}
