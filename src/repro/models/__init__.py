"""Model zoo: dense/MoE/SSM/hybrid/enc-dec/VLM backbones in pure JAX."""
