"""Encoder-decoder backbone (seamless-m4t-large-v2 assignment).

The modality frontend is a STUB per the brief: ``input_specs()`` provides
precomputed audio *frame embeddings* (B, S_enc, d) that feed the encoder
directly (in the real system the speech frontend produces these).  The text
decoder is a standard causal transformer with per-layer cross-attention to
the encoder output.

Shape mapping for the assigned cells: encoder length = max(128, seq_len//4)
(m4t's speech frontend downsamples ~4x), decoder length = seq_len.  Decode
shapes cache the decoder self-attention KV plus the per-layer projected
cross K/V (computed once at prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import P, init_params, abstract_params
from repro.parallel.sharding import Ax, constrain


def enc_len_for(seq_len: int) -> int:
    return max(128, seq_len // 4)


def _cross_spec(cfg):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    kvh = cfg.n_kv_heads
    return {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }


def _cross_kv(params, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    return k, v


def _cross_attend(params, x, ck, cv, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    o = L.blockwise_attention(q, ck, cv, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


class EncDec:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_encoder_layers > 0

    def spec(self):
        cfg = self.cfg
        enc_one = {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "ln2": L.rmsnorm_spec(cfg.d_model),
            "attn": L.attention_spec(cfg),
            "mlp": L.mlp_spec(cfg),
        }
        dec_one = dict(enc_one)
        dec_one["ln_x"] = L.rmsnorm_spec(cfg.d_model)
        dec_one["cross"] = _cross_spec(cfg)
        stack = lambda one, n: jax.tree.map(
            lambda p: p.with_leading(n), one, is_leaf=lambda x: isinstance(x, P)
        )
        return {
            "embed": L.embed_spec(cfg),
            "encoder": stack(enc_one, cfg.n_encoder_layers),
            "decoder": stack(dec_one, cfg.n_layers),
            "enc_norm": L.rmsnorm_spec(cfg.d_model),
            "final_norm": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }

    def init(self, key, dtype=jnp.float32):
        return init_params(self.spec(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.spec(), dtype)

    def encode(self, params, frames):
        """frames: (B, S_enc, d) stub embeddings -> (B, S_enc, d)."""
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = constrain(x, "batch", "seq", "embed_act")

        def body(xc, lp):
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            q, kv = L.attention_qkv(lp["attn"], h, positions, cfg)
            o = L.blockwise_attention(q, kv.k, kv.v, causal=False)
            xc = xc + L.attention_out(lp["attn"], o, xc.dtype)
            h = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            xc = xc + L.mlp(lp["mlp"], h, cfg.mlp_act)
            return constrain(xc, "batch", "seq", "embed_act"), None

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, _ = L.scan_or_unroll(
            body_fn, x, params["encoder"], cfg.n_encoder_layers, cfg.scan_layers
        )
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def forward(self, params, tokens, frames):
        """Teacher-forced training forward.  Returns (logits, aux)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = constrain(x, "batch", "seq", "embed_act")

        def body(xc, lp):
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            xc = xc + L.self_attention(lp["attn"], h, positions, cfg)
            h = L.rmsnorm(lp["ln_x"], xc, cfg.norm_eps)
            ck, cv = _cross_kv(lp["cross"], enc_out)
            xc = xc + _cross_attend(lp["cross"], h, ck, cv, cfg)
            h = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            xc = xc + L.mlp(lp["mlp"], h, cfg.mlp_act)
            return constrain(xc, "batch", "seq", "embed_act"), None

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, _ = L.scan_or_unroll(
            body_fn, x, params["decoder"], cfg.n_layers, cfg.scan_layers
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)
        return constrain(logits, "batch", "seq", "vocab"), 0.0

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16, enc_len=None):
        cfg = self.cfg
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        se = enc_len or enc_len_for(max_len)
        lkv = (cfg.n_layers, batch, max_len, kvh, hd)
        return {
            "k": jnp.zeros(lkv, dtype),
            "v": jnp.zeros(lkv, dtype),
            "cross_k": jnp.zeros((cfg.n_layers, batch, se, kvh, hd), dtype),
            "cross_v": jnp.zeros((cfg.n_layers, batch, se, kvh, hd), dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self):
        kv = Ax(("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim"))
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv,
                "pos": Ax(("cache_batch",))}

    def prefill_encoder(self, params, cache, frames):
        """Run the encoder once and stash projected cross K/V per layer."""
        enc_out = self.encode(params, frames)

        def body(_, lp):
            k, v = _cross_kv(lp["cross"], enc_out)
            return None, (k.astype(cache["cross_k"].dtype),
                          v.astype(cache["cross_v"].dtype))

        _, (ck, cv) = jax.lax.scan(body, None, params["decoder"])
        return dict(cache, cross_k=ck, cross_v=cv)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
        pos = cache["pos"]

        def body(xc, xs):
            lp, ck, cv, xk, xv = xs
            h = L.rmsnorm(lp["ln1"], xc, cfg.norm_eps)
            attn, nk, nv = L.decode_attention(lp["attn"], h, ck, cv, pos, cfg)
            xc = xc + attn
            h = L.rmsnorm(lp["ln_x"], xc, cfg.norm_eps)
            xc = xc + _cross_attend(lp["cross"], h, xk, xv, cfg)
            h = L.rmsnorm(lp["ln2"], xc, cfg.norm_eps)
            xc = xc + L.mlp(lp["mlp"], h, cfg.mlp_act)
            return xc, (nk, nv)

        x, (nk, nv) = L.scan_or_unroll(
            body, x,
            (params["decoder"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]),
            cfg.n_layers, cfg.scan_layers,
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)
        return logits, dict(cache, k=nk, v=nv, pos=pos + 1)
