"""Shared transformer layers: norms, RoPE, GQA attention, MLP variants.

Attention uses a blockwise online-softmax formulation (flash-attention
style, pure ``lax.scan`` over key blocks) so 32k-token prefill never
materialises a full (S, S) score matrix.  Sliding-window and causal masking
are fused into the block iteration: fully-masked key blocks still stream by
(static grid) but their compute is trivially skipped by the mask add.

Shapes follow (batch, seq, heads, head_dim).  Logical axes used for
sharding: 'batch', 'seq', 'heads', 'kv_heads', 'head_dim', 'embed', 'mlp',
'vocab', 'layers', 'expert'.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import P

NEG_INF = -1e30


def scan_or_unroll(body_fn, carry, xs, length: int, scan: bool):
    """lax.scan when ``scan`` else a python unroll (used by the dry-run's
    per-layer cost extrapolation, where distinct per-layer HLO is needed)."""
    if scan:
        return jax.lax.scan(body_fn, carry, xs)
    ys = []
    for i in range(length):
        xsi = jax.tree.map(lambda x: x[i], xs) if xs is not None else None
        carry, y = body_fn(carry, xsi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm_spec(d):
    return {"scale": P((d,), ("embed",), "ones")}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(x, scale, eps=1e-5):
    """Per-head qk-norm (qwen3): normalise over head_dim."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, theta=1e4):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Blockwise (flash-style) attention
# --------------------------------------------------------------------------

@functools.partial(jax.named_call, name="blockwise_attention")
def blockwise_attention(q, k, v, *, causal=True, window=0, block_k=512,
                        q_offset=0):
    """Online-softmax attention, grouped-query layout (no KV replication).

    q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (decode /
    chunked prefill).  ``window`` > 0 = sliding-window attention.
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / np.sqrt(d)
    q = (q * scale).astype(q.dtype).reshape(b, sq, kh, g, d)

    block_k = min(block_k, sk)
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qpos = q_offset + jnp.arange(sq)  # (Sq,)

    def body(carry, i):
        acc, m, l = carry  # acc (B,Sq,K,G,D) f32; m,l (B,Sq,K,G)
        kb = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, axis=1)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q, kb,
                       preferred_element_type=jnp.float32)
        kpos = i * block_k + jnp.arange(block_k)  # (Bk,)
        mask = kpos[None, :] < sk  # padding
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        # window may be a traced per-layer scalar; 0/negative = full attention
        wthr = jnp.where(window > 0, qpos[:, None] - window, jnp.int32(-(2**30)))
        mask = mask & (kpos[None, :] > wthr)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kh, g, d), jnp.float32)
    m0 = jnp.full((b, sq, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------

def attention_spec(cfg):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P((hd,), ("head_dim",), "ones")
        spec["k_norm"] = P((hd,), ("head_dim",), "ones")
    return spec


class KVUpdate(NamedTuple):
    k: jax.Array  # (B, S, K, D) new keys (pre-cache)
    v: jax.Array


def attention_qkv(params, x, positions, cfg):
    """Project + rope + qk-norm.  Returns q, KVUpdate."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = head_rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, KVUpdate(k, v)


def attention_out(params, o, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x_dtype))


def self_attention(params, x, positions, cfg, *, window=0, block_k=512):
    """Full training-mode self-attention (causal)."""
    q, kv = attention_qkv(params, x, positions, cfg)
    o = blockwise_attention(q, kv.k, kv.v, causal=True, window=window,
                            block_k=block_k)
    return attention_out(params, o, x.dtype)


def decode_attention(params, x, cache_k, cache_v, pos, cfg, *, window=0,
                     uniform_pos=True):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, K, D); pos: (B,) current lengths.
    Returns (out, new_k, new_v) where new_k/v are the updated caches.

    ``uniform_pos=True`` (the batched-serving fast path: every row is at
    the same step, as in our serve engine) writes the new KV with an
    in-place ``dynamic_update_slice`` -- with a donated cache this is a
    true in-place update, where the general one-hot scatter costs two
    full cache copies of temp HBM (measured: 14.3 GiB -> 6.5 GiB on
    minicpm-2b decode_32k, §Perf/1 iteration 2).
    """
    b, _, _ = x.shape
    positions = pos[:, None]  # (B,1)
    q, kv = attention_qkv(params, x, positions, cfg)
    if uniform_pos:
        # all rows share pos[0]; write one slice in place
        zero = jnp.zeros((), jnp.int32)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, kv.k.astype(cache_k.dtype), (zero, pos[0], zero, zero)
        )
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, kv.v.astype(cache_v.dtype), (zero, pos[0], zero, zero)
        )
    else:
        # ragged batch: scatter new kv at per-row pos
        oh = jax.nn.one_hot(pos, cache_k.shape[1], dtype=cache_k.dtype)  # (B,S)
        cache_k = cache_k * (1 - oh[..., None, None]) + oh[..., None, None] * kv.k
        cache_v = cache_v * (1 - oh[..., None, None]) + oh[..., None, None] * kv.v
    sk = cache_k.shape[1]
    kh = cfg.n_kv_heads
    g = cfg.n_heads // kh
    qg = (q / np.sqrt(cfg.head_dim)).reshape(b, 1, kh, g, cfg.head_dim)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, cache_k,
                   preferred_element_type=jnp.float32)
    kpos = jnp.arange(sk)[None, None, None, None, :]
    mask = kpos <= pos[:, None, None, None, None]
    wthr = jnp.where(window > 0, pos[:, None, None, None, None] - window,
                     jnp.int32(-(2**30)))
    mask = mask & (kpos > wthr)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, cache_v)
    o = o.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    return attention_out(params, o, x.dtype), cache_k, cache_v


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

def mlp_spec(cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {
            "wi": P((d, f), ("embed", "mlp")),
            "wg": P((d, f), ("embed", "mlp")),
            "wo": P((f, d), ("mlp", "embed")),
        }
    return {
        "wi": P((d, f), ("embed", "mlp")),
        "wo": P((f, d), ("mlp", "embed")),
    }


def mlp(params, x, act: str):
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_spec(cfg):
    # table padded to vocab_padded for even vocab-axis sharding; ids are
    # always < vocab_size, and loss/serve mask the padded logit slots.
    return {"embedding": P((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"))}


def embed(params, ids):
    return jnp.take(params["embedding"], ids, axis=0)


def unembed_spec(cfg):
    return {"w": P((cfg.d_model, cfg.vocab_padded), ("embed", "vocab"))}


def unembed(params, x):
    return jnp.einsum("bsd,dv->bsv", x, params["w"].astype(x.dtype))
