"""Train step factory: loss (chunked CE + z-loss + MoE aux), grad, update.

``make_train_step(model, run)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with explicit shardings.  Batches carry:
    tokens  (B, S) int32                       -- always
    frames  (B, S_enc, d) float                -- audio (encoder stub input)
    prefix  (B, P, d) float                    -- vlm (patch stub input)
Loss is next-token cross entropy over text positions; the padded vocab tail
is masked out of the softmax.  Gradient accumulation: set run.microbatch to
split the per-device batch into sequential microbatches (scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt
from repro.parallel.sharding import constrain


def cross_entropy(logits, labels, vocab_size, zloss=0.0, chunk=512,
                  weights=None):
    """Mean next-token CE, chunked over sequence to bound logit memory.

    logits: (B, S, Vp) (padded vocab); labels: (B, S) (already shifted);
    weights: optional (B, S) loss mask (0 = ignore position).
    """
    b, s, vp = logits.shape
    chunk = min(chunk, s)
    n = s // chunk if s % chunk == 0 else 1
    if s % chunk:
        chunk = s
    if weights is None:
        weights = jnp.ones((b, s), jnp.float32)
    lg = logits.reshape(b, n, chunk, vp)
    lb = labels.reshape(b, n, chunk)
    lw = weights.astype(jnp.float32).reshape(b, n, chunk)

    def body(acc, xs):
        lgc, lbc, lwc = xs  # (B, chunk, Vp), (B, chunk), (B, chunk)
        x = lgc.astype(jnp.float32)
        # mask padded vocab slots out of the softmax
        valid = jnp.arange(vp) < vocab_size
        x = jnp.where(valid[None, None, :], x, -1e30)
        m = jnp.max(x, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
        # gold logit via one-hot contraction: take_along_axis over a
        # vocab-sharded axis would force GSPMD to all-gather the logits;
        # the einsum reduces over the sharded axis instead (psum).
        oh = (lbc[..., None] == jnp.arange(vp)[None, None, :]).astype(x.dtype)
        gold = jnp.einsum("bsv,bsv->bs", x, oh)
        ce = jnp.sum((lse - gold) * lwc)
        zl = jnp.sum(jnp.square(lse) * lwc) * zloss
        return acc + ce + zl, None

    xs = (jnp.moveaxis(lg, 1, 0), jnp.moveaxis(lb, 1, 0),
          jnp.moveaxis(lw, 1, 0))
    total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
    return total / jnp.maximum(jnp.sum(weights), 1.0)


def make_loss_fn(model, run):
    cfg = model.cfg

    def loss_fn(params, batch):
        # Forward the FULL token length and mask the final position out of
        # the loss instead of slicing tokens[:, :-1].  An odd sequence
        # length (4095) breaks every power-of-two tiling downstream --
        # MoE group reshape (forces a full activation all-gather +
        # replicated dispatch under GSPMD: measured 14.2 GB/layer/device
        # of collectives on deepseek train_4k), chunked-CE scan, and the
        # SSM chunk scan.  See EXPERIMENTS.md §Perf/2.
        tokens = batch["tokens"]
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1
        )
        wts = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
        if "frames" in batch:
            logits, aux = model.forward(params, tokens, batch["frames"])
        elif "prefix" in batch:
            logits, aux = model.forward(
                params, tokens, prefix_embeds=batch["prefix"]
            )
            logits = logits[:, batch["prefix"].shape[1]:]
        else:
            logits, aux = model.forward(params, tokens)
        ce = cross_entropy(logits, labels, cfg.vocab_size, zloss=cfg.zloss,
                           weights=wts)
        return ce + aux, {"ce": ce, "aux": jnp.float32(aux)}

    return loss_fn


def _replicate_over_data(model, params):
    """Constrain every param to its sharding with FSDP ('embed'/'expert'
    over 'data') disabled -- one all-gather here instead of one per
    micro-iteration; the transpose is a single grad reduce-scatter."""
    from repro.models import params as pmod
    from repro.parallel import sharding as shd

    mesh = shd.active_mesh()
    if mesh is None:
        return params
    rules = dict(shd.active_rules())
    rules["embed"] = None
    rules["expert"] = None

    def one(p, axes):
        ns = jax.sharding.NamedSharding(
            mesh, shd.pspec(axes, rules=rules, mesh=mesh, shape=p.shape)
        )
        return jax.lax.with_sharding_constraint(p, ns)

    return pmod.map_with_axes(one, params, model.spec())


def make_train_step(model, run):
    loss_fn = make_loss_fn(model, run)
    schedule = opt.make_schedule(run)

    def train_step(params, opt_state, batch):
        if run.microbatch and run.microbatch > 1:
            n = run.microbatch
            mbs = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )
            if run.gather_weights_once:
                # grads accumulate on the hoisted (replicated) copy inside
                # grad-of-scan; one reduce-scatter at the transpose of the
                # constraint (EXPERIMENTS.md §Perf/2 it.3)
                def total_loss(p):
                    pc = _replicate_over_data(model, p)
                    body = jax.checkpoint(
                        lambda acc, mb: (acc + loss_fn(pc, mb)[0], None)
                    )
                    tot, _ = jax.lax.scan(body, jnp.float32(0.0), mbs)
                    return tot / n

                loss, grads = jax.value_and_grad(total_loss)(params)
                metrics = {}
            else:
                def micro(carry, mb):
                    gacc, lacc = carry
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb
                    )
                    return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (grads, ltot), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / n, grads)
                loss = ltot / n
                metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        lr = schedule(opt_state.step)
        params, opt_state, gnorm = opt.adamw_update(
            params, grads, opt_state, lr,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip,
        )
        out = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        out.update(metrics)
        return params, opt_state, out

    return train_step


def make_eval_step(model, run):
    loss_fn = make_loss_fn(model, run)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
