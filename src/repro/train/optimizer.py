"""AdamW + LR schedules, pure-pytree implementation (no optax dependency).

Optimizer moments inherit the parameter shardings (params are FSDP-sharded
over 'data' via the logical-axis rules), which is the ZeRO-sharded-state
arrangement: no device holds a full copy of m/v for the large weights.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: dict
    v: dict


def init_opt_state(params, dtype=jnp.float32) -> OptState:
    """Moments default to f32; pass bfloat16 for memory-tight giants
    (arctic-480b on a single 256-chip pod: 480B x 12B/chip of f32 state
    does not fit 16 GB HBM -- bf16 moments are the standard compromise)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def make_schedule(run: RunConfig):
    """Returns lr(step).  'wsd' = warmup-stable-decay (MiniCPM)."""

    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, run.warmup_steps))
        if run.schedule == "constant":
            dec = 1.0
        elif run.schedule == "cosine":
            t = jnp.clip((step - run.warmup_steps)
                         / max(1, run.steps - run.warmup_steps), 0.0, 1.0)
            dec = 0.5 * (1 + jnp.cos(np.pi * t))
        elif run.schedule == "wsd":
            decay_start = int(run.steps * 0.9)
            t = jnp.clip((step - decay_start) / max(1, run.steps - decay_start),
                         0.0, 1.0)
            dec = 1.0 - t * (1.0 - 0.1)  # linear decay to 10%
        else:
            raise ValueError(run.schedule)
        return run.learning_rate * warm * dec

    return lr


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state: OptState, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """One AdamW step with global-norm clipping.  Returns (params, state)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9)) if grad_clip else 1.0
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mdt = m.dtype
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m2.astype(mdt), v2.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), gnorm
