"""Fault-tolerant training loop.

Wires together: jitted train step (explicit shardings), async atomic
checkpointing with auto-resume, preemption (SIGTERM) emergency save,
straggler logging, and JSONL metrics.  The same class drives the tiny CPU
end-to-end example and (with a production mesh) a pod-scale run.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.parallel import sharding as shd
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (
    PreemptionHandler,
    StepTimer,
    StragglerDetector,
)
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


class Trainer:
    def __init__(self, model, run: RunConfig, data_iter, workdir,
                 mesh=None, rules=None):
        self.model = model
        self.run = run
        self.data_iter = data_iter
        self.workdir = Path(workdir)
        self.workdir.mkdir(parents=True, exist_ok=True)
        self.mesh = mesh
        self.rules = rules
        self.ckpt = CheckpointManager(self.workdir / "ckpt", keep=run.keep_checkpoints)
        self.straggler = StragglerDetector()
        self.metrics_path = self.workdir / "metrics.jsonl"

        step_fn = make_train_step(model, run)
        if mesh is not None:
            p_sh = shd.param_shardings(model.spec(), mesh, rules)
            o_sh = opt.OptState(
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                p_sh, jax.tree.map(lambda x: x, p_sh),
            )
            self._p_sh, self._o_sh = p_sh, o_sh
            self.step_fn = jax.jit(
                step_fn, in_shardings=(p_sh, o_sh, None),
                out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1),
            )
        else:
            self._p_sh = self._o_sh = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- state --------------------------------------------------------------
    def init_state(self, seed=0):
        params = self.model.init(jax.random.PRNGKey(seed))
        if self._p_sh is not None:
            params = jax.tree.map(jax.device_put, params, self._p_sh)
        return params, opt.init_opt_state(params)

    def resume_or_init(self, seed=0):
        params, opt_state = self.init_state(seed)
        skeleton = (params, opt_state)
        shardings = (self._p_sh, self._o_sh) if self._p_sh is not None else None
        out = self.ckpt.restore_latest(skeleton, shardings)
        if out is None:
            return 0, params, opt_state
        step, (params, opt_state), _ = out
        print(f"[trainer] resumed from step {step}")
        return step, params, opt_state

    # -- loop ---------------------------------------------------------------
    def train(self, steps=None, seed=0):
        steps = steps or self.run.steps
        start, params, opt_state = self.resume_or_init(seed)
        preempt = PreemptionHandler().install()
        mfile = self.metrics_path.open("a")
        last = {}
        try:
            ctx = shd.use_mesh(self.mesh, self.rules) if self.mesh else _null()
            with ctx:
                for step in range(start, steps):
                    batch = next(self.data_iter)
                    with StepTimer() as t:
                        params, opt_state, metrics = self.step_fn(
                            params, opt_state, batch
                        )
                        jax.block_until_ready(metrics["loss"])
                    slow = self.straggler.observe(step, t.seconds)
                    rec = {
                        "step": step,
                        "loss": float(metrics["loss"]),
                        "lr": float(metrics["lr"]),
                        "grad_norm": float(metrics["grad_norm"]),
                        "step_s": round(t.seconds, 4),
                        "straggler": slow,
                    }
                    last = rec
                    mfile.write(json.dumps(rec) + "\n")
                    mfile.flush()
                    do_ckpt = (
                        (step + 1) % self.run.checkpoint_every == 0
                        or step + 1 == steps
                        or preempt.requested
                    )
                    if do_ckpt:
                        if self.run.async_checkpoint and not preempt.requested:
                            self.ckpt.save_async(step + 1, (params, opt_state))
                        else:
                            self.ckpt.save(step + 1, (params, opt_state))
                    if preempt.requested:
                        print(f"[trainer] preempted at step {step + 1}; "
                              "checkpoint written")
                        break
        finally:
            self.ckpt.wait()
            mfile.close()
            preempt.uninstall()
        return params, opt_state, last


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
