"""Roofline accounting from compiled XLA artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` provides per-device FLOPs and bytes (the
compiled module is the per-device SPMD program).  Collective bytes are NOT
in cost_analysis, so we parse the optimized HLO text and sum the result
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (including their -start async forms).  Shapes in
post-SPMD HLO are already per-device, so dividing by per-link bandwidth
matches the brief's ``collective_bytes / (chips * link_bw)`` with
``collective_bytes = per_device_bytes * chips``.
"""
from __future__ import annotations

import re

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# e.g.:  %ag = bf16[4,512]{1,0} all-gather(...)   or tuple results
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\s*\("
)


def shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[shape] occurrence in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved, by collective kind (result-shape sizes).

    '-done' ops are skipped so async start/done pairs count once.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue
        out[kind] += shape_bytes(shape_txt)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# Loop-aware cost correction.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE, not times its trip
# count -- so scanned layer stacks (28-48 trips), blockwise-attention KV
# scans and chunked-CE scans are badly undercounted.  We therefore walk the
# *jaxpr* of the lowered function twice -- once multiplying scan bodies by
# their static `length`, once not -- and scale the HLO numbers by the ratio.
# This is exact for FLOPs up to sharding uniformity across iterations (all
# our scan bodies shard identically per iteration).
#
# The walk counts dot_general FLOPs exactly (2*M*N*K) AND one FLOP per
# output element of elementwise arithmetic / one per input element of
# reductions: the extraction kernels (pair sweeps, marching cubes, the
# intensity families) are elementwise-dominated with NO dots at all, so a
# dot-only count would leave their correction ratio pinned at 1.0 and the
# scan undercount uncorrected.
# ---------------------------------------------------------------------------

# elementwise primitives costed at one FLOP per OUTPUT element
_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "integer_pow",
    "exp", "log", "log1p", "sqrt", "rsqrt", "abs", "neg", "floor",
    "ceil", "round", "sign", "tanh", "logistic", "erf", "expm1",
    "and", "or", "xor", "not", "eq", "ne", "lt", "le", "gt", "ge",
    "select_n", "clamp", "rem", "nextafter", "atan2",
    "cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp",
})

# reduction primitives costed at one FLOP per INPUT element
_REDUCTIONS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "argmax", "argmin",
})


def _nelems(aval) -> float:
    try:
        n = 1
        for d in aval.shape:
            n *= int(d)
        return float(n)
    except Exception:
        return 0.0


def _aval_bytes(aval) -> float:
    try:
        n = 1
        for d in aval.shape:
            n *= int(d)
        return float(n * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    lfree = 1
    for i, d in enumerate(lhs.shape):
        if i not in lb and i not in lc:
            lfree *= d
    rfree = 1
    for i, d in enumerate(rhs.shape):
        if i not in rb and i not in rc:
            rfree *= d
    return 2.0 * batch * lfree * rfree * contract


def jaxpr_cost(jaxpr, multiply_loops: bool = True):
    """(flops, naive_bytes) of a (closed) jaxpr, loop-aware.

    FLOPs = exact dot_general count + one per elementwise output element
    + one per reduction input element (see the section comment above).
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
            byts += sum(_aval_bytes(v.aval) for v in list(eqn.invars) + list(eqn.outvars))
            continue
        sub_mult = 1.0
        subs = []
        p = eqn.params
        if name == "scan":
            subs = [p["jaxpr"]]
            sub_mult = float(p.get("length", 1)) if multiply_loops else 1.0
        elif name == "while":
            subs = [p["body_jaxpr"]]
        elif name == "cond":
            subs = list(p["branches"])[:1]  # branches are cost-equivalent here
        elif "jaxpr" in p:
            subs = [p["jaxpr"]]
        elif "call_jaxpr" in p:
            subs = [p["call_jaxpr"]]
        elif "branches" in p:
            subs = list(p["branches"])[:1]
        if subs:
            for s in subs:
                f, b = jaxpr_cost(s, multiply_loops)
                flops += sub_mult * f
                byts += sub_mult * b
        else:
            if name in _ELEMENTWISE:
                flops += sum(_nelems(v.aval) for v in eqn.outvars)
            elif name in _REDUCTIONS:
                flops += sum(_nelems(v.aval) for v in eqn.invars)
            byts += sum(_aval_bytes(v.aval) for v in list(eqn.invars) + list(eqn.outvars))
    return flops, byts


def loop_corrections(fn, *abstract_args) -> tuple[float, float, dict]:
    """(flop_correction, byte_correction, detail) for a traced function."""
    import jax

    closed = jax.make_jaxpr(fn)(*abstract_args)
    f1, b1 = jaxpr_cost(closed, multiply_loops=True)
    f0, b0 = jaxpr_cost(closed, multiply_loops=False)
    detail = {
        "jaxpr_dot_flops_total": f1,
        "jaxpr_dot_flops_loops_once": f0,
    }
    fc = f1 / f0 if f0 > 0 else 1.0
    bc = b1 / b0 if b0 > 0 else 1.0
    return fc, bc, detail


def compiled_cost(compiled) -> tuple[float, float]:
    """Uncorrected (flops, bytes accessed) straight off ``cost_analysis()``.

    Handles the older-jax list-of-dict return form; missing fields read
    as zero.  Pair with :func:`loop_corrections` for scan-heavy programs.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def cost_terms(compiled, n_chips: int, model_flops: float | None = None,
               hlo_text: str | None = None, flop_correction: float = 1.0,
               byte_correction: float = 1.0,
               bytes_override: float | None = None,
               collective_total_override: float | None = None,
               structural_bytes: float | None = None,
               hw: dict | None = None) -> dict:
    """The roofline report for one compiled executable.

    ``hw`` overrides the static mesh constants with a measured hardware
    profile (``peak_flops_bf16`` / ``hbm_bw`` / ``ici_bw`` keys; missing
    keys fall back to the mesh defaults) -- see
    ``repro.runtime.autotune.get_hw_profile``.
    """
    raw_flops, raw_bytes = compiled_cost(compiled)
    flops = raw_flops * flop_correction
    if bytes_override is not None:
        bytes_acc = bytes_override
    else:
        bytes_acc = raw_bytes * byte_correction
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    coll_total = (
        collective_total_override
        if collective_total_override is not None
        else coll["total"]
    )

    hw = {**HW, **(hw or {})}
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_acc / hw["hbm_bw"]
    t_collective = coll_total / hw["ici_bw"]
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    if structural_bytes is not None:
        terms["memory_s"] = structural_bytes / hw["hbm_bw"]
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    report = {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "memory_s_xla": t_memory,
        "structural_hbm_bytes": structural_bytes,
        "flop_correction": flop_correction,
        "byte_correction": byte_correction,
        "collective_bytes_per_device": coll_total,
        "collective_bytes_loops_once": coll["total"],
        "collective_ops": coll["count"],
        "collective_breakdown": {k: coll[k] for k in _COLLECTIVES},
        "n_chips": n_chips,
    }
    if model_flops is not None and flops > 0:
        report["model_flops_total"] = model_flops
        report["useful_flops_ratio"] = model_flops / (flops * n_chips)
    if bound > 0:
        # roofline fraction: how much of the bound step is pure compute
        report["roofline_fraction"] = t_compute / bound
    return report


def memory_report(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["hbm_total_bytes"] = (
            out["argument_size_in_bytes"] + out["temp_size_in_bytes"]
        )
    return out


def structural_hbm_bytes(cfg, shape, n_chips: int, tp: int = 16,
                         dp: int = 16, cache_shard: int = 1) -> float:
    """Structural per-chip HBM-traffic model for a TPU execution.

    XLA's `bytes accessed` on the CPU backend counts every op boundary --
    on a TPU the attention/SSM inner loops run fused in VMEM, so real HBM
    traffic is dominated by: weight reads (x3 for fwd/remat/bwd in
    training), optimizer state read+write, saved layer-boundary
    activations, logits, and (decode) the KV cache.  This model counts
    exactly those.  Reported alongside the XLA number; see DESIGN.md
    §Roofline-accounting.
    """
    N = cfg.n_active_params
    b_loc = max(1, shape.global_batch // dp)
    s = shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_encoder_layers
    vp = cfg.vocab_padded
    w_read = 2.0 * N / tp  # bf16 weight shard streamed per pass
    if shape.kind == "train":
        passes = 3.0  # fwd + remat-recompute + bwd
        opt = 10.0 * 4.0 * N / n_chips  # p,m,v,g r/w at f32, fully sharded
        acts = 2.0 * L * b_loc * s * d * 2.0  # save + reload layer inputs
        logits = 3.0 * b_loc * s * (vp / tp) * 2.0
        return passes * w_read + opt + acts + logits
    if shape.kind == "prefill":
        acts = 2.0 * L * b_loc * s * d * 2.0
        logits = b_loc * 1 * (vp / tp) * 2.0
        return w_read + acts + logits
    # decode: one token -- weights + cache traffic dominate
    cache = 0.0
    if cfg.family == "ssm":
        nh = d // 64
        cache = 2.0 * L * b_loc * (2 * d + nh * 64 * 64 * 2) * 2.0
    elif cfg.family == "hybrid":
        di = cfg.ssm_expand * d
        nh = di // cfg.head_dim
        for i in range(cfg.n_layers):
            w = cfg.attn_window if i not in cfg.global_attn_layers else 0
            slots = min(s, w) if w else s
            cache += b_loc * slots * cfg.n_kv_heads * cfg.head_dim * 2 * 2.0
            cache += b_loc * nh * cfg.ssm_state * cfg.head_dim * 4 * 2.0
    else:
        kv = max(1, cfg.n_kv_heads // 1)  # kv heads often replicated on TP
        cache = L * b_loc * s * kv * cfg.head_dim * 2 * 2.0
        if cfg.family in ("audio", "encdec"):
            cache += L * b_loc * (s // 4) * kv * cfg.head_dim * 2 * 2.0
    cache /= max(1, cache_shard)  # seq-sharded cache (flash-decode layout)
    logits = b_loc * (vp / tp) * 2.0
    return w_read + cache + logits


def model_flops_train(cfg, tokens: int) -> float:
    """6 * N_active * D (the standard training-FLOPs estimate)."""
    return 6.0 * cfg.n_active_params * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    return 2.0 * cfg.n_active_params * tokens
