"""Utilities: roofline accounting, HLO collective parsing."""
