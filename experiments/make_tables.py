"""Regenerate the EXPERIMENTS.md roofline tables from dryrun JSONs.

Usage: python experiments/make_tables.py [--mesh single|multi]
Prints GitHub-flavoured markdown.
"""
import argparse
import json
from pathlib import Path

DIR = Path(__file__).resolve().parent / "dryrun"


def fmt(mesh: str, dir=None):
    global DIR
    if dir is not None:
        DIR = Path(dir)
    print(f"\n#### Mesh: {mesh}\n")
    print("| arch | shape | dominant | compute (s) | memory (s) | collective (s) "
          "| roofline frac | useful FLOPs | HBM GiB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for p in sorted(DIR.glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        arch, shape = d["arch"], d["shape"]
        if d.get("skipped"):
            print(f"| {arch} | {shape} | — | — | — | — | — | — | — | "
                  f"skipped: sub-quadratic-only shape |")
            continue
        if d.get("status") != "ok":
            print(f"| {arch} | {shape} | FAIL | | | | | | | {d.get('error','')[:60]} |")
            continue
        r = d["roofline"]
        m = d.get("memory", {})
        hbm = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30
        note = "OVER-HBM" if hbm > 16 else ""
        print(
            f"| {arch} | {shape} | {r['dominant'].replace('_s','')} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r.get('roofline_fraction', 0):.2f} | {r.get('useful_flops_ratio', 0):.2f} "
            f"| {hbm:.1f} | {note} |"
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--dir", default=None,
                    help="JSON dir (default experiments/dryrun; use "
                         "experiments/dryrun_baseline for the paper-faithful table)")
    a = ap.parse_args()
    for mesh in ([a.mesh] if a.mesh else ["single", "multi"]):
        fmt(mesh, dir=a.dir)
