"""Batched autoregressive serving with a KV/state cache.

Serves a reduced-config model from the zoo: prefill the prompt batch, then
step the jitted serve_step (one token per call against the cache).  Works
for every family -- attention KV caches, RWKV6 constant-size state, and
Hymba's hybrid window+SSM cache -- because each model implements
``init_cache`` / ``decode_step`` behind the same interface.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config, get_model, list_archs
from repro.serve.serve_step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_len = P + args.tokens

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)

    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    step = jax.jit(make_serve_step(model, temperature=args.temperature))

    # prefill: teacher-force the prompt through decode_step (cache warmup)
    t0 = time.perf_counter()
    for i in range(P):
        _, _, cache = step(params, cache, prompts[:, i : i + 1],
                           jax.random.PRNGKey(i))
    jax.block_until_ready(cache)
    t_prefill = time.perf_counter() - t0

    # decode loop
    tok = prompts[:, -1:]
    out = []
    t0 = time.perf_counter()
    for i in range(args.tokens):
        tok, _, cache = step(params, cache, tok, jax.random.PRNGKey(1000 + i))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} family={cfg.family} batch={B}")
    print(f"prefill: {P} tokens in {t_prefill*1e3:.1f} ms")
    print(f"decode : {args.tokens} tokens in {t_decode*1e3:.1f} ms "
          f"({B*args.tokens/t_decode:.1f} tok/s)")
    print(f"sample row 0: {np.asarray(gen[0])[:16].tolist()}")


if __name__ == "__main__":
    main()
