"""HPC radiomics pipeline: batched extraction with restart, the xLUNGS story.

The paper's motivation is feature extraction over ~40 000 CT scans on a
cluster.  This driver shows the production pattern for that job:

  * cases are bucketed by padded shape so each bucket compiles once;
  * the batch axis shards over the mesh 'data' axis when >1 device is
    present (one case per chip in flight);
  * host->device feeding is double-buffered (transfer overlaps compute --
    the DMA overlap the paper's conclusion calls out);
  * completed features are checkpointed to a JSONL manifest, so a killed
    job resumes where it left off (cluster preemption safety).

    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24
"""
import argparse
import json
from pathlib import Path

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case, table2_cases

FEATURE_NAMES = ("MeshVolume", "SurfaceArea", "Maximum3DDiameter",
                 "Maximum2DDiameterSlice", "Maximum2DDiameterRow",
                 "Maximum2DDiameterColumn", "n_vertices")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=16)
    ap.add_argument("--out", default="/tmp/repro_pipeline/features.jsonl")
    ap.add_argument("--variant", default="seqacc")
    ap.add_argument("--no-prune", action="store_true",
                    help="legacy one-pass pipeline (no exact pruning)")
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if out.exists():  # restart: skip already-extracted cases
        done = {json.loads(l)["case"] for l in out.read_text().splitlines()}
        print(f"resuming: {len(done)} cases already extracted")

    # synthetic KITS19-like workload, small-to-medium Table-2 dims repeated
    dims_pool = [d for _, d in table2_cases() if min(d) >= 10][:8]
    todo, cases = [], []
    for i in range(args.cases):
        name = f"case-{i:05d}"
        if name in done:
            continue
        img, msk, sp = make_case(dims_pool[i % len(dims_pool)], seed=i)
        todo.append(name)
        cases.append((img, msk, sp))
    if not cases:
        print("nothing to do")
        return

    ext = BatchedExtractor(  # mesh=None: single device
        variant=args.variant, prune=not args.no_prune
    )
    results, stats = ext.run(cases, batch_size=4)

    with out.open("a") as f:
        for name, feat in zip(todo, results):
            rec = {"case": name}
            rec.update({k: float(v) for k, v in zip(FEATURE_NAMES, feat)})
            f.write(json.dumps(rec) + "\n")
    print(f"extracted {stats['cases']} cases in {stats['seconds']:.1f}s "
          f"({stats['cases_per_second']:.2f} cases/s, "
          f"{stats['buckets']} shape buckets, "
          f"{stats['vertex_buckets']} vertex buckets)")
    if stats["two_pass"]:
        print(f"two-pass pruning: {stats['pruned_cases']} cases shrunk, "
              f"mean keep fraction {stats['mean_keep_fraction']:.3f}")
    print(f"manifest: {out}")


if __name__ == "__main__":
    main()
