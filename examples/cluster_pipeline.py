"""HPC radiomics pipeline: resilient streaming extraction, the xLUNGS story.

The paper's motivation is feature extraction over ~40 000 CT scans on a
cluster.  This driver shows the production pattern for that job, built on
the resilience layer (``runtime/resilience``) over the streaming
plan/executor pipeline:

  * cases flow through as an ITERATOR -- nothing materialises the whole
    batch; the runner mirrors ``extract_stream``'s overlap (host prep of
    window k+1 while the device executes window k);
  * completed features land in a :class:`RunManifest` -- atomic
    append-only JSONL keyed by a CONTENT hash of each mask+spacing, so a
    killed job resumes where it left off even if cases were renamed or
    reordered, redoing at most one window of work;
  * a poisoned case (NaN mask, dead loader) quarantines as a row-level
    ``error`` record instead of killing the run, and ``--retries`` turns
    on backed-off re-submission of a window whose collect hits a
    transient fault;
  * SIGTERM (the cluster preemption notice) is caught by the runner's
    :class:`PreemptionHandler`: the in-flight window drains and commits,
    the open buffer is abandoned, and the next invocation resumes;
  * every window's plan census (shape/cap buckets, pad waste, resolved
    schedule, straggler flag) prints as it drains -- the telemetry a
    cluster operator watches for bucket explosion on heterogeneous
    cohorts;
  * the executor still configures itself (the PR 5 cost-model layer):
    ``--schedule auto`` picks counted vs static per window and
    ``--prep hint`` keeps the submit path free of per-case host syncs --
    all bit-identical to the fixed knobs (tier-1-locked).

    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24
    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24 \\
        --window 8 --schedule static --prep count --retries 2  # pin knobs
"""
import argparse

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import stream_cases
from repro.runtime.resilience import (
    FEATURE_NAMES,  # noqa: F401  (re-export kept for downstream scripts)
    ResilientRunner,
    RetryPolicy,
    RunManifest,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=16)
    ap.add_argument("--window", type=int, default=8,
                    help="cases per stream window (a kill redoes at most "
                         "one of these)")
    ap.add_argument("--out", default="/tmp/repro_pipeline/features.jsonl")
    ap.add_argument("--variant", default="seqacc")
    ap.add_argument("--schedule", default="auto",
                    choices=("auto", "static", "counted"),
                    help="pass-2b bucket schedule (auto: cost-model-picked "
                         "per window; static: sync-free pass 1)")
    ap.add_argument("--prep", default="hint", choices=("hint", "count"),
                    help="pass-0 cap sizing (hint: metadata-only, "
                         "sync-free; count: per-case measured)")
    ap.add_argument("--retries", type=int, default=2,
                    help="per-window collect retries (0 disables)")
    args = ap.parse_args()

    def census(widx, s):
        print(f"window {widx}: {s['cases']} cases, "
              f"{s['shape_buckets']} shape buckets, "
              f"{s['cap_buckets']} vertex buckets, "
              f"pad waste mask {s['mask_pad_waste']:.0%} / "
              f"verts {s['vertex_pad_waste']:.0%}, "
              f"schedule={s['schedule']}, {s['seconds']:.2f}s"
              + (", QUARANTINED={}".format(s["quarantined"])
                 if s.get("quarantined") else "")
              + (", STRAGGLER" if s.get("straggler") else ""))

    ext = BatchedExtractor(  # mesh=None: single device
        variant=args.variant, schedule=args.schedule, prep=args.prep,
        retry=RetryPolicy(max_retries=args.retries) if args.retries else None,
    )
    manifest = RunManifest(args.out)
    already = len(manifest.resume())
    if already:
        print(f"resuming: {already} cases already in the manifest")

    runner = ResilientRunner(ext, manifest, window=args.window,
                             stats_callback=census)
    # stream (name, image, mask, spacing); the runner skips done cases
    # by CONTENT id, so renames/reorders of the input cannot double-run
    rep = runner.run(stream_cases(args.cases))
    manifest.close()

    if rep.processed == 0 and rep.status == "complete":
        print(f"nothing to do ({rep.skipped} cases already extracted)")
        return
    log = ext.executor.transfer_log
    print(f"{rep.status}: {rep.processed} rows in {rep.seconds:.1f}s "
          f"({rep.cases_per_second:.2f} cases/s, {rep.windows} windows, "
          f"skipped {rep.skipped} done, quarantined {rep.quarantined}, "
          f"window retries {rep.window_retries}, "
          f"stragglers {len(rep.stragglers)}; "
          f"per-case host syncs: pass0={log.get('prep', 0)} "
          f"pass1={log.get('pass1', 0)})")
    print(f"manifest: {manifest.path}")
    if rep.status == "preempted":
        print("preempted -- re-run the same command to resume")


if __name__ == "__main__":
    main()
