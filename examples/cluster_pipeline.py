"""HPC radiomics pipeline: streaming extraction with restart, the xLUNGS story.

The paper's motivation is feature extraction over ~40 000 CT scans on a
cluster.  This driver shows the production pattern for that job, built on
the dataset-level streaming front-end (``extract_stream``):

  * cases flow through as an ITERATOR -- nothing materialises the whole
    batch; host prep (load + crop + pad + bucket) of window k+1 overlaps
    device execution of window k (the DMA/compute overlap the paper's
    conclusion calls out);
  * ``--schedule static`` removes the pass-1 survivor-count sync, so the
    submit path never blocks on the device -- the right schedule for
    streaming (bit-identical features; see core/plan.py);
  * every window's plan census (shape/cap buckets, pad waste) prints at
    submit time, the telemetry a cluster operator watches for bucket
    explosion on heterogeneous cohorts;
  * completed features are checkpointed to a JSONL manifest as each
    window drains, so a killed job resumes where it left off (cluster
    preemption safety) -- at most one window of work is ever redone.

    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24 --window 8
"""
import argparse
import json
from pathlib import Path

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import stream_cases

FEATURE_NAMES = ("MeshVolume", "SurfaceArea", "Maximum3DDiameter",
                 "Maximum2DDiameterSlice", "Maximum2DDiameterRow",
                 "Maximum2DDiameterColumn", "n_vertices")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=16)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--out", default="/tmp/repro_pipeline/features.jsonl")
    ap.add_argument("--variant", default="seqacc")
    ap.add_argument("--schedule", default="static",
                    choices=("static", "counted"),
                    help="pass-2b bucket schedule (static: sync-free pass 1)")
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if out.exists():  # restart: skip already-extracted cases
        done = {json.loads(l)["case"] for l in out.read_text().splitlines()}
        print(f"resuming: {len(done)} cases already extracted")

    # synthetic KITS19-like workload, streamed lazily (never a full batch)
    names = []

    def cases():
        for name, img, msk, sp in stream_cases(args.cases, skip=done):
            names.append(name)
            yield img, msk, sp

    def window_stats(i, s):
        print(f"window {i}: {s['cases']} cases, "
              f"{s['shape_buckets']} shape buckets, "
              f"{s['cap_buckets']} vertex buckets, "
              f"pad waste mask {s['mask_pad_waste']:.0%} / "
              f"verts {s['vertex_pad_waste']:.0%}")

    ext = BatchedExtractor(  # mesh=None: single device
        variant=args.variant, schedule=args.schedule
    )
    n_done = 0
    import time
    t0 = time.perf_counter()
    with out.open("a") as f:
        for feat in ext.extract_stream(cases(), window=args.window,
                                       stats_callback=window_stats):
            rec = {"case": names[n_done]}
            rec.update({k: float(v) for k, v in zip(FEATURE_NAMES, feat)})
            f.write(json.dumps(rec) + "\n")
            f.flush()  # checkpoint per row: preemption loses < one window
            n_done += 1
    dt = time.perf_counter() - t0
    if n_done == 0:
        print("nothing to do")
        return
    print(f"extracted {n_done} cases in {dt:.1f}s "
          f"({n_done / dt:.2f} cases/s, schedule={args.schedule}, "
          f"pass-1 host syncs: "
          f"{ext.executor.transfer_log.get('pass1', 0)})")
    print(f"manifest: {out}")


if __name__ == "__main__":
    main()
