"""HPC radiomics pipeline: streaming extraction with restart, the xLUNGS story.

The paper's motivation is feature extraction over ~40 000 CT scans on a
cluster.  This driver shows the production pattern for that job, built on
the dataset-level streaming front-end (``extract_stream``):

  * cases flow through as an ITERATOR -- nothing materialises the whole
    batch; host prep (load + crop + pad + bucket) of window k+1 overlaps
    device execution of window k (the DMA/compute overlap the paper's
    conclusion calls out);
  * the pipeline configures ITSELF by default (the PR 5 cost-model
    layer, ``runtime/costmodel``): ``--window auto`` closes windows at
    census-decided bucket boundaries, ``--schedule auto`` picks counted
    vs static per window from the calibrated ``sync/<backend>`` probe,
    and ``--prep hint`` sizes vertex caps from metadata alone so the
    submit path performs ZERO per-case host syncs -- all bit-identical
    to the fixed knobs (tier-1-locked), which remain available for
    pinning;
  * every window's plan census (shape/cap buckets, pad waste, resolved
    schedule) prints at submit time, the telemetry a cluster operator
    watches for bucket explosion on heterogeneous cohorts;
  * completed features are checkpointed to a JSONL manifest as each
    window drains, so a killed job resumes where it left off (cluster
    preemption safety) -- at most one window of work is ever redone.

    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24
    PYTHONPATH=src python examples/cluster_pipeline.py --cases 24 \\
        --window 8 --schedule static --prep count   # pin every knob
"""
import argparse
import json
from pathlib import Path

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import stream_cases

FEATURE_NAMES = ("MeshVolume", "SurfaceArea", "Maximum3DDiameter",
                 "Maximum2DDiameterSlice", "Maximum2DDiameterRow",
                 "Maximum2DDiameterColumn", "n_vertices")


def _window(value: str):
    return value if value == "auto" else int(value)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=16)
    ap.add_argument("--window", type=_window, default="auto",
                    help="cases per stream window, or 'auto' for "
                         "census-decided adaptive boundaries")
    ap.add_argument("--out", default="/tmp/repro_pipeline/features.jsonl")
    ap.add_argument("--variant", default="seqacc")
    ap.add_argument("--schedule", default="auto",
                    choices=("auto", "static", "counted"),
                    help="pass-2b bucket schedule (auto: cost-model-picked "
                         "per window; static: sync-free pass 1)")
    ap.add_argument("--prep", default="hint", choices=("hint", "count"),
                    help="pass-0 cap sizing (hint: metadata-only, "
                         "sync-free; count: per-case measured)")
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if out.exists():  # restart: skip already-extracted cases
        done = {json.loads(l)["case"] for l in out.read_text().splitlines()}
        print(f"resuming: {len(done)} cases already extracted")

    # synthetic KITS19-like workload, streamed lazily (never a full batch)
    names = []

    def cases():
        for name, img, msk, sp in stream_cases(args.cases, skip=done):
            names.append(name)
            yield img, msk, sp

    def window_stats(i, s):
        print(f"window {i}: {s['cases']} cases, "
              f"{s['shape_buckets']} shape buckets, "
              f"{s['cap_buckets']} vertex buckets, "
              f"pad waste mask {s['mask_pad_waste']:.0%} / "
              f"verts {s['vertex_pad_waste']:.0%}, "
              f"schedule={s['schedule']}")  # the cost model's per-window pick

    ext = BatchedExtractor(  # mesh=None: single device
        variant=args.variant, schedule=args.schedule, prep=args.prep
    )
    n_done = 0
    import time
    t0 = time.perf_counter()
    with out.open("a") as f:
        for feat in ext.extract_stream(cases(), window=args.window,
                                       stats_callback=window_stats):
            rec = {"case": names[n_done]}
            rec.update({k: float(v) for k, v in zip(FEATURE_NAMES, feat)})
            f.write(json.dumps(rec) + "\n")
            f.flush()  # checkpoint per row: preemption loses < one window
            n_done += 1
    dt = time.perf_counter() - t0
    if n_done == 0:
        print("nothing to do")
        return
    log = ext.executor.transfer_log
    print(f"extracted {n_done} cases in {dt:.1f}s "
          f"({n_done / dt:.2f} cases/s, schedule={args.schedule}, "
          f"prep={args.prep}, window={args.window}, "
          f"per-case host syncs: pass0={log.get('prep', 0)} "
          f"pass1={log.get('pass1', 0)})")
    print(f"manifest: {out}")


if __name__ == "__main__":
    main()
