"""Quickstart: the paper's 4-line usage, TPU-adapted.

PyRadiomics-cuda's promise is that acceleration is *transparent*:

    from radiomics import featureextractor
    ext = featureextractor.RadiomicsFeatureExtractor()
    res = ext.execute('scan.nii.gz', 'mask.nii.gz')
    print(res['MeshVolume'], res['SurfaceArea'])

Here the same four lines run against our JAX/Pallas backend.  The
dispatcher probes for a TPU, uses the Pallas kernels when found, and falls
back to the pure-jnp reference path otherwise -- identical features either
way (set REPRO_BACKEND=interpret to execute the TPU kernel bodies in
Python on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py [scan.nii mask.nii]
"""
import sys

from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case


def main():
    if len(sys.argv) == 3:  # real NIfTI inputs, as in the paper
        from repro.data.nifti import read_nifti

        image, _ = read_nifti(sys.argv[1])
        mask, spacing = read_nifti(sys.argv[2])
    else:  # synthetic KITS19-like case (offline container)
        image, mask, spacing = make_case((128, 96, 80), seed=7)

    ext = ShapeFeatureExtractor()  # backend='auto': TPU if present, else CPU
    res, times = ext.execute(image, mask, spacing, with_times=True)

    print(f"backend          : {ext.backend}")
    print(f"MeshVolume       : {res['MeshVolume']:.2f}")
    print(f"SurfaceArea      : {res['SurfaceArea']:.2f}")
    print(f"Maximum3DDiameter: {res['Maximum3DDiameter']:.2f}")
    print(f"Sphericity       : {res['Sphericity']:.4f}")
    print(f"mesh vertices    : {int(res['_n_mesh_vertices'])}")
    print(
        "stage times (ms) : "
        f"prep={times.preprocess_ms:.1f} transfer={times.transfer_ms:.1f} "
        f"mc={times.mesh_ms:.1f} diam={times.diameter_ms:.1f}"
    )


if __name__ == "__main__":
    main()
