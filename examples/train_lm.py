"""End-to-end training driver: ~100M-parameter LM for a few hundred steps.

This is the workload the paper's pipeline feeds (xLUNGS: radiomics features
-> AI model training).  It exercises the full production stack on any
device count: config system -> model zoo -> AdamW(+WSD) -> jitted train
step with explicit shardings -> fault-tolerant Trainer (async atomic
checkpoints, auto-resume, straggler log, SIGTERM emergency save).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch granite-3-2b --smoke

Kill it mid-run and start it again: it resumes from the latest committed
checkpoint.  ``--smoke`` shrinks the model for a fast CPU sanity pass.
"""
import argparse

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.models.registry import get_config, get_model
from repro.train.trainer import Trainer

# qwen3-family config scaled to ~100M params (d=512, L=8, untied embeddings)
M100 = dict(
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=32_000, dtype="float32",
)


def synthetic_batches(vocab_size: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream with learnable n-gram structure."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab_size, size=(64, seq + 1))
    while True:
        rows = rng.integers(0, base.shape[0], size=batch)
        noise = rng.integers(0, vocab_size, size=(batch, seq + 1))
        keep = rng.random((batch, seq + 1)) < 0.9
        tokens = np.where(keep, base[rows], noise)
        yield {"tokens": jax.numpy.asarray(tokens[:, : seq + 1], jax.numpy.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + 5 steps (CI-speed sanity check)")
    args = ap.parse_args()

    base = get_config(args.arch)
    if args.smoke:
        cfg = base.reduced()
        steps = 5
    else:
        cfg = base.reduced(**M100)
        steps = args.steps
    model = get_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params~{cfg.n_params/1e6:.1f}M "
          f"steps={steps} devices={jax.device_count()}")

    run = RunConfig(
        steps=steps, learning_rate=3e-4, warmup_steps=max(2, steps // 20),
        schedule="wsd", checkpoint_every=max(1, steps // 4),
        async_checkpoint=True,
    )
    data = synthetic_batches(cfg.vocab_size, args.batch, args.seq)
    trainer = Trainer(model, run, data, args.workdir)
    params, _, last = trainer.train(steps=steps)
    print(f"final: step={last['step']} loss={last['loss']:.4f} "
          f"median_step_s={trainer.straggler.median:.3f}")


if __name__ == "__main__":
    main()
