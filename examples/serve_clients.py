"""Radiomics-as-a-service: concurrent tenants sharing one device pipeline.

The cluster example (``cluster_pipeline.py``) is the BATCH story -- one
job, 40k cases, a manifest.  This example is the SERVICE story (ROADMAP
direction 3): several independent clients -- think a clinical viewer
asking for one study's features next to a research sweep chewing through
a cohort -- submit cases concurrently to one ``ExtractionService``, and
the driver fuses their cases into shared device windows:

  * the **viewer** tenant submits single cases with a deadline: if the
    queue cannot serve a case in time it gets a ``DeadlineExceeded``
    error row back immediately instead of silently waiting forever (and
    its expired request never occupies a window slot);
  * the **cohort** tenant submits batches with no deadline and simply
    rides along -- its cases pad out the viewer's windows, so device
    utilisation stays high without hurting viewer latency (the cost
    model closes a window early when the oldest pending deadline is at
    risk: ``CostModel.deadline_at_risk``);
  * admission control bounds the ESTIMATED bytes queued on the host
    (``--queue-mb``); when the cohort outruns the device its submits
    BLOCK -- backpressure, not OOM;
  * every row is bit-identical to what ``extract_stream`` would have
    produced for the same case (the serving parity contract,
    tier-1-locked in ``tests/test_service.py``).

    PYTHONPATH=src python examples/serve_clients.py
    PYTHONPATH=src python examples/serve_clients.py \\
        --viewer-cases 8 --cohort-cases 24 --deadline-ms 2000
"""
import argparse
import threading
import time

import numpy as np

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import mixed_traffic_stream, stream_cases


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="two tenants (deadline viewer + batch cohort) sharing "
                    "one extraction service")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--viewer-cases", type=int, default=6)
    ap.add_argument("--cohort-cases", type=int, default=12)
    ap.add_argument("--cohort-batch", type=int, default=4)
    ap.add_argument("--deadline-ms", type=float, default=5000.0)
    ap.add_argument("--queue-mb", type=float, default=64.0)
    args = ap.parse_args(argv)

    bx = BatchedExtractor(backend=args.backend, prep="hint",
                          schedule="static")
    viewer_cases = [(i, m, s) for _, i, m, s in
                    mixed_traffic_stream(args.viewer_cases, huge_every=0)]
    # clinic-sized cohort shapes: the full Table-2 pool has 300-voxel
    # giants that take minutes per case on a CPU ref backend
    cohort_cases = [(i, m, s) for _, i, m, s in
                    stream_cases(args.cohort_cases, seed=7,
                                 dims_pool=[(40, 44, 36), (48, 48, 48),
                                            (36, 52, 40), (44, 40, 48)])]

    def viewer(svc, out):
        for i, case in enumerate(viewer_cases):
            t0 = time.perf_counter()
            res = svc.submit_case(case, tenant="viewer",
                                  deadline_s=args.deadline_ms / 1e3
                                  ).result(timeout=600)
            dt = (time.perf_counter() - t0) * 1e3
            verdict = ("EXPIRED" if res.errors
                       else f"MeshVolume={float(res.rows[0][0]):.1f}")
            print(f"[viewer] case {i}: {dt:7.1f} ms  {verdict}")
            out.append(res)

    def cohort(svc, out):
        for lo in range(0, len(cohort_cases), args.cohort_batch):
            res = svc.submit(cohort_cases[lo:lo + args.cohort_batch],
                             tenant="cohort").result(timeout=600)
            print(f"[cohort] batch {lo // args.cohort_batch}: "
                  f"{len(res.rows)} rows, errors={len(res.errors)}")
            out.append(res)

    v_out, c_out = [], []
    with bx.serve(max_queue_bytes=args.queue_mb * 2**20) as svc:
        threads = [threading.Thread(target=viewer, args=(svc, v_out)),
                   threading.Thread(target=cohort, args=(svc, c_out))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = svc.stats()

    # parity spot-check: the cohort's served rows == the batch pipeline's
    ref, _ = bx.run(cohort_cases)
    got = [np.asarray(r) for res in c_out for r in res.rows]
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), b)

    cross = sum(1 for t in stats["window_tenants"] if t > 1)
    print(f"\n[serve] {stats['served_cases']} cases in {wall:.2f}s "
          f"({stats['served_cases'] / wall:.1f} cases/s), "
          f"{stats['windows']} windows ({cross} cross-tenant), "
          f"{stats['expired_cases']} expired, parity OK")


if __name__ == "__main__":
    main()
