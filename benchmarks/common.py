"""Shared helpers for the benchmark harness.

Every benchmark emits ``name,us_per_call,derived`` CSV rows (run.py collects
them).  ``derived`` is a ';'-separated key=value list specific to each
benchmark (speedups, fractions, projections).
"""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, repeat: int = 3, warmup: int = 1, **kw):
    """Median wall-clock seconds of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, us: float, **derived) -> str:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{us:.1f},{d}"


# TPU v5e roofline constants (the TARGET device; this container is CPU-only).
V5E = {
    "peak_flops_bf16": 197e12,  # FLOP/s (MXU)
    "peak_flops_f32": 49e12,    # MXU f32 ~ 1/4 bf16
    "vpu_flops": 7e12,          # elementwise f32 ops/s (vector unit)
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s/link
    "pcie_bw": 32e9,            # host->device B/s (transfer-stage projection)
}


def diameter_projection(M: int, block: int, variant: str) -> float:
    """Roofline seconds for one diameter-kernel configuration on a v5e.

    Unlike the generic :func:`tpu_projection`, this accounts for variants
    that split work across units: the 'gram' variant's pair sweep runs on
    the MXU while only combo-assembly stays on the VPU, so the bound is
    max(VPU term, MXU term, HBM term).
    """
    from repro.kernels import diameter as dk

    fl = dk.flop_estimate(M, block, variant)
    by = dk.bytes_estimate(M, block, variant)
    mx = dk.mxu_flop_estimate(M, block, variant)
    return max(
        fl / V5E["vpu_flops"], mx / V5E["peak_flops_f32"], by / V5E["hbm_bw"]
    )


def tpu_projection(flops: float, bytes_hbm: float, unit: str = "vpu") -> float:
    """Roofline lower-bound seconds on one v5e chip.

    ``unit``: 'mxu_f32' / 'mxu_bf16' for matmul-dominated kernels (the MC
    one-hot table gather), 'vpu' for elementwise-dominated ones (the
    pairwise diameter sweep) -- using MXU peak for elementwise work would
    overstate speedups ~25x.
    """
    peak = {"mxu_f32": V5E["peak_flops_f32"],
            "mxu_bf16": V5E["peak_flops_bf16"],
            "vpu": V5E["vpu_flops"]}[unit]
    return max(flops / peak, bytes_hbm / V5E["hbm_bw"])
