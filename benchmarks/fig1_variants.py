"""Paper Fig. 1 analogue: diameter-kernel optimization-variant comparison.

The paper compares 5 CUDA strategies (equal-load baseline, block-based
atomic reduction, 2D shared memory, local thread accumulators, 1D arrays)
across three GPUs.  Our TPU analogues (see kernels/diameter.py):

    naive        -- one pass per feature combo (4 launches)
    fused        -- all 4 combos, one pass              [mem-access opt]
    tri          -- fused + predicated lower-tri skip   [load balance]
    seqacc       -- fused + sequential in-kernel accumulator
                    (the paper's 'local thread accumulators')
    tri_prefetch -- 1-D grid over upper-tri block pairs via scalar
                    prefetch (skipped blocks cost no DMA)

For each variant we report: structural FLOPs + HBM bytes (the dry-run
profile), the v5e roofline projection, and measured interpret-mode wall
time on a reduced size (execution-semantics check; absolute CPU times are
not TPU times).  Correctness vs the jnp oracle is asserted.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit, tpu_projection
from repro.kernels import diameter as dk
from repro.kernels import ref as ref_k


def _cloud(m: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    verts = jnp.asarray(rng.normal(size=(m, 3)) * 50.0, jnp.float32)
    mask = jnp.ones((m,), jnp.float32)
    return verts, mask


def run(m_interp: int = 2048, m_project: int = 262_144, block: int = 256):
    verts, mask = _cloud(m_interp)
    want = np.asarray(ref_k.max_diameters(verts, mask))
    rows = []
    for variant in dk.VARIANTS:
        got = np.asarray(
            dk.max_diameters_pallas(
                verts, mask, block=block, variant=variant, interpret=True
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
        t = timeit(
            dk.max_diameters_pallas, verts, mask,
            block=block, variant=variant, interpret=True, repeat=2,
        )
        fl = dk.flop_estimate(m_project, block, variant)
        by = dk.bytes_estimate(m_project, block, variant)
        proj = tpu_projection(fl, by)
        rows.append(
            row(
                f"fig1/{variant}",
                t * 1e6,
                M_project=m_project,
                flops=f"{fl:.3e}",
                hbm_bytes=f"{by:.3e}",
                v5e_proj_ms=f"{proj * 1e3:.2f}",
                correct="yes",
            )
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048)
    args = ap.parse_args(argv)
    for r in run(m_interp=args.m):
        print(r)


if __name__ == "__main__":
    main()
