"""Paper Fig. 1 analogue: diameter-kernel optimization-variant comparison.

The paper compares 5 CUDA strategies (equal-load baseline, block-based
atomic reduction, 2D shared memory, local thread accumulators, 1D arrays)
across three GPUs.  Our TPU analogues (see kernels/diameter.py):

    naive        -- one pass per feature combo (4 launches)
    fused        -- all 4 combos, one pass              [mem-access opt]
    tri          -- fused + predicated lower-tri skip   [load balance]
    seqacc       -- fused + sequential in-kernel accumulator
                    (the paper's 'local thread accumulators')
    tri_prefetch -- 1-D grid over upper-tri block pairs via scalar
                    prefetch (skipped blocks cost no DMA)
    nomask       -- tri_prefetch minus the mask streams
    gram         -- tri_prefetch schedule, pair sweep on the MXU via the
                    augmented Gram identity (per-axis (B,3)x(3,B) products)
    pruned+*     -- exact candidate pruning (kernels/prune.py) shrinks
                    M -> M' first; guaranteed-identical maxima

For each variant we report: measured interpret-mode wall time on a reduced
size (execution-semantics check; absolute CPU times are not TPU times),
structural VPU/MXU FLOPs + HBM bytes at the measured size, and the v5e
roofline projection at the paper-scale vertex count.  Correctness vs the
jnp oracle is asserted (the Gram path at its documented 1e-3 relative
bound, everything else at 1e-5).

``run(records=...)`` appends one dict per row -- ``benchmarks.run --json``
serialises them as the ``BENCH_diameter.json`` perf-trajectory record.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import diameter_projection, row, timeit
from repro.kernels import diameter as dk
from repro.kernels import ops
from repro.kernels import ref as ref_k


def _cloud(m: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    verts = jnp.asarray(rng.normal(size=(m, 3)) * 50.0, jnp.float32)
    mask = jnp.ones((m,), jnp.float32)
    return verts, mask


def _emit(rows, records, name, variant, t_s, m, m_prime, m_project,
          block, want, got):
    rtol = 1e-3 if variant == "gram" else 1e-5
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-4)
    m_eff = ops.vertex_bucket(m_prime) if m_prime < m else m
    fl = dk.flop_estimate(m_eff, block, variant)
    by = dk.bytes_estimate(m_eff, block, variant)
    mx = dk.mxu_flop_estimate(m_eff, block, variant)
    proj_m = int(m_project * (m_prime / m)) if m_prime < m else m_project
    proj_m = max(proj_m, block)
    proj = diameter_projection(proj_m, block, variant)
    rows.append(
        row(
            f"fig1/{name}",
            t_s * 1e6,
            M=m,
            M_prime=m_prime,
            M_project=proj_m,
            flops=f"{fl:.3e}",
            mxu_flops=f"{mx:.3e}",
            hbm_bytes=f"{by:.3e}",
            v5e_proj_ms=f"{proj * 1e3:.2f}",
            correct="yes",
        )
    )
    if records is not None:
        records.append(
            {
                "name": name,
                "variant": variant,
                "us_per_call": t_s * 1e6,
                "M": int(m),
                "M_prime": int(m_prime),
                "est_flops": fl,
                "est_mxu_flops": mx,
                "est_bytes": by,
                "v5e_proj_ms": proj * 1e3,
            }
        )


def run(m_interp: int = 2048, m_project: int = 262_144, block: int = 256,
        records=None):
    verts, mask = _cloud(m_interp)
    want = np.asarray(ref_k.max_diameters(verts, mask))
    rows = []
    for variant in dk.VARIANTS:
        got = np.asarray(
            dk.max_diameters_pallas(
                verts, mask, block=block, variant=variant, interpret=True
            )
        )
        t = timeit(
            dk.max_diameters_pallas, verts, mask,
            block=block, variant=variant, interpret=True, repeat=2,
        )
        _emit(rows, records, variant, variant, t, m_interp, m_interp,
              m_project, block, want, got)

    # exact candidate pruning + the two best schedules: identical maxima,
    # (M/M')^2 less pair work
    v2, m2, info = ops.prune_candidates(np.asarray(verts), np.asarray(mask))
    t_prune = timeit(  # variant-independent: measure once
        lambda: ops.prune_candidates(np.asarray(verts), np.asarray(mask)),
        repeat=2,
    )
    for variant in ("seqacc", "gram"):
        got = np.asarray(
            dk.max_diameters_pallas(
                v2, m2, block=block, variant=variant, interpret=True
            )
        )
        t_kernel = timeit(
            dk.max_diameters_pallas, v2, m2,
            block=block, variant=variant, interpret=True, repeat=2,
        )
        _emit(rows, records, f"pruned+{variant}", variant,
              t_prune + t_kernel, m_interp, info.m_kept, m_project, block,
              want, got)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2048)
    args = ap.parse_args(argv)
    for r in run(m_interp=args.m):
        print(r)


if __name__ == "__main__":
    main()
