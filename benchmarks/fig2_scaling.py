"""Paper Fig. 2 analogue: processing time vs case size across 'hardware'.

The paper plots KITS19 feature-extraction time (log-log) on three CPUs and
three GPUs, showing 8x-2000x GPU speedups growing with vertex count.  In
this CPU-only container the measurable series is the reference CPU path;
the TPU series are roofline projections of the Pallas kernels at v5e specs
(compute term vs HBM term, whichever binds).

Emits one row per (size, series): measured CPU ms + projected v5e ms +
the projected speedup (the paper's Fig. 2 RIGHT).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import diameter_projection, row, timeit, tpu_projection
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case
from repro.kernels import diameter as dk
from repro.kernels import marching_cubes as mck
from repro.kernels import ops

# (label, image dims) spanning the paper's size range (small -> large)
SIZES = [
    ("tiny", (40, 36, 12)),
    ("small", (52, 52, 64)),
    ("medium", (128, 96, 80)),
    ("large", (232, 104, 176)),
]


def run(repeat: int = 1, block: int = 256, variant: str = "seqacc"):
    # unpruned/seqacc measured baseline (the paper's CPU series) ...
    ext = ShapeFeatureExtractor(backend="ref", prune=False,
                                diameter_variant="seqacc")
    # ... plus a measured run of the pruned path (identical outputs) so the
    # M -> M' win shows up as wall-clock, not just projection
    ext_pruned = ShapeFeatureExtractor(backend="ref", prune=True,
                                       diameter_variant="seqacc")
    rows = []
    for label, dims in SIZES:
        img, msk, sp = make_case(dims, seed=17)
        feats, times = ext.execute(img, msk, sp, with_times=True)
        _, times_p = ext_pruned.execute(img, msk, sp, with_times=True)
        pinfo = ext_pruned.last_prune_info
        m_prime = pinfo.m_kept if pinfo is not None else 0
        n_verts = int(feats["_n_mesh_vertices"])
        cap = ops.vertex_bucket(n_verts)
        cpu_ms = times.mesh_ms + times.diameter_ms
        cpu_pruned_ms = times_p.mesh_ms + times_p.diameter_ms

        mc_t = tpu_projection(
            mck.flop_estimate(dims), 4.0 * float(np.prod(dims)) * 1.35
        )
        d_t = diameter_projection(cap, block, variant)
        d_t_pg = diameter_projection(
            ops.vertex_bucket(max(m_prime, 1)), block, "gram"
        )
        tpu_ms = (mc_t + d_t) * 1e3
        tpu_pg_ms = (mc_t + d_t_pg) * 1e3
        rows.append(
            row(
                f"fig2/{label}",
                times.total_ms * 1e3,
                dims="x".join(map(str, dims)),
                vertices=n_verts,
                m_prime=m_prime,
                cpu_compute_ms=f"{cpu_ms:.1f}",
                cpu_pruned_ms=f"{cpu_pruned_ms:.1f}",
                v5e_proj_ms=f"{tpu_ms:.3f}",
                v5e_pruned_gram_ms=f"{tpu_pg_ms:.3f}",
                proj_speedup=f"{cpu_ms / max(tpu_ms, 1e-9):.0f}",
                proj_speedup_pruned=f"{cpu_ms / max(tpu_pg_ms, 1e-9):.0f}",
            )
        )
    return rows


def main(argv=None):
    argparse.ArgumentParser().parse_args(argv)
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
