"""Paper Fig. 2 analogue: processing time vs case size across 'hardware'.

The paper plots KITS19 feature-extraction time (log-log) on three CPUs and
three GPUs, showing 8x-2000x GPU speedups growing with vertex count.  In
this CPU-only container the measurable series is the reference CPU path;
the TPU series are roofline projections of the Pallas kernels at v5e specs
(compute term vs HBM term, whichever binds).

Emits one row per (size, series): measured CPU ms + projected v5e ms +
the projected speedup (the paper's Fig. 2 RIGHT).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import row, timeit, tpu_projection
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case
from repro.kernels import diameter as dk
from repro.kernels import marching_cubes as mck
from repro.kernels import ops

# (label, image dims) spanning the paper's size range (small -> large)
SIZES = [
    ("tiny", (40, 36, 12)),
    ("small", (52, 52, 64)),
    ("medium", (128, 96, 80)),
    ("large", (232, 104, 176)),
]


def run(repeat: int = 1, block: int = 256, variant: str = "seqacc"):
    ext = ShapeFeatureExtractor(backend="ref")
    rows = []
    for label, dims in SIZES:
        img, msk, sp = make_case(dims, seed=17)
        feats, times = ext.execute(img, msk, sp, with_times=True)
        n_verts = int(feats["_n_mesh_vertices"])
        cap = ops.vertex_bucket(n_verts)
        cpu_ms = times.mesh_ms + times.diameter_ms

        mc_t = tpu_projection(
            mck.flop_estimate(dims), 4.0 * float(np.prod(dims)) * 1.35
        )
        d_t = tpu_projection(
            dk.flop_estimate(cap, block, variant),
            dk.bytes_estimate(cap, block, variant),
        )
        tpu_ms = (mc_t + d_t) * 1e3
        rows.append(
            row(
                f"fig2/{label}",
                times.total_ms * 1e3,
                dims="x".join(map(str, dims)),
                vertices=n_verts,
                cpu_compute_ms=f"{cpu_ms:.1f}",
                v5e_proj_ms=f"{tpu_ms:.3f}",
                proj_speedup=f"{cpu_ms / max(tpu_ms, 1e-9):.0f}",
            )
        )
    return rows


def main(argv=None):
    argparse.ArgumentParser().parse_args(argv)
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
