"""Paper Table 2 analogue: per-case stage breakdown of shape extraction.

For each synthetic KITS19-dimensioned case (same image dims as the paper's
Table 2) we measure wall-clock per stage on the reference CPU path (the
'PyRadiomics on CPU' role in this CPU-only container) and report:

  * preprocess / transfer / marching-cubes / diameter milliseconds,
  * the diameter share of compute time (paper: 95.7%..99.9%),
  * a TPU-v5e roofline projection of the accelerated stages (the
    'PyRadiomics-cuda time' column we cannot wall-clock without hardware)
    and the implied computation speedup (paper: 3.9x..18.2x on RTX4070).

Cases above ``max_vertices`` are skipped by default (O(M^2) on a container
CPU); pass --full to run all 20.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import V5E, diameter_projection, row, tpu_projection
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import table2_suite
from repro.kernels import diameter as diam_k
from repro.kernels import marching_cubes as mc_k
from repro.kernels import ops


def project_tpu_ms(mask_shape, n_verts, diam_block=256, variant="seqacc"):
    """Roofline projection (ms) of the two accelerated stages on one v5e."""
    mc_fl = mc_k.flop_estimate(mask_shape)
    mc_by = 4.0 * float(np.prod(mask_shape)) * 1.35  # brick halo overhead
    t_mc = tpu_projection(mc_fl, mc_by, unit="mxu_f32")  # one-hot matmuls
    cap = ops.vertex_bucket(n_verts)
    t_d = diameter_projection(cap, diam_block, variant)
    return t_mc * 1e3, t_d * 1e3


def run(full: bool = False, max_vertices: int = 25_000, repeat: int = 1):
    # the measured CPU column stays unpruned/seqacc so the breakdown mirrors
    # the paper's Table 2; pruning and the gram kernel enter as the extra
    # projected columns (m_prime, tpu_pruned_gram_ms, speedup_pruned)
    ext = ShapeFeatureExtractor(backend="ref", prune=False,
                                diameter_variant="seqacc")
    rows = []
    for name, img, msk, sp in table2_suite():
        # cheap vertex count FIRST (one elementwise pass) so the O(M^2)
        # monsters are skipped before any diameter work
        from repro.core.shape_features import crop_to_roi

        _, m_roi, _ = crop_to_roi(img, msk)
        fields = ops.vertex_fields(m_roi, 0.5, sp)
        n_est = int(ops.count_vertices(fields))
        if not full and n_est > max_vertices:
            continue
        feats, times = ext.execute(img, msk, sp, with_times=True)
        n_verts = int(feats["_n_mesh_vertices"])
        comp_ms = times.mesh_ms + times.diameter_ms
        diam_frac = times.diameter_ms / comp_ms if comp_ms > 0 else 0.0
        mc_tpu_ms, d_tpu_ms = project_tpu_ms(msk.shape, n_verts)
        transfer_tpu_ms = 4.0 * msk.size / V5E["pcie_bw"] * 1e3
        tpu_total = mc_tpu_ms + d_tpu_ms + transfer_tpu_ms
        comp_speedup = comp_ms / max(1e-9, mc_tpu_ms + d_tpu_ms)
        # exact pruning + gram: the measured-identical fast path
        verts, vmask, _ = ops.compact_vertices(fields, ops.vertex_bucket(n_verts))
        _, _, pinfo = ops.prune_candidates(np.asarray(verts), np.asarray(vmask))
        d_prune_ms = diameter_projection(
            ops.vertex_bucket(pinfo.m_kept), 256, "gram") * 1e3
        speedup_pruned = comp_ms / max(1e-9, mc_tpu_ms + d_prune_ms)
        rows.append(
            row(
                f"table2/{name}",
                times.total_ms * 1e3,  # us
                vertices=n_verts,
                prep_ms=f"{times.preprocess_ms:.1f}",
                mc_ms=f"{times.mesh_ms:.1f}",
                diam_ms=f"{times.diameter_ms:.1f}",
                diam_frac=f"{diam_frac:.4f}",
                tpu_proj_ms=f"{tpu_total:.3f}",
                comp_speedup_proj=f"{comp_speedup:.1f}",
                m_prime=pinfo.m_kept,
                tpu_pruned_gram_ms=f"{mc_tpu_ms + d_prune_ms:.3f}",
                speedup_pruned=f"{speedup_pruned:.1f}",
                mesh_volume=f"{feats['MeshVolume']:.1f}",
            )
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    for r in run(full=args.full):
        print(r)


if __name__ == "__main__":
    main()
