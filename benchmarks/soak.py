"""Resilience soak: faulted, preempted, resumed extraction at scale.

The paper's cluster workload (~40 000 CT scans, xLUNGS) runs for hours on
shared nodes; the question this soak answers is not "how fast" but "does
a faulted, preempted, resumed run produce EXACTLY the same manifest as an
uninterrupted one".  Three phases over the same synthetic case stream,
with the same deterministic :class:`FaultPlan` (injected load errors,
NaN-poisoned and emptied masks, a transient collect fault exercising the
retry path, one artificial straggler window):

  A. uninterrupted reference run -> manifest A;
  B. the same run with a REAL SIGTERM landing mid-stream
     (``preempt_at_case``) -> partial manifest B;
  C. resume into manifest B with a fresh extractor -> completed B.

Hard assertions (the soak FAILS the bench run if any break):

  * zero lost and zero duplicated case ids (exactly one record per case);
  * the resumed manifest's record set is bit-identical to manifest A's
    (windows ordinals aside -- they restart on resume);
  * at most ONE window of extraction work was redone
    (``windows_B + windows_C <= windows_A + 1``);
  * the injected transient collect fault was absorbed by the retry path;
  * the sync-free submit invariants survived all of it (zero prep /
    pass-1 fetches under ``static`` + ``hint``).

``run(records=...)`` appends a ``soak_resilience`` row (throughput of the
faulted uninterrupted run) to the ``BENCH_pipeline.json`` trajectory;
``python -m benchmarks.soak --n 10000`` is the standalone large soak.

    SOAK_CASES=200 python -m benchmarks.run --only pipeline soak \\
        --json-pipeline BENCH_pipeline.json
"""
from __future__ import annotations

import argparse
import functools
import os
import tempfile
from pathlib import Path

from benchmarks.common import row
from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.runtime.resilience import (
    FaultPlan,
    ResilientRunner,
    RetryPolicy,
    RunManifest,
)

# small-to-medium KITS19-like dims: a few shape buckets, fast per case
DIMS = ((20, 18, 16), (24, 20, 18), (22, 26, 14), (18, 16, 20))

# the fault cocktail, identical (seeded) across all three phases
FAULTS = dict(
    seed=20260808,
    load_error_rate=0.02,      # dead loaders -> quarantined by name
    poison_nan_rate=0.02,      # poisoned masks -> row-level error records
    poison_empty_rate=0.01,    # emptied masks -> the all-zero-row contract
    fail_windows=(1,),         # one guaranteed transient collect fault
    window_fault_rate=0.02,    # plus a seeded sprinkle of extra ones
    straggle_windows=(3,),     # one artificial straggler for the census
    straggle_seconds=0.25,
)


def _stream(n: int):
    """Lazy (name, loader) case stream: nothing materialises up front."""
    for i in range(n):
        yield (
            f"soak-{i:06d}",
            functools.partial(make_case, DIMS[i % len(DIMS)], seed=1000 + i),
        )


def _runner(manifest: RunManifest, fp: FaultPlan, window: int,
            drain_on_preempt: bool = True):
    ext = BatchedExtractor(
        backend="ref", schedule="static", prep="hint",
        transfer_callback=fp.transfer_hook,
        retry=RetryPolicy(max_retries=3, base_delay=0.01),
    )
    return ext, ResilientRunner(
        ext, manifest, window=window, fault_plan=fp,
        drain_on_preempt=drain_on_preempt,
    )


def _strip(rows):
    # window ordinals restart on resume; everything else must match exactly
    return sorted(
        [{k: v for k, v in r.items() if k != "window"} for r in rows],
        key=lambda r: r["id"],
    )


def run(n: int | None = None, window: int = 16, records=None, out=None):
    if n is None:
        n = int(os.environ.get("SOAK_CASES", "200"))
    if n < 3 * window:
        raise ValueError(f"soak needs n >= 3*window, got n={n} window={window}")
    tmp = None
    if out is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro_soak_")
        out = tmp.name
    out = Path(out)
    try:
        # A: uninterrupted faulted reference
        man_a = RunManifest(out / "soak_a.jsonl")
        ext_a, run_a = _runner(man_a, FaultPlan(**FAULTS), window)
        rep_a = run_a.run(_stream(n))
        man_a.close()
        assert rep_a.status == "complete" and rep_a.processed == n
        assert rep_a.quarantined > 0, "fault rates injected nothing"
        assert rep_a.window_retries >= 1, "transient fault never exercised retry"

        # B: same faults + a REAL SIGTERM mid-stream (grace-period drain)
        man_b = RunManifest(out / "soak_b.jsonl")
        _, run_b = _runner(
            man_b, FaultPlan(**FAULTS, preempt_at_case=max(window + 1, n // 2)),
            window,
        )
        rep_b = run_b.run(_stream(n))
        man_b.close()
        assert rep_b.status == "preempted"
        assert 0 < rep_b.processed < n

        # C: resume into the same manifest with a fresh extractor
        man_c = RunManifest(out / "soak_b.jsonl")
        ext_c, run_c = _runner(man_c, FaultPlan(**FAULTS), window)
        rep_c = run_c.run(_stream(n))
        assert rep_c.status == "complete"

        # zero lost, zero duplicated ids; exactly one record per case
        ids = [r["id"] for r in man_c.rows()]
        assert len(ids) == n == len(set(ids)), \
            f"lost/duplicated ids: {len(ids)} rows, {len(set(ids))} unique"
        assert rep_b.processed + rep_c.processed == n

        # at most ONE window of extraction work redone after the kill
        redone = rep_b.windows + rep_c.windows - rep_a.windows
        assert redone <= 1, f"{redone} extra windows redone after preemption"

        # the resumed manifest is bit-identical to the uninterrupted one
        assert _strip(man_c.rows()) == _strip(man_a.rows()), \
            "resumed manifest diverged from the uninterrupted run"

        # the sync-free submit invariants survived the whole cocktail
        for ext in (ext_a, ext_c):
            assert ext.executor.transfer_log["prep"] == 0
            assert ext.executor.transfer_log["pass1"] == 0
    finally:
        if tmp is not None:
            tmp.cleanup()

    derived = dict(
        cases=n,
        cases_per_s=f"{rep_a.cases_per_second:.2f}",
        quarantined=rep_a.quarantined,
        window_retries=rep_a.window_retries + rep_b.window_retries
        + rep_c.window_retries,
        stragglers=len(rep_a.stragglers),
        redone_windows=max(0, redone),
        resumed_rows=rep_c.processed,
    )
    rows = [row("soak/resilience", rep_a.seconds / n * 1e6, **derived)]
    if records is not None:
        records.append({
            "name": "soak_resilience",
            "cases": n,
            "seconds": rep_a.seconds,
            "cases_per_second": rep_a.cases_per_second,
            "quarantined": rep_a.quarantined,
            "window_retries": derived["window_retries"],
            "redone_windows": derived["redone_windows"],
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000,
                    help="cases to soak (CI uses SOAK_CASES=200 via "
                         "benchmarks.run)")
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--out", default=None,
                    help="keep the soak manifests here (default: tempdir)")
    args = ap.parse_args(argv)
    for r in run(n=args.n, window=args.window, out=args.out):
        print(r)


if __name__ == "__main__":
    main()
