"""Benchmark harness entry point: ``python -m benchmarks.run``.

One benchmark per paper table/figure (+ the roofline report):

    table2   -- per-case stage breakdown            (paper Table 2)
    fig1     -- diameter kernel variant comparison  (paper Fig. 1)
    fig2     -- size scaling + projected speedup    (paper Fig. 2)
    pipeline -- batched multi-case throughput       (paper §3 workflow)
    roofline -- dry-run roofline table              (EXPERIMENTS §Roofline)

Prints ``name,us_per_call,derived`` CSV.  Select suites with --only.
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("table2", "fig1", "fig2", "pipeline", "roofline")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=SUITES, default=list(SUITES))
    ap.add_argument("--full", action="store_true",
                    help="table2: run all 20 cases incl. the O(M^2) giants")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for suite in args.only:
        t0 = time.time()
        try:
            if suite == "table2":
                from benchmarks import table2_breakdown
                rows = table2_breakdown.run(full=args.full)
            elif suite == "fig1":
                from benchmarks import fig1_variants
                rows = fig1_variants.run()
            elif suite == "fig2":
                from benchmarks import fig2_scaling
                rows = fig2_scaling.run()
            elif suite == "pipeline":
                from benchmarks import pipeline_throughput
                rows = pipeline_throughput.run()
            else:
                from benchmarks import roofline_report
                rows = roofline_report.run()
        except Exception as e:  # pragma: no cover
            print(f"{suite}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            print(r)
        print(f"# {suite} done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
