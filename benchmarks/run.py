"""Benchmark harness entry point: ``python -m benchmarks.run``.

One benchmark per paper table/figure (+ the roofline report):

    table2   -- per-case stage breakdown            (paper Table 2)
    fig1     -- diameter kernel variant comparison  (paper Fig. 1)
    fig2     -- size scaling + projected speedup    (paper Fig. 2)
    pipeline -- batched multi-case throughput       (paper §3 workflow)
    soak     -- faulted/preempted/resumed soak      (resilience gate)
    serve    -- service mixed-traffic p50/p99       (serving-tier gate)
    roofline -- per-kernel roofline efficiency      (CI efficiency gate)

Prints ``name,us_per_call,derived`` CSV.  Select suites with --only.
``--json PATH`` additionally writes a ``BENCH_diameter.json`` trajectory
record (per-variant us_per_call, M, M', structural FLOP/byte estimates)
from the fig1 suite, and ``--json-pipeline PATH`` a ``BENCH_pipeline.json``
record (cases/sec for the single loop, the unpruned batched baseline, the
host-compaction two-pass pipeline, and the default device-compaction
two-pass pipeline) from the pipeline suite, so successive PRs can track
both perf curves.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

SUITES = ("table2", "fig1", "fig2", "pipeline", "soak", "serve", "roofline")


def _write_record(path: str, bench: str, suite: str, rows: list, ok: bool):
    if ok:
        record = {
            "bench": bench,
            "suite": suite,
            "written_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "rows": rows,
        }
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)
    else:  # keep any previous record rather than clobber it
        print(f"# {suite} failed; NOT overwriting {path}", file=sys.stderr)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", metavar="SUITE",
                    default=list(SUITES),
                    help=f"suites to run (any of: {', '.join(SUITES)})")
    ap.add_argument("--full", action="store_true",
                    help="table2: run all 20 cases incl. the O(M^2) giants")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the diameter perf-trajectory record here")
    ap.add_argument("--json-pipeline", metavar="PATH", default=None,
                    help="write the batched-throughput trajectory record here")
    args = ap.parse_args(argv)
    # validate by hand: a bare ``--only`` (empty list) used to silently
    # run NOTHING and exit 0, and an unknown name must die loudly
    if not args.only:
        ap.error(f"--only needs at least one suite name; valid suites: "
                 f"{', '.join(SUITES)}")
    unknown = [s for s in args.only if s not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {', '.join(unknown)}; valid suites: "
                 f"{', '.join(SUITES)}")
    if args.json is not None and "fig1" not in args.only:
        ap.error("--json records the fig1 suite; add fig1 to --only")
    if args.json_pipeline is not None and "pipeline" not in args.only:
        ap.error("--json-pipeline records the pipeline suite; add pipeline "
                 "to --only")
    for path in (args.json, args.json_pipeline):
        if path is not None:
            # fail on an unwritable path BEFORE benching -- append mode so
            # an existing trajectory record is not clobbered until the new
            # one is ready
            open(path, "a").close()

    print("name,us_per_call,derived")
    failures = 0
    diameter_records: list[dict] = []
    pipeline_records: list[dict] = []
    fig1_ok = pipeline_ok = False
    for suite in args.only:
        t0 = time.time()
        try:
            if suite == "table2":
                from benchmarks import table2_breakdown
                rows = table2_breakdown.run(full=args.full)
            elif suite == "fig1":
                from benchmarks import fig1_variants
                rows = fig1_variants.run(records=diameter_records)
                fig1_ok = True
            elif suite == "fig2":
                from benchmarks import fig2_scaling
                rows = fig2_scaling.run()
            elif suite == "pipeline":
                from benchmarks import pipeline_throughput
                rows = pipeline_throughput.run(records=pipeline_records)
                pipeline_ok = True
            elif suite == "soak":
                # the resilience soak rides the pipeline trajectory record
                # (its soak_resilience row is cases/sec like the others)
                from benchmarks import soak
                rows = soak.run(records=pipeline_records)
            elif suite == "serve":
                # serving-tier mixed-traffic rows ride the same record:
                # throughput is cases/sec, and the p50/p99 latency rows
                # encode 1/latency as cases_per_second so the gate's
                # higher-is-better rule catches latency regressions too
                from benchmarks import serve_latency
                rows = serve_latency.run(records=pipeline_records)
            else:
                # per-kernel roofline-efficiency rows ride the pipeline
                # record too: each row's cases_per_second carries the
                # achieved fraction of the kernel's roofline bound (a
                # same-host ratio), so the committed trajectory gates
                # silent efficiency regressions under the same >30% rule
                from benchmarks import roofline_report
                rows = roofline_report.run(records=pipeline_records)
        except Exception as e:  # pragma: no cover
            print(f"{suite}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            print(r)
        print(f"# {suite} done in {time.time() - t0:.1f}s", file=sys.stderr)

    if args.json is not None:
        _write_record(args.json, "diameter", "fig1", diameter_records, fig1_ok)
    if args.json_pipeline is not None:
        _write_record(args.json_pipeline, "pipeline", "pipeline",
                      pipeline_records, pipeline_ok)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
