"""Paper §3 'workflow' analogue: batched multi-case pipeline throughput.

The paper's motivating workload is ~40 000 CT scans on a cluster (xLUNGS);
its discussion notes that for complete workflows data loading dominates
small cases and DMA/compute overlap is the open opportunity.  This
benchmark runs the BatchedExtractor (bucketed compile cache, double-
buffered host->device feeding, optional data-axis sharding) over a batch
of synthetic cases and reports cases/second, plus the single-case loop for
comparison -- the throughput story GPU/TPU acceleration exists to serve.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import row
from repro.core.pipeline import BatchedExtractor
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case


def _cases(n: int, dims=(48, 48, 48)):
    return [make_case(dims, seed=100 + i) for i in range(n)]


def run(n_cases: int = 12):
    cases = _cases(n_cases)
    rows = []

    ext = ShapeFeatureExtractor(backend="ref")
    t0 = time.perf_counter()
    for img, msk, sp in cases:
        ext.execute(img, msk, sp)
    t_loop = time.perf_counter() - t0

    bx = BatchedExtractor(backend="ref")
    results, stats = bx.run(cases)
    assert all(r is not None for r in results)

    rows.append(
        row(
            "pipeline/single_case_loop",
            t_loop / n_cases * 1e6,
            cases=n_cases,
            cases_per_s=f"{n_cases / t_loop:.2f}",
        )
    )
    rows.append(
        row(
            "pipeline/batched",
            stats["seconds"] / n_cases * 1e6,
            cases=n_cases,
            cases_per_s=f"{stats['cases_per_second']:.2f}",
            buckets=stats["buckets"],
            speedup_vs_loop=f"{t_loop / stats['seconds']:.2f}",
        )
    )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12)
    args = ap.parse_args(argv)
    for r in run(args.n):
        print(r)


if __name__ == "__main__":
    main()
