"""Paper §3 'workflow' analogue: batched multi-case pipeline throughput.

The paper's motivating workload is ~40 000 CT scans on a cluster (xLUNGS);
its discussion notes that for complete workflows data loading dominates
small cases and DMA/compute overlap is the open opportunity.  This
benchmark runs the BatchedExtractor over a batch of synthetic cases in
three modes -- the single-case loop, the legacy one-pass batched pipeline
(no pruning: the unpruned baseline), and the two-pass pruned pipeline
(pass 1: vmapped exact pruning bound; pass 2: re-bucketed by M') -- and
reports cases/second for each, the throughput story GPU/TPU acceleration
exists to serve.

``run(records=...)`` appends one dict per mode; ``benchmarks.run
--json-pipeline`` serialises them as the ``BENCH_pipeline.json``
perf-trajectory record (pruned vs unpruned cases/sec across PRs).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import row
from repro.core.pipeline import BatchedExtractor
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case


def _cases(n: int, dims=(48, 48, 48)):
    return [make_case(dims, seed=100 + i) for i in range(n)]


def run(n_cases: int = 12, records=None):
    cases = _cases(n_cases)
    rows = []

    ext = ShapeFeatureExtractor(backend="ref")
    t0 = time.perf_counter()
    for img, msk, sp in cases:
        ext.execute(img, msk, sp)
    t_loop = time.perf_counter() - t0

    unpruned = BatchedExtractor(backend="ref", prune=False)
    res_u, stats_u = unpruned.run(cases)
    pruned = BatchedExtractor(backend="ref", prune=True)
    res_p, stats_p = pruned.run(cases)
    assert all(r is not None for r in res_u + res_p)
    for a, b in zip(res_u, res_p):  # pruning must not move the features
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def emit(name, seconds, stats=None, **extra):
        derived = dict(
            cases=n_cases, cases_per_s=f"{n_cases / seconds:.2f}", **extra
        )
        rows.append(row(f"pipeline/{name}", seconds / n_cases * 1e6, **derived))
        if records is not None:
            rec = {
                "name": name,
                "cases": n_cases,
                "seconds": seconds,
                "cases_per_second": n_cases / seconds,
            }
            if stats is not None:
                rec.update(
                    buckets=stats["buckets"],
                    vertex_buckets=stats["vertex_buckets"],
                    pruned_cases=stats["pruned_cases"],
                    mean_keep_fraction=stats["mean_keep_fraction"],
                    prune_seconds=stats["prune_seconds"],
                )
            records.append(rec)

    emit("single_case_loop", t_loop)
    emit(
        "batched_unpruned", stats_u["seconds"], stats_u,
        buckets=stats_u["buckets"],
        speedup_vs_loop=f"{t_loop / stats_u['seconds']:.2f}",
    )
    emit(
        "batched_two_pass_pruned", stats_p["seconds"], stats_p,
        buckets=stats_p["buckets"],
        vertex_buckets=stats_p["vertex_buckets"],
        keep_frac=f"{stats_p['mean_keep_fraction']:.3f}",
        speedup_vs_loop=f"{t_loop / stats_p['seconds']:.2f}",
        speedup_vs_unpruned=f"{stats_u['seconds'] / stats_p['seconds']:.2f}",
    )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12)
    args = ap.parse_args(argv)
    for r in run(args.n):
        print(r)


if __name__ == "__main__":
    main()
