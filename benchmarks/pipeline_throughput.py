"""Paper §3 'workflow' analogue: batched multi-case pipeline throughput.

The paper's motivating workload is ~40 000 CT scans on a cluster (xLUNGS);
its discussion notes that for complete workflows data loading dominates
small cases and DMA/compute overlap is the open opportunity.  This
benchmark runs the BatchedExtractor over a batch of synthetic cases in
eight modes -- the single-case loop, the legacy one-pass batched pipeline
(no pruning: the unpruned baseline), the two-pass pruned pipeline with
PR 2's host-side survivor compaction (``device_compact=False``), the
device-resident counted pipeline (PR 3's default), the sync-free
``schedule='static'`` pipeline (PR 4: zero pass-1 host fetches, padded
pair-sweep work instead), the cost-model-driven auto configuration
(PR 5: ``schedule='auto'`` + sync-free ``prep='hint'``), the streaming
front-end (``extract_stream``, window overlap), and the fully
self-configuring stream (``window='auto'``) -- and reports cases/second
for each, the throughput story GPU/TPU acceleration exists to serve.

PR 7 adds the feature-family rows: ``first_order_batch`` and
``glcm_batch`` run each intensity family alone on the same windows, and
``multi_family_batch`` runs shape+firstorder+glcm together; the
multi-family rows are asserted bit-identical per ``plan.family_slices``
slice against the shape-only and single-family runs before timing is
reported, so the throughput rows double as a batch-scale parity gate.

PR 9 adds the out-of-core rows: ``tiled_sparse_prune`` measures the
tiled engine on a sparse two-blob mask with hierarchical tile pruning
on vs the naive full-tiling baseline (the >= 2x speedup is asserted
before the row is reported, and occupancy-pruned rows are asserted
bit-identical to naive), and ``tiled_out_of_core`` streams an analytic
192^3 sphere through the engine under a staged-bytes budget ~28x below
the materialized volume.

``run(records=...)`` appends one dict per mode; ``benchmarks.run
--json-pipeline`` serialises them as the ``BENCH_pipeline.json``
perf-trajectory record (cases/sec per mode across PRs; the
``two_pass_auto`` and ``streaming_auto`` rows are PR 5's additions, and
``scripts/check_bench.py`` gates fresh rows against the committed
trajectory).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import row
from repro.core import plan as planlib
from repro.core.pipeline import BatchedExtractor
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case


def _cases(n: int, dims=(48, 48, 48)):
    return [make_case(dims, seed=100 + i) for i in range(n)]


def _best_interleaved(exts, cases, repeat):
    """Warmup + interleaved best-of-``repeat`` runs per extractor.

    The first run of each mode pays its sub-batch compilations (and the
    runtime's allocator/dispatch caches settle over the next); a
    throughput record that mixed those one-time costs into cases/sec
    would charge the 40k-case sweep's setup to every 12-case window, so
    warmup runs are excluded and each mode reports its best measured run
    (same best-of policy as the autotune sweeps).  Measured runs are
    INTERLEAVED round-robin across the modes so slow machine-load drift
    lands on all of them equally instead of biasing whichever mode ran
    last.
    """
    best = [None] * len(exts)
    for ext in exts:
        ext.run(cases)  # warmup: compile + settle, excluded
    order = list(range(len(exts)))
    for r in range(max(1, repeat)):
        for k in order if r % 2 == 0 else reversed(order):  # ABBA: a load
            # burst spanning a round boundary hits both orderings equally
            res, stats = exts[k].run(cases)
            if best[k] is None or stats["seconds"] < best[k][1]["seconds"]:
                best[k] = (res, stats)
    return best


def run(n_cases: int = 12, records=None, repeat: int = 8):
    cases = _cases(n_cases)
    rows = []

    ext = ShapeFeatureExtractor(backend="ref")
    t0 = time.perf_counter()
    for img, msk, sp in cases:
        ext.execute(img, msk, sp)
    t_loop = time.perf_counter() - t0

    unpruned = BatchedExtractor(backend="ref", prune=False)
    pruned = BatchedExtractor(backend="ref", prune=True, device_compact=False)
    device = BatchedExtractor(backend="ref", prune=True, device_compact=True)
    static = BatchedExtractor(backend="ref", schedule="static")
    auto = BatchedExtractor(backend="ref", schedule="auto", prep="hint")
    # the unpruned baseline is ~15x slower per run: two measured runs
    # bound its noise well enough without dominating the bench's runtime
    ((res_u, stats_u),) = _best_interleaved((unpruned,), cases, 2)
    # host- vs device-compaction vs static schedule vs the cost-model-
    # driven auto configuration are close contests: interleave their runs
    # so machine-load drift cannot bias the winner
    ((res_p, stats_p), (res_d, stats_d), (res_s, stats_s),
     (res_a, stats_a)) = _best_interleaved(
        (pruned, device, static, auto), cases, repeat
    )
    assert all(r is not None for r in res_u + res_p + res_d + res_s + res_a)
    for a, b in zip(res_u, res_p):  # pruning must not move the features
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
    for a, b in zip(res_p, res_d):  # device compaction must not move a BIT
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(res_d, res_s):  # nor may the sync-free static schedule
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(res_d, res_a):  # nor hint prep + the auto schedule
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats_s["host_fetches"].get("pass1", 0) == 0  # the claim measured
    # the sync-free-prep claim, measured the same way: hint prep performed
    # zero per-case pass-0 syncs across every run of the auto mode
    assert auto.executor.transfer_log.get("prep", 0) == 0

    # streaming front-end: same windows, prep of k+1 overlapping exec of k
    def stream_once():
        t0 = time.perf_counter()
        rows = list(static.extract_stream(iter(cases), window=max(4, n_cases // 2)))
        return rows, time.perf_counter() - t0

    stream_once()  # warmup (compiles shared with static, but settle anyway)
    res_st, t_stream = min(
        (stream_once() for _ in range(max(2, repeat // 2))), key=lambda r: r[1]
    )
    for a, b in zip(res_d, res_st):  # streaming must not move a bit either
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # fully self-configuring stream: census-sized windows, cost-model
    # schedule, sync-free hint prep (the PR 5 acceptance configuration)
    def stream_auto_once():
        t0 = time.perf_counter()
        rows = list(auto.extract_stream(iter(cases), window="auto"))
        return rows, time.perf_counter() - t0

    stream_auto_once()  # warmup
    res_sa, t_stream_auto = min(
        (stream_auto_once() for _ in range(max(2, repeat // 2))),
        key=lambda r: r[1],
    )
    for a, b in zip(res_d, res_sa):  # nor the auto-everything stream
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert auto.executor.transfer_log.get("prep", 0) == 0

    # feature families (PR 7): first-order / GLCM texture rows on the
    # same sync-free windows.  Family launches ride inside the window
    # (staged intensity shared by both), so their cost shows up as extra
    # per-window work, not extra sync round-trips.
    fo = BatchedExtractor(backend="ref", families="firstorder")
    gl = BatchedExtractor(backend="ref", families="glcm")
    multi = BatchedExtractor(
        backend="ref", families=("shape", "firstorder", "glcm")
    )
    ((res_f, stats_f), (res_g, stats_g), (res_m, stats_m)) = _best_interleaved(
        (fo, gl, multi), cases, max(2, repeat // 2)
    )
    # family parity at bench scale: the multi-family run's shape slice is
    # bit-identical to the shape-only device rows (families never perturb
    # the shape pipeline), and each intensity slice is bit-identical to
    # the corresponding single-family run (host-side derivation makes the
    # rows independent of which families ride along)
    sl = planlib.family_slices(multi.families)
    for m, d, f, g in zip(res_m, res_d, res_f, res_g):
        np.testing.assert_array_equal(np.asarray(m)[sl["shape"]], np.asarray(d))
        np.testing.assert_array_equal(np.asarray(m)[sl["firstorder"]],
                                      np.asarray(f))
        np.testing.assert_array_equal(np.asarray(m)[sl["glcm"]], np.asarray(g))

    # out-of-core tiling (PR 9): hierarchical tile pruning on a sparse
    # mask, and a volume streamed through the engine under a device
    # budget far below its materialized size.  The pruning row's speedup
    # claim (>= 2x vs naive full-tiling) is asserted before it is
    # reported, and the parity ladder (occupancy bitwise, bounds
    # allclose on ref) re-checks the tier-1 contract at bench scale.
    from repro.core.tiled import TiledExtractor
    from repro.data.tiles import FnSlabSource, TiledCase

    X, Y, Z = 48, 48, 576
    sparse = np.zeros((X, Y, Z), np.float32)
    xs, ys = np.meshgrid(np.arange(X), np.arange(Y), indexing="ij")
    for zc in (24, Z - 24):  # two blobs at the z extremes, empty middle
        for z in range(zc - 12, zc + 12):
            r2 = ((xs - X / 2) / 14.0) ** 2 + ((ys - Y / 2) / 14.0) ** 2 \
                + ((z - zc) / 12.0) ** 2
            sparse[:, :, z][r2 < 1.0] = 1.0
    sp = np.asarray([1.0, 1.0, 1.0], np.float32)
    tcase = TiledCase(sparse, spacing=sp)
    shape_only = BatchedExtractor(backend="ref")
    budget = 288 * 1024  # single-granule tiles: 18 on this frame, ~16 empty
    t_naive = TiledExtractor(shape_only.executor, budget_bytes=budget,
                             tile_prune="none")
    t_occ = TiledExtractor(shape_only.executor, budget_bytes=budget,
                           tile_prune="occupancy")
    t_bnd = TiledExtractor(shape_only.executor, budget_bytes=budget,
                           tile_prune="bounds")

    def best_tiled(tx, k=3):
        best = None
        res = tx.extract(tcase)  # warmup: compiles excluded, as above
        for _ in range(k):
            t0 = time.perf_counter()
            res = tx.extract(tcase)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return res, best

    res_naive, dt_naive = best_tiled(t_naive)
    res_occ, dt_occ = best_tiled(t_occ)
    res_bnd, dt_bnd = best_tiled(t_bnd)
    np.testing.assert_array_equal(res_naive.row, res_occ.row)
    np.testing.assert_allclose(res_naive.row, res_bnd.row,
                               rtol=1e-5, atol=1e-5)
    prune_speedup = dt_naive / dt_bnd
    assert prune_speedup >= 2.0, (
        f"tile pruning speedup {prune_speedup:.2f}x < 2x on the sparse "
        f"mask (naive {dt_naive:.3f}s vs bounds {dt_bnd:.3f}s)"
    )

    # out-of-core: a 192^3 analytic sphere (28 MiB materialized x2 for
    # the frame+halo staging) under a 2 MiB staged budget -- the volume
    # never exists whole on host or device
    N = 192

    def sphere_slab(z0, z1):
        zz = np.arange(z0, z1)
        r2 = (((np.arange(N) - N / 2) / (N * 0.42)) ** 2)[:, None, None] \
            + (((np.arange(N) - N / 2) / (N * 0.42)) ** 2)[None, :, None] \
            + (((zz - N / 2) / (N * 0.42)) ** 2)[None, None, :]
        return (r2 < 1.0).astype(np.float32)

    ooc_budget = 2 * 1024 * 1024
    ooc = TiledCase(FnSlabSource(sphere_slab, (N, N, N)), spacing=sp)
    t_ooc = TiledExtractor(shape_only.executor, budget_bytes=ooc_budget,
                           tile_prune="bounds")
    res_ooc, dt_ooc = best_tiled(t_ooc, k=2)
    assert res_ooc.stats["staged_bytes_peak"] <= 2 * ooc_budget
    ooc_ratio = 4 * N ** 3 / ooc_budget

    def emit(name, seconds, stats=None, **extra):
        derived = dict(
            cases=n_cases, cases_per_s=f"{n_cases / seconds:.2f}", **extra
        )
        rows.append(row(f"pipeline/{name}", seconds / n_cases * 1e6, **derived))
        if records is not None:
            rec = {
                "name": name,
                "cases": n_cases,
                "seconds": seconds,
                "cases_per_second": n_cases / seconds,
            }
            if stats is not None:
                rec.update(
                    buckets=stats["buckets"],
                    vertex_buckets=stats["vertex_buckets"],
                    pruned_cases=stats["pruned_cases"],
                    mean_keep_fraction=stats["mean_keep_fraction"],
                    prune_seconds=stats["prune_seconds"],
                )
            records.append(rec)

    emit("single_case_loop", t_loop)
    emit(
        "batched_unpruned", stats_u["seconds"], stats_u,
        buckets=stats_u["buckets"],
        speedup_vs_loop=f"{t_loop / stats_u['seconds']:.2f}",
    )
    emit(
        "batched_two_pass_pruned", stats_p["seconds"], stats_p,
        buckets=stats_p["buckets"],
        vertex_buckets=stats_p["vertex_buckets"],
        keep_frac=f"{stats_p['mean_keep_fraction']:.3f}",
        speedup_vs_loop=f"{t_loop / stats_p['seconds']:.2f}",
        speedup_vs_unpruned=f"{stats_u['seconds'] / stats_p['seconds']:.2f}",
    )
    emit(
        "two_pass_device_compact", stats_d["seconds"], stats_d,
        buckets=stats_d["buckets"],
        vertex_buckets=stats_d["vertex_buckets"],
        keep_frac=f"{stats_d['mean_keep_fraction']:.3f}",
        speedup_vs_loop=f"{t_loop / stats_d['seconds']:.2f}",
        speedup_vs_host_compact=f"{stats_p['seconds'] / stats_d['seconds']:.2f}",
    )
    emit(
        "two_pass_static", stats_s["seconds"], stats_s,
        buckets=stats_s["buckets"],
        vertex_buckets=stats_s["vertex_buckets"],
        pass1_syncs=0,
        speedup_vs_loop=f"{t_loop / stats_s['seconds']:.2f}",
        speedup_vs_counted=f"{stats_d['seconds'] / stats_s['seconds']:.2f}",
    )
    emit(
        "two_pass_auto", stats_a["seconds"], stats_a,
        buckets=stats_a["buckets"],
        vertex_buckets=stats_a["vertex_buckets"],
        prep="hint",
        resolved_schedule=stats_a["plan"]["schedule"],
        pass0_syncs=0,
        speedup_vs_loop=f"{t_loop / stats_a['seconds']:.2f}",
        speedup_vs_counted=f"{stats_d['seconds'] / stats_a['seconds']:.2f}",
    )
    emit(
        "streaming", t_stream,
        speedup_vs_loop=f"{t_loop / t_stream:.2f}",
        speedup_vs_batched=f"{stats_s['seconds'] / t_stream:.2f}",
        window=max(4, n_cases // 2),
    )
    emit(
        "streaming_auto", t_stream_auto,
        speedup_vs_loop=f"{t_loop / t_stream_auto:.2f}",
        speedup_vs_fixed_stream=f"{t_stream / t_stream_auto:.2f}",
        window="auto",
    )
    emit(
        "first_order_batch", stats_f["seconds"], stats_f,
        families="firstorder",
        row_width=planlib.row_width(fo.families),
        speedup_vs_loop=f"{t_loop / stats_f['seconds']:.2f}",
    )
    emit(
        "glcm_batch", stats_g["seconds"], stats_g,
        families="glcm",
        row_width=planlib.row_width(gl.families),
        speedup_vs_loop=f"{t_loop / stats_g['seconds']:.2f}",
    )
    emit(
        "multi_family_batch", stats_m["seconds"], stats_m,
        families="shape+firstorder+glcm",
        row_width=planlib.row_width(multi.families),
        vs_shape_only=f"{stats_m['seconds'] / stats_d['seconds']:.2f}",
    )

    def emit_tiled(name, seconds, tstats, **extra):
        derived = dict(cases=1, cases_per_s=f"{1 / seconds:.2f}",
                       tiles=tstats["tiles"],
                       tiles_skipped=tstats["tiles_skipped"], **extra)
        rows.append(row(f"pipeline/{name}", seconds * 1e6, **derived))
        if records is not None:
            records.append({
                "name": name, "cases": 1, "seconds": seconds,
                "cases_per_second": 1 / seconds,
                "tiles": tstats["tiles"],
                "tiles_skipped": tstats["tiles_skipped"],
                "tiles_bounds_pruned": tstats["tiles_bounds_pruned"],
            })

    emit_tiled(
        "tiled_sparse_prune", dt_bnd, res_bnd.stats,
        speedup_vs_naive=f"{prune_speedup:.2f}",
        naive_seconds=f"{dt_naive:.3f}",
        budget_kb=budget // 1024,
    )
    emit_tiled(
        "tiled_out_of_core", dt_ooc, res_ooc.stats,
        volume=f"{N}^3",
        budget_over_volume=f"1/{ooc_ratio:.0f}",
        staged_peak_mb=f"{res_ooc.stats['staged_bytes_peak'] / 2**20:.1f}",
    )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12)
    args = ap.parse_args(argv)
    for r in run(args.n):
        print(r)


if __name__ == "__main__":
    main()
