"""Serving-tier mixed-traffic latency/throughput bench (PR 8, gated).

The serving tier (``serve/service``) turns the batch pipeline into a
persistent multi-tenant front door; its contract is LATENCY under mixed
traffic, not just aggregate throughput.  This bench drives the service
with the clinic-plus-research workload -- many small ROIs interleaved
with rare huge cases (``data.synthetic.mixed_traffic_stream``) -- from
concurrent client threads submitting single-case requests, and reports:

* ``serve_mixed_throughput`` -- end-to-end cases/second across the run
  (plus the window-fusion census: windows, cross-tenant windows);
* ``serve_latency_p50`` / ``serve_latency_p99`` -- request latency
  percentiles (submit -> rows resolved), aggregated over every measured
  round for stable tails.

Gate encoding: ``scripts/check_bench.py`` gates the pipeline record on
``cases_per_second`` (higher is better), so the latency rows encode the
percentile as its RECIPROCAL (requests/second at that percentile,
``cases_per_second = 1 / latency_s``) -- a latency regression shows up
as a throughput drop and trips the same >30% rule.  The human-readable
``latency_ms`` rides along in each record.

Before any timing, one full service pass is asserted bit-identical to
``extract_stream`` on the same cases (the serving parity contract), and
the deadline-expiry path is exercised: an already-expired request must
complete with ``DeadlineExceeded`` errors while a co-tenant request in
the same service keeps its bit-identical rows (counts ride the
throughput record as ``expired_cases`` / ``deadline_co_tenant_ok``).
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from benchmarks.common import row
from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import mixed_traffic_stream


def _drive(bx, cases, clients, deadline_s=None):
    """One full pass of ``cases`` through a fresh service.

    ``clients`` threads submit single-case requests round-robin (client
    c owns cases c, c+clients, ...).  Returns (rows in input order,
    per-request latencies, wall seconds, service stats).
    """
    rows_out: list = [None] * len(cases)
    latencies: list = []
    lock = threading.Lock()

    def client(cidx, svc):
        for i in range(cidx, len(cases), clients):
            fut = svc.submit([cases[i]], tenant=f"client-{cidx}",
                             deadline_s=deadline_s)
            res = fut.result(timeout=600)
            with lock:
                rows_out[i] = res.rows[0]
                latencies.append(res.latency_s)

    with bx.serve() as svc:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c, svc))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        stats = svc.stats()
    return rows_out, latencies, dt, stats


def run(n_cases: int = 24, clients: int = 3, records=None, repeat: int = 3,
        huge_every: int = 8):
    bx = BatchedExtractor(backend="ref", prep="hint", schedule="static")
    cases = [(img, msk, sp) for _, img, msk, sp
             in mixed_traffic_stream(n_cases, huge_every=huge_every)]

    # parity first (also the warmup: compiles every bucket the traffic
    # uses): served rows must be bit-identical to the batch stream
    ref_rows = [np.asarray(r) for r in
                bx.extract_stream(iter(cases), window=max(4, n_cases // 3))]
    served, _, _, _ = _drive(bx, cases, clients)
    for a, b in zip(ref_rows, served):
        np.testing.assert_array_equal(a, np.asarray(b))

    # deadline-expiry path: an already-expired request completes with
    # DeadlineExceeded errors and must not perturb a co-tenant's rows
    with bx.serve() as svc:
        f_live = svc.submit(cases[:4], tenant="live")
        f_dead = svc.submit(cases[4:8], tenant="hurried", deadline_s=0.0)
        live, dead = f_live.result(600), f_dead.result(600)
        dstats = svc.stats()
    assert all("DeadlineExceeded" in e for e in dead.errors.values())
    assert dead.errors and not live.errors
    for a, b in zip(ref_rows[:4], live.rows):
        np.testing.assert_array_equal(a, np.asarray(b))

    # measured rounds: aggregate request latencies across rounds for a
    # stable p99 tail; throughput reports the best round (the bench-wide
    # best-of policy -- warmup above already paid the compiles)
    all_lat: list = []
    best = None
    for _ in range(max(1, repeat)):
        _, lat, dt, stats = _drive(bx, cases, clients)
        all_lat.extend(lat)
        if best is None or dt < best[0]:
            best = (dt, stats)
    dt, stats = best
    lat = np.asarray(all_lat)
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    cross = sum(1 for t in stats["window_tenants"] if t > 1)

    rows = [
        row("serve/mixed_throughput", dt / n_cases * 1e6,
            cases=n_cases, clients=clients,
            cases_per_s=f"{n_cases / dt:.2f}",
            windows=stats["windows"], cross_tenant_windows=cross),
        row("serve/latency_p50", p50 * 1e6, ms=f"{p50 * 1e3:.1f}"),
        row("serve/latency_p99", p99 * 1e6, ms=f"{p99 * 1e3:.1f}"),
    ]
    if records is not None:
        records.append({
            "name": "serve_mixed_throughput",
            "cases": n_cases,
            "seconds": dt,
            "cases_per_second": n_cases / dt,
            "clients": clients,
            "windows": stats["windows"],
            "cross_tenant_windows": cross,
            "expired_cases": dstats["expired_cases"],
            "deadline_co_tenant_ok": True,
        })
        for pname, p in (("p50", p50), ("p99", p99)):
            records.append({
                # reciprocal encoding: requests/second at this latency
                # percentile, so the cases_per_second gate catches a
                # latency regression as a throughput drop
                "name": f"serve_latency_{pname}",
                "cases": 1,
                "seconds": p,
                "cases_per_second": 1.0 / p,
                "latency_ms": p * 1e3,
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args(argv)
    for r in run(args.n, args.clients, repeat=args.repeat):
        print(r)


if __name__ == "__main__":
    main()
