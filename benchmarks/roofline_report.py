"""Per-kernel roofline-efficiency rows for the gated pipeline trajectory.

For every launch kind the executor dispatches (pair-sweep diameter, prune
bound, segmented compaction, fused marching cubes, first-order, GLCM) at
a small canonical bucket grid, this suite:

1. measures the real batched 'ref' launch (``benchmarks.common.timeit``
   median, depth :data:`DEPTH`);
2. prices the same launch with the structural work model
   (``repro.runtime.roofline``) under a hardware profile MEASURED fresh
   in-process (``repro.runtime.autotune.measure_hw_profile`` -- same
   host, same minute as the kernel timing, so the ratio below is a
   same-machine quantity);
3. reports the achieved fraction of the roofline bound,
   ``bound_us / measured_us``.

The fraction rides the ``cases_per_second`` field of each
``roofline/<kernel>/<bucket>`` row -- the same higher-is-better encoding
the serve-latency rows use for 1/latency -- so the committed
``BENCH_pipeline.json`` trajectory gates it under the existing >30%
regression rule: a kernel silently dropping from 40% to 15% of its
roofline bound fails the build even when absolute-throughput noise would
hide it.  Because both the bound (via the fresh probe) and the
measurement come from the same host, the fraction is far more portable
across machines than the raw throughput rows it sits beside.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row

DEPTH = 4  # batch depth of every measured launch (= runtime.roofline.CAL_DEPTH)

# best-of-N timing: the gated fraction is a capability ratio, and transient
# host load only ever LOWERS an individual sample, so the minimum is the
# stable estimator (the same reason the sync probe is best-of-64) -- a
# median here swings the fraction well past the 30% gate on a busy runner
TIMING_REPEAT = 5

# the probe and the kernel timings are re-taken in ROUNDS interleaved
# rounds and each row keeps its best fraction: load during the probe
# lowers the bound, load during the kernel raises the measurement, so ALL
# noise pushes the fraction down -- the max over rounds is a tight,
# one-sided estimator of the true capability ratio
ROUNDS = 3


def _best_time(fn, *args, repeat: int = TIMING_REPEAT, warmup: int = 2):
    """Best-of-``repeat`` wall-clock seconds with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best

#: The measured (kind, bucket) grid -- one row per entry.  Kept small:
#: this runs inside the CI bench stage on the CPU 'ref' backend.
GRID = (
    {"kind": "diameter", "m": 1024},
    {"kind": "diameter", "m": 2048},
    {"kind": "prune", "m": 2048},
    {"kind": "compact", "m": 2048, "cap": 1024},
    {"kind": "mc", "shape": (34, 34, 34)},
    {"kind": "firstorder", "shape": (34, 34, 34)},
    {"kind": "glcm", "shape": (34, 34, 34)},
)


def _bucket_label(spec: dict) -> str:
    if "m" in spec:
        label = f"M{spec['m']}"
        if "cap" in spec:
            label += f"c{spec['cap']}"
        return label
    return "S" + "x".join(str(s) for s in spec["shape"])


def _launch(spec: dict):
    """(fn, args) for the batched 'ref' launch of one grid entry."""
    kind = spec["kind"]
    if kind == "diameter":
        from repro.kernels import ref as _ref

        args = (jnp.zeros((DEPTH, spec["m"], 3), jnp.float32),
                jnp.ones((DEPTH, spec["m"]), bool))

        def fn(v, msk):
            return jax.lax.map(
                lambda a: _ref.max_diameters_sq(a[0], a[1]), (v, msk)
            )
    elif kind == "prune":
        from repro.kernels import prune as _prune

        args = (jnp.zeros((DEPTH, spec["m"], 3), jnp.float32),
                jnp.ones((DEPTH, spec["m"]), bool))

        def fn(v, msk):
            return _prune.keep_mask_batch(v, msk, 16)
    elif kind == "compact":
        from repro.kernels import compact as _compact

        cap = spec["cap"]
        args = (jnp.zeros((DEPTH, spec["m"], 3), jnp.float32),
                jnp.ones((DEPTH, spec["m"]), bool))

        def fn(v, keep):
            return _compact.compact_batch_ref(v, keep, cap)
    elif kind == "mc":
        from repro.kernels import ops as _ops

        args = (jnp.zeros((DEPTH,) + spec["shape"], jnp.float32),
                jnp.ones((DEPTH, 3), jnp.float32))

        def fn(vols, sps):
            return _ops.mc_volume_area_batch(vols, 0.5, sps, backend="ref")
    else:
        from repro.kernels import firstorder as _fo
        from repro.kernels import glcm as _glcm

        op = (_fo.firstorder_packed_batch_ref if kind == "firstorder"
              else _glcm.glcm_matrix_batch_ref)
        args = (jnp.zeros((DEPTH,) + spec["shape"], jnp.float32),
                jnp.ones((DEPTH,) + spec["shape"], bool))

        def fn(images, masks):
            return op(images, masks, 32)
    return jax.jit(fn), args


def run(records: list | None = None):
    """Measure the grid; returns printable rows, appends record dicts."""
    from repro.runtime import autotune, roofline

    costs = {}
    launches = {}
    for spec in GRID:
        name = f"roofline/{spec['kind']}/{_bucket_label(spec)}"
        costs[name] = roofline.model_kernel_cost(
            spec["kind"], depth=DEPTH, m=spec.get("m"), cap=spec.get("cap"),
            shape=spec.get("shape"),
        )
        launches[name] = _launch(spec)

    best: dict = {}
    for _ in range(ROUNDS):
        profile = autotune.measure_hw_profile()
        for name, (flops, nbytes) in costs.items():
            bound_us = roofline.roofline_us(flops, nbytes, profile)
            fn, args = launches[name]
            measured_us = _best_time(fn, *args) * 1e6
            frac = bound_us / measured_us if measured_us > 0 else 0.0
            if name not in best or frac > best[name]["frac"]:
                best[name] = {
                    "frac": frac, "measured_us": measured_us,
                    "bound_us": bound_us, "profile": profile,
                }

    rows = []
    for name, (flops, nbytes) in costs.items():
        b = best[name]
        rows.append(
            row(
                name,
                b["measured_us"],
                roofline_frac=f"{b['frac']:.4f}",
                bound_us=f"{b['bound_us']:.1f}",
                gflops=f"{flops / 1e9:.3f}",
                mbytes=f"{nbytes / 2**20:.1f}",
            )
        )
        if records is not None:
            records.append(
                {
                    "name": name,
                    "cases": DEPTH,
                    "seconds": b["measured_us"] / 1e6,
                    # the gated metric: achieved fraction of the roofline
                    # bound (higher is better, same-host ratio)
                    "cases_per_second": b["frac"],
                    "measured_us": b["measured_us"],
                    "bound_us": b["bound_us"],
                    "model_flops": flops,
                    "model_bytes": nbytes,
                    "peak_flops": b["profile"]["peak_flops"],
                    "mem_bw": b["profile"]["mem_bw"],
                }
            )
    return rows


def main(argv=None):
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
