"""Aggregate experiments/dryrun/*.json into the §Roofline table.

One row per (arch, shape, mesh) dry-run cell: the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the
roofline fraction.  This is the report the perf loop iterates on.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import row

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str | None = None):
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") not in (mesh, None):
            continue
        cells.append(d)
    return cells


def run(mesh: str | None = None):
    rows = []
    for d in load_cells(mesh):
        name = f"roofline/{d['arch']}/{d['shape']}/{d.get('mesh', '?')}"
        if d.get("skipped"):
            rows.append(row(name, 0.0, status="skipped"))
            continue
        if d.get("status") != "ok":
            rows.append(row(name, 0.0, status="FAILED"))
            continue
        r = d["roofline"]
        m = d.get("memory", {})
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            row(
                name,
                bound_s * 1e6,  # bound step time (us) = the 'call'
                dominant=r["dominant"].replace("_s", ""),
                compute_s=f"{r['compute_s']:.3e}",
                memory_s=f"{r['memory_s']:.3e}",
                collective_s=f"{r['collective_s']:.3e}",
                roofline_frac=f"{r.get('roofline_fraction', 0):.3f}",
                useful_flops=f"{r.get('useful_flops_ratio', 0):.3f}",
                hbm_gib=f"{(m.get('argument_size_in_bytes', 0) + m.get('temp_size_in_bytes', 0)) / 2**30:.2f}",
            )
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)
    for r in run(args.mesh):
        print(r)


if __name__ == "__main__":
    main()
