"""First-order + GLCM feature families: parity, registry, executor wiring.

The contracts under test (see kernels/firstorder.py, kernels/glcm.py,
core/plan.py, core/executor.py):

* FIRST-ORDER BITWISE parity: the Pallas kernel's packed stats equal the
  reference oracle's bit-for-bit, for every block size (the canonical-
  chunk left-fold contract makes ``block`` a pure performance axis), and
  batched extraction equals single-case extraction bit-for-bit;
* GLCM EXACTNESS: count matrices are integer-valued f32 and exactly
  equal across backends and blocks (one-hot-matmul scatter), so the
  host-derived Haralick rows are bitwise identical too (well inside the
  1e-5 tolerance the family promises);
* both reference paths match independent NUMPY oracles (float64 stats,
  ``np.add.at`` scatter);
* edge cases: empty mask, single voxel, constant intensity, bin-edge
  straddling values -- no NaNs, documented values;
* the family REGISTRY (plan.FAMILIES) resolves requests to canonical
  order, derives row widths/slices/names, and rejects unknown names;
* the EXECUTOR schedules family launches inside the sync-free window:
  enabling families never adds a prep/pass-1/pass-2 host fetch (each
  family drains through its own transfer stage), the shape columns of a
  multi-family run equal a shape-only run bit-for-bit, quarantined cases
  produce FULL-WIDTH NaN rows, and ``extract_stream`` == ``run`` ==
  ``extract_one`` per family;
* the ``firstorder/<backend>`` / ``glcm/<backend>`` autotune namespaces
  round-trip through the v3 cache.
"""
import numpy as np
import pytest

from repro.core import plan as planlib
from repro.core.executor import PlanExecutor
from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.kernels import firstorder as fok
from repro.kernels import glcm as gk
from repro.kernels import ops
from repro.runtime import autotune

pytestmark = pytest.mark.tier1

N_BINS = 32


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


def _stack(cases):
    imgs = np.stack([np.asarray(c[0], np.float32) for c in cases])
    msks = np.stack([np.asarray(c[1], np.float32) for c in cases])
    return imgs, msks


def _cases(n=3, shape=(20, 22, 18)):
    return [make_case(shape, seed=i) for i in range(n)]


# ---------------------------------------------------------------------------
# numpy oracles (independent of jax)
# ---------------------------------------------------------------------------


def np_quantize(image, mask, n_bins=N_BINS):
    """Bit-replica of ref.quantize_intensity in numpy f32."""
    img = np.asarray(image, np.float32).reshape(-1)
    m = np.asarray(mask).reshape(-1) > 0
    if not m.any():
        return np.zeros_like(img), np.float32(0), np.float32(0), np.float32(0)
    lo = np.float32(img[m].min())
    hi = np.float32(img[m].max())
    width = np.float32((hi - lo) / np.float32(n_bins))
    safe = width if width > 0 else np.float32(1.0)
    q = np.clip(np.floor((img - lo) / safe), 0.0, n_bins - 1).astype(np.float32)
    return np.where(m, q, np.float32(0)), lo, hi, width


def np_firstorder(image, mask, n_bins=N_BINS):
    """Float64 first-order oracle (histogram features off np_quantize)."""
    img = np.asarray(image, np.float64).reshape(-1)
    m = np.asarray(mask).reshape(-1) > 0
    if not m.any():
        return np.zeros(fok.N_FEATURES, np.float64)
    v = img[m]
    q, lo, hi, width = np_quantize(image, mask, n_bins)
    hist = np.bincount(q[m].astype(np.int64), minlength=n_bins).astype(np.float64)
    n = float(m.sum())
    p = hist / n
    ent = -np.sum(np.where(p > 0, p * np.log2(np.where(p > 0, p, 1.0)), 0.0))
    centers = lo + (np.arange(n_bins) + 0.5) * float(width)
    cum = np.cumsum(hist)

    def pct(f):
        return centers[int(np.argmax(cum >= f * n))]

    return np.array([
        v.mean(), np.sqrt(np.maximum(v.var(), 0.0)), v.min(), v.max(),
        pct(0.1), pct(0.5), pct(0.9),
        float(np.sum(np.float32(v) * np.float32(v), dtype=np.float64)),
        ent,
    ])


def np_glcm_matrix(image, mask, n_bins=N_BINS):
    """np.add.at scatter oracle for the symmetric count matrix."""
    q, _, _, _ = np_quantize(image, mask, n_bins)
    shape = np.asarray(image).shape
    q = q.reshape(shape)
    m = (np.asarray(mask) > 0).astype(np.float32)
    g = np.zeros((n_bins, n_bins), np.float64)
    for off in gk.OFFSETS:
        a = tuple(slice(None, -o) if o else slice(None) for o in off)
        b = tuple(slice(o, None) for o in off)
        valid = (m[a] * m[b]) > 0
        np.add.at(g, (q[a][valid].astype(np.int64),
                      q[b][valid].astype(np.int64)), 1.0)
    return (g + g.T).astype(np.float32)


# ---------------------------------------------------------------------------
# first-order: bitwise parity, block invariance, batched == single
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,seed", [((20, 22, 18), 1), ((33, 17, 25), 7)])
def test_fo_ref_vs_pallas_bitwise(shape, seed):
    imgs, msks = _stack([make_case(shape, seed=seed)])
    ref = np.asarray(fok.firstorder_packed_batch_ref(imgs, msks))
    pal = np.asarray(
        fok.firstorder_packed_batch_pallas(imgs, msks, interpret=True)
    )
    np.testing.assert_array_equal(ref, pal)
    np.testing.assert_array_equal(
        fok.features_from_packed_np(ref), fok.features_from_packed_np(pal)
    )


def test_fo_block_invariance_bitwise():
    imgs, msks = _stack(_cases(2))
    outs = [
        np.asarray(fok.firstorder_packed_batch_pallas(
            imgs, msks, block=b, interpret=True
        ))
        for b in (1024, 2048, 4096)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_fo_block_must_tile_canonical_chunk():
    imgs, msks = _stack(_cases(1))
    with pytest.raises(ValueError, match="CANON_CHUNK"):
        fok.firstorder_packed_batch_pallas(imgs, msks, block=1536,
                                           interpret=True)


def test_fo_batched_equals_single_bitwise():
    cases = _cases(4, (18, 20, 16))
    imgs, msks = _stack(cases)
    batched = np.asarray(
        fok.firstorder_packed_batch_pallas(imgs, msks, interpret=True)
    )
    for i in range(len(cases)):
        single = np.asarray(fok.firstorder_packed_batch_pallas(
            imgs[i:i + 1], msks[i:i + 1], interpret=True
        ))[0]
        np.testing.assert_array_equal(batched[i], single)


def test_fo_matches_numpy_oracle():
    img, msk, _ = make_case((24, 21, 19), seed=3)
    row = ops.firstorder_features_batch(img[None], msk[None],
                                       backend="ref")[0]
    want = np_firstorder(img, msk)
    # f32 chunk-fold sums vs float64: loose on the moments, exact-ish on
    # order statistics (min/max/percentiles are picked, not accumulated)
    np.testing.assert_allclose(row, want, rtol=1e-3)
    np.testing.assert_allclose(row[2:7], want[2:7], rtol=1e-6)


# ---------------------------------------------------------------------------
# glcm: integer-exact matrices, scatter oracle, batched == single
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block", [512, 2048])
def test_glcm_ref_vs_pallas_exact(block):
    imgs, msks = _stack(_cases(2))
    ref = np.asarray(gk.glcm_matrix_batch_ref(imgs, msks))
    pal = np.asarray(gk.glcm_matrix_batch_pallas(imgs, msks, block=block,
                                                 interpret=True))
    np.testing.assert_array_equal(ref, pal)
    # integer-valued counts, symmetric
    np.testing.assert_array_equal(ref, np.round(ref))
    np.testing.assert_array_equal(ref, np.transpose(ref, (0, 2, 1)))
    np.testing.assert_array_equal(
        gk.glcm_features_from_matrix_np(ref),
        gk.glcm_features_from_matrix_np(pal),
    )


def test_glcm_matches_numpy_scatter():
    img, msk, _ = make_case((19, 23, 17), seed=5)
    ref = np.asarray(gk.glcm_matrix_batch_ref(img[None], msk[None]))[0]
    np.testing.assert_array_equal(ref, np_glcm_matrix(img, msk))


def test_glcm_batched_equals_single_exact():
    cases = _cases(3, (16, 18, 20))
    imgs, msks = _stack(cases)
    batched = np.asarray(gk.glcm_matrix_batch_pallas(imgs, msks,
                                                     interpret=True))
    for i in range(len(cases)):
        single = np.asarray(gk.glcm_matrix_batch_pallas(
            imgs[i:i + 1], msks[i:i + 1], interpret=True
        ))[0]
        np.testing.assert_array_equal(batched[i], single)


# ---------------------------------------------------------------------------
# edge cases (both backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_empty_mask_zero_rows(backend):
    img = np.zeros((12, 12, 12), np.float32)
    msk = np.zeros((12, 12, 12), np.float32)
    kw = {} if backend == "ref" else {"block": 2048}
    fo = ops.firstorder_features_batch(img[None], msk[None], backend=backend,
                                       **kw)[0]
    gl = ops.glcm_features_batch(img[None], msk[None], backend=backend,
                                 **kw)[0]
    np.testing.assert_array_equal(fo, np.zeros(fok.N_FEATURES))
    np.testing.assert_array_equal(gl, np.zeros(gk.N_FEATURES))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_single_voxel(backend):
    img = np.zeros((10, 10, 10), np.float32)
    msk = np.zeros((10, 10, 10), np.float32)
    img[4, 5, 6] = 42.5
    msk[4, 5, 6] = 1.0
    kw = {} if backend == "ref" else {"block": 2048}
    fo = ops.firstorder_features_batch(img[None], msk[None], backend=backend,
                                       **kw)[0]
    x = np.float32(42.5)
    np.testing.assert_array_equal(
        fo, [x, 0.0, x, x, x, x, x, x * x, 0.0]
    )
    # one voxel has no co-occurring neighbour inside the mask
    gl = ops.glcm_features_batch(img[None], msk[None], backend=backend,
                                 **kw)[0]
    np.testing.assert_array_equal(gl, np.zeros(gk.N_FEATURES))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_constant_intensity(backend):
    img = np.full((10, 12, 9), 7.0, np.float32)
    msk = np.zeros((10, 12, 9), np.float32)
    msk[2:7, 3:9, 2:6] = 1.0
    n = msk.sum()
    kw = {} if backend == "ref" else {"block": 2048}
    fo = ops.firstorder_features_batch(img[None], msk[None], backend=backend,
                                       **kw)[0]
    np.testing.assert_array_equal(
        fo, [7.0, 0.0, 7.0, 7.0, 7.0, 7.0, 7.0, 49.0 * n, 0.0]
    )
    # single gray level: contrast 0, correlation defined as 1, idm/energy 1
    gl = ops.glcm_features_batch(img[None], msk[None], backend=backend,
                                 **kw)[0]
    np.testing.assert_array_equal(gl, [0.0, 1.0, 1.0, 1.0])


def test_bin_edge_straddling_values():
    # integer intensities 0..31 put the masked max EXACTLY on the top
    # edge: floor((hi-lo)/width) == n_bins must clip into the last bin,
    # and the histogram must still count every masked voxel
    img = np.tile(np.arange(32, dtype=np.float32), 32).reshape(8, 16, 8)
    msk = np.ones((8, 16, 8), np.float32)
    packed = np.asarray(
        fok.firstorder_packed_batch_ref(img[None], msk[None])
    )[0]
    hist = packed[3:3 + N_BINS]
    assert packed[0] == img.size
    assert hist.sum() == img.size
    np.testing.assert_array_equal(hist, np.full(N_BINS, img.size / N_BINS))
    q, lo, hi, width = np_quantize(img, msk)
    assert (lo, hi) == (0.0, 31.0) and q.max() == N_BINS - 1


# ---------------------------------------------------------------------------
# registry (plan layer)
# ---------------------------------------------------------------------------


def test_registry_resolution_and_layout():
    assert planlib.resolve_families(None) == ("shape",)
    assert planlib.resolve_families("glcm") == ("glcm",)
    # canonical order is registry order, independent of request order
    fams = planlib.resolve_families(("glcm", "shape", "firstorder"))
    assert fams == ("shape", "firstorder", "glcm")
    assert planlib.row_width(fams) == 7 + 9 + 4
    sl = planlib.family_slices(fams)
    assert sl["shape"] == slice(0, 7)
    assert sl["firstorder"] == slice(7, 16)
    assert sl["glcm"] == slice(16, 20)
    names = planlib.feature_names(fams)
    assert len(names) == 20 and names[7] == "Mean" and names[16] == "Contrast"
    assert planlib.needs_intensity(fams)
    assert not planlib.needs_intensity(("shape",))
    with pytest.raises(ValueError, match="unknown"):
        planlib.resolve_families(("shape", "wavelet"))
    with pytest.raises(ValueError):
        planlib.resolve_families(())


def test_meta_bytes_counts_intensity_volume():
    base = planlib.CaseMeta((32, 32, 32), (20, 20, 20), 1024, 500)
    with_img = planlib.CaseMeta((32, 32, 32), (20, 20, 20), 1024, 500,
                                intensity=True)
    assert (planlib.meta_bytes(with_img) - planlib.meta_bytes(base)
            == 4 * 32 * 32 * 32)


def test_plan_carries_families():
    metas = [planlib.CaseMeta((32, 32, 32), (20, 20, 20), 1024, 500,
                              intensity=True)]
    plan = planlib.build_plan(metas, families=("glcm", "shape"))
    assert plan.families == ("shape", "glcm")
    assert plan.stats()["families"] == ["shape", "glcm"]


# ---------------------------------------------------------------------------
# executor: sync-free windows, quarantine, stream/run/one parity
# ---------------------------------------------------------------------------


def test_families_ride_the_window_sync_free():
    cases = _cases(4) + [make_case((33, 17, 25), seed=9)]
    shape_only = PlanExecutor(backend="interpret")
    rows_s, stats_s = shape_only.run(cases)
    multi = PlanExecutor(backend="interpret",
                         families=("shape", "firstorder", "glcm"))
    rows_m, stats_m = multi.run(cases)

    # enabling families must not add a single shape-pass host fetch:
    # the transfer_log census of every pre-existing stage is unchanged
    for stage in ("prep", "pass1", "pass2a", "pass2b"):
        assert stats_m["host_fetches"].get(stage, 0) == \
            stats_s["host_fetches"].get(stage, 0), stage
    # family drains ride their own stages
    assert stats_m["host_fetches"]["firstorder"] >= 1
    assert stats_m["host_fetches"]["glcm"] >= 1

    sl = planlib.family_slices(multi.families)
    for rs, rm in zip(rows_s, rows_m):
        np.testing.assert_array_equal(rs, rm[sl["shape"]])


def test_stream_equals_run_equals_one_multi_family():
    cases = _cases(5, (18, 20, 16))
    ex = BatchedExtractor(backend="interpret",
                          families=("shape", "firstorder", "glcm"))
    rows, _ = ex.run(cases)
    streamed = list(ex.extract_stream(iter(cases), window=2))
    assert len(streamed) == len(rows)
    for a, b in zip(rows, streamed):
        np.testing.assert_array_equal(a, b)
    one = ex.extract_one(*cases[0])
    np.testing.assert_array_equal(rows[0], one)


def test_intensity_only_request_skips_shape_passes():
    cases = _cases(3)
    ex = PlanExecutor(backend="interpret", families="firstorder")
    rows, stats = ex.run(cases)
    assert rows[0].shape == (fok.N_FEATURES,)
    for stage in ("pass1", "pass2a", "pass2b"):
        assert stats["host_fetches"].get(stage, 0) == 0, stage
    full = PlanExecutor(
        backend="interpret", families=("shape", "firstorder")
    )
    rows_f, _ = full.run(cases)
    for r, rf in zip(rows, rows_f):
        np.testing.assert_array_equal(r, rf[7:])


def test_quarantine_multi_family_full_width_nan():
    good = _cases(3)
    img, msk, sp = make_case((16, 16, 16), seed=9)
    poisoned = (img, np.full_like(np.asarray(msk, np.float32), np.nan), sp)
    no_image = (None, msk, sp)
    fams = ("shape", "firstorder", "glcm")
    ex = PlanExecutor(backend="interpret", families=fams)
    rows, stats = ex.run(good + [poisoned, no_image])
    width = planlib.row_width(fams)
    for i in (3, 4):
        assert rows[i].shape == (width,)
        assert np.isnan(rows[i]).all()
    assert set(stats["errors"]) == {3, 4}
    assert "intensity" in stats["errors"][4]
    # the quarantined cases must not perturb their window-mates
    clean, _ = PlanExecutor(backend="interpret", families=fams).run(good)
    for a, b in zip(clean, rows[:3]):
        np.testing.assert_array_equal(a, b)


def test_missing_image_ok_when_shape_only():
    img, msk, sp = make_case((16, 16, 16), seed=2)
    ex = PlanExecutor(backend="interpret")
    rows, stats = ex.run([(None, msk, sp), (img, msk, sp)])
    assert not stats["errors"]
    np.testing.assert_array_equal(rows[0], rows[1])


# ---------------------------------------------------------------------------
# autotune namespaces
# ---------------------------------------------------------------------------


def test_family_autotune_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    shape = (16, 16, 16)
    cfg = autotune.get_family_config(
        "firstorder", shape, "interpret", blocks=(1024, 2048), repeat=1
    )
    assert cfg.block in (1024, 2048)
    cache = autotune.AutotuneCache()
    entry = cache.get(autotune.family_key("firstorder", shape, "interpret"))
    assert entry is not None and entry["block"] == cfg.block
    assert set(entry["table"]) == {"1024", "2048"}
    # a poisoned cache entry whose block violates the canonical-chunk
    # contract is rejected, not trusted
    cache.put(autotune.family_key("firstorder", shape, "interpret"),
              {"block": 1536, "us": 1.0, "table": {}})
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    cfg2 = autotune.get_family_config("firstorder", shape, "interpret")
    assert cfg2.block % fok.CANON_CHUNK == 0

    glcfg = autotune.get_family_config("glcm", shape, "ref")
    assert glcfg == autotune.DEFAULT_GLCM_CONFIG


def test_dispatcher_family_config_passthrough():
    from repro.core import dispatcher

    assert dispatcher.firstorder_config("interpret", (16, 16, 16), 4096) == 4096
    assert dispatcher.glcm_config("ref", (16, 16, 16)) == \
        autotune.DEFAULT_GLCM_CONFIG.block
