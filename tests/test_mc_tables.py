"""Property tests of the generated marching-cubes table.

The table is *derived* (see core/mc_tables.py); these tests pin down the
invariants that make the derivation correct:
  * every case triangulates exactly its active edges,
  * the global mesh over any volume is closed and consistently oriented
    (every directed half-edge is matched by its reverse),
  * no duplicated triangles (no degenerate membranes),
  * orientation gives positive signed volume for convex solids.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mc_tables as mct


def test_shape_and_bounds():
    assert mct.TRI_TABLE.shape == (256, 3 * mct.MAX_TRIS)
    assert mct.TRI_TABLE.min() >= -1 and mct.TRI_TABLE.max() <= 11
    assert mct.N_TRIS[0] == 0 and mct.N_TRIS[255] == 0
    # complementary cases triangulate the same edge set
    for case in range(256):
        a = set(x for x in mct.TRI_TABLE[case] if x >= 0)
        b = set(x for x in mct.TRI_TABLE[255 - case] if x >= 0)
        assert a == b


def test_single_corner_cases():
    # corner c uses exactly its three incident edges
    for c in range(8):
        case = 1 << c
        assert mct.N_TRIS[case] == 1
        used = sorted(x for x in mct.TRI_TABLE[case] if x >= 0)
        incident = sorted(
            e for e, (a, b) in enumerate(np.asarray(mct.EDGES)) if c in (a, b)
        )
        assert used == incident


def test_active_edges_match_table():
    for case in range(256):
        used = set(int(x) for x in mct.TRI_TABLE[case] if x >= 0)
        active = set(np.nonzero(mct.EDGE_ACTIVE[case])[0].tolist())
        assert used == active


def _global_mesh_edges(vol, iso=0.5):
    inside = vol > iso
    nx, ny, nz = vol.shape
    edges: dict = {}
    tris: dict = {}

    def canon(i, j, k, e):
        off = mct.EDGE_CELL_OFFSET[e]
        ax = mct.EDGE_CELL_AXIS[e]
        return (i + off[0], j + off[1], k + off[2], int(ax))

    for i, j, k in itertools.product(range(nx - 1), range(ny - 1), range(nz - 1)):
        idx = sum(
            int(inside[i + dx, j + dy, k + dz]) << c
            for c, (dx, dy, dz) in enumerate(np.asarray(mct.CORNERS))
        )
        row = mct.TRI_TABLE[idx]
        for t in range(mct.N_TRIS[idx]):
            vs = [canon(i, j, k, int(e)) for e in row[3 * t : 3 * t + 3]]
            key = tuple(sorted(vs))
            tris[key] = tris.get(key, 0) + 1
            for z in range(3):
                p, q = vs[z], vs[(z + 1) % 3]
                edges[(p, q)] = edges.get((p, q), 0) + 1
    return edges, tris


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_watertight_oriented_random_volumes(seed):
    rng = np.random.default_rng(seed)
    vol = np.pad(rng.random((7, 6, 8)).astype(np.float32), 1)
    edges, tris = _global_mesh_edges(vol)
    for (p, q), n in edges.items():
        assert edges.get((q, p), 0) == n, "open or inconsistently oriented mesh"
    assert all(n == 1 for n in tris.values()), "duplicated triangle"


def test_binary_blob_watertight():
    rng = np.random.default_rng(3)
    vol = np.pad((rng.random((6, 7, 5)) > 0.5).astype(np.float32), 1)
    edges, tris = _global_mesh_edges(vol)
    for (p, q), n in edges.items():
        assert edges.get((q, p), 0) == n
    assert all(n == 1 for n in tris.values())
