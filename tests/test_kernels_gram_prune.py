"""gram MXU variant, exact candidate pruning, autotune round-trip.

Plain-pytest property sweeps (seed-parametrised, no hypothesis dependency:
this module must collect in the minimal container, unlike the
hypothesis-gated kernel suites -- see tests/conftest.py).
"""
import json
import os

import numpy as np
import pytest

from repro.kernels import diameter as dk
from repro.kernels import ops, prune
from repro.kernels import ref as ref_k
from conftest import sphere_mask

pytestmark = pytest.mark.tier1


def _brute(verts, mask):
    v = np.asarray(verts)[np.asarray(mask).astype(bool)]
    if len(v) < 2:
        return np.zeros(4, np.float32)
    d = v[:, None, :] - v[None, :, :]
    q = d * d
    qx, qy, qz = q[..., 0], q[..., 1], q[..., 2]
    return np.array(
        [(qx + qy + qz).max(), (qx + qy).max(), (qx + qz).max(), (qy + qz).max()]
    )


def _cloud(seed, m=None, scale=None, hole=0.25):
    rng = np.random.default_rng(seed)
    m = m or int(rng.integers(8, 400))
    scale = scale or rng.uniform(1.0, 80.0)
    verts = (rng.normal(size=(m, 3)) * scale).astype(np.float32)
    mask = rng.random(m) > hole
    if mask.sum() < 2:
        mask[:2] = True
    return verts, mask


# ---------------------------------------------------------------------------
# (a) gram matches seqacc / the oracle within 1e-3 relative
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("M,block", [(100, 64), (300, 128), (513, 256)])
def test_gram_matches_seqacc(seed, M, block):
    verts, mask = _cloud(seed * 1000 + M, m=M)
    want = np.asarray(
        dk.max_diameters_sq_pallas(
            verts, mask, block=block, variant="seqacc", interpret=True
        )
    )
    got = np.asarray(
        dk.max_diameters_sq_pallas(
            verts, mask, block=block, variant="gram", interpret=True
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-3)
    np.testing.assert_allclose(got, _brute(verts, mask), rtol=1e-3, atol=1e-3)


def test_gram_all_masked_and_single_vertex():
    verts = np.full((64, 3), 5.0, np.float32)
    mask = np.zeros(64, bool)
    got = np.asarray(
        dk.max_diameters_pallas(verts, mask, block=64, variant="gram", interpret=True)
    )
    np.testing.assert_allclose(got, 0.0)
    mask[3] = True
    got = np.asarray(
        dk.max_diameters_pallas(verts, mask, block=64, variant="gram", interpret=True)
    )
    np.testing.assert_allclose(got, 0.0)


def test_gram_cost_model():
    """gram moves the pair sweep to the MXU: its VPU flops must undercut
    every subtract-square variant, and the MXU term exists only for gram."""
    M, B = 262_144, 256
    assert dk.flop_estimate(M, B, "gram") < dk.flop_estimate(M, B, "tri_prefetch")
    assert dk.mxu_flop_estimate(M, B, "gram") > 0.0
    assert dk.mxu_flop_estimate(M, B, "seqacc") == 0.0
    assert dk.bytes_estimate(M, B, "gram") == dk.bytes_estimate(M, B, "tri_prefetch")


# ---------------------------------------------------------------------------
# (b) pruning + any variant is bit-identical to the unpruned search
# ---------------------------------------------------------------------------

_VARIANTS = ("seqacc", "tri_prefetch", "nomask", "gram")


@pytest.mark.parametrize("variant", _VARIANTS)
@pytest.mark.parametrize("seed", range(6))
def test_prune_bit_identical_random(variant, seed):
    # prune_vertices directly: ops.prune_candidates would no-op these
    # small clouds (the 512 vertex-bucket floor cannot shrink them)
    verts, mask = _cloud(seed)
    v2, m2, info = prune.prune_vertices(verts, mask)
    a = np.asarray(
        dk.max_diameters_sq_pallas(
            verts, mask, block=64, variant=variant, interpret=True
        )
    )
    b = np.asarray(
        dk.max_diameters_sq_pallas(v2, m2, block=64, variant=variant, interpret=True)
    )
    assert np.array_equal(a, b), (info, a, b)


@pytest.mark.parametrize("seed", [100, 101, 102, 103, 340])
def test_prune_ulp_identical_ref_backend(seed):
    """The ref path is NOT bit-identical across pruning: XLA fuses its
    sweep shape-dependently, so results can move by ~1 ulp when M shrinks
    (seed 340 reproduces this).  The guarantee there is identity up to f32
    rounding of the same real quantity."""
    verts, mask = _cloud(seed)
    v2, m2, _ = ops.prune_candidates(verts, mask)
    a = np.asarray(ref_k.max_diameters_sq(verts, mask.astype(np.float32)))
    b = np.asarray(ref_k.max_diameters_sq(v2, m2.astype(np.float32)))
    np.testing.assert_allclose(b, a, rtol=1e-6)  # ~8 f32 ulp headroom


def test_prune_single_vertex():
    verts = np.full((16, 3), 2.0, np.float32)
    mask = np.zeros(16, bool)
    mask[5] = True
    v2, m2, info = prune.prune_vertices(verts, mask)
    assert not info.pruned and info.m_kept == 1
    got = np.asarray(dk.max_diameters_pallas(v2, m2, block=16, interpret=True))
    np.testing.assert_allclose(got, 0.0)


def test_prune_collinear():
    t = np.linspace(0.0, 9.0, 37, dtype=np.float32)
    verts = np.stack([t, 2.0 * t, -t], 1)
    mask = np.ones(len(t), bool)
    v2, m2, info = prune.prune_vertices(verts, mask)
    a = np.asarray(dk.max_diameters_sq_pallas(verts, mask, block=64, interpret=True))
    b = np.asarray(dk.max_diameters_sq_pallas(v2, m2, block=64, interpret=True))
    assert np.array_equal(a, b)
    assert info.m_kept <= info.m_valid


def test_prune_all_but_two():
    """Dense central cluster + two far endpoints: pruning must keep the
    endpoints (exactness) and drop essentially the whole cluster."""
    rng = np.random.default_rng(3)
    cluster = rng.normal(size=(500, 3)).astype(np.float32)  # radius ~ 1
    ends = np.array([[-100.0, 0.0, 0.0], [100.0, 0.0, 0.0]], np.float32)
    verts = np.concatenate([cluster, ends])
    mask = np.ones(len(verts), bool)
    v2, m2, info = prune.prune_vertices(verts, mask)
    assert info.pruned and info.m_kept < 20
    for variant in _VARIANTS:
        a = np.asarray(
            dk.max_diameters_sq_pallas(
                verts, mask, block=128, variant=variant, interpret=True
            )
        )
        b = np.asarray(
            dk.max_diameters_sq_pallas(
                v2, np.ones(len(v2), bool), block=128, variant=variant,
                interpret=True,
            )
        )
        assert np.array_equal(a, b)


def test_prune_shrinks_pair_flops_2x_on_blob():
    """Acceptance: >= 2x fewer pair-FLOPs at equal M on a blob-like set."""
    rng = np.random.default_rng(0)
    verts = (rng.normal(size=(1024, 3)) * [30.0, 10.0, 5.0]).astype(np.float32)
    mask = np.ones(1024, bool)
    v2, m2, info = ops.prune_candidates(verts, mask)
    assert info.pruned and info.m_kept < info.m_valid
    assert len(v2) == ops.vertex_bucket(info.m_kept) < 1024  # compacted
    full = dk.flop_estimate(1024, 256, "seqacc")
    pruned = dk.flop_estimate(ops.vertex_bucket(info.m_kept), 256, "seqacc")
    assert full >= 2.0 * pruned, (info, full, pruned)


# ---------------------------------------------------------------------------
# autotune: sweep once, cache to JSON, never re-sweep for the same bucket
# ---------------------------------------------------------------------------


def _force_autotune(monkeypatch, tmp_path):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    return path


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    from repro.runtime import autotune

    path = _force_autotune(monkeypatch, tmp_path)
    sweeps = []
    orig = autotune.sweep_diameter

    def counting(*a, **kw):
        sweeps.append(a)
        kw["variants"], kw["blocks"] = ("seqacc", "gram"), (128,)
        return orig(*a, **kw)

    monkeypatch.setattr(autotune, "sweep_diameter", counting)
    cfg1 = autotune.get_diameter_config(256, "interpret")
    assert len(sweeps) == 1
    cfg2 = autotune.get_diameter_config(256, "interpret")
    assert len(sweeps) == 1  # second call: pure cache read
    assert cfg1 == cfg2
    data = json.load(open(path))
    assert data["schema"] == autotune.SCHEMA_VERSION  # v2 envelope (PR 2)
    rec = data["entries"][autotune.sweep_key(256, "interpret")]
    assert rec["variant"] == cfg1.variant and rec["block"] == cfg1.block
    assert len(rec["table"]) == 2  # the restricted candidate sweep


def test_extractor_autotune_roundtrip(tmp_path, monkeypatch):
    """Acceptance: the second execute() with the same vertex bucket reads
    the cached (variant, block) without re-sweeping."""
    from repro.core.shape_features import ShapeFeatureExtractor
    from repro.runtime import autotune

    _force_autotune(monkeypatch, tmp_path)
    sweeps = []
    orig = autotune.sweep_diameter
    orig_mc = autotune.sweep_mc

    def counting(*a, **kw):
        sweeps.append(a)
        kw["variants"], kw["blocks"] = ("seqacc", "gram"), (256,)
        return orig(*a, **kw)

    def restricted_mc(*a, **kw):
        # mc_block='auto' sweeps too now; restrict it so this test stays
        # focused (and fast) on the diameter round-trip
        kw["blocks"], kw["chunks"] = ((8, 8, 8),), (512,)
        return orig_mc(*a, **kw)

    monkeypatch.setattr(autotune, "sweep_diameter", counting)
    monkeypatch.setattr(autotune, "sweep_mc", restricted_mc)
    img = np.zeros((12, 12, 12), np.float32)
    msk = sphere_mask(12, 4.0)
    f1 = ShapeFeatureExtractor(backend="interpret").execute(img, msk)
    n_after_first = len(sweeps)
    assert n_after_first >= 1
    f2 = ShapeFeatureExtractor(backend="interpret").execute(img, msk)
    assert len(sweeps) == n_after_first  # cache hit on the JSON file
    for k in f1:
        np.testing.assert_allclose(f1[k], f2[k], rtol=0, atol=0)


def test_autotune_disabled_returns_default(tmp_path, monkeypatch):
    from repro.runtime import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cfg = autotune.get_diameter_config(512, "interpret")
    assert cfg == autotune.DEFAULT_CONFIG
    assert not os.path.exists(str(tmp_path / "at.json"))  # nothing cached
