"""Pallas diameter kernel vs pure-jnp oracle: shape/dtype/variant sweeps."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import diameter, ref


def _brute(verts, mask):
    v = verts[mask.astype(bool)]
    if len(v) < 2:
        return np.zeros(4, np.float32)
    d = v[:, None, :] - v[None, :, :]
    q = d * d
    qx, qy, qz = q[..., 0], q[..., 1], q[..., 2]
    return np.sqrt(
        np.array(
            [
                (qx + qy + qz).max(),
                (qx + qy).max(),
                (qx + qz).max(),
                (qy + qz).max(),
            ]
        )
    )


@pytest.mark.parametrize("variant", diameter.VARIANTS)
@pytest.mark.parametrize("M,block", [(64, 64), (100, 64), (300, 128), (513, 256)])
def test_variants_match_bruteforce(variant, M, block):
    rng = np.random.default_rng(M + block)
    verts = rng.normal(size=(M, 3)).astype(np.float32) * [3.0, 7.0, 1.5]
    mask = rng.random(M) > 0.25
    want = _brute(verts, mask)
    got = np.asarray(
        diameter.max_diameters_pallas(
            verts, mask, block=block, variant=variant, interpret=True
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16])
def test_dtype_cast(dtype):
    rng = np.random.default_rng(0)
    verts = rng.normal(size=(130, 3)).astype(dtype)
    mask = np.ones(130, bool)
    got = np.asarray(
        diameter.max_diameters_pallas(verts, mask, block=128, interpret=True)
    )
    want = _brute(verts.astype(np.float32), mask)
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_ref_matches_bruteforce_blocked():
    rng = np.random.default_rng(1)
    verts = rng.normal(size=(777, 3)).astype(np.float32)
    mask = rng.random(777) > 0.5
    want = _brute(verts, mask)
    got = np.asarray(ref.max_diameters(jnp.asarray(verts), jnp.asarray(mask), row_block=64))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_all_masked_returns_zero():
    verts = np.zeros((64, 3), np.float32)
    mask = np.zeros(64, bool)
    got = np.asarray(diameter.max_diameters_pallas(verts, mask, block=64, interpret=True))
    np.testing.assert_allclose(got, 0.0)


def test_single_vertex_returns_zero():
    verts = np.full((64, 3), 5.0, np.float32)
    mask = np.zeros(64, bool)
    mask[3] = True
    got = np.asarray(diameter.max_diameters_pallas(verts, mask, block=64, interpret=True))
    np.testing.assert_allclose(got, 0.0)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(2, 90),
    st.integers(0, 2**31 - 1),
    st.sampled_from(["fused", "seqacc", "tri_prefetch"]),
)
def test_property_matches_bruteforce(m, seed, variant):
    rng = np.random.default_rng(seed)
    verts = (rng.random((m, 3)).astype(np.float32) - 0.5) * rng.integers(1, 100)
    mask = np.ones(m, bool)
    want = _brute(verts, mask)
    got = np.asarray(
        diameter.max_diameters_pallas(
            verts, mask, block=64, variant=variant, interpret=True
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flop_model_monotonic():
    f_full = diameter.flop_estimate(4096, 256, "fused")
    f_tri = diameter.flop_estimate(4096, 256, "tri")
    f_naive = diameter.flop_estimate(4096, 256, "naive")
    assert f_tri < f_full < f_naive
