"""Resilience layer lockdown: manifest, quarantine, retry, kill/resume.

The contracts under test (see runtime/resilience.py + core/executor.py):

* ``RunManifest``: content-hashed case identity, idempotent append,
  torn-tail repair on resume;
* quarantine: a poisoned / unloadable case degrades to a row-level NaN
  row + ``errors`` stats entry, the rest of the window bit-identical to
  a run without it, and the sync-free ``static``+``hint`` config stays
  at ZERO prep/pass-1 fetches with quarantined cases in the window;
* ``RetryPolicy``: a transient collect fault costs one backed-off
  re-submit (``resubmit_window``) and the retried rows are bit-identical
  to an undisturbed run; exhaustion re-raises;
* ``PreemptionHandler``: chains a pre-existing SIGTERM handler, restores
  it on uninstall, idempotent install;
* ``StragglerDetector``: warmup grace swallows the cold-compile outlier
  (it is neither flagged nor admitted to the median);
* THE acceptance criterion: a preempted + resumed run's manifest record
  set is bit-identical to an uninterrupted run's, with zero lost and
  zero duplicated ids, redoing at most one window of work.
"""
import functools
import json
import signal

import numpy as np
import pytest

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.runtime.fault_tolerance import PreemptionHandler, StragglerDetector
from repro.runtime.resilience import (
    COLLECT_STAGES,
    FEATURE_NAMES,
    FaultPlan,
    InjectedFault,
    ResilientRunner,
    RetryPolicy,
    RunManifest,
)

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # parity must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@functools.lru_cache(maxsize=None)
def _case(shape, seed):
    return make_case(shape, seed=seed)


def _poisoned(shape=(20, 18, 16), seed=3):
    img, msk, sp = _case(shape, seed)
    bad = np.asarray(msk, np.float32).copy()
    bad[tuple(d // 2 for d in shape)] = np.nan
    return img, bad, sp


def _nan_row(row):
    return np.isnan(np.asarray(row)).any()


# ---------------------------------------------------------------------------
# manifest: identity, idempotence, torn-tail repair
# ---------------------------------------------------------------------------


def test_case_id_is_content_sensitive():
    img, msk, sp = _case((20, 18, 16), 1)
    base = RunManifest.case_id(msk, sp)
    # pure function of content: same content -> same id
    assert RunManifest.case_id(msk.copy(), tuple(sp)) == base
    # one voxel flip, spacing change, dtype change: all new identities
    flipped = msk.copy()
    flipped[0, 0, 0] = 1.0 - flipped[0, 0, 0]
    assert RunManifest.case_id(flipped, sp) != base
    assert RunManifest.case_id(msk, (1.0, 1.0, 2.0)) != base
    assert RunManifest.case_id(msk.astype(np.float64), sp) != base
    # shape is hashed independently of the raw bytes
    assert RunManifest.case_id(msk.reshape(-1), sp) != base


def test_manifest_roundtrip_and_idempotence(tmp_path):
    p = tmp_path / "run.jsonl"
    man = RunManifest(p)
    assert man.resume() == set()
    feats = dict(zip(FEATURE_NAMES, map(float, range(7))))
    assert man.record("aaa", "done", name="c0", features=feats, window=0)
    assert man.record("bbb", "error", name="c1", error="boom", window=0)
    # idempotent: an id already committed is never written twice
    assert not man.record("aaa", "done", name="c0", features=feats, window=9)
    man.close()

    man2 = RunManifest(p)
    assert man2.resume() == {"aaa", "bbb"}
    rows = man2.rows()
    assert [r["id"] for r in rows] == ["aaa", "bbb"]  # first-written order
    assert rows[0]["status"] == "done" and rows[0]["features"] == feats
    assert rows[0]["window"] == 0  # the duplicate did not overwrite
    assert rows[1]["status"] == "error" and rows[1]["error"] == "boom"
    assert len(p.read_text().splitlines()) == 2


def test_manifest_torn_tail_repaired_on_resume(tmp_path):
    p = tmp_path / "run.jsonl"
    with RunManifest(p) as man:
        man.record("aaa", "done", features={})
        man.record("bbb", "done", features={})
    # a kill mid-write leaves an unterminated (or corrupt) final line
    with open(p, "ab") as f:
        f.write(b'{"id": "ccc", "status"')
    man2 = RunManifest(p)
    assert man2.resume() == {"aaa", "bbb"}
    # the torn bytes were truncated away: appends start on a clean line
    assert p.read_bytes().endswith(b"\n") and b"ccc" not in p.read_bytes()
    assert man2.record("ccc", "done", features={})
    assert RunManifest(p).resume() == {"aaa", "bbb", "ccc"}

    # a terminated-but-corrupt line also stops the replay at the tear
    with open(p, "ab") as f:
        f.write(b"not json at all\n")
        f.write(b'{"id": "ddd", "status": "done"}\n')
    assert RunManifest(p).resume() == {"aaa", "bbb", "ccc"}


def test_fault_plan_is_deterministic_per_index():
    def outcomes(fp):
        out = []
        for i in range(40):
            img, msk, sp = _case((20, 18, 16), 1)
            try:
                _, m2, _ = fp.inject_case(i, (img, msk, sp))
            except InjectedFault:
                out.append("load")
                continue
            m2 = np.asarray(m2)
            if np.issubdtype(m2.dtype, np.floating) and np.isnan(m2).any():
                out.append("nan")
            elif not m2.any():
                out.append("empty")
            else:
                out.append("ok")
        return out

    a = outcomes(FaultPlan(seed=7, load_error_rate=0.15, poison_nan_rate=0.15,
                           poison_empty_rate=0.1))
    b = outcomes(FaultPlan(seed=7, load_error_rate=0.15, poison_nan_rate=0.15,
                           poison_empty_rate=0.1))
    assert a == b
    assert {"load", "nan", "ok"} <= set(a)  # the rates actually fire


# ---------------------------------------------------------------------------
# quarantine: row-level errors through the executor, sync-free invariants
# ---------------------------------------------------------------------------


def test_poisoned_case_quarantines_row_level_and_sync_free():
    good = [_case((20, 18, 16), 1), _case((20, 18, 16), 2)]
    ext0 = BatchedExtractor(schedule="static", prep="hint")
    rows0, _ = ext0.run(good)

    ext = BatchedExtractor(schedule="static", prep="hint")
    rows, stats = ext.run([good[0], _poisoned(), good[1]])
    assert _nan_row(rows[1]) and not _nan_row(rows[0]) and not _nan_row(rows[2])
    assert stats["quarantined_cases"] == 1
    assert "non-finite" in stats["errors"][1]
    # the healthy cases are bit-identical to a run without the poison
    np.testing.assert_array_equal(rows[0], rows0[0])
    np.testing.assert_array_equal(rows[2], rows0[1])
    # quarantine is pure host work: the sync-free submit invariants hold
    assert ext.executor.transfer_log["prep"] == 0
    assert ext.executor.transfer_log["pass1"] == 0


def test_loader_error_quarantines_in_stream():
    good = [_case((20, 18, 16), 1), _case((20, 18, 16), 2)]
    ext0 = BatchedExtractor(schedule="static", prep="hint")
    rows0, _ = ext0.run(good)

    def dead_loader():
        raise OSError("NFS mount went away")

    ext = BatchedExtractor(schedule="static", prep="hint")
    rows = list(ext.extract_stream([good[0], dead_loader, good[1]], window=2))
    assert len(rows) == 3 and _nan_row(rows[1])
    np.testing.assert_array_equal(rows[0], rows0[0])
    np.testing.assert_array_equal(rows[2], rows0[1])


def test_invalid_spacing_quarantines():
    img, msk, _ = _case((20, 18, 16), 1)
    ext = BatchedExtractor(schedule="static", prep="hint")
    rows, stats = ext.run([(img, msk, (1.0, -1.0, 1.0))])
    assert _nan_row(rows[0]) and "spacing" in stats["errors"][0]


# ---------------------------------------------------------------------------
# retry: transient collect faults re-submit bit-identically
# ---------------------------------------------------------------------------


def test_window_retry_is_bit_identical():
    cases = [_case((20, 18, 16), s) for s in (1, 2, 4)]
    ext0 = BatchedExtractor(schedule="static", prep="hint")
    rows0, _ = ext0.run(cases)

    fp = FaultPlan(seed=0, fail_windows=(0,))
    fp.begin_window(0)  # arm the one-shot collect fault
    ext = BatchedExtractor(
        schedule="static", prep="hint", transfer_callback=fp.transfer_hook,
        retry=RetryPolicy(max_retries=2, base_delay=0.001),
    )
    rows, stats = ext.run(cases)
    assert ext.executor.window_retries == 1
    assert stats["window_retries"] == 1
    for r, r0 in zip(rows, rows0):
        np.testing.assert_array_equal(r, r0)


def test_retry_exhaustion_reraises():
    def always_fail(stage, x):
        if stage in COLLECT_STAGES:
            raise InjectedFault(f"permanent fault at {stage}")

    ext = BatchedExtractor(
        schedule="static", prep="hint", transfer_callback=always_fail,
        retry=RetryPolicy(max_retries=1, base_delay=0.001),
    )
    with pytest.raises(InjectedFault, match="permanent"):
        ext.run([_case((20, 18, 16), 1)])
    assert ext.executor.window_retries == 1


def test_retry_policy_backoff_schedule():
    p = RetryPolicy(base_delay=0.1, multiplier=3.0, max_delay=0.5)
    assert p.delay(0) == pytest.approx(0.1)
    assert p.delay(1) == pytest.approx(0.3)
    assert p.delay(2) == pytest.approx(0.5)  # capped


# ---------------------------------------------------------------------------
# fault_tolerance: handler chaining, straggler warmup
# ---------------------------------------------------------------------------


def test_preemption_handler_chains_and_restores():
    calls = []
    original = signal.getsignal(signal.SIGTERM)
    try:
        def outer(signum, frame):
            calls.append(signum)

        signal.signal(signal.SIGTERM, outer)
        h = PreemptionHandler().install()
        installed = signal.getsignal(signal.SIGTERM)
        assert installed is not outer
        h.install()  # idempotent: no self-chaining
        assert signal.getsignal(signal.SIGTERM) is installed

        installed(signal.SIGTERM, None)
        assert h.requested and calls == [signal.SIGTERM]  # chained through

        h.reset()
        assert not h.requested
        h.uninstall()
        assert signal.getsignal(signal.SIGTERM) is outer  # restored exactly
        h.uninstall()  # idempotent no-op
        assert signal.getsignal(signal.SIGTERM) is outer
    finally:
        signal.signal(signal.SIGTERM, original)


def test_straggler_warmup_swallows_cold_compile():
    det = StragglerDetector(window=8, threshold=2.0, warmup=1, min_samples=2)
    # the cold-compile outlier: not flagged AND kept out of the median
    assert not det.observe(0, 10.0)
    for i in range(1, 5):
        assert not det.observe(i, 0.1)
    assert det.median == pytest.approx(0.1)
    assert det.observe(5, 1.0)  # a real straggler still trips
    # default construction keeps the legacy contract (no warmup)
    legacy = StragglerDetector(window=8, threshold=2.0)
    assert legacy.warmup == 0 and legacy.min_samples is None


# ---------------------------------------------------------------------------
# THE acceptance test: kill mid-stream, resume, compare manifests
# ---------------------------------------------------------------------------


def _cases(n):
    out = []
    for i in range(n):
        if i == 5:  # one poisoned case rides along mid-stream
            out.append((f"case-{i:03d}",) + _poisoned(seed=50))
        else:
            out.append((f"case-{i:03d}",) + _case((20, 18, 16), 10 + i))
    return out


def _strip(rows):
    # window ordinals restart on resume; everything else must match exactly
    return sorted(
        [{k: v for k, v in r.items() if k != "window"} for r in rows],
        key=lambda r: r["id"],
    )


def test_preempt_resume_manifest_bit_identical(tmp_path):
    n, window = 10, 4
    cases = _cases(n)

    # uninterrupted reference run
    man_a = RunManifest(tmp_path / "a.jsonl")
    rep_a = ResilientRunner(
        BatchedExtractor(schedule="static", prep="hint"), man_a, window=window
    ).run(cases)
    assert rep_a.status == "complete" and rep_a.processed == n
    assert rep_a.quarantined == 1  # the poisoned case, as an error row
    windows_a = rep_a.windows

    # preempted run: a REAL SIGTERM lands at case 9; drain_on_preempt=False
    # models a hard kill -- the submitted in-flight window is abandoned
    man_b = RunManifest(tmp_path / "b.jsonl")
    ext1 = BatchedExtractor(schedule="static", prep="hint")
    rep1 = ResilientRunner(
        ext1, man_b, window=window,
        fault_plan=FaultPlan(preempt_at_case=9), drain_on_preempt=False,
    ).run(cases)
    assert rep1.status == "preempted"
    assert 0 < rep1.processed < n  # partial progress committed
    man_b.close()

    # resume into the same manifest (fresh process would do exactly this)
    man_b2 = RunManifest(tmp_path / "b.jsonl")
    ext2 = BatchedExtractor(schedule="static", prep="hint")
    rep2 = ResilientRunner(ext2, man_b2, window=window).run(cases)
    assert rep2.status == "complete"
    assert rep2.skipped == rep1.processed  # the done-set skip
    # quarantine + resume are pure host work: sync-free invariants hold
    assert ext2.executor.transfer_log["prep"] == 0
    assert ext2.executor.transfer_log["pass1"] == 0

    # zero lost, zero duplicated ids
    assert rep1.processed + rep2.processed == n
    ids = [r["id"] for r in man_b2.rows()]
    assert len(ids) == n == len(set(ids))

    # at most ONE window of work is redone after the kill
    assert rep1.windows + rep2.windows <= windows_a + 1

    # record set bit-identical to the uninterrupted run's
    assert _strip(man_b2.rows()) == _strip(RunManifest(tmp_path / "a.jsonl")
                                           .__enter__().rows())
    errs = [r for r in man_b2.rows() if r["status"] == "error"]
    assert [e["name"] for e in errs] == ["case-005"]


def test_resilient_runner_load_error_quarantined_and_stable(tmp_path):
    cases = _cases(4)

    def dead():
        raise OSError("gone")

    cases[2] = ("case-002", dead)
    man = RunManifest(tmp_path / "m.jsonl")
    rep = ResilientRunner(
        BatchedExtractor(schedule="static", prep="hint"), man, window=2
    ).run(cases)
    assert rep.processed == 4 and rep.quarantined == 1
    err = [r for r in man.rows() if r["status"] == "error"]
    # STABLE id: keyed by the case NAME, not by its stream position
    assert len(err) == 1 and err[0]["id"] == "load-error:case-002"
    # a second pass re-quarantines idempotently (same id -> skip)
    rep2 = ResilientRunner(
        BatchedExtractor(schedule="static", prep="hint"), man, window=2
    ).run(cases)
    assert rep2.processed == 0 and rep2.skipped == 4


def test_resume_after_load_error_over_filtered_stream(tmp_path):
    """A resume that filters/reorders the stream must not double-count a
    load-error case: its quarantine id is name-keyed, not position-keyed
    (the old ``name@index`` id changed whenever earlier cases were
    filtered out, so the same failing case was recorded twice)."""
    cases = _cases(5)

    def dead():
        raise OSError("gone")

    cases[3] = ("case-003", dead)
    man = RunManifest(tmp_path / "m.jsonl")
    rep = ResilientRunner(
        BatchedExtractor(schedule="static", prep="hint"), man, window=2
    ).run(cases)
    assert rep.processed == 5 and rep.quarantined == 1
    man.close()

    # resume over a FILTERED + REORDERED stream: done cases dropped, the
    # failing case now at stream index 0 (it was at index 3)
    man2 = RunManifest(tmp_path / "m.jsonl")
    rep2 = ResilientRunner(
        BatchedExtractor(schedule="static", prep="hint"), man2, window=2
    ).run([cases[3], cases[4], cases[1]])
    assert rep2.processed == 0 and rep2.skipped == 3  # nothing re-recorded
    ids = [r["id"] for r in man2.rows()]
    assert len(ids) == 5 == len(set(ids))  # zero lost, zero duplicated


class _PartialNaNExecutor:
    """Fake executor whose window contains a LEGIT row with a NaN feature
    (tag 7 in the mask corner) next to a truly quarantined case (tag 9,
    all-NaN row + an ``errors`` entry) -- the discriminating fixture for
    the errors-map-vs-NaN-sniffing contract.  No real feature pipeline
    produces a partial-NaN legit row (GLCM defines the zero-variance
    correlation as 1.0), hence the fabrication."""

    n_features = 7
    prune = True

    def prep_case(self, case):
        return case

    def submit_prepped(self, prepped):
        return list(prepped)

    def collect_window(self, window):
        rows, errors = [], {}
        for j, (img, msk, sp) in enumerate(window):
            tag = float(np.asarray(msk)[0, 0, 0])
            if tag == 9.0:
                rows.append(np.full(7, np.nan, np.float32))
                errors[j] = "ValueError: poisoned"
            elif tag == 7.0:
                row = np.arange(7, dtype=np.float32)
                row[3] = np.nan  # a NaN VALUE in an otherwise-good row
                rows.append(row)
            else:
                rows.append(np.full(7, float(j), np.float32))
        return rows, {"errors": errors}


def test_partial_nan_legit_row_not_misrecorded_as_quarantined(tmp_path):
    """Quarantine must key off the executor's ``stats['errors']`` map; a
    legitimate feature row that happens to CONTAIN a NaN value is
    ``done``, not ``error`` (this fails on NaN-sniffing ``_collect``)."""
    def tagged(tag, fill):
        msk = np.full((4, 4, 4), fill, np.float32)
        msk[0, 0, 0] = tag
        return np.zeros((4, 4, 4), np.float32), msk, (1.0, 1.0, 1.0)

    cases = [("plain",) + tagged(0, 1), ("nan-feature",) + tagged(7, 2),
             ("poisoned",) + tagged(9, 3)]
    man = RunManifest(tmp_path / "m.jsonl")
    rep = ResilientRunner(_PartialNaNExecutor(), man, window=3).run(cases)
    assert rep.processed == 3
    assert rep.quarantined == 1  # ONLY the case with an errors entry
    by_name = {r["name"]: r for r in man.rows()}
    assert by_name["poisoned"]["status"] == "error"
    assert by_name["poisoned"]["error"] == "ValueError: poisoned"
    assert by_name["plain"]["status"] == "done"
    rec = by_name["nan-feature"]
    assert rec["status"] == "done"  # NaN value does not imply quarantine
    feats = list(rec["features"].values())
    assert np.isnan(feats[3]) and not np.isnan(feats[2])


def test_stream_cases_skip_yields_promised_count():
    from repro.data.synthetic import stream_cases

    full = list(stream_cases(6, seed=3))
    out = list(stream_cases(6, seed=3,
                            skip={"case-00001", "case-00003"}))
    assert len(out) == 6  # the promised count, not 4
    assert [n for n, *_ in out] == [
        "case-00000", "case-00002", "case-00004",
        "case-00005", "case-00006", "case-00007",
    ]
    # surviving cases stay content-identical to the unskipped stream
    by_name = {n: (img, msk) for n, img, msk, _ in full}
    for n, img, msk, _ in out:
        if n in by_name:
            np.testing.assert_array_equal(img, by_name[n][0])
            np.testing.assert_array_equal(msk, by_name[n][1])


def test_runner_rejects_non_integer_window(tmp_path):
    with pytest.raises(ValueError, match="window"):
        ResilientRunner(object(), RunManifest(tmp_path / "x.jsonl"),
                        window="auto")


def test_manifest_record_json_is_line_atomic(tmp_path):
    # each record is exactly one line of valid JSON, sorted keys
    man = RunManifest(tmp_path / "m.jsonl")
    man.record("x", "done", features={"MeshVolume": 1.5}, window=3)
    man.close()
    (line,) = (tmp_path / "m.jsonl").read_text().splitlines()
    rec = json.loads(line)
    assert list(rec) == sorted(rec)
    assert rec == {"id": "x", "status": "done",
                   "features": {"MeshVolume": 1.5}, "window": 3}
