"""Feature-level tests: PyRadiomics-compatible outputs + backend equivalence.

The paper's central correctness claim: the accelerated backend produces
"output with identical quality to the original PyRadiomics" -- here, the
Pallas (interpret) backend must match the reference backend feature-for-
feature.
"""
import numpy as np
import pytest

from repro.core import ShapeFeatureExtractor, crop_to_roi
from repro.data import synthetic
from conftest import sphere_mask, box_mask

pytestmark = pytest.mark.tier1

KEYS = [
    "MeshVolume", "VoxelVolume", "SurfaceArea", "SurfaceVolumeRatio",
    "Sphericity", "Compactness1", "Compactness2", "SphericalDisproportion",
    "Maximum3DDiameter", "Maximum2DDiameterSlice", "Maximum2DDiameterColumn",
    "Maximum2DDiameterRow", "MajorAxisLength", "MinorAxisLength",
    "LeastAxisLength", "Elongation", "Flatness",
]


@pytest.fixture(scope="module")
def case():
    return synthetic.make_case((48, 40, 36), seed=11)


def test_feature_keys_present(case):
    img, msk, sp = case
    feats = ShapeFeatureExtractor(backend="ref").execute(img, msk, sp)
    for k in KEYS:
        assert k in feats and np.isfinite(feats[k]), k


def test_backend_equivalence(case):
    """ref CPU path == Pallas kernels (interpret mode), feature-for-feature."""
    img, msk, sp = case
    a = ShapeFeatureExtractor(backend="ref").execute(img, msk, sp)
    b = ShapeFeatureExtractor(backend="interpret").execute(img, msk, sp)
    for k in KEYS:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-4, err_msg=k)


def test_sphere_features():
    r = 10.0
    msk = sphere_mask(26, r).astype(bool)
    img = msk.astype(np.float32) * 100.0
    f = ShapeFeatureExtractor(backend="ref").execute(img, msk, (1.0, 1.0, 1.0))
    assert abs(f["MeshVolume"] / (4 / 3 * np.pi * r**3) - 1) < 0.02
    assert abs(f["Maximum3DDiameter"] - (2 * r + 1)) < 1.0
    assert f["Sphericity"] > 0.85  # staircase area lowers it below 1.0
    assert abs(f["Elongation"] - 1.0) < 0.05
    assert abs(f["Flatness"] - 1.0) < 0.05


def test_anisotropic_spacing_scales_features():
    msk = sphere_mask(20, 6.0).astype(bool)
    img = msk.astype(np.float32)
    f1 = ShapeFeatureExtractor(backend="ref").execute(img, msk, (1.0, 1.0, 1.0))
    f2 = ShapeFeatureExtractor(backend="ref").execute(img, msk, (2.0, 2.0, 2.0))
    np.testing.assert_allclose(f2["MeshVolume"], 8 * f1["MeshVolume"], rtol=1e-4)
    np.testing.assert_allclose(f2["SurfaceArea"], 4 * f1["SurfaceArea"], rtol=1e-4)
    np.testing.assert_allclose(f2["Maximum3DDiameter"], 2 * f1["Maximum3DDiameter"], rtol=1e-4)


def test_elongated_box_axes():
    msk = box_mask((40, 14, 8), (2, 2, 2), (38, 12, 6)).astype(bool)
    img = msk.astype(np.float32)
    f = ShapeFeatureExtractor(backend="ref").execute(img, msk)
    assert f["MajorAxisLength"] > f["MinorAxisLength"] > f["LeastAxisLength"]
    assert f["Elongation"] < 0.5
    assert f["Flatness"] < 0.25
    # max 3D diameter: between the voxel-centre diagonal and the padded
    # diagonal (MC chamfers the corners, trimming the +0.5 overhang)
    lo = np.sqrt(35.0**2 + 9.0**2 + 3.0**2)
    hi = np.sqrt(37.0**2 + 11.0**2 + 5.0**2)
    assert lo <= f["Maximum3DDiameter"] <= hi


def test_crop_to_roi():
    msk = np.zeros((30, 30, 30), bool)
    msk[10:14, 12:20, 5:6] = True
    img = np.ones_like(msk, np.float32)
    im, m, lo = crop_to_roi(img, msk)
    assert m.shape == (4 + 2, 8 + 2, 1 + 2)
    assert lo == [10, 12, 5]
    assert m.sum() == msk.sum()


def test_empty_mask_raises():
    with pytest.raises(ValueError):
        crop_to_roi(np.zeros((5, 5, 5)), np.zeros((5, 5, 5), bool))


def test_stage_times_reported(case):
    img, msk, sp = case
    feats, times = ShapeFeatureExtractor(backend="ref").execute(
        img, msk, sp, with_times=True
    )
    assert times.total_ms > 0
    assert times.mesh_ms > 0 and times.diameter_ms > 0
