"""Out-of-core tiled extraction: parity, pruning, halo and routing gates.

Tier-1 contract (ROADMAP "Out-of-core tiling"): on any case both paths
can run, the tiled engine's row is bit-identical to the in-core
``extract_one`` oracle -- for every tile size (budget), for
``tile_prune`` in {'none', 'occupancy'} on every backend, and for
'bounds' on the gram-kernel backends; 'bounds' on the ref backend may
move only the diameters, within f32 rounding (the same contract vertex
pruning already has).  The suite also locks the slab-source contracts,
the routing facade (``tiled=`` / ``TiledCase``), and the budget
accounting the out-of-core claim rests on.
"""
import os
import warnings

import numpy as np
import pytest

from repro.core.executor import PlanExecutor
from repro.core.pipeline import BatchedExtractor
from repro.core.tiled import TiledExtractor, tile_budget_bytes
from repro.data.nifti import write_nifti
from repro.data.tiles import (
    ArraySlabSource,
    FnSlabSource,
    NiftiSlabSource,
    TiledCase,
)

pytestmark = pytest.mark.tier1

SP = np.asarray([1.0, 1.25, 0.75], np.float32)


def _ellipsoid(shape=(40, 44, 57), radii=(12, 15, 20), seed=0):
    X, Y, Z = shape
    xs, ys, zs = np.meshgrid(np.arange(X), np.arange(Y), np.arange(Z),
                             indexing="ij")
    c = (X / 2, Y / 2, Z / 2)
    r2 = (((xs - c[0]) / radii[0]) ** 2 + ((ys - c[1]) / radii[1]) ** 2
          + ((zs - c[2]) / radii[2]) ** 2)
    mask = (r2 < 1.0).astype(np.float32)
    image = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    return image, mask


def _two_blob(shape=(36, 40, 180)):
    """Sparse mask: blobs at the z extremes, a long empty middle."""
    X, Y, Z = shape
    mask = np.zeros(shape, np.float32)
    xs, ys, zs = np.meshgrid(np.arange(X), np.arange(Y), np.arange(Z),
                             indexing="ij")
    for cx, cy, cz, rx, ry, rz in ((18, 20, 15, 8, 9, 10),
                                   (16, 18, 165, 7, 8, 9)):
        r2 = (((xs - cx) / rx) ** 2 + ((ys - cy) / ry) ** 2
              + ((zs - cz) / rz) ** 2)
        mask[r2 < 1.0] = 1.0
    image = np.random.default_rng(1).normal(size=shape).astype(np.float32)
    return image, mask


def _tiled_row(ex, image, mask, budget, prune="occupancy", spacing=SP):
    tx = TiledExtractor(ex, budget_bytes=budget, tile_prune=prune)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return tx.extract(TiledCase(mask, image=image, spacing=spacing))


# -- bit-parity across tile sizes and prune levels --------------------------


@pytest.mark.parametrize("budget", [1 << 30, 200_000, 60_000])
@pytest.mark.parametrize("prune", ["none", "occupancy"])
def test_ref_bitwise_across_tile_sizes(budget, prune):
    image, mask = _ellipsoid()
    ex = PlanExecutor(backend="ref", families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)
    res = _tiled_row(ex, image, mask, budget, prune)
    np.testing.assert_array_equal(oracle, res.row)


def test_ref_bounds_allclose_and_exact_nonshape_columns():
    image, mask = _two_blob()
    ex = PlanExecutor(backend="ref", families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)
    res = _tiled_row(ex, image, mask, 400_000, "bounds")
    # ref diameter path is shape-dependent in the candidate count: the
    # bounds level may move the 4 diameter columns within f32 rounding
    np.testing.assert_allclose(oracle, res.row, rtol=1e-5, atol=1e-5)
    d = slice(2, 6)
    np.testing.assert_array_equal(oracle[:2], res.row[:2])   # MC vol/area
    np.testing.assert_array_equal(oracle[6:], res.row[6:])   # count + fo


def test_interpret_backend_bitwise_incl_bounds():
    image, mask = _two_blob()
    ex = PlanExecutor(backend="interpret", families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)
    for prune in ("none", "occupancy", "bounds"):
        res = _tiled_row(ex, image, mask, 400_000, prune)
        np.testing.assert_array_equal(oracle, res.row)


def test_halo_straddling_mask_bitwise():
    # a rod spanning z, so every internal tile boundary cuts through the
    # surface and correctness rides on the halo planes
    mask = np.zeros((24, 24, 130), np.float32)
    mask[8:14, 9:15, 10:120] = 1.0
    image = np.random.default_rng(3).normal(size=mask.shape).astype(np.float32)
    ex = PlanExecutor(backend="ref", families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)
    for budget in (300_000, 150_000):
        res = _tiled_row(ex, image, mask, budget, "occupancy")
        assert res.stats["tiles"] > 1
        np.testing.assert_array_equal(oracle, res.row)


def test_occupancy_skips_without_dropping_vertices():
    image, mask = _two_blob()
    ex = PlanExecutor(backend="ref")
    oracle = ex.extract_one(None, mask, SP)
    res = _tiled_row(ex, image, mask, 400_000, "occupancy")
    assert res.stats["tiles_skipped"] > 0          # middle tiles skipped
    assert res.stats["emitted_vertices"] == res.meta.n_vertices
    assert res.row[6] == oracle[6]                 # global vertex count
    np.testing.assert_array_equal(oracle, res.row)


def test_bounds_prunes_interior_tile_keeps_count_exact():
    # two wide plates at the z extremes (the farthest-pair endpoints for
    # every combo) and a small centred dot between them: the dot's tile
    # is occupied but provably endpoint-free
    mask = np.zeros((36, 36, 170), np.float32)
    mask[4:32, 4:32, 4:8] = 1.0
    mask[4:32, 4:32, 162:166] = 1.0
    mask[16:19, 16:19, 80:83] = 1.0
    ex = PlanExecutor(backend="ref")
    oracle = ex.extract_one(None, mask, SP)
    res = _tiled_row(ex, None, mask, 300_000, "bounds", spacing=SP)
    assert res.stats["tiles_bounds_pruned"] >= 1
    assert res.stats["emitted_vertices"] < res.meta.n_vertices
    np.testing.assert_allclose(oracle, res.row, rtol=1e-5, atol=1e-5)
    assert res.row[6] == oracle[6]                 # n_vertices stays global
    # the gram-kernel backends stay fully bitwise under bounds pruning
    exi = PlanExecutor(backend="interpret")
    res_i = _tiled_row(exi, None, mask, 300_000, "bounds", spacing=SP)
    np.testing.assert_array_equal(exi.extract_one(None, mask, SP), res_i.row)


@pytest.mark.parametrize("prune", ["none", "occupancy", "bounds"])
def test_degenerate_one_voxel_and_empty(prune):
    ex = PlanExecutor(backend="ref", families=["shape", "firstorder"])
    one = np.zeros((20, 20, 40), np.float32)
    one[10, 11, 21] = 1.0
    img = np.random.default_rng(4).normal(size=one.shape).astype(np.float32)
    oracle = ex.extract_one(img, one, SP)
    res = _tiled_row(ex, img, one, 1 << 30, prune)
    np.testing.assert_array_equal(oracle, res.row)

    empty = np.zeros((16, 16, 40), np.float32)
    res_e = _tiled_row(ex, img[:16, :16, :], empty, 1 << 30, prune)
    np.testing.assert_array_equal(
        ex.extract_one(img[:16, :16, :], empty, SP), res_e.row)
    assert res_e.meta.empty


def test_ref_mc_chunk_lever_parity():
    # mc_chunk on the ref backend shrinks the scan granule (the tiled
    # engine's plane budget lever); tiled and in-core agree bitwise at
    # the same setting
    image, mask = _ellipsoid(shape=(30, 30, 66), radii=(10, 10, 25))
    ex = PlanExecutor(backend="ref", mc_chunk=4,
                      families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)
    res = _tiled_row(ex, image, mask, 120_000, "occupancy")
    assert res.stats["granule_cz"] == 4
    assert res.stats["tiles"] > 2
    np.testing.assert_array_equal(oracle, res.row)


# -- engine guards -----------------------------------------------------------


def test_glcm_and_missing_image_rejected():
    ex = PlanExecutor(backend="ref", families=["shape", "glcm"])
    with pytest.raises(ValueError, match="glcm"):
        TiledExtractor(ex)
    exf = PlanExecutor(backend="ref", families=["firstorder"])
    tx = TiledExtractor(exf, budget_bytes=1 << 30)
    mask = np.zeros((8, 8, 8), np.float32)
    mask[3:5, 3:5, 3:5] = 1.0
    with pytest.raises(ValueError, match="image source"):
        tx.extract(TiledCase(mask, spacing=SP))
    with pytest.raises(ValueError, match="tile_prune"):
        TiledExtractor(PlanExecutor(backend="ref"), tile_prune="bogus")


def test_budget_accounting_and_env_default(monkeypatch):
    image, mask = _ellipsoid()
    ex = PlanExecutor(backend="ref")
    res = _tiled_row(ex, None, mask, 200_000, "occupancy")
    assert res.stats["staged_bytes_peak"] == 2 * res.stats["tile_bytes"]
    monkeypatch.setenv("REPRO_TILE_MEM_MB", "64")
    assert tile_budget_bytes() == 64 * 2**20
    tx = TiledExtractor(ex)
    assert tx.budget_bytes == 64 * 2**20


def test_over_budget_minimum_tile_warns():
    mask = np.zeros((40, 44, 57), np.float32)
    mask[4:36, 4:40, 4:53] = 1.0
    ex = PlanExecutor(backend="ref")
    tx = TiledExtractor(ex, budget_bytes=10_000, tile_prune="occupancy")
    with pytest.warns(RuntimeWarning, match="cannot hold two minimal"):
        tx.extract(TiledCase(mask, spacing=SP))


# -- slab sources ------------------------------------------------------------


def test_array_and_fn_sources_agree(tmp_path):
    image, mask = _ellipsoid(shape=(26, 28, 44), radii=(8, 9, 15))
    ex = PlanExecutor(backend="ref", families=["shape", "firstorder"])
    oracle = ex.extract_one(image, mask, SP)

    fn_case = TiledCase(
        FnSlabSource(lambda z0, z1: mask[:, :, z0:z1], mask.shape),
        image=FnSlabSource(lambda z0, z1: image[:, :, z0:z1], image.shape),
        spacing=SP,
    )
    tx = TiledExtractor(ex, budget_bytes=150_000, tile_prune="occupancy")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        np.testing.assert_array_equal(oracle, tx.extract(fn_case).row)

    mp, ip = tmp_path / "mask.nii", tmp_path / "img.nii"
    write_nifti(mp, mask, SP)
    write_nifti(ip, image, SP)
    nifti_case = TiledCase(NiftiSlabSource(mp), image=NiftiSlabSource(ip))
    np.testing.assert_allclose(nifti_case.spacing, SP, rtol=1e-6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        np.testing.assert_array_equal(oracle, tx.extract(nifti_case).row)

    img2, msk2, sp2 = nifti_case.materialize()
    np.testing.assert_array_equal(msk2, mask)
    np.testing.assert_array_equal(img2, image)


def test_fn_source_shape_validated():
    src = FnSlabSource(lambda z0, z1: np.zeros((4, 4, z1 - z0 + 1)), (4, 4, 8))
    with pytest.raises(ValueError, match="slab fn returned shape"):
        src.read(0, 2)
    with pytest.raises(ValueError, match="3D"):
        ArraySlabSource(np.zeros((4, 4)))


def test_gz_slab_source_rejected_with_workaround(tmp_path):
    mask = np.zeros((6, 6, 6), np.float32)
    mask[2:4, 2:4, 2:4] = 1.0
    p = tmp_path / "m.nii.gz"
    write_nifti(p, mask, SP)
    with pytest.raises(ValueError, match="gunzip"):
        NiftiSlabSource(p)


# -- routing facade ----------------------------------------------------------


def test_run_merges_tiled_rows_in_order():
    image, mask = _ellipsoid(shape=(26, 28, 44), radii=(8, 9, 15))
    small = [(image, mask, SP)] * 2
    big_img, big_mask = _two_blob()
    bx = BatchedExtractor(backend="ref", families=["shape", "firstorder"],
                          tiled=True, tile_mem_mb=0.4)
    cases = [small[0], (big_img, big_mask, SP), small[1],
             TiledCase(big_mask, image=big_img, spacing=SP)]
    oracle = [bx.extract_one(*c) for c in cases[:3]]
    oracle.append(bx.extract_one(big_img, big_mask, SP))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rows, stats = bx.run(cases)
    assert stats["tiled"]["cases"] == 2
    assert stats["tiled"]["census"].cases == 2
    assert stats["tiled"]["tiles_skipped"] > 0
    for a, b in zip(oracle, rows):
        np.testing.assert_array_equal(a, b)


def test_stream_handles_tiled_cases_between_segments():
    image, mask = _ellipsoid(shape=(26, 28, 44), radii=(8, 9, 15))
    big_img, big_mask = _two_blob()
    bx = BatchedExtractor(backend="ref", families=["shape", "firstorder"])
    cases = [(image, mask, SP), (image, mask, SP),
             TiledCase(big_mask, image=big_img, spacing=SP),
             (image, mask, SP)]
    oracle = ([bx.extract_one(image, mask, SP)] * 2
              + [bx.extract_one(big_img, big_mask, SP)]
              + [bx.extract_one(image, mask, SP)])
    rows = list(bx.extract_stream(iter(cases), window=2))
    assert len(rows) == 4
    for a, b in zip(oracle, rows):
        np.testing.assert_array_equal(a, b)


def test_default_extractor_leaves_tuples_incore():
    image, mask = _ellipsoid(shape=(26, 28, 44), radii=(8, 9, 15))
    bx = BatchedExtractor(backend="ref")
    assert not bx._route_tiled((image, mask, SP))
    assert bx._route_tiled(TiledCase(mask, spacing=SP))
    bxt = BatchedExtractor(backend="ref", tiled=True, tile_mem_mb=0.01)
    assert bxt._route_tiled((image, mask, SP))


# -- the out-of-core acceptance case ----------------------------------------


def test_out_of_core_sphere_under_budget():
    # 160^3 analytic sphere: 16 MiB materialized (mask alone), run under
    # a 1 MiB staged budget with the ref mc_chunk granule lever -- the
    # same configuration the 1024^3 demo scales up (REPRO_TILED_BIG=1)
    N = 160

    def sphere(z0, z1):
        ax = ((np.arange(N) - N / 2) / (N * 0.42)) ** 2
        az = ((np.arange(z0, z1) - N / 2) / (N * 0.42)) ** 2
        return (ax[:, None, None] + ax[None, :, None]
                + az[None, None, :] < 1.0).astype(np.float32)

    ex = PlanExecutor(backend="ref", mc_chunk=4)
    tx = TiledExtractor(ex, budget_bytes=1 << 20, tile_prune="bounds")
    res = tx.extract(TiledCase(FnSlabSource(sphere, (N, N, N))))
    assert res.stats["staged_bytes_peak"] <= 1 << 20
    assert 4 * N ** 3 / res.stats["staged_bytes_peak"] >= 16
    r = N * 0.42
    assert res.row[0] == pytest.approx(4 / 3 * np.pi * r**3, rel=0.01)
    # MC over a binary mask overestimates a smooth sphere's area by the
    # usual ~8% staircase bias; gate loosely, the parity tests do the
    # exactness work
    assert res.row[1] == pytest.approx(4 * np.pi * r**2, rel=0.12)
    assert res.row[2] == pytest.approx(2 * r, rel=0.02)


@pytest.mark.skipif(os.environ.get("REPRO_TILED_BIG") != "1",
                    reason="1024^3 demo: set REPRO_TILED_BIG=1 (minutes)")
def test_gib_scale_volume_streams_under_64x_budget():
    # the ISSUE acceptance case: a 1024^3 synthetic (4 GiB materialized)
    # through the tiled path under a budget >= 64x smaller
    N = 1024

    def sphere(z0, z1):
        ax = ((np.arange(N) - N / 2) / (N * 0.45)) ** 2
        az = ((np.arange(z0, z1) - N / 2) / (N * 0.45)) ** 2
        return (ax[:, None, None] + ax[None, :, None]
                + az[None, None, :] < 1.0).astype(np.float32)

    budget = (4 * N ** 3) // 64  # 64 MiB
    ex = PlanExecutor(backend="ref", mc_chunk=4)
    tx = TiledExtractor(ex, budget_bytes=budget, tile_prune="bounds")
    res = tx.extract(TiledCase(FnSlabSource(sphere, (N, N, N))))
    assert res.stats["staged_bytes_peak"] <= budget
    r = N * 0.45
    assert res.row[0] == pytest.approx(4 / 3 * np.pi * r**3, rel=0.005)
    assert res.row[2] == pytest.approx(2 * r, rel=0.01)
