"""Cost-model-driven scheduling lockdown: hint prep, auto windows, auto schedule.

The contracts under test (see runtime/costmodel.py + core/executor.py):

* ``prep='hint'`` == ``prep='count'`` bit-identically on ref + interpret,
  with ZERO per-case pass-0 host syncs (``transfer_log``-asserted), and
  a FORCED hint-overflow case resolves through the count-sized retry to
  the same bits;
* ``window='auto'`` == any fixed window bit-identically, and a census
  fragmentation case (new shape bucket arriving at a window whose
  sub-batches are all past break-even depth) PROVABLY splits the window;
* ``schedule='auto'`` resolves to counted on this container (cheap d2h
  sync) and to static under a spied expensive ``sync/<backend>`` cache
  entry -- either way bit-identical to the fixed schedules;
* the cost model is a deterministic pure function of (backend, cache
  file, metadata): identical queries return identical answers and never
  write the cache when probing is disabled.
"""
import functools
import json
import os

import numpy as np
import pytest

from repro.core import plan as planlib
from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.runtime import autotune, costmodel
from repro.runtime import roofline as rooflib

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # decisions must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@functools.lru_cache(maxsize=None)
def _case(shape, seed):
    return make_case(shape, seed=seed)


def _empty():
    z = np.zeros((10, 10, 10), np.float32)
    return (z, z.copy(), (1.0, 1.0, 1.0))


def _mixed_cases():
    return [
        _case((48, 48, 48), 1),
        _empty(),                # empty mask mid-batch: zero row, no n_fut
        _case((20, 18, 16), 5),  # floor-cap case
        _case((70, 20, 20), 4),  # different shape bucket
        _case((48, 48, 48), 2),
    ]


def _assert_rows_equal(want, got):
    assert len(want) == len(got)
    for i, (a, b) in enumerate(zip(want, got)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"case {i}"
        )


# ---------------------------------------------------------------------------
# prep='hint': sync-free pass 0, bit-identical, overflow retried
# ---------------------------------------------------------------------------


def test_hint_prep_equals_count_prep_bit_identical_ref():
    cases = _mixed_cases()
    count = BatchedExtractor(backend="ref", prep="count")
    hint = BatchedExtractor(backend="ref", prep="hint")
    rc, _ = count.run(cases)
    rh, sh = hint.run(cases)
    _assert_rows_equal(rc, rh)
    # the acceptance criterion is a counter: count prep syncs once per
    # non-empty case, hint prep NEVER syncs in pass 0
    assert count.executor.transfer_log["prep"] == 4
    assert hint.executor.transfer_log.get("prep", 0) == 0
    assert "prep" not in sh["host_fetches"]
    # the true counts were drained at collect time instead (a feature of
    # the row, and the overflow detector)
    assert hint.executor.transfer_log["collect_counts"] == 4
    # no overflow on this cohort: the hint over-allocates, never retries
    assert hint.executor.transfer_log.get("hint_retry", 0) == 0


def test_hint_prep_equals_count_prep_bit_identical_interpret():
    cases = [_case((48, 48, 48), 2), _case((20, 18, 16), 5)]
    count = BatchedExtractor(backend="interpret", prep="count")
    hint = BatchedExtractor(backend="interpret", prep="hint")
    rc, _ = count.run(cases)
    rh, _ = hint.run(cases)
    _assert_rows_equal(rc, rh)
    assert hint.executor.transfer_log.get("prep", 0) == 0
    # extract_one stays the (count-sized) oracle of the hint path
    np.testing.assert_array_equal(
        np.asarray(rh[0]), hint.extract_one(*cases[0])
    )


@pytest.mark.parametrize("schedule", ["counted", "static"])
def test_hint_overflow_retries_count_sized(monkeypatch, schedule):
    """A hint that UNDER-estimates drops vertices in pass 0; the collector
    must detect the overflow from the deferred count and re-run the case
    count-sized -- bit-identical to the count-prep baseline."""
    cases = [_case((48, 48, 48), 1), _case((20, 18, 16), 5)]
    baseline = BatchedExtractor(backend="ref", prep="count",
                                schedule=schedule)
    rc, _ = baseline.run(cases)

    # force the overflow: every hint collapses to the bucket floor (512),
    # far below the 48^3 blob's real dedup count
    monkeypatch.setattr(planlib, "vertex_hint", lambda *a, **k: 1)
    hint = BatchedExtractor(backend="ref", prep="hint", schedule=schedule)
    rh, _ = hint.run(cases)
    _assert_rows_equal(rc, rh)
    ex = hint.executor
    assert ex.transfer_log.get("prep", 0) == 0
    assert ex.transfer_log.get("hint_retry", 0) >= 1  # the retry really ran
    if schedule == "static":
        assert ex.transfer_log.get("pass1", 0) == 0  # still sync-free


def test_hint_prep_requires_device_resident_path():
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", prep="hint", prune=False)
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", prep="hint", device_compact=False)
    with pytest.raises(ValueError, match="prep"):
        BatchedExtractor(backend="ref", prep="guess")


# ---------------------------------------------------------------------------
# window='auto': census-driven boundaries, bit-identical to fixed windows
# ---------------------------------------------------------------------------


def test_window_auto_equals_fixed_and_splits_on_fragmentation():
    """Four same-bucket cases then a new shape bucket: with the default
    break-even depth (4) the census says the open window's sub-batches
    are all healthy, so the newcomer must START WINDOW 2 -- and the rows
    must equal the fixed-window run bit for bit."""
    a = _case((48, 48, 48), 1)
    b = _case((70, 20, 20), 4)  # new shape bucket -> fragments the census
    cases = [a, a, a, a, b]
    bx = BatchedExtractor(backend="ref")
    want, _ = bx.run(cases)
    seen = []
    got = list(bx.extract_stream(iter(cases), window="auto",
                                 stats_callback=lambda i, s: seen.append((i, s))))
    _assert_rows_equal(want, got)
    assert [(i, s["cases"]) for i, s in seen] == [(0, 4), (1, 1)]
    assert seen[0][1]["shape_buckets"] == 1  # the split kept window 0 pure


def test_window_auto_absorbs_heterogeneity_below_break_even():
    """A fragmenting case arriving while the window is still shallow must
    be ABSORBED (windows must be allowed to grow past one bucket)."""
    cases = [_case((48, 48, 48), 1), _case((70, 20, 20), 4),
             _empty(), _case((20, 18, 16), 5)]
    bx = BatchedExtractor(backend="ref")
    want, _ = bx.run(cases)
    seen = []
    got = list(bx.extract_stream(iter(cases), window="auto",
                                 stats_callback=lambda i, s: seen.append(s)))
    _assert_rows_equal(want, got)
    assert len(seen) == 1 and seen[0]["cases"] == 4
    assert seen[0]["shape_buckets"] >= 2  # heterogeneous, by design


def test_window_auto_respects_memory_budget():
    cases = [_case((48, 48, 48), 1)] * 3
    bx = BatchedExtractor(backend="ref")
    want, _ = bx.run(cases)
    # a one-byte budget forces every window down to a single case
    bx.executor._cost_model = costmodel.CostModel("ref", window_mem_bytes=1)
    seen = []
    got = list(bx.extract_stream(iter(cases), window="auto",
                                 stats_callback=lambda i, s: seen.append(s)))
    _assert_rows_equal(want, got)
    assert [s["cases"] for s in seen] == [1, 1, 1]


def test_window_rejects_junk():
    bx = BatchedExtractor(backend="ref")
    with pytest.raises(ValueError, match="window"):
        next(bx.extract_stream(iter([]), window="adaptive"))
    with pytest.raises(ValueError, match="window"):
        next(bx.extract_stream(iter([]), window=0))


# ---------------------------------------------------------------------------
# schedule='auto': sync-cost-calibrated counted/static selection
# ---------------------------------------------------------------------------


def test_schedule_auto_resolves_counted_on_this_container():
    cases = [_case((48, 48, 48), 1), _case((48, 48, 48), 2)]
    bx = BatchedExtractor(backend="ref", schedule="auto")
    rows, stats = bx.run(cases)
    # cheap local sync (the uncalibrated default): counted wins, exactly
    # the measured PR 4 trade-off on a zero-latency device
    assert stats["schedule"] == "auto"
    assert stats["plan"]["schedule"] == "counted"
    want, _ = BatchedExtractor(backend="ref", schedule="counted").run(cases)
    _assert_rows_equal(want, rows)


def test_schedule_auto_forced_static_by_spied_sync_entry():
    """Positive control: a calibrated ``sync/<backend>`` entry recording an
    expensive link must flip the same window to the sync-free schedule."""
    cases = [_case((48, 48, 48), 1), _case((48, 48, 48), 2)]
    want, _ = BatchedExtractor(backend="ref", schedule="counted").run(cases)
    autotune.AutotuneCache().put(autotune.sync_key("ref"), {"us": 1e9})
    bx = BatchedExtractor(backend="ref", schedule="auto")
    rows, stats = bx.run(cases)
    assert stats["plan"]["schedule"] == "static"
    assert bx.executor.transfer_log.get("pass1", 0) == 0  # it really was
    _assert_rows_equal(want, rows)


def test_schedule_auto_requires_device_resident_path():
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", schedule="auto", prune=False)
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", schedule="auto", device_compact=False)


def test_choose_schedule_census_sensitivity():
    cm = costmodel.CostModel("ref")
    # nothing to schedule: the zero-latency default
    assert cm.choose_schedule([planlib.CaseMeta(None, None, 0, 0)]) == "counted"
    # an all-floor-cap window: the static targets equal the caps, so the
    # counted schedule's sync buys nothing -- static must win
    floor = [planlib.CaseMeta((32, 32, 32), (20, 20, 20), 512, 300)] * 4
    assert cm.choose_schedule(floor) == "static"
    # a big-cap window on a cheap link: tight buckets beat the sync cost
    big = [planlib.CaseMeta((64, 64, 64), (50, 50, 50), 8192, 6000)] * 4
    assert cm.choose_schedule(big) == "counted"


# ---------------------------------------------------------------------------
# cost-model determinism given a fixed cache file
# ---------------------------------------------------------------------------


def test_cost_model_deterministic_given_fixed_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "fixed.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    cache = autotune.AutotuneCache()
    cache.put(autotune.sync_key("ref"), {"us": 777.0})
    for depth, us in ((1, 100.0), (2, 120.0), (4, 160.0), (8, 300.0)):
        cache.put(
            autotune.sweep_key(1024, "ref", depth),
            {"variant": "gram", "block": 128, "us": us, "table": {}},
        )
    before = open(path).read()

    def snapshot():
        cm = costmodel.CostModel("ref")
        metas = [planlib.CaseMeta((64,) * 3, (50,) * 3, 1024, 900)] * 3
        return (
            cm.sync_cost_us(),
            cm.diameter_case_us(1024, 1),
            cm.diameter_case_us(1024, 8),
            cm.diameter_case_us(1024, 16),  # nearest shallower: the B8 row
            cm.diameter_case_us(2048, 1),   # unmeasured: roofline fallback
            cm.break_even_depth(1024),
            cm.break_even_depth(4096),      # unmeasured: the default ladder
            cm.choose_schedule(metas),
        )

    first, second = snapshot(), snapshot()
    assert first == second
    assert first[0] == 777.0        # the calibrated sync entry, verbatim
    assert first[1] == 100.0        # B1: per-case == per-launch
    assert first[2] == 300.0 / 8    # B8: launch us / depth bucket
    assert first[3] == 300.0 / 8    # depth 16 falls back to the B8 row
    # an unmeasured bucket rides the roofline estimate under the default
    # 'ref' hardware profile, NOT the analytic constant
    profile = autotune.DEFAULT_HW_PROFILES["ref"]
    flops, nbytes = rooflib.diameter_cost(2048, 1)
    assert first[4] == rooflib.roofline_us(flops, nbytes, profile)
    # per-case ladder 100/60/40/37.5: depth 4 is the first within 1.25x
    assert first[5] == 4
    assert first[6] == costmodel.DEFAULT_BREAK_EVEN_DEPTH
    # pure reads: the fixed cache file was never rewritten
    assert open(path).read() == before


def test_unmeasured_bucket_rides_roofline_with_empty_cache():
    # empty cache + probing disabled: the default 'ref' profile prices
    # the bucket via the roofline bound (estimate hierarchy step 2)
    cm = costmodel.CostModel("ref")
    profile = autotune.DEFAULT_HW_PROFILES["ref"]
    for cap in (512, 2048, 8192):
        flops, nbytes = rooflib.diameter_cost(cap, 1)
        assert cm.diameter_case_us(cap, 1) == rooflib.roofline_us(
            flops, nbytes, profile
        )
        assert cm.diameter_case_us(cap, 1) != (
            cap / 1024.0
        ) ** 2 * costmodel.PAIR_SWEEP_US


def test_analytic_constant_only_without_hw_profile(monkeypatch):
    # REPRO_ROOFLINE=0 removes the hardware profile: the analytic
    # constant (estimate hierarchy step 3) must take over -- and an
    # unknown backend string has no default profile either
    monkeypatch.setenv("REPRO_ROOFLINE", "0")
    cm = costmodel.CostModel("ref")
    assert cm.hw_profile() is None
    assert cm.diameter_case_us(2048, 1) == (
        2048 / 1024.0
    ) ** 2 * costmodel.PAIR_SWEEP_US
    monkeypatch.delenv("REPRO_ROOFLINE")
    assert autotune.get_hw_profile("not-a-backend") is None


def test_sync_cost_defaults_without_calibration():
    # REPRO_AUTOTUNE=0 (fixture): no probe may run, no entry exists
    assert autotune.get_sync_cost("ref") == autotune.DEFAULT_SYNC_US
    cm = costmodel.CostModel("ref")
    assert cm.sync_cost_us() == autotune.DEFAULT_SYNC_US
    assert cm.hw_profile() == autotune.DEFAULT_HW_PROFILES["ref"]
    assert not os.path.exists(os.environ["REPRO_AUTOTUNE_CACHE"])


# ---------------------------------------------------------------------------
# the acceptance criterion, end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_full_auto_stream_equals_fixed_counted_count_baseline(backend):
    """``extract_stream(window='auto', schedule='auto', prep='hint')`` must
    be bit-identical to the fixed-window counted count-sized baseline and
    perform zero per-case pass-0 host syncs."""
    cases = _mixed_cases() if backend == "ref" else _mixed_cases()[:3]
    baseline = BatchedExtractor(backend=backend, schedule="counted",
                                prep="count")
    want = list(baseline.extract_stream(iter(cases), window=2))
    auto = BatchedExtractor(backend=backend, schedule="auto", prep="hint")
    got = list(auto.extract_stream(iter(cases), window="auto"))
    _assert_rows_equal(want, got)
    assert auto.executor.transfer_log.get("prep", 0) == 0
    assert auto.executor.transfer_log["collect_counts"] >= 1


def test_plan_census_and_meta_bytes():
    m = planlib.CaseMeta((64, 64, 64), (50, 50, 50), 4096, 3000)
    empty = planlib.CaseMeta(None, None, 0, 0)
    assert planlib.meta_bytes(m) == 4 * 64**3 + 16 * 4096
    assert planlib.meta_bytes(empty) == 0
    c = planlib.WindowCensus()
    assert c.fragments(m)  # any bucket is new to an empty census (the
    # never-close-an-empty-window guard lives in CostModel.should_close)
    c.add(m)
    assert c.cases == 1 and c.bytes == planlib.meta_bytes(m)
    assert not c.fragments(m)      # same buckets: homogeneous
    assert not c.fragments(empty)  # empty cases never fragment
    c.add(empty)
    assert c.cases == 2 and c.shape_depths == {(64, 64, 64): 1}
    other = planlib.CaseMeta((96, 32, 32), (70, 22, 22), 4096, 2500)
    assert c.fragments(other)  # new shape bucket (same cap bucket)
    c.add(other)
    assert c.cap_depths == {4096: 2}


def test_env_float_warns_once_on_malformed(monkeypatch):
    import warnings

    monkeypatch.setenv("REPRO_STREAM_MEM_MB", "lots")
    costmodel._warned_env.discard("REPRO_STREAM_MEM_MB")
    # malformed: warn ONCE naming the variable, fall back to the default
    with pytest.warns(RuntimeWarning, match="REPRO_STREAM_MEM_MB"):
        assert costmodel._env_float("REPRO_STREAM_MEM_MB", 512.0) == 512.0
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        # once per process: the second malformed read is silent
        assert costmodel._env_float("REPRO_STREAM_MEM_MB", 512.0) == 512.0
        # unset and well-formed values never warn
        monkeypatch.delenv("REPRO_STREAM_MEM_MB")
        assert costmodel._env_float("REPRO_STREAM_MEM_MB", 1.5) == 1.5
        monkeypatch.setenv("REPRO_STREAM_MEM_MB", "256")
        assert costmodel._env_float("REPRO_STREAM_MEM_MB", 1.5) == 256.0
    costmodel._warned_env.discard("REPRO_STREAM_MEM_MB")


def test_malformed_stream_env_falls_back_in_cost_model(monkeypatch):
    monkeypatch.setenv("REPRO_STREAM_MAX_CASES", "many")
    costmodel._warned_env.discard("REPRO_STREAM_MAX_CASES")
    with pytest.warns(RuntimeWarning, match="REPRO_STREAM_MAX_CASES"):
        cm = costmodel.CostModel("ref")
    assert cm.window_max_cases == costmodel.DEFAULT_WINDOW_MAX_CASES
    costmodel._warned_env.discard("REPRO_STREAM_MAX_CASES")
