"""End-to-end system behaviour: checkpointing, fault tolerance, trainer.

These are the 'would it survive a cluster' tests: atomic checkpoint
commit, async save, resume-after-crash, reshard-on-load / elastic remesh,
straggler detection, SIGTERM preemption, and int8 error-feedback gradient
compression.
"""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.models.registry import get_config, get_model
from repro.parallel import compression as comp
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (
    PreemptionHandler,
    StragglerDetector,
    elastic_remesh,
    surviving_mesh,
)
from repro.train.trainer import Trainer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "layers": [
            {"a": jnp.asarray(rng.normal(size=(3,)), jnp.float32)},
            {"a": jnp.asarray(rng.normal(size=(3,)), jnp.float32)},
        ],
        "step": jnp.int32(7),
    }


def _assert_tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    t = _tree()
    m.save(3, t, extras={"note": "hi"})
    got, extras = m.restore(3, jax.tree.map(lambda x: x, t))
    _assert_tree_equal(t, got)
    assert extras == {"note": "hi"}


def test_checkpoint_async_and_retention(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        m.save_async(s, _tree(s))
    m.wait()
    assert m.all_steps() == [3, 4]
    assert m.latest_step() == 4


def test_checkpoint_atomicity_ignores_uncommitted(tmp_path):
    m = CheckpointManager(tmp_path, keep=0)
    m.save(5, _tree())
    # simulate a crashed writer: step dir without the commit marker
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "MANIFEST.json").write_text("{}")
    assert m.latest_step() == 5


def test_checkpoint_reshard_on_load(tmp_path):
    m = CheckpointManager(tmp_path)
    t = _tree()
    m.save(1, t)
    mesh = surviving_mesh(model_parallel=1)
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        t,
    )
    step, got, _ = m.restore_latest(jax.tree.map(lambda x: x, t), sh)
    assert step == 1
    _assert_tree_equal(t, got)


def test_elastic_remesh_resumes(tmp_path):
    m = CheckpointManager(tmp_path)
    t = _tree()
    m.save(11, t)

    def make_shardings(mesh):
        return jax.tree.map(
            lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            ),
            t,
        )

    out = elastic_remesh(m, jax.tree.map(lambda x: x, t), make_shardings)
    assert out is not None
    mesh, step, got, _ = out
    assert step == 11
    assert mesh.shape["model"] == 1
    _assert_tree_equal(t, got)


# --------------------------------------------------------------------------
# fault tolerance primitives
# --------------------------------------------------------------------------

def test_straggler_detector_flags_slow_step():
    d = StragglerDetector(window=8, threshold=2.0)
    flagged = []
    for i in range(20):
        flagged.append(d.observe(i, 0.1))
    assert not any(flagged)
    assert d.observe(20, 0.5)  # 5x median
    assert d.slow_steps and d.slow_steps[-1][0] == 20


def test_preemption_handler_sigterm():
    h = PreemptionHandler().install()
    try:
        assert not h.requested
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.requested
    finally:
        h.uninstall()


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def test_compression_error_feedback_telescopes():
    """Accumulated dequantised updates track the true gradient sum."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(64,)), jnp.float32) for _ in range(30)]
    err = jnp.zeros((64,), jnp.float32)
    applied = jnp.zeros((64,), jnp.float32)
    for g in g_true:
        q, scale, err = comp.compress_leaf(g, err)
        applied = applied + q.astype(jnp.float32) * scale
    total = sum(g_true)
    # the residual is bounded by a few quantisation steps, not 30 of them
    resid = np.abs(np.asarray(applied - total))
    step = float(np.max(np.abs(np.asarray(total)))) / 127.0
    assert resid.max() <= 3.0 * step + 1e-5


def test_compressed_psum_tree_single_worker_identity():
    grads = {"a": jnp.linspace(-1, 1, 16), "b": jnp.ones((4, 4))}
    err = comp.init_error_state(grads)
    out, new_err = comp.compressed_psum_tree(grads, err)
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(grads[k]), atol=2.0 / 127.0
        )
    # error feedback carries exactly the quantisation residual
    jax.tree.map(
        lambda g, o, e: np.testing.assert_allclose(
            np.asarray(e), np.asarray(g - o), atol=1e-6
        ),
        grads, out, new_err,
    )


# --------------------------------------------------------------------------
# trainer end-to-end (tiny qwen3-family config on CPU)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen3-1.7b").reduced()
    model = get_model(cfg)
    run = RunConfig(steps=6, checkpoint_every=2, warmup_steps=2,
                    learning_rate=1e-3, async_checkpoint=False)
    rng = np.random.default_rng(0)
    B, S = 4, 32

    def data_iter():
        while True:
            yield {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
                )
            }

    return cfg, model, run, data_iter


def test_trainer_end_to_end_and_resume(tmp_path, tiny_setup):
    cfg, model, run, data_iter = tiny_setup
    t1 = Trainer(model, run, data_iter(), tmp_path)
    params, opt_state, last = t1.train(steps=4)
    assert np.isfinite(last["loss"])
    assert t1.ckpt.latest_step() == 4

    # metrics were logged
    lines = [json.loads(l) for l in
             (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert [l["step"] for l in lines] == [0, 1, 2, 3]

    # a fresh Trainer resumes from step 4 (crash-restart path)
    t2 = Trainer(model, run, data_iter(), tmp_path)
    start, p2, o2 = t2.resume_or_init()
    assert start == 4
    _assert_tree_equal(p2, params)

    # and continues to train to step 6
    p3, o3, last2 = t2.train(steps=6)
    assert t2.ckpt.latest_step() == 6
    assert np.isfinite(last2["loss"])
