import importlib.util

import numpy as np
import pytest

# Property-test modules need hypothesis (see requirements-dev.txt); skip
# them at collection time when it is absent so the rest of the suite runs.
collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = [
        "test_kernels_diameter.py",
        "test_kernels_mc.py",
        "test_mc_tables.py",
        "test_prune_properties.py",
        "test_families_properties.py",
    ]


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier1: fast correctness gate run by scripts/ci_smoke.sh",
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def sphere_mask(n: int, r: float) -> np.ndarray:
    g = np.arange(n) - (n - 1) / 2
    x, y, z = np.meshgrid(g, g, g, indexing="ij")
    return (x * x + y * y + z * z <= r * r).astype(np.float32)


def box_mask(shape, lo, hi) -> np.ndarray:
    m = np.zeros(shape, np.float32)
    m[lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]] = 1.0
    return m
