"""Roofline cost-model gates: HLO/jaxpr parsers + the agreement contract.

Two layers under test:

* ``repro.utils.roofline`` -- the compiled-artifact parsers: dtype/shape
  byte sizing (unknown dtypes must be SKIPPED, not crash), collective
  accounting over tuple results and async -start/-done pairs, and the
  loop-aware jaxpr FLOP/byte walk (elementwise + reduction counting, scan
  trip-count correction).

* ``repro.runtime.roofline`` -- the structural work models the cost model
  prices unmeasured launches with.  The CI ``roofline`` stage's core
  contract lives here: for every (kind, bucket) in ``AGREEMENT_GRID`` the
  plan-derived FLOPs/bytes must agree with XLA's loop-corrected
  ``cost_analysis()`` on the real 'ref' launch within ``AGREEMENT_RTOL``
  (10%).  A drifted kernel implementation or a stale ``CAL`` constant
  fails this gate, not the scheduling heuristics downstream of it.
"""
import math

import pytest

from repro.core import plan as planlib
from repro.runtime import roofline
from repro.utils import roofline as uro

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# utils/roofline: shape + collective parsers
# ---------------------------------------------------------------------------

def test_shape_bytes_counts_known_dtypes():
    assert uro.shape_bytes("f32[4,512]") == 4 * 512 * 4
    assert uro.shape_bytes("bf16[8]") == 8 * 2
    assert uro.shape_bytes("pred[3,3]") == 9
    # scalar: empty dims -> one element
    assert uro.shape_bytes("f32[]") == 4
    # several shapes in one string sum
    assert uro.shape_bytes("f32[2] u8[2]") == 8 + 2


def test_shape_bytes_skips_unknown_dtypes():
    # an unrecognised dtype token must contribute ZERO, not raise --
    # future XLA dtypes (f4, mx formats, ...) should never crash the gate
    assert uro.shape_bytes("q8[1024]") == 0
    assert uro.shape_bytes("q8[1024] f32[2]") == 8


def test_collective_bytes_plain_and_tuple_results():
    hlo = """
      %ag = bf16[4,512]{1,0} all-gather(%x), dimensions={0}
      %t = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), to_apply=%sum
    """
    out = uro.collective_bytes(hlo)
    assert out["all-gather"] == 4 * 512 * 2
    # tuple result: both element shapes count
    assert out["all-reduce"] == 2 * 8 * 4
    assert out["count"] == 2
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_collective_bytes_async_start_done_counted_once():
    hlo = """
      %s = f32[1024]{0} reduce-scatter-start(%x), dimensions={0}
      %d = f32[1024]{0} reduce-scatter-done(%s)
    """
    out = uro.collective_bytes(hlo)
    assert out["reduce-scatter"] == 1024 * 4  # -start counted, -done skipped
    assert out["count"] == 1


def test_collective_bytes_skips_unknown_dtype_shapes():
    out = uro.collective_bytes("%x = q8[4096]{0} all-to-all(%y)")
    assert out["all-to-all"] == 0
    assert out["count"] == 1  # the op itself is still seen


# ---------------------------------------------------------------------------
# utils/roofline: loop-aware jaxpr walk
# ---------------------------------------------------------------------------

def test_jaxpr_cost_elementwise_and_reduction():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return jnp.sum(x * x)

    closed = jax.make_jaxpr(fn)(jnp.zeros((8, 16), jnp.float32))
    flops, byts = uro.jaxpr_cost(closed)
    # one mul per output element + one reduce-add per input element
    assert flops == pytest.approx(2 * 8 * 16)
    assert byts > 0


def test_jaxpr_cost_scan_multiplies_trip_count():
    import jax
    import jax.numpy as jnp

    length = 13

    def fn(x):
        def body(carry, _):
            return carry + x, None

        out, _ = jax.lax.scan(body, x, None, length=length)
        return out

    closed = jax.make_jaxpr(fn)(jnp.zeros((32,), jnp.float32))
    f_mult, _ = uro.jaxpr_cost(closed, multiply_loops=True)
    f_once, _ = uro.jaxpr_cost(closed, multiply_loops=False)
    assert f_mult == pytest.approx(length * f_once)

    fc, bc, _ = uro.loop_corrections(fn, jnp.zeros((32,), jnp.float32))
    assert fc == pytest.approx(length)


def test_compiled_cost_reads_cost_analysis():
    import jax
    import jax.numpy as jnp

    def fn(a, b):
        return a @ b

    x = jnp.zeros((64, 64), jnp.float32)
    compiled = jax.jit(fn).lower(x, x).compile()
    flops, byts = uro.compiled_cost(compiled)
    # a 64^3 matmul is 2*64^3 FLOPs; XLA reports exactly that on CPU
    assert flops == pytest.approx(2 * 64**3, rel=0.01)
    assert byts >= 3 * 64 * 64 * 4  # two operands + result, at least


# ---------------------------------------------------------------------------
# runtime/roofline: structural models + plan census
# ---------------------------------------------------------------------------

def _meta(shape, cap, intensity=False):
    return planlib.CaseMeta(
        shape=shape, roi_shape=shape, vertex_cap=cap, n_vertices=cap // 2,
        intensity=intensity,
    )


def test_work_census_kinds_and_depths():
    metas = [
        _meta((32, 32, 32), 1024),
        _meta((32, 32, 32), 1024),
        _meta((64, 64, 64), 2048),
    ]
    plan = planlib.build_plan(metas, schedule="counted",
                              families=("shape", "firstorder", "glcm"))
    items = plan.work_census()
    by_kind = {}
    for it in items:
        by_kind.setdefault(it.kind, []).append(it)
    # one MC + one firstorder + one glcm item per shape group
    assert {len(by_kind[k]) for k in ("mc", "firstorder", "glcm")} == {2}
    # one prune + compact + diameter chain per cap group
    assert {len(by_kind[k]) for k in ("prune", "compact", "diameter")} == {2}
    assert sorted(it.depth for it in by_kind["mc"]) == [1, 2]
    # counted schedule: the diameter sweep prices the conservative cap
    assert sorted(it.m for it in by_kind["diameter"]) == [1024, 2048]


def test_work_census_static_sweeps_at_target():
    metas = [_meta((32, 32, 32), 2048)]
    plan = planlib.build_plan(metas, schedule="static")
    diam = [it for it in plan.work_census() if it.kind == "diameter"]
    compact = [it for it in plan.work_census() if it.kind == "compact"]
    assert len(diam) == 1 and len(compact) == 1
    # static schedule sweeps the aligned compaction target, not the cap
    assert diam[0].m == compact[0].cap
    assert diam[0].m <= 2048


def test_plan_cost_sums_work_items():
    metas = [_meta((32, 32, 32), 1024), _meta((48, 48, 48), 2048)]
    plan = planlib.build_plan(metas, schedule="counted")
    cost = roofline.plan_cost(plan)
    f = sum(roofline.work_item_cost(it)[0] for it in plan.work_census())
    b = sum(roofline.work_item_cost(it)[1] for it in plan.work_census())
    assert cost["flops"] == pytest.approx(f)
    assert cost["bytes"] == pytest.approx(b)
    assert set(cost["per_kind"]) == {"mc", "prune", "compact", "diameter"}


def test_work_item_cost_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown work item kind"):
        roofline.work_item_cost(planlib.WorkItem(kind="fft", depth=1))


def test_roofline_us_is_max_of_compute_and_memory():
    profile = {"peak_flops": 1e9, "mem_bw": 1e8}
    # compute-bound: 1e9 FLOPs at 1e9/s = 1s; 1e6 B at 1e8/s = 10ms
    assert roofline.roofline_us(1e9, 1e6, profile) == pytest.approx(1e6)
    # memory-bound: 1e8 B at 1e8/s = 1s
    assert roofline.roofline_us(1e3, 1e8, profile) == pytest.approx(1e6)


def test_mc_cost_follows_padded_slab_volume():
    # 34^3: nz-1=33 cells -> 2 slabs of 32 -> 64*34*34 padded cells
    assert roofline.mc_slab_cells((34, 34, 34)) == 64 * 34 * 34
    # depth scales linearly
    f1, b1 = roofline.mc_cost((34, 34, 34), depth=1)
    f4, b4 = roofline.mc_cost((34, 34, 34), depth=4)
    assert f4 == pytest.approx(4 * f1) and b4 == pytest.approx(4 * b1)


# ---------------------------------------------------------------------------
# the agreement contract (what the CI roofline stage asserts)
# ---------------------------------------------------------------------------

def _grid_id(spec):
    parts = [spec["kind"]]
    if "m" in spec:
        parts.append(f"M{spec['m']}")
    if "cap" in spec:
        parts.append(f"c{spec['cap']}")
    if "shape" in spec:
        parts.append("x".join(str(s) for s in spec["shape"]))
    return "-".join(parts)


@pytest.mark.parametrize(
    "spec", roofline.AGREEMENT_GRID, ids=[_grid_id(s) for s in roofline.AGREEMENT_GRID]
)
def test_model_agrees_with_cost_analysis(spec):
    """Plan census == loop-corrected cost_analysis() within 10% on ref."""
    rep = roofline.agreement(
        spec["kind"], m=spec.get("m"), cap=spec.get("cap"),
        shape=spec.get("shape"),
    )
    assert rep["ok"], (
        f"{_grid_id(spec)}: flops model={rep['model_flops']:.3g} "
        f"xla={rep['xla_flops']:.3g} (rel {rep['flops_rel_err']:.1%}); "
        f"bytes model={rep['model_bytes']:.3g} "
        f"xla={rep['xla_bytes']:.3g} (rel {rep['bytes_rel_err']:.1%}); "
        f"tolerance {roofline.AGREEMENT_RTOL:.0%}"
    )


def test_agreement_checks_are_nontrivial():
    # the gate must be comparing real numbers, not inf/0 placeholders
    rep = roofline.agreement("diameter", m=512)
    assert rep["xla_flops"] > 0 and rep["xla_bytes"] > 0
    assert rep["model_flops"] > 0 and rep["model_bytes"] > 0
    assert math.isfinite(rep["flops_rel_err"])
    assert math.isfinite(rep["bytes_rel_err"])
