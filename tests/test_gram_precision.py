"""f32 augmented-Gram precision guardrail at paper-scale coordinates.

The 'gram' diameter variant computes |r - c|^2 on the MXU via the augmented
Gram identity |r|^2 + |c|^2 - 2<r, c> in f32 -- numerically looser than the
subtract-square sweep because the norm terms grow with the coordinate
magnitude while the distance does not.  The ROADMAP documents a 1e-3
relative bound for it; this suite *characterizes* that bound at the
coordinate scale the paper's workload actually produces (CT mm-spacing
times up-to-512^3 voxel extents, plus a scanner-frame origin offset)
against an f64 oracle, and fails loudly if either

  * the kernel regresses PAST the documented bound (a real precision bug), or
  * the baseline subtract-square variant stops being the tight reference
    the bound is measured against.

If a future PR tightens the documented tolerance, this is the test that
must be re-derived first (see the ROADMAP 'Gram-kernel precision
guardrail' item: a compensated/centred formulation is the known fix).
"""
import numpy as np
import pytest

from repro.kernels import diameter as dk

pytestmark = pytest.mark.tier1

GRAM_RTOL = 1e-3  # the documented bound (kernels/diameter docstring, ROADMAP)
BASELINE_RTOL = 1e-5  # subtract-square stays ~f32-rounding tight


def _paper_scale_cloud(seed: int, m: int = 384, offset_mm: float = 0.0):
    """Vertices at KITS19-like physical scale: mm spacing x 512^3 extent."""
    rng = np.random.default_rng(seed)
    spacing = np.array([0.7, 0.7, 5.0])  # axial CT voxel spacing (mm)
    extent = np.array([512, 512, 512], np.float64)
    idx = rng.uniform(0.0, 1.0, size=(m, 3)) * extent
    return (idx * spacing + offset_mm).astype(np.float32)


def _diameters_f64(verts: np.ndarray) -> np.ndarray:
    v = verts.astype(np.float64)
    d = v[:, None, :] - v[None, :, :]
    q = d * d
    planes = (q.sum(-1), q[..., 0] + q[..., 1], q[..., 0] + q[..., 2],
              q[..., 1] + q[..., 2])
    return np.sqrt(np.asarray([p.max() for p in planes]))


def _variant(verts, variant):
    mask = np.ones(len(verts), np.float32)
    return np.asarray(
        dk.max_diameters_pallas(
            verts, mask, block=128, variant=variant, interpret=True
        ),
        np.float64,
    )


@pytest.mark.parametrize("seed", range(6))
def test_gram_error_within_documented_bound(seed):
    verts = _paper_scale_cloud(seed)
    want = _diameters_f64(verts)
    rel = np.abs(_variant(verts, "gram") - want) / want
    assert rel.max() < GRAM_RTOL, (
        f"gram f32 relative error {rel.max():.2e} exceeds the documented "
        f"{GRAM_RTOL:.0e} bound at paper-scale coordinates (seed {seed})"
    )


@pytest.mark.parametrize("offset_mm", [500.0, 1500.0])
def test_gram_bound_survives_scanner_frame_offsets(offset_mm):
    """Un-centred scanner coordinates inflate |r|^2 without growing the
    distance -- the gram variant's worst realistic case.  The documented
    bound must hold here too (the pipeline crops to the ROI origin, so
    production inputs are strictly easier than this)."""
    verts = _paper_scale_cloud(17, offset_mm=offset_mm)
    want = _diameters_f64(verts)
    rel = np.abs(_variant(verts, "gram") - want) / want
    assert rel.max() < GRAM_RTOL, (offset_mm, rel.max())


@pytest.mark.parametrize("seed", range(3))
def test_baseline_variant_is_the_tight_reference(seed):
    """seqacc (subtract-square) must stay ~f32-rounding accurate at the
    same scale: it is the reference the 1e-3 gram bound is measured
    against, and the parity oracle the pruning exactness argument uses."""
    verts = _paper_scale_cloud(seed)
    want = _diameters_f64(verts)
    rel = np.abs(_variant(verts, "seqacc") - want) / want
    assert rel.max() < BASELINE_RTOL, rel.max()


def test_bound_is_calibrated_not_vacuous():
    """The guardrail must measure the real error regime: if the gram error
    at paper scale collapsed to baseline levels, the documented 1e-3 bound
    (and the ROADMAP note about a compensated formulation) would be stale
    -- surface that instead of silently over-promising.  Measured f32
    error for an exactly-representable oracle sits well above zero."""
    worst = 0.0
    for seed in range(6):
        verts = _paper_scale_cloud(seed)
        want = _diameters_f64(verts)
        worst = max(worst, float(np.max(np.abs(_variant(verts, "gram") - want) / want)))
    assert worst < GRAM_RTOL
    assert worst > 1e-9, (
        f"gram error {worst:.2e} is now at f64-oracle noise level; the "
        "documented 1e-3 bound and this guardrail need re-deriving"
    )
