"""Serving-tier lockdown: parity, fusion, deadlines, backpressure, demux.

The contracts under test (see serve/service.py + core/pipeline.py's
Serving section):

* **parity** -- rows served through the multi-tenant driver are
  bit-identical to ``extract_stream`` on the same cases (ref AND
  interpret backends): window fusion must never change a feature value;
* **cross-tenant fusion** -- concurrently queued requests from different
  tenants share windows (the driver is plugged with a blocking loader to
  make the queue state deterministic);
* **deadlines** -- a request that expires while queued completes with
  ``DeadlineExceeded`` error rows, never occupies a window slot, and
  does not stall or perturb co-tenant rows; ``CostModel.window_cost_us``
  / ``deadline_at_risk`` (the latency-vs-throughput decision) behave
  sanely at the unit level;
* **backpressure** -- admission is bounded by estimated queue bytes:
  ``block=False`` raises ``ServiceOverloaded``, a blocking submit times
  out while the budget is held, and frees admit it; an oversize request
  is admitted only against an empty queue;
* **demux** -- a batch request's rows come back in ITS OWN input order
  with quarantine errors keyed by the request's case index.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case, mixed_traffic_stream
from repro.serve.service import (
    ExtractionService,
    ServiceClosed,
    ServiceOverloaded,
    estimate_case_bytes,
)

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # parity must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


def _cases(n, shape=(20, 18, 16)):
    return [make_case(shape, seed=40 + i) for i in range(n)]


class _Plug:
    """Loader that blocks the driver inside prep until released.

    While the driver is parked here, everything submitted afterwards is
    guaranteed to be QUEUED together -- the deterministic setup for the
    fusion / deadline / backpressure tests.
    """

    def __init__(self, case):
        self.entered = threading.Event()
        self.release = threading.Event()
        self._case = case

    def __call__(self):
        self.entered.set()
        assert self.release.wait(60), "plug never released"
        return self._case


# ---------------------------------------------------------------------------
# parity: served rows == extract_stream rows, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_served_rows_bit_identical_to_stream(backend):
    bx = BatchedExtractor(backend=backend, prep="hint", schedule="static")
    cases = _cases(5) + [make_case((26, 22, 18), seed=91)]
    ref = [np.asarray(r) for r in bx.extract_stream(iter(cases), window=3)]
    with bx.serve() as svc:
        # mixed single and batch submits from two tenants
        futs = [svc.submit([cases[0], cases[1]], tenant="a"),
                svc.submit([cases[2]], tenant="b"),
                svc.submit(cases[3:], tenant="a")]
        got = [np.asarray(r) for f in futs for r in f.result(timeout=600).rows]
        assert all(not f.result().errors for f in futs)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_serve_facade_and_loader_cases():
    bx = BatchedExtractor(backend="ref")
    case = _cases(1)[0]
    (ref_row,), _ = bx.run([case])
    svc = bx.serve()
    try:
        fut = svc.submit_case(lambda: case, shape_hints=None, tenant="lazy")
        res = fut.result(timeout=600)
        assert res.ok and not res.late
        np.testing.assert_array_equal(np.asarray(res.rows[0]),
                                      np.asarray(ref_row))
        assert res.latency_s > 0
    finally:
        svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit_case(case)


# ---------------------------------------------------------------------------
# cross-tenant fusion
# ---------------------------------------------------------------------------


def test_cross_tenant_requests_fuse_into_shared_windows():
    bx = BatchedExtractor(backend="ref", prep="hint", schedule="static")
    cases = _cases(4)
    plug = _Plug(cases[0])
    with bx.serve() as svc:
        f0 = svc.submit([plug], tenant="a")
        assert plug.entered.wait(30)
        # driver is parked inside prep: these queue up behind the plug
        f1 = svc.submit([cases[1], cases[2]], tenant="b")
        f2 = svc.submit([cases[3]], tenant="c")
        plug.release.set()
        for f in (f0, f1, f2):
            assert not f.result(timeout=600).errors
        stats = svc.stats()
    # 3 requests, fewer windows, and at least one window is multi-tenant
    assert stats["requests"] == 3
    assert stats["windows"] < 3
    assert any(t > 1 for t in stats["window_tenants"])
    # fusion must not change the rows
    ref, _ = bx.run(cases)
    got = [f0.result().rows[0], *f1.result().rows, *f2.result().rows]
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_expired_request_errors_without_stalling_cotenants():
    bx = BatchedExtractor(backend="ref", prep="hint", schedule="static")
    cases = _cases(4)
    ref, _ = bx.run(cases)
    plug = _Plug(cases[0])
    with bx.serve() as svc:
        f_plug = svc.submit([plug], tenant="live")
        assert plug.entered.wait(30)
        f_live = svc.submit([cases[1], cases[2]], tenant="live")
        f_dead = svc.submit([cases[3]], tenant="hurried", deadline_s=0.01)
        time.sleep(0.05)  # the deadline passes while the request is queued
        plug.release.set()
        live, dead = f_live.result(timeout=600), f_dead.result(timeout=600)
        stats = svc.stats()
    # expired: per-case DeadlineExceeded errors, all-NaN rows, no window
    assert set(dead.errors) == {0}
    assert "DeadlineExceeded" in dead.errors[0]
    assert np.isnan(np.asarray(dead.rows[0])).all()
    assert stats["expired_cases"] == 1
    # co-tenant rows untouched and bit-identical
    assert not live.errors and not f_plug.result().errors
    np.testing.assert_array_equal(np.asarray(f_plug.result().rows[0]),
                                  np.asarray(ref[0]))
    for a, b in zip(ref[1:3], live.rows):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deadline_at_risk_closes_early_at_unit_level():
    from repro.core import plan as planlib

    bx = BatchedExtractor(backend="ref")
    cm = bx.cost_model
    census = planlib.WindowCensus()
    # empty window / no deadline: never at risk
    assert not cm.deadline_at_risk(census, 5.0)
    assert not cm.deadline_at_risk(census, None)
    img, msk, sp = _cases(1)[0]
    p = bx.executor.prep_case((img, msk, sp))
    census.add(bx.executor.case_meta(p))
    cost = cm.window_cost_us(census)
    assert cost > 0
    # monotone: more cases in the window cannot get cheaper
    census.add(bx.executor.case_meta(p))
    assert cm.window_cost_us(census) >= cost
    # generous slack: safe; tiny or spent slack: at risk
    assert not cm.deadline_at_risk(census, 1e12)
    assert cm.deadline_at_risk(census, 1e-3)
    assert cm.deadline_at_risk(census, 0.0)
    assert cm.deadline_at_risk(census, -5.0)


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------


def test_admission_control_bounds_queue_bytes():
    bx = BatchedExtractor(backend="ref")
    cases = _cases(4)
    b = estimate_case_bytes(cases[0])
    assert b > 0
    plug = _Plug(cases[0])
    # budget: the plug + one queued case fit, a second queued case does not
    with bx.serve(max_queue_bytes=2.5 * b) as svc:
        svc.loader_case_bytes = b  # charge the plug like a real case
        f0 = svc.submit([plug], tenant="a")
        assert plug.entered.wait(30)
        f1 = svc.submit([cases[1]], tenant="b")
        with pytest.raises(ServiceOverloaded):
            svc.submit([cases[2]], tenant="c", block=False)
        t0 = time.perf_counter()
        with pytest.raises(ServiceOverloaded):
            svc.submit([cases[2]], tenant="c", timeout=0.2)
        assert time.perf_counter() - t0 >= 0.2
        plug.release.set()
        # rows resolve, bytes free, the same submit is admitted
        assert not f0.result(timeout=600).errors
        f2 = svc.submit([cases[2]], tenant="c", timeout=600)
        assert not f1.result(timeout=600).errors
        assert not f2.result(timeout=600).errors


def test_oversize_request_admitted_only_against_empty_queue():
    bx = BatchedExtractor(backend="ref")
    case = _cases(1)[0]
    b = estimate_case_bytes(case)
    with bx.serve(max_queue_bytes=b / 2) as svc:
        # bigger than the whole budget, but the queue is empty: admitted
        res = svc.submit([case], tenant="big").result(timeout=600)
        assert res.ok


def test_estimate_case_bytes_modes():
    img, msk, sp = _cases(1)[0]
    b = estimate_case_bytes((img, msk, sp))
    assert b > 0
    # intensity families stage the image next to the mask: costlier
    assert estimate_case_bytes((img, msk, sp), needs_intensity=True) > b
    # a loader with a shape hint prices like the equivalent tuple
    hinted = estimate_case_bytes(lambda: (img, msk, sp),
                                 shape_hint=msk.shape)
    assert hinted == estimate_case_bytes((img, msk, sp))
    # no hint, no shape: the flat default
    from repro.serve.service import DEFAULT_LOADER_CASE_BYTES
    assert (estimate_case_bytes(lambda: (img, msk, sp))
            == DEFAULT_LOADER_CASE_BYTES)


# ---------------------------------------------------------------------------
# demux + quarantine through the service
# ---------------------------------------------------------------------------


def test_batch_demux_preserves_request_order_with_quarantine():
    bx = BatchedExtractor(backend="ref")
    good = _cases(3)
    img, msk, sp = good[1]
    bad_mask = np.asarray(msk, np.float32).copy()
    bad_mask[10, 9, 8] = np.nan  # poisoned: quarantined at prep
    batch = [good[0], (img, bad_mask, sp), good[2]]
    ref, _ = bx.run(good)
    with bx.serve() as svc:
        res = svc.submit(batch, tenant="mixed").result(timeout=600)
        stats = svc.stats()
    # the poisoned case errors AT ITS REQUEST INDEX with an all-NaN row
    assert set(res.errors) == {1}
    assert np.isnan(np.asarray(res.rows[1])).all()
    assert not res.ok
    assert stats["quarantined_cases"] == 1
    # neighbours are bit-identical to a run without the poison
    np.testing.assert_array_equal(np.asarray(res.rows[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(res.rows[2]), np.asarray(ref[2]))


def test_mixed_traffic_stream_shapes():
    out = list(mixed_traffic_stream(7, huge_every=3, huge_dims=(48, 48, 48)))
    assert len(out) == 7
    names = [n for n, *_ in out]
    # every 3rd case is the huge one, the rest are small
    assert [n.startswith("huge") for n in names] == \
        [i % 3 == 2 for i in range(7)]
    assert out[2][1].shape == (48, 48, 48)
    assert out[0][1].shape != (48, 48, 48)


def test_service_driver_survives_and_reports_on_close():
    bx = BatchedExtractor(backend="ref")
    svc = ExtractionService(bx)
    res = svc.submit_case(_cases(1)[0]).result(timeout=600)
    assert res.ok
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(ServiceClosed):
        svc.submit_case(_cases(1)[0])


def test_estimate_case_bytes_peeks_loader_nifti_header(tmp_path):
    """PR 9: a loader exposing its NIfTI path is sized by a header peek,
    not the flat default -- admission control sees real volume bytes."""
    import functools

    from repro.data.nifti import write_nifti

    img, msk, sp = _cases(1)[0]
    p = tmp_path / "mask.nii"
    write_nifti(p, np.asarray(msk, np.uint8), sp)

    def loader():
        from repro.data.nifti import read_nifti

        mask, spacing = read_nifti(loader.path)
        return img, mask.astype(np.float32), spacing

    loader.path = p
    want = estimate_case_bytes((img, msk, sp))
    assert estimate_case_bytes(loader) == want
    assert estimate_case_bytes(loader, needs_intensity=True) > want

    # a functools.partial keyword path works the same way
    part = functools.partial(lambda nifti_path: None, nifti_path=p)
    assert estimate_case_bytes(part) == want

    # unreadable / missing paths fall back to the flat default, never raise
    from repro.serve.service import DEFAULT_LOADER_CASE_BYTES

    broken = lambda: None  # noqa: E731
    broken.path = tmp_path / "nope.nii"
    assert estimate_case_bytes(broken) == DEFAULT_LOADER_CASE_BYTES
