"""Two-pass pruned batched pipeline: batched-vs-single parity lockdown.

The contract under test (see core/pipeline.py): batching may never change a
feature value.  ``BatchedExtractor.extract_one`` runs the identical stages
case-by-case (same bucket padding, pruning bound, tuned configs, kernels)
and is the oracle; on the Pallas ('interpret') backend the batched rows
must be **bit-identical** to it, on the pure-jnp 'ref' backend identical up
to f32 rounding (XLA fuses shape-dependently -- the documented ulp caveat
of kernels/prune).  Plain-pytest seeded property mirrors of the hypothesis
suite (tests/test_prune_properties.py) ride along so the invariants are
exercised even in the minimal container without hypothesis.
"""
import functools

import numpy as np
import pytest

from repro.core.pipeline import BatchedExtractor, group_indices
from repro.core.shape_features import ShapeFeatureExtractor
from repro.data.synthetic import make_case
from repro.kernels import diameter as dk
from repro.kernels import ops, prune

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # parity must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@functools.lru_cache(maxsize=None)
def _case(shape, seed):
    return make_case(shape, seed=seed)


def _blob_cases():
    # 48^3 blobs: ~3-4k vertices (cap 4096) pruning to the 512-bucket floor,
    # plus an elongated case landing in a different shape bucket
    return [
        _case((48, 48, 48), 1),
        _case((48, 48, 48), 2),
        _case((70, 20, 20), 4),
    ]


# ---------------------------------------------------------------------------
# batched == single, bit-for-bit (Pallas semantics)
# ---------------------------------------------------------------------------


def test_two_pass_bit_identical_to_single_interpret():
    bx = BatchedExtractor(backend="interpret")
    cases = _blob_cases()
    results, stats = bx.run(cases)
    assert stats["two_pass"] and stats["pruned_cases"] >= 2
    assert stats["buckets"] >= 2  # the elongated case straddles shapes
    for case, row in zip(cases, results):
        single = bx.extract_one(*case)
        np.testing.assert_array_equal(row, single)


def test_two_pass_matches_gold_extractor_interpret():
    """Against the user-facing single-case extractor: diameters bit-equal
    (same vertex point set; pruning exactness), volume/area to f32
    rounding (the bucket padding moves the MC centring origin)."""
    bx = BatchedExtractor(backend="interpret")
    cases = _blob_cases()[:2]
    results, _ = bx.run(cases)
    gold = ShapeFeatureExtractor(backend="interpret")
    for (img, msk, sp), row in zip(cases, results):
        f = gold.execute(img, msk, sp)
        want_d = np.asarray(
            [f["Maximum3DDiameter"], f["Maximum2DDiameterSlice"],
             f["Maximum2DDiameterRow"], f["Maximum2DDiameterColumn"]],
            np.float32,
        )
        np.testing.assert_array_equal(row[2:6], want_d)
        np.testing.assert_allclose(row[0], f["MeshVolume"], rtol=1e-6)
        np.testing.assert_allclose(row[1], f["SurfaceArea"], rtol=1e-6)
        assert row[6] == f["_n_mesh_vertices"]


def test_ref_backend_parity():
    bx = BatchedExtractor(backend="ref")
    cases = _blob_cases() + [_case((20, 18, 16), 5)]
    results, stats = bx.run(cases)
    assert stats["vertex_buckets"] >= 1
    for case, row in zip(cases, results):
        np.testing.assert_allclose(
            row, bx.extract_one(*case), rtol=1e-6, atol=1e-6
        )


# ---------------------------------------------------------------------------
# re-bucketing edge cases
# ---------------------------------------------------------------------------


def test_empty_mask_yields_zero_row_not_crash():
    """A 40k-case sweep must not die on one degenerate segmentation."""
    img = np.zeros((12, 12, 12), np.float32)
    empty = (img, np.zeros((12, 12, 12), np.float32), (1.0, 1.0, 1.0))
    good = _case((20, 18, 16), 5)
    for prune_flag in (True, False):
        bx = BatchedExtractor(backend="ref", prune=prune_flag)
        results, stats = bx.run([empty, good, empty])
        assert stats["empty_cases"] == 2
        np.testing.assert_array_equal(results[0], np.zeros(7, np.float32))
        np.testing.assert_array_equal(results[2], np.zeros(7, np.float32))
        assert np.all(np.isfinite(results[1])) and results[1][0] > 0
        np.testing.assert_array_equal(
            bx.extract_one(*empty), np.zeros(7, np.float32)
        )
    # the strict single-case extractor keeps its documented ValueError
    with pytest.raises(ValueError, match="empty"):
        ShapeFeatureExtractor(backend="ref").execute(empty[0], empty[1])


def test_single_voxel_case():
    msk = np.zeros((9, 9, 9), np.float32)
    msk[4, 4, 4] = 1.0
    case = (np.zeros((9, 9, 9), np.float32), msk, (1.0, 1.0, 1.0))
    bx = BatchedExtractor(backend="ref")
    results, _ = bx.run([case, _case((20, 18, 16), 5)])
    np.testing.assert_allclose(results[0], bx.extract_one(*case), rtol=1e-6)
    assert np.all(np.isfinite(results[0]))
    assert 0.0 < results[0][2] < 4.0  # one-voxel surface: ~voxel-scale d3


def test_all_cases_pruned_to_same_bucket():
    """Identical-geometry cases must collapse to ONE pruned sub-batch."""
    case = _case((48, 48, 48), 7)
    bx = BatchedExtractor(backend="ref")
    results, stats = bx.run([case] * 3)
    assert stats["buckets"] == 1 and stats["vertex_buckets"] == 1
    assert stats["pruned_cases"] == 3
    np.testing.assert_array_equal(results[0], results[1])
    np.testing.assert_array_equal(results[1], results[2])


def test_bucket_straddling_with_batch_padding():
    """Mixed M' buckets + batch_size that forces a padded trailing chunk."""
    cases = [_blob_cases()[0], _case((20, 18, 16), 5), _blob_cases()[1],
             _case((48, 48, 48), 3), _case((16, 16, 16), 6)]
    bx = BatchedExtractor(backend="ref")
    want = [bx.extract_one(*c) for c in cases]
    results, stats = bx.run(cases, batch_size=2)
    assert len(results) == len(cases) and all(r is not None for r in results)
    for w, r in zip(want, results):
        np.testing.assert_allclose(r, w, rtol=1e-6, atol=1e-6)


def test_permutation_invariance_of_outputs():
    """Re-bucketing never drops, duplicates, or cross-contaminates a case."""
    cases = _blob_cases() + [_case((20, 18, 16), 5)]
    bx = BatchedExtractor(backend="ref")
    base, _ = bx.run(cases)
    perm = [2, 0, 3, 1]
    permuted, _ = bx.run([cases[i] for i in perm])
    for j, i in enumerate(perm):
        np.testing.assert_array_equal(permuted[j], base[i])


def test_one_pass_two_pass_agree():
    """The legacy unpruned pipeline stays a valid baseline."""
    cases = _blob_cases()[:2]
    two, _ = BatchedExtractor(backend="ref", prune=True).run(cases)
    one, stats = BatchedExtractor(backend="ref", prune=False).run(cases)
    assert not stats["two_pass"] and stats["pruned_cases"] == 0
    for a, b in zip(two, one):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_stats_record_prune_trajectory():
    results, stats = BatchedExtractor(backend="ref").run(_blob_cases())
    assert stats["cases"] == 3 and stats["cases_per_second"] > 0
    assert 0.0 < stats["mean_keep_fraction"] <= 1.0
    assert stats["prune_seconds"] >= 0.0
    assert stats["pruned_cases"] >= 2  # 48^3 blobs must actually shrink


# ---------------------------------------------------------------------------
# seeded mirrors of the hypothesis pruning-invariant properties
# ---------------------------------------------------------------------------


def _cloud(seed, m):
    rng = np.random.default_rng(seed)
    verts = (rng.normal(size=(m, 3)) * rng.uniform(1.0, 60.0)).astype(np.float32)
    mask = rng.random(m) > 0.2
    if mask.sum() < 2:
        mask[:2] = True
    return verts, mask


@pytest.mark.parametrize("seed", range(6))
def test_pruned_set_contains_farthest_pair_endpoints(seed):
    verts, mask = _cloud(seed, 128 + 16 * seed)
    keep, _ = prune.candidate_keep_mask(verts, mask)
    keep = np.asarray(keep)
    valid = np.nonzero(mask)[0]
    v = verts[valid]
    d = v[:, None, :] - v[None, :, :]
    q = (d * d).astype(np.float32)
    planes = (q[..., 0] + q[..., 1] + q[..., 2], q[..., 0] + q[..., 1],
              q[..., 0] + q[..., 2], q[..., 1] + q[..., 2])
    for s in planes:
        ii, jj = np.nonzero(s == s.max())
        for i in np.unique(np.concatenate([valid[ii], valid[jj]])):
            assert keep[i], f"true endpoint {i} pruned away (seed {seed})"


@pytest.mark.parametrize("seed", range(4))
def test_m_prime_never_exceeds_m(seed):
    verts, mask = _cloud(seed, 200)
    _, _, info = prune.prune_vertices(verts, mask)
    assert info.m_kept <= info.m_valid <= info.m_total


@pytest.mark.parametrize("seed", range(3))
def test_batched_prune_matches_single_prune_diameters(seed):
    """The vmapped pass-1 bound may tie-break differently from the single
    path, but both surviving sets must yield bit-identical diameters."""
    stack_v, stack_m = zip(*(_cloud(seed * 10 + j, 96) for j in range(3)))
    batch = ops.prune_candidates_batch(np.stack(stack_v), np.stack(stack_m))
    assert len(batch) == 3  # no case dropped or duplicated
    for (v, m), (v2, m2, info) in zip(zip(stack_v, stack_m), batch):
        assert info.m_kept <= info.m_valid
        sv, sm, _ = ops.prune_candidates(v, m)
        a = np.asarray(dk.max_diameters_sq_pallas(v2, m2, block=64, interpret=True))
        b = np.asarray(dk.max_diameters_sq_pallas(sv, sm, block=64, interpret=True))
        np.testing.assert_array_equal(a, b)


def test_group_indices_is_a_partition():
    keys = ["a", None, "b", "a", "c", None, "b", "a"]
    groups = group_indices(keys)
    flat = sorted(i for idxs in groups.values() for i in idxs)
    assert flat == [i for i, k in enumerate(keys) if k is not None]
    assert groups["a"] == [0, 3, 7]  # order-preserving within a group
