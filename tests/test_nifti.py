"""NIfTI IO: round-trip, header quirks, feature-extraction integration.

The header-quirk cases are the real-world loader bugs PR 7 flushed out:
``scl_slope``/``scl_inter`` rescaling silently ignored (wrong intensity
features from rescaled CT exports), degenerate 4D single-timepoint files
rejected, and big-endian files misread as garbage instead of erroring.
"""
import gzip
import struct

import numpy as np
import pytest

from repro.data.nifti import read_nifti, write_nifti
from repro.data.synthetic import make_case

pytestmark = pytest.mark.tier1


@pytest.mark.parametrize("gz", [False, True])
@pytest.mark.parametrize("dtype", [np.uint8, np.int16, np.float32])
def test_roundtrip(tmp_path, gz, dtype):
    rng = np.random.default_rng(0)
    data = (rng.random((9, 7, 5)) * 50).astype(dtype)
    sp = (0.7, 1.2, 3.0)
    p = tmp_path / ("vol.nii.gz" if gz else "vol.nii")
    write_nifti(p, data, sp)
    got, spacing = read_nifti(p)
    np.testing.assert_array_equal(got, data)
    np.testing.assert_allclose(spacing, sp, rtol=1e-6)


@pytest.mark.parametrize("slope,inter", [(2.0, 0.0), (1.0, -1024.0),
                                         (0.5, 100.0), (0.0, -1024.0)])
def test_scl_slope_inter_applied(tmp_path, slope, inter):
    stored = np.arange(24, dtype=np.int16).reshape(4, 3, 2)
    p = tmp_path / "ct.nii"
    write_nifti(p, stored, scl_slope=slope, scl_inter=inter)
    got, _ = read_nifti(p)
    # slope 0 means "unset" per the standard: applied as 1
    eff = slope if slope != 0.0 else 1.0
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, eff * stored + inter, rtol=1e-6)


@pytest.mark.parametrize("slope,inter", [(0.0, 0.0), (1.0, 0.0)])
def test_scl_noop_header_keeps_stored_values(tmp_path, slope, inter):
    stored = np.arange(24, dtype=np.int16).reshape(4, 3, 2)
    p = tmp_path / "raw.nii"
    write_nifti(p, stored, scl_slope=slope, scl_inter=inter)
    got, _ = read_nifti(p)
    assert got.dtype == np.int16  # untouched, not silently floated
    np.testing.assert_array_equal(got, stored)


def test_degenerate_4d_single_timepoint_squeezed(tmp_path):
    vol = (np.random.default_rng(0).random((6, 5, 4)) * 40).astype(np.float32)
    p = tmp_path / "t1.nii"
    write_nifti(p, vol[..., None])  # 4D export, one timepoint
    got, _ = read_nifti(p)
    assert got.shape == (6, 5, 4)
    np.testing.assert_array_equal(got, vol)
    # genuinely 4D data still refuses
    p2 = tmp_path / "dyn.nii"
    write_nifti(p2, np.zeros((4, 4, 4, 3), np.float32))
    with pytest.raises(ValueError, match="1-3D"):
        read_nifti(p2)


@pytest.mark.parametrize("gz", [False, True])
def test_big_endian_clear_error(tmp_path, gz):
    p = tmp_path / ("be.nii.gz" if gz else "be.nii")
    write_nifti(tmp_path / "le.nii", np.zeros((3, 3, 3), np.uint8))
    raw = bytearray((tmp_path / "le.nii").read_bytes())
    # byte-swap sizeof_hdr: the standard's endianness marker
    struct.pack_into(">i", raw, 0, 348)
    p.write_bytes(gzip.compress(bytes(raw)) if gz else bytes(raw))
    with pytest.raises(ValueError, match="byte order unsupported"):
        read_nifti(p)


def test_intensity_features_from_rescaled_nifti(tmp_path):
    """scl-rescaled CT + firstorder family: the end-to-end loader fix."""
    img, msk, sp = make_case((18, 16, 14), seed=3)
    stored = np.round(img * 2.0).astype(np.int16)  # quantised export
    write_nifti(tmp_path / "ct.nii.gz", stored, sp,
                scl_slope=0.5, scl_inter=-10.0)
    write_nifti(tmp_path / "m.nii.gz", msk.astype(np.uint8), sp)
    image, _ = read_nifti(tmp_path / "ct.nii.gz")
    mask, spacing = read_nifti(tmp_path / "m.nii.gz")

    from repro.core.executor import PlanExecutor

    ex = PlanExecutor(backend="ref", families="firstorder")
    got = ex.extract_one(image, mask, spacing)
    want = ex.extract_one(0.5 * stored.astype(np.float32) - 10.0,
                          msk.astype(np.float32), sp)
    np.testing.assert_array_equal(got, want)


def test_feature_extraction_from_nifti(tmp_path):
    img, msk, sp = make_case((24, 20, 18), seed=5)
    write_nifti(tmp_path / "scan.nii.gz", img.astype(np.float32), sp)
    write_nifti(tmp_path / "mask.nii.gz", msk.astype(np.uint8), sp)

    image, _ = read_nifti(tmp_path / "scan.nii.gz")
    mask, spacing = read_nifti(tmp_path / "mask.nii.gz")

    from repro.core.shape_features import ShapeFeatureExtractor

    res = ShapeFeatureExtractor(backend="ref").execute(image, mask, spacing)
    want = ShapeFeatureExtractor(backend="ref").execute(img, msk, sp)
    for k in ("MeshVolume", "SurfaceArea", "Maximum3DDiameter"):
        np.testing.assert_allclose(res[k], want[k], rtol=1e-6)


# -- windowed slab reader (the out-of-core tiling IO path, PR 9) -------------


def test_slab_reader_matches_full_read(tmp_path):
    from repro.data.nifti import read_nifti_slab

    rng = np.random.default_rng(11)
    data = (rng.random((7, 6, 13)) * 100).astype(np.int16)
    sp = (0.9, 1.1, 2.5)
    p = tmp_path / "vol.nii"
    write_nifti(p, data, sp, scl_slope=0.25, scl_inter=-5.0)
    full, spacing = read_nifti(p)
    for z0, z1 in ((0, 13), (0, 4), (5, 9), (12, 13), (6, 6)):
        slab, sp_slab = read_nifti_slab(p, z0, z1)
        assert slab.shape == (7, 6, z1 - z0)
        np.testing.assert_array_equal(slab, full[:, :, z0:z1])
        np.testing.assert_allclose(sp_slab, spacing, rtol=1e-6)
    with pytest.raises(ValueError, match="out of range"):
        read_nifti_slab(p, 0, 14)
    with pytest.raises(ValueError, match="out of range"):
        read_nifti_slab(p, -1, 4)


def test_slab_reader_refuses_gz_with_workaround(tmp_path):
    from repro.data.nifti import read_nifti_slab

    p = tmp_path / "vol.nii.gz"
    write_nifti(p, np.zeros((4, 4, 8), np.uint8))
    with pytest.raises(ValueError, match=r"gunzip.*\.nii file"):
        read_nifti_slab(p, 0, 2)
    # gz content behind a .nii name is sniffed, not trusted by suffix
    sneaky = tmp_path / "sneaky.nii"
    sneaky.write_bytes(p.read_bytes())
    with pytest.raises(ValueError, match="do not support seeking"):
        read_nifti_slab(sneaky, 0, 2)


def test_header_peek_matches_full_read(tmp_path):
    from repro.data.nifti import read_nifti_header

    data = (np.arange(4 * 3 * 5) % 7).astype(np.uint8).reshape(4, 3, 5)
    for name in ("v.nii", "v.nii.gz"):
        p = tmp_path / name
        write_nifti(p, data, (1.5, 2.0, 0.5), scl_slope=3.0, scl_inter=1.0)
        hdr = read_nifti_header(p)
        assert hdr.shape3 == (4, 3, 5)
        assert hdr.dtype == np.uint8
        assert hdr.data_bytes == 60
        assert hdr.gzipped == name.endswith(".gz")
        assert (hdr.scl_slope, hdr.scl_inter) == (3.0, 1.0)
        np.testing.assert_allclose(hdr.spacing, (1.5, 2.0, 0.5), rtol=1e-6)


def test_slab_reader_truncated_data_errors(tmp_path):
    from repro.data.nifti import read_nifti_slab

    p = tmp_path / "trunc.nii"
    write_nifti(p, np.ones((4, 4, 6), np.int16))
    raw = p.read_bytes()
    p.write_bytes(raw[: len(raw) - 40])  # chop the tail planes
    read_nifti_slab(p, 0, 3)  # early planes still intact
    with pytest.raises(ValueError, match="truncated NIfTI data"):
        read_nifti_slab(p, 4, 6)
