"""NIfTI IO: round-trip + feature-extraction integration."""
import numpy as np
import pytest

from repro.data.nifti import read_nifti, write_nifti
from repro.data.synthetic import make_case


@pytest.mark.parametrize("gz", [False, True])
@pytest.mark.parametrize("dtype", [np.uint8, np.int16, np.float32])
def test_roundtrip(tmp_path, gz, dtype):
    rng = np.random.default_rng(0)
    data = (rng.random((9, 7, 5)) * 50).astype(dtype)
    sp = (0.7, 1.2, 3.0)
    p = tmp_path / ("vol.nii.gz" if gz else "vol.nii")
    write_nifti(p, data, sp)
    got, spacing = read_nifti(p)
    np.testing.assert_array_equal(got, data)
    np.testing.assert_allclose(spacing, sp, rtol=1e-6)


def test_feature_extraction_from_nifti(tmp_path):
    img, msk, sp = make_case((24, 20, 18), seed=5)
    write_nifti(tmp_path / "scan.nii.gz", img.astype(np.float32), sp)
    write_nifti(tmp_path / "mask.nii.gz", msk.astype(np.uint8), sp)

    image, _ = read_nifti(tmp_path / "scan.nii.gz")
    mask, spacing = read_nifti(tmp_path / "mask.nii.gz")

    from repro.core.shape_features import ShapeFeatureExtractor

    res = ShapeFeatureExtractor(backend="ref").execute(image, mask, spacing)
    want = ShapeFeatureExtractor(backend="ref").execute(img, msk, sp)
    for k in ("MeshVolume", "SurfaceArea", "Maximum3DDiameter"):
        np.testing.assert_allclose(res[k], want[k], rtol=1e-6)
