"""Train-step unit tests: chunked CE, loss masking, grad accumulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.registry import get_config, get_model
from repro.train.train_step import cross_entropy, make_loss_fn, make_train_step


def test_cross_entropy_matches_naive():
    rng = np.random.default_rng(0)
    b, s, v, vp = 2, 8, 11, 16
    logits = jnp.asarray(rng.normal(size=(b, s, vp)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = cross_entropy(logits, labels, v, chunk=4)
    # naive masked softmax CE
    x = np.array(logits)  # writable copy
    x[..., v:] = -1e30
    x = x - x.max(-1, keepdims=True)
    lse = np.log(np.exp(x).sum(-1))
    gold = np.take_along_axis(x, np.asarray(labels)[..., None], -1)[..., 0]
    want = (lse - gold).mean()
    np.testing.assert_allclose(float(got), want, rtol=1e-5)


def test_cross_entropy_weights_mask_positions():
    rng = np.random.default_rng(1)
    b, s, vp = 2, 6, 8
    logits = jnp.asarray(rng.normal(size=(b, s, vp)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, vp, (b, s)), jnp.int32)
    w = jnp.ones((b, s)).at[:, -1].set(0.0)
    # perturbing the masked position's logits must not change the loss
    l1 = cross_entropy(logits, labels, vp, weights=w)
    logits2 = logits.at[:, -1, :].add(7.0)
    l2 = cross_entropy(logits2, labels, vp, weights=w)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_loss_fn_full_sequence_no_shift_leak():
    """The loss must not depend on a 'future' token beyond the mask.

    Changing the LAST token of the batch changes only the label of
    position S-2 and the (masked) position S-1 input; with causal masking
    and the loss mask this must equal the explicitly shifted formulation.
    """
    cfg = get_config("qwen3-1.7b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss_fn = make_loss_fn(model, RunConfig())
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    l1, _ = loss_fn(params, {"tokens": tokens})

    # manual shifted-CE oracle on the same params
    logits, _ = model.forward(params, tokens)
    want = cross_entropy(
        logits[:, :-1], tokens[:, 1:], cfg.vocab_size, zloss=cfg.zloss
    )
    np.testing.assert_allclose(float(l1), float(want), rtol=2e-5, atol=1e-5)


def test_gather_weights_once_matches_manual_accumulation():
    """§Perf/2 it.3 option: grad-of-scan with a hoisted weight constraint
    must equal the manual per-micro accumulation exactly."""
    cfg = get_config("qwen3-1.7b").reduced()
    model = get_model(cfg)
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}
    params = model.init(jax.random.PRNGKey(1))
    from repro.train import optimizer as opt

    outs = {}
    for gw in (False, True):
        run = RunConfig(microbatch=2, learning_rate=1e-2, warmup_steps=1,
                        gather_weights_once=gw)
        step = jax.jit(make_train_step(model, run))
        p, o = jax.tree.map(lambda x: x, params), opt.init_opt_state(params)
        p2, _, m = step(p, o, batch)
        outs[gw] = (float(m["loss"]), p2)
    np.testing.assert_allclose(outs[False][0], outs[True][0], rtol=1e-6)
    # 'exactly' up to summation order: hoisting the weight constraint
    # reassociates the per-micro gradient adds, so parameters differ by
    # f32 accumulation noise ~ eps * |grad| * n_micro (observed ~2e-5 on
    # O(1) updates); 5e-5 abs + 2e-4 rel bounds that with margin while
    # still catching any real (>1 ulp-scale) divergence.
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=5e-5
        ),
        outs[False][1], outs[True][1],
    )


def test_grad_accumulation_matches_single_batch():
    cfg = get_config("granite-3-2b").reduced()
    model = get_model(cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}

    params = model.init(jax.random.PRNGKey(1))
    from repro.train import optimizer as opt

    out = {}
    for mb in (0, 2):
        run = RunConfig(microbatch=mb, learning_rate=1e-2, warmup_steps=1)
        step = jax.jit(make_train_step(model, run))
        p, o = jax.tree.map(lambda x: x, params), opt.init_opt_state(params)
        p2, _, m = step(p, o, batch)
        out[mb] = (m["loss"], p2)
    np.testing.assert_allclose(float(out[0][0]), float(out[2][0]), rtol=1e-5)
    # f32 accumulation-order differences (XLA CPU reductions are not
    # run-deterministic) pass through Adam's rsqrt; one update has
    # magnitude <= lr (1e-2), so 2e-3 absolute = "identical up to a fifth
    # of one update".  The loss equality above is the exact-accumulation
    # check; this bounds the optimizer path.
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-2, atol=2e-3
        ),
        out[0][1], out[2][1],
    )
