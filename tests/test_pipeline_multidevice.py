"""Sharded two-pass pipeline over 8 forced host devices: bit-identity.

Pass 1 (pruning bound + device compaction) and pass 2 (MC + diameter
sub-batches) both shard over the mesh's ``data`` axis.  This test runs the
real collective path -- 8 host CPU devices, ``shard_map`` pass 1, sharded
``jit`` pass 2 -- on the Pallas 'interpret' backend and checks the feature
rows are **bit-identical** to the unsharded single-device run (batches are
padded to the data-axis multiple with duplicate rows, so per-case kernel
shapes never change).  The mesh is delivered via the ambient
``use_mesh`` context to cover the BatchedExtractor's mesh pickup.  Same
subprocess pattern as tests/test_compression_multidevice.py.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.tier1

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_AUTOTUNE"] = "0"
    import jax, numpy as np
    from repro.core.pipeline import BatchedExtractor
    from repro.parallel.sharding import use_mesh
    from repro.data.synthetic import make_case

    assert jax.device_count() == 8, jax.device_count()
    cases = [make_case((18, 16, 14), seed=s) for s in (1, 2, 3)]
    cases.append((np.zeros((8, 8, 8), np.float32),
                  np.zeros((8, 8, 8), np.float32), (1.0, 1.0, 1.0)))

    base, bstats = BatchedExtractor(backend="interpret").run(cases)
    assert bstats["data_parallel"] == 1 and bstats["device_compact"]

    mesh = jax.make_mesh((8,), ("data",))
    with use_mesh(mesh):
        bx = BatchedExtractor(backend="interpret")
    assert bx.mesh is mesh  # picked up from the ambient use_mesh context
    sharded, sstats = bx.run(cases)
    assert sstats["data_parallel"] == 8
    assert sstats["empty_cases"] == bstats["empty_cases"] == 1

    for i, (a, b) in enumerate(zip(base, sharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"case {i}")
    print("SHARDED-PIPELINE-OK")
    """
)


def test_sharded_two_pass_bit_identical_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert "SHARDED-PIPELINE-OK" in out.stdout, out.stdout + out.stderr
