"""Model zoo tests: reduced-config smoke + decode/forward equivalence.

Decode equivalence is the cache-correctness test: teacher-forcing tokens
one at a time through ``decode_step`` must reproduce the training
``forward`` logits (same params, same tokens).
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.registry import list_archs, get_config, get_model
from repro.models.encdec import EncDec, enc_len_for

B, S = 2, 24


def _reduced(name):
    cfg = get_config(name).reduced(capacity_factor=8.0)  # no MoE drops
    return cfg, get_model(cfg)


def _tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)


@pytest.mark.parametrize("name", list_archs())
def test_forward_shapes_finite(name):
    cfg, model = _reduced(name)
    params = model.init(jax.random.PRNGKey(0))
    tokens = _tokens(cfg)
    if isinstance(model, EncDec):
        frames = jnp.full((B, enc_len_for(S), cfg.d_model), 0.1, jnp.float32)
        logits, aux = jax.jit(model.forward)(params, tokens, frames)
    elif cfg.frontend_tokens:
        pre = jnp.full((B, cfg.frontend_tokens, cfg.d_model), 0.1)
        logits, aux = jax.jit(model.forward)(params, tokens, prefix_embeds=pre)
        assert logits.shape == (B, S + cfg.frontend_tokens, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits)))
        return
    else:
        logits, aux = jax.jit(model.forward)(params, tokens)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list_archs())
def test_decode_matches_forward(name):
    cfg, model = _reduced(name)
    params = model.init(jax.random.PRNGKey(1))
    tokens = _tokens(cfg, seed=1)
    if isinstance(model, EncDec):
        frames = jnp.full((B, enc_len_for(S), cfg.d_model), 0.1, jnp.float32)
        want, _ = jax.jit(model.forward)(params, tokens, frames)
        cache = model.init_cache(B, S, dtype=jnp.float32, enc_len=enc_len_for(S))
        cache = jax.jit(model.prefill_encoder)(params, cache, frames)
    elif cfg.frontend_tokens:
        pytest.skip("vlm decode tested via dense family (same Decoder)")
    else:
        want, _ = jax.jit(model.forward)(params, tokens)
        cache = model.init_cache(B, S, dtype=jnp.float32)

    step = jax.jit(model.decode_step)
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t : t + 1])
        got.append(np.asarray(logits[:, 0]))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-3, atol=2e-3)


def test_grad_flows_dense():
    cfg, model = _reduced("qwen3-1.7b")
    params = model.init(jax.random.PRNGKey(2))
    tokens = _tokens(cfg, 2)

    def loss(p):
        logits, aux = model.forward(p, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        ll = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
        return -jnp.mean(ll) + aux

    g = jax.grad(loss)(params)
    norms = [float(jnp.linalg.norm(x)) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(norms)) and sum(norms) > 0


def test_grad_flows_moe_and_aux():
    cfg, model = _reduced("deepseek-moe-16b")
    params = model.init(jax.random.PRNGKey(3))
    tokens = _tokens(cfg, 3)

    def loss(p):
        logits, aux = model.forward(p, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        ll = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
        return -jnp.mean(ll) + aux

    val, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    rnorm = float(jnp.linalg.norm(g["layers"]["moe"]["router"]))
    assert np.isfinite(rnorm) and rnorm > 0  # router receives gradient


def test_hybrid_window_vs_full_differ():
    cfg, model = _reduced("hymba-1.5b")
    cfg_full = dataclasses.replace(cfg, attn_window=0, global_attn_layers=())
    params = model.init(jax.random.PRNGKey(4))
    tokens = _tokens(cfg, 4)
    a, _ = jax.jit(model.forward)(params, tokens)
    model_full = get_model(cfg_full)
    b_, _ = jax.jit(model_full.forward)(params, tokens)
    assert not np.allclose(np.asarray(a), np.asarray(b_))


def test_param_counts_full_configs():
    """Full (non-reduced) configs land near their nominal sizes."""
    approx = {
        "arctic-480b": 480e9,
        "deepseek-moe-16b": 16e9,
        "nemotron-4-15b": 15e9,
        "qwen3-1.7b": 1.7e9,
        "minicpm-2b": 2.4e9,
        "granite-3-2b": 2.5e9,
        "rwkv6-1.6b": 1.6e9,
        "hymba-1.5b": 1.5e9,
    }
    for name, want in approx.items():
        n = get_config(name).n_params
        assert 0.5 * want < n < 1.8 * want, (name, n, want)
