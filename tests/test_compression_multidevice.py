"""int8 error-feedback gradient sync under shard_map over 4 devices.

The cross-pod data-parallel all-reduce is the compression target
(parallel/compression.py).  This test runs the real collective path:
4 host devices, per-shard gradients, compressed psum — and checks (a) the
reduced value approximates the true mean within one quantisation step and
(b) error feedback keeps the *accumulated* drift bounded over many steps.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel import compression as comp
    from repro.parallel.sharding import shard_map_compat

    mesh = jax.make_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    G = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)  # per-worker grads

    def sync(g, e):
        out, ne = comp.compressed_psum_tree({"g": g}, {"g": e},
                                            axis_name="data")
        return out["g"], ne["g"]

    shmap = shard_map_compat(sync, mesh=mesh,
                             in_specs=(P("data"), P("data")),
                             out_specs=(P("data"), P("data")))

    err = jnp.zeros((4, 64), jnp.float32)
    acc = jnp.zeros((64,), jnp.float32)
    true_acc = jnp.zeros((64,), jnp.float32)
    for step in range(30):
        g = G * (1.0 + 0.1 * step)
        out, err = shmap(g, err)
        # every shard received the same mean
        o = np.asarray(out)
        np.testing.assert_allclose(o[0], o[1], atol=1e-6)
        acc = acc + o[0]
        true_acc = true_acc + np.asarray(g).mean(0)
        step_size = float(np.abs(np.asarray(g)).max()) / 127.0
        np.testing.assert_allclose(o[0], np.asarray(g).mean(0),
                                   atol=2.0 * step_size)
    # error feedback: accumulated drift stays ~one quantisation step
    drift = np.abs(np.asarray(acc - true_acc)).max()
    bound = 4.0 * float(np.abs(np.asarray(G)).max() * 4.0) / 127.0
    assert drift < bound, (drift, bound)
    print("COMPRESS-OK")
    """
)


def test_compressed_allreduce_four_workers():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert "COMPRESS-OK" in out.stdout, out.stdout + out.stderr
