"""Autotune cache: versioned schema round-trip, v1/v2 migration, MC sweeps.

The cache outlives code versions (it sits in ~/.cache across PRs), so the
failure modes under test are the real ones: PR 1 wrote a flat schema-less
JSON object; PR 2/3 wrote a v2 envelope whose keys carry no batch-depth
segment; files can be truncated or hand-edited; entries can reference
configurations that no longer validate.  Every one of those must degrade
to a re-sweep (or, for v1/v2, migrate to the depth-1 slot of the v3 key
space), never a crash, and diameter + MC + compact entries must coexist
in one file.
"""
import json
import os

import numpy as np
import pytest

from repro.runtime import autotune

pytestmark = pytest.mark.tier1

SHAPE = (16, 16, 16)
# restricted candidate sets: keep interpret-mode measuring sweeps cheap
MC_RESTRICT = dict(blocks=((8, 8, 8),), chunks=(256,))


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    return path


def _v1_payload():
    # PR 1-era flat layout: no "schema" field, keys at top level
    return {
        "diameter/interpret/M256": {
            "variant": "gram", "block": 128, "us": 11.0,
            "table": {"gram/128": 11.0}, "swept_at": "2026-01-01T00:00:00",
        }
    }


# ---------------------------------------------------------------------------
# schema round-trip + migration
# ---------------------------------------------------------------------------


def test_v3_schema_roundtrip_mixed_entries(cache_path):
    cache = autotune.AutotuneCache()
    cache.put(autotune.sweep_key(512, "interpret"),
              {"variant": "seqacc", "block": 256, "us": 1.0, "table": {}})
    cache.put(autotune.mc_key(SHAPE, "interpret"),
              {"block": [8, 8, 8], "chunk": 256, "us": 2.0, "table": {}})
    cache.put(autotune.sweep_key(512, "interpret", batch=8),
              {"variant": "gram", "block": 128, "us": 0.5, "table": {}})
    raw = json.load(open(cache_path))
    assert raw["schema"] == autotune.SCHEMA_VERSION
    assert set(raw["entries"]) == {
        "diameter/interpret/M512/B1", "mc/interpret/S16x16x16/B1",
        "diameter/interpret/M512/B8",
    }
    assert cache.get("diameter/interpret/M512/B1")["variant"] == "seqacc"
    assert cache.get("mc/interpret/S16x16x16/B1")["chunk"] == 256
    # depth buckets are independent slots of the same (backend, bucket)
    assert cache.get("diameter/interpret/M512/B8")["variant"] == "gram"


def test_batch_bucket_is_a_pow2_ladder():
    assert [autotune.batch_bucket(b) for b in (1, 2, 3, 4, 5, 8, 9, 33)] == \
        [1, 2, 4, 4, 8, 8, 16, 64]
    assert autotune.sweep_key(256, "pallas", batch=6) == \
        "diameter/pallas/M256/B8"
    assert autotune.compact_key(1024, "pallas", batch=3) == \
        "compact/pallas/M1024/B4"
    assert autotune.mc_key(SHAPE, "pallas", batch=2) == \
        "mc/pallas/S16x16x16/B2"


def test_v1_file_migrates_on_load(cache_path, monkeypatch):
    with open(cache_path, "w") as f:
        json.dump(_v1_payload(), f)
    # the migrated entry must satisfy the config lookup WITHOUT a sweep
    monkeypatch.setattr(
        autotune, "sweep_diameter",
        lambda *a, **k: pytest.fail("migrated v1 entry ignored: re-swept"),
    )
    cfg = autotune.get_diameter_config(256, "interpret")
    assert cfg == autotune.DiameterConfig("gram", 128)


def test_v1_file_upgraded_and_preserved_on_put(cache_path):
    with open(cache_path, "w") as f:
        json.dump(_v1_payload(), f)
    cache = autotune.AutotuneCache()
    cache.put(autotune.mc_key(SHAPE, "interpret"),
              {"block": [8, 8, 8], "chunk": 512, "us": 3.0, "table": {}})
    raw = json.load(open(cache_path))
    assert raw["schema"] == autotune.SCHEMA_VERSION
    # the PR 1 diameter entry rode along into the v3 envelope, migrated
    # to the depth-1 slot (PR 1 sweeps measured single-case launches)
    assert raw["entries"]["diameter/interpret/M256/B1"]["variant"] == "gram"
    assert raw["entries"]["mc/interpret/S16x16x16/B1"]["chunk"] == 512


def _v2_payload():
    # PR 2/3-era layout: versioned envelope, depth-less keys
    return {
        "schema": 2,
        "entries": {
            "diameter/interpret/M256": {
                "variant": "gram", "block": 128, "us": 11.0,
                "table": {"gram/128": 11.0},
            },
            "compact/interpret/M1024": {"block": 256, "us": 9.0, "table": {}},
            "mc/interpret/S16x16x16": {
                "block": [16, 8, 8], "chunk": 256, "us": 2.0, "table": {},
            },
            "bogus-non-dict": 17,
        },
    }


def test_v2_file_migrates_on_load(cache_path, monkeypatch):
    """Every v2 entry kind resolves from its migrated /B1 slot, sweep-free;
    a depth the v2 file never measured still re-sweeps."""
    with open(cache_path, "w") as f:
        json.dump(_v2_payload(), f)
    for name in ("sweep_diameter", "sweep_mc", "sweep_compact"):
        monkeypatch.setattr(
            autotune, name,
            lambda *a, **k: pytest.fail("migrated v2 entry ignored: re-swept"),
        )
    assert autotune.get_diameter_config(256, "interpret") == \
        autotune.DiameterConfig("gram", 128)
    assert autotune.get_compact_config(1024, "interpret") == \
        autotune.CompactConfig(256)
    assert autotune.get_mc_config(SHAPE, "interpret") == \
        autotune.MCConfig((16, 8, 8), 256)
    # an unmeasured depth is a miss: the B4 slot must sweep
    swept = []
    monkeypatch.setattr(
        autotune, "sweep_diameter",
        lambda *a, **k: (
            swept.append(a) or (autotune.DiameterConfig("seqacc", 128),
                                {"seqacc/128": 1.0})
        ),
    )
    autotune.get_diameter_config(256, "interpret", batch=4)
    assert len(swept) == 1


def test_v2_file_upgraded_and_preserved_on_put(cache_path):
    with open(cache_path, "w") as f:
        json.dump(_v2_payload(), f)
    cache = autotune.AutotuneCache()
    cache.put(autotune.sweep_key(256, "interpret", batch=4),
              {"variant": "seqacc", "block": 128, "us": 1.0, "table": {}})
    raw = json.load(open(cache_path))
    assert raw["schema"] == autotune.SCHEMA_VERSION
    assert set(raw["entries"]) == {
        "diameter/interpret/M256/B1", "compact/interpret/M1024/B1",
        "mc/interpret/S16x16x16/B1", "diameter/interpret/M256/B4",
    }  # migrated + new depth slot; the malformed non-dict entry dropped


def test_unknown_future_schema_resweeps_without_destroying_file(
        cache_path, monkeypatch):
    """A schema from a NEWER code version reads as empty (re-sweep) but is
    never rewritten: losing the newer version's entries would exceed the
    documented 'worst case: re-measure' contract."""
    future = {"schema": 99, "entries": _v1_payload()}
    with open(cache_path, "w") as f:
        json.dump(future, f)
    sweeps = []
    orig = autotune.sweep_diameter

    def counting(*a, **kw):
        sweeps.append(a)
        kw["variants"], kw["blocks"] = ("seqacc",), (128,)
        return orig(*a, **kw)

    monkeypatch.setattr(autotune, "sweep_diameter", counting)
    cfg = autotune.get_diameter_config(256, "interpret")
    assert len(sweeps) == 1 and cfg.variant == "seqacc"
    assert json.load(open(cache_path)) == future  # untouched
    # ... and with no cached winner, the next lookup re-sweeps again
    autotune.get_diameter_config(256, "interpret")
    assert len(sweeps) == 2


def test_malformed_file_reads_empty_and_recovers(cache_path):
    with open(cache_path, "w") as f:
        f.write("{ not json !!")
    cache = autotune.AutotuneCache()
    assert cache.get("diameter/interpret/M256") is None
    cache.put("k", {"v": 1})  # recovery: put overwrites the broken file
    assert cache.get("k") == {"v": 1}


# ---------------------------------------------------------------------------
# MC brick sweep: round-trip, stale-entry re-sweep, coexistence
# ---------------------------------------------------------------------------


def test_mc_sweep_roundtrip_caches_once(cache_path, monkeypatch):
    sweeps = []
    orig = autotune.sweep_mc

    def counting(*a, **kw):
        sweeps.append(a)
        kw.update(MC_RESTRICT)
        return orig(*a, **kw)

    monkeypatch.setattr(autotune, "sweep_mc", counting)
    cfg1 = autotune.get_mc_config(SHAPE, "interpret")
    assert len(sweeps) == 1
    cfg2 = autotune.get_mc_config(SHAPE, "interpret")
    assert len(sweeps) == 1  # pure cache read
    assert cfg1 == cfg2 == autotune.MCConfig((8, 8, 8), 256)
    rec = autotune.AutotuneCache().get(autotune.mc_key(SHAPE, "interpret"))
    assert rec["block"] == [8, 8, 8] and rec["chunk"] == 256
    assert rec["table"]  # the measured table is the persisted trajectory


@pytest.mark.parametrize("bad", [
    {"block": "bogus", "chunk": 256},
    {"block": [8, 8], "chunk": 256},          # wrong rank
    {"block": [8, 8, 8], "chunk": 7},         # chunk no longer tiles brick
    {"block": [8, -8, 8], "chunk": 256},
    {"chunk": 256},
])
def test_malformed_or_stale_mc_entry_triggers_resweep(cache_path, bad):
    cache = autotune.AutotuneCache()
    cache.put(autotune.mc_key(SHAPE, "interpret"), bad)
    cfg = autotune.get_mc_config(SHAPE, "interpret", **MC_RESTRICT)
    assert cfg == autotune.MCConfig((8, 8, 8), 256)  # swept, not crashed
    rec = cache.get(autotune.mc_key(SHAPE, "interpret"))
    assert rec["block"] == [8, 8, 8] and rec["chunk"] == 256


def test_mc_and_diameter_entries_coexist(cache_path, monkeypatch):
    monkeypatch.setattr(
        autotune, "sweep_diameter",
        lambda bucket, backend, **kw: (
            autotune.DiameterConfig("seqacc", 64), {"seqacc/64": 1.0}
        ),
    )
    autotune.get_diameter_config(128, "interpret")
    autotune.get_mc_config(SHAPE, "interpret", **MC_RESTRICT)
    raw = json.load(open(cache_path))
    assert set(raw["entries"]) == {
        "diameter/interpret/M128/B1", "mc/interpret/S16x16x16/B1"
    }
    # each lookup reads back only its own entry
    assert autotune.get_diameter_config(128, "interpret").block == 64
    assert autotune.get_mc_config(SHAPE, "interpret").chunk == 256


def test_mc_disabled_returns_default_uncached(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    path = str(tmp_path / "at.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    assert autotune.get_mc_config(SHAPE, "interpret") == autotune.DEFAULT_MC_CONFIG
    assert not os.path.exists(path)


def test_mc_ref_backend_has_no_axis(cache_path):
    assert autotune.get_mc_config(SHAPE, "ref") == autotune.DEFAULT_MC_CONFIG


# ---------------------------------------------------------------------------
# dispatcher / extractor wiring for mc_block='auto'
# ---------------------------------------------------------------------------


def test_dispatcher_mc_auto_reads_cached_entry(cache_path):
    from repro.core import dispatcher

    bucket = autotune.mc_shape_bucket((30, 29, 31))
    assert bucket == (32, 32, 32)
    autotune.AutotuneCache().put(
        autotune.mc_key(bucket, "interpret"),
        {"block": [16, 8, 8], "chunk": 512, "us": 1.0, "table": {}},
    )
    blk, chunk = dispatcher.mc_config("interpret", (30, 29, 31))
    assert (blk, chunk) == ((16, 8, 8), 512)
    # explicit values always win over the tuned entry
    blk, chunk = dispatcher.mc_config("interpret", (30, 29, 31),
                                      block=(8, 8, 8), chunk=256)
    assert (blk, chunk) == ((8, 8, 8), 256)
    # ref backend: the choice is moot
    assert dispatcher.mc_config("ref", (30, 29, 31))[0] == (8, 8, 8)


def test_extractor_mc_autotune_roundtrip(cache_path, monkeypatch):
    """Second execute() with the same shape bucket reads the cached MC
    (brick, chunk) without re-sweeping -- the MC analogue of the diameter
    autotune acceptance test."""
    from conftest import sphere_mask
    from repro.core.shape_features import ShapeFeatureExtractor

    mc_sweeps, diam_sweeps = [], []
    orig_mc, orig_d = autotune.sweep_mc, autotune.sweep_diameter

    def counting_mc(*a, **kw):
        mc_sweeps.append(a)
        kw.update(MC_RESTRICT)
        return orig_mc(*a, **kw)

    def counting_d(*a, **kw):
        diam_sweeps.append(a)
        kw["variants"], kw["blocks"] = ("seqacc",), (256,)
        return orig_d(*a, **kw)

    monkeypatch.setattr(autotune, "sweep_mc", counting_mc)
    monkeypatch.setattr(autotune, "sweep_diameter", counting_d)
    img = np.zeros((12, 12, 12), np.float32)
    msk = sphere_mask(12, 4.0)
    f1 = ShapeFeatureExtractor(backend="interpret").execute(img, msk)
    n_mc, n_d = len(mc_sweeps), len(diam_sweeps)
    assert n_mc == 1 and n_d >= 1
    f2 = ShapeFeatureExtractor(backend="interpret").execute(img, msk)
    assert len(mc_sweeps) == n_mc and len(diam_sweeps) == n_d
    for k in f1:
        np.testing.assert_allclose(f1[k], f2[k], rtol=0, atol=0)


# ---------------------------------------------------------------------------
# sync/<backend> d2h-latency probe (the cost model's calibration entry)
# ---------------------------------------------------------------------------


def test_sync_probe_roundtrip_caches_once(cache_path, monkeypatch):
    probes = []
    orig = autotune.measure_sync_cost

    def counting(**kw):
        probes.append(kw)
        return orig(repeat=4, warmup=1)

    monkeypatch.setattr(autotune, "measure_sync_cost", counting)
    us1 = autotune.get_sync_cost("interpret")
    assert len(probes) == 1 and us1 > 0
    # second resolution is a pure cache hit -- the probe is one-time
    us2 = autotune.get_sync_cost("interpret")
    assert len(probes) == 1 and us2 == us1
    entry = autotune.AutotuneCache().get(autotune.sync_key("interpret"))
    assert entry["us"] == us1 and "probed_at" in entry


def test_sync_probe_disabled_returns_default_uncached(tmp_path, monkeypatch):
    path = str(tmp_path / "no_probe.json")
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    assert autotune.get_sync_cost("pallas") == autotune.DEFAULT_SYNC_US
    assert not os.path.exists(path)


def test_sync_entry_honoured_for_every_backend(cache_path, monkeypatch):
    # a calibrated (or operator-pinned) entry wins even where kernel
    # sweeps are disallowed: the sync cost belongs to the link
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    autotune.AutotuneCache().put(autotune.sync_key("ref"), {"us": 123.5})
    assert autotune.get_sync_cost("ref") == 123.5


def test_malformed_sync_entry_falls_back(cache_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    cache = autotune.AutotuneCache()
    for bad in ({"us": "fast"}, {"us": -1.0}, {"probed_at": "x"}):
        cache.put(autotune.sync_key("ref"), bad)
        assert autotune.get_sync_cost("ref") == autotune.DEFAULT_SYNC_US


def test_sync_entry_coexists_and_survives_migration(cache_path):
    cache = autotune.AutotuneCache()
    cache.put(autotune.sync_key("pallas"), {"us": 321.0})
    cache.put(
        autotune.sweep_key(512, "pallas", batch=2),
        {"variant": "gram", "block": 128, "us": 9.0, "table": {}},
    )
    raw = json.load(open(cache_path))
    assert raw["schema"] == autotune.SCHEMA_VERSION
    assert set(raw["entries"]) == {"sync/pallas", "diameter/pallas/M512/B2"}
    # _migrate_key must pass the 2-segment sync key through untouched
    assert autotune._migrate_key("sync/pallas") == "sync/pallas"
