"""Bench-regression gate: baseline resolution + failure-mode contracts.

The bugs PR 7 fixed, locked down with real throwaway git repos:

* ``git show REF:path`` resolves against the repo ROOT -- the gate must
  translate its record path to repo-relative (and work from any cwd /
  with absolute paths) instead of silently skipping;
* only a genuinely MISSING baseline (first commit, never-committed file,
  no repo) skips the gate; any other lookup failure -- a corrupt
  committed record, an unreadable object -- must FAIL it, because a gate
  that skips on unexpected errors has stopped gating.

Plus the vanished-row contract (a committed baseline row missing from
the fresh record FAILS unless named in ``--allow-vanished`` -- it used
to warn only, so dropped bench modes sailed through) and the advisory
``--stages`` wall-time comparison.
"""
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.tier1

_SCRIPT = Path(__file__).resolve().parent.parent / "scripts" / "check_bench.py"


@pytest.fixture(scope="module")
def cb():
    spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["check_bench"] = mod
    spec.loader.exec_module(mod)
    return mod


def _git(repo, *args):
    subprocess.run(["git", "-C", str(repo), *args], check=True,
                   capture_output=True)


def _record(rows):
    return {"rows": [{"name": n, "cases_per_second": v} for n, v in rows]}


@pytest.fixture
def repo(tmp_path):
    repo = tmp_path / "repo"
    repo.mkdir()
    _git(repo, "init", "-q")
    _git(repo, "config", "user.email", "t@e.st")
    _git(repo, "config", "user.name", "t")
    return repo


def _commit_baseline(repo, payload, name="BENCH_pipeline.json"):
    (repo / name).write_text(json.dumps(payload))
    _git(repo, "add", name)
    _git(repo, "commit", "-q", "-m", "baseline")


def test_gate_passes_and_fails_on_regression(cb, repo, monkeypatch):
    _commit_baseline(repo, _record([("fast", 10.0), ("slow", 10.0)]))
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("fast", 9.0), ("slow", 10.0)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--pipeline", str(fresh)]) == 0
    fresh.write_text(json.dumps(_record([("fast", 5.0), ("slow", 10.0)])))
    assert cb.main(["--pipeline", str(fresh)]) == 1


def test_absolute_path_from_foreign_cwd(cb, repo, tmp_path, monkeypatch):
    """The repo-relative fix: gate must find the baseline from anywhere."""
    _commit_baseline(repo, _record([("row", 10.0)]))
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("row", 2.0)])))  # 5x regression
    elsewhere = tmp_path / "elsewhere"
    elsewhere.mkdir()
    monkeypatch.chdir(elsewhere)
    # before the fix this skipped (git show failed) and returned 0
    assert cb.main(["--pipeline", str(fresh)]) == 1


def test_nested_cwd_resolves_repo_relative(cb, repo, monkeypatch):
    _commit_baseline(repo, _record([("row", 10.0)]))
    sub = repo / "sub"
    sub.mkdir()
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("row", 2.0)])))
    monkeypatch.chdir(sub)
    assert cb.main(["--pipeline", "../BENCH_pipeline.json"]) == 1


def test_missing_baseline_skips(cb, repo, monkeypatch):
    # committed repo, but this record was never committed
    _commit_baseline(repo, _record([("row", 1.0)]), name="OTHER.json")
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("row", 0.1)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--pipeline", str(fresh)]) == 0


def test_unborn_ref_skips(cb, repo, monkeypatch):
    # fresh init, zero commits: HEAD is an unknown revision -> skip
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("row", 0.1)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--pipeline", str(fresh)]) == 0


def test_outside_any_repo_skips(cb, tmp_path, monkeypatch):
    lone = tmp_path / "norepo"
    lone.mkdir()
    fresh = lone / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("row", 0.1)])))
    monkeypatch.chdir(lone)
    assert cb.main(["--pipeline", str(fresh)]) == 0


def test_corrupt_committed_baseline_fails_loudly(cb, repo, monkeypatch):
    """A non-missing lookup problem must FAIL, not silently skip."""
    (repo / "BENCH_pipeline.json").write_text("{not json")
    _git(repo, "add", "BENCH_pipeline.json")
    _git(repo, "commit", "-q", "-m", "corrupt")
    (repo / "BENCH_pipeline.json").write_text(
        json.dumps(_record([("row", 1.0)]))
    )
    monkeypatch.chdir(repo)
    assert cb.main(["--pipeline", str(repo / "BENCH_pipeline.json")]) == 1


def test_load_baseline_triple_contract(cb, repo, monkeypatch):
    _commit_baseline(repo, _record([("row", 1.0)]))
    monkeypatch.chdir(repo)
    data, skip, err = cb.load_baseline("BENCH_pipeline.json", "HEAD")
    assert data is not None and skip is None and err is None
    data, skip, err = cb.load_baseline("BENCH_pipeline.json", "no-such-ref")
    assert data is None and skip is not None and err is None


def test_vanished_baseline_row_fails(cb, repo, monkeypatch):
    """Regression: a baseline row missing from the fresh record must FAIL.

    The old behaviour only printed a warning, so deleting a bench mode
    (and its committed trajectory rows with it) sailed through the gate;
    a dropped row is indistinguishable from a broken bench wiring unless
    someone acknowledges it explicitly.
    """
    _commit_baseline(repo, _record([("kept", 10.0), ("dropped", 10.0)]))
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("kept", 10.0)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--pipeline", str(fresh)]) == 1


def test_allow_vanished_acknowledges_dropped_rows(cb, repo, monkeypatch):
    _commit_baseline(repo, _record([("kept", 10.0), ("dropped", 10.0)]))
    fresh = repo / "BENCH_pipeline.json"
    fresh.write_text(json.dumps(_record([("kept", 10.0)])))
    monkeypatch.chdir(repo)
    # naming the dropped row passes; naming the WRONG row still fails
    assert cb.main(["--pipeline", str(fresh),
                    "--allow-vanished", "dropped"]) == 0
    assert cb.main(["--pipeline", str(fresh),
                    "--allow-vanished", "other"]) == 1


def _stages(times):
    return {"stages": dict(times)}


def _commit_stages(repo, payload):
    (repo / "ci_stage_times.json").write_text(json.dumps(payload))
    _git(repo, "add", "ci_stage_times.json")
    _git(repo, "commit", "-q", "-m", "stage times")


def test_stage_growth_warns_but_never_fails(cb, repo, monkeypatch, capsys):
    _commit_stages(repo, _stages([("tier1", 60), ("parity", 10)]))
    fresh = repo / "ci_stage_times.json"
    fresh.write_text(json.dumps(_stages([("tier1", 200), ("parity", 10)])))
    monkeypatch.chdir(repo)
    # >2x growth on tier1: advisory, so the gate still exits 0
    assert cb.main(["--stages", str(fresh)]) == 0
    out = capsys.readouterr().out
    assert "stages/tier1" in out and "WARNING" in out
    assert "stages/parity" in out and "OK" in out


def test_stage_noise_floor_and_missing_stage(cb, repo, monkeypatch, capsys):
    _commit_stages(repo, _stages([("quick", 1), ("gone", 30)]))
    fresh = repo / "ci_stage_times.json"
    # 1s -> 4s is quantisation, not growth; 'gone' vanished entirely
    fresh.write_text(json.dumps(_stages([("quick", 4)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--stages", str(fresh)]) == 0
    out = capsys.readouterr().out
    assert "below the noise floor" in out
    assert "stages/gone" in out and "missing" in out


def test_stages_missing_baseline_skips(cb, repo, monkeypatch):
    _commit_baseline(repo, _record([("row", 1.0)]))  # some commit, no stages
    fresh = repo / "ci_stage_times.json"
    fresh.write_text(json.dumps(_stages([("tier1", 60)])))
    monkeypatch.chdir(repo)
    assert cb.main(["--stages", str(fresh)]) == 0
