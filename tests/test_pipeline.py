"""Batched pipeline: bucketing, batch==single equivalence, sharded run."""
import numpy as np
import pytest

from repro.core import BatchedExtractor, ShapeFeatureExtractor, assign_bucket
from repro.data import synthetic


def test_bucket_assignment_deterministic():
    b1 = assign_bucket((30, 40, 50))
    b2 = assign_bucket((30, 40, 50))
    assert b1 == b2
    assert all(s % 32 == 0 for s in b1.shape)


def test_batch_matches_single():
    cases = [synthetic.make_case((36, 30, 28), seed=s) for s in range(3)]
    bx = BatchedExtractor(backend="ref")
    results, stats = bx.run(cases)
    assert stats["cases"] == 3
    single = ShapeFeatureExtractor(backend="ref")
    for (img, msk, sp), row in zip(cases, results):
        f = single.execute(img, msk, sp)
        np.testing.assert_allclose(row[0], f["MeshVolume"], rtol=1e-3)
        np.testing.assert_allclose(row[1], f["SurfaceArea"], rtol=1e-3)
        np.testing.assert_allclose(row[2], f["Maximum3DDiameter"], rtol=1e-3)


def test_mixed_sizes_bucketed():
    cases = [
        synthetic.make_case((20, 20, 20), seed=1),
        synthetic.make_case((64, 50, 40), seed=2),
        synthetic.make_case((21, 19, 22), seed=3),
    ]
    bx = BatchedExtractor(backend="ref")
    results, stats = bx.run(cases)
    assert all(r is not None for r in results)
    assert stats["buckets"] >= 2
