"""Pallas fused MC kernel vs pure-jnp oracle: shape/block sweeps + analytics."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import marching_cubes as mck
from repro.kernels import ref
from conftest import sphere_mask, box_mask


@pytest.mark.parametrize(
    "shape,block,chunk",
    [
        ((10, 11, 9), (4, 4, 4), 64),
        ((16, 8, 12), (8, 4, 4), 128),
        ((13, 13, 13), (4, 8, 4), 128),
    ],
)
def test_matches_ref_random(shape, block, chunk):
    rng = np.random.default_rng(sum(shape))
    vol = np.pad(rng.random(shape).astype(np.float32), 1)
    wv, wa = ref.mc_volume_area(jnp.asarray(vol))
    gv, ga = mck.mc_volume_area_pallas(vol, block=block, chunk=chunk, interpret=True)
    np.testing.assert_allclose(float(gv), float(wv), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(ga), float(wa), rtol=1e-4, atol=1e-3)


def test_sphere_analytic():
    m = np.pad(sphere_mask(28, 9.0), 1)
    gv, ga = mck.mc_volume_area_pallas(m, block=(8, 8, 4), chunk=128, interpret=True)
    vol_true = 4 / 3 * np.pi * 9.0**3
    assert abs(float(gv) / vol_true - 1) < 0.02
    # staircase area overshoot is bounded (known MC-on-binary behaviour)
    area_true = 4 * np.pi * 9.0**2
    assert 1.0 < float(ga) / area_true < 1.15


def test_anisotropic_spacing():
    m = np.pad(sphere_mask(20, 6.0), 1)
    v1, a1 = mck.mc_volume_area_pallas(m, spacing=(1.0, 1.0, 1.0), block=(4, 4, 4), chunk=64, interpret=True)
    v2, a2 = mck.mc_volume_area_pallas(m, spacing=(2.0, 1.0, 1.0), block=(4, 4, 4), chunk=64, interpret=True)
    assert abs(float(v2) / float(v1) - 2.0) < 1e-4


def test_box_volume_close_to_voxel_count():
    m = box_mask((12, 12, 12), (2, 2, 2), (9, 10, 8))
    m = np.pad(m, 1)
    gv, _ = mck.mc_volume_area_pallas(m, block=(4, 4, 4), chunk=64, interpret=True)
    nvox = 7 * 8 * 6
    # mesh volume = voxel volume minus edge/corner chamfers: slightly below
    assert nvox * 0.9 < float(gv) <= nvox


def test_empty_volume():
    m = np.zeros((9, 9, 9), np.float32)
    gv, ga = mck.mc_volume_area_pallas(m, block=(4, 4, 4), chunk=64, interpret=True)
    assert float(gv) == 0.0 and float(ga) == 0.0


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_translation_invariance_and_ref_match(seed):
    rng = np.random.default_rng(seed)
    vol = np.pad((rng.random((6, 7, 5)) > 0.55).astype(np.float32), 1)
    wv, wa = ref.mc_volume_area(jnp.asarray(vol))
    gv, ga = mck.mc_volume_area_pallas(vol, block=(4, 4, 4), chunk=32, interpret=True)
    np.testing.assert_allclose(float(gv), float(wv), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(ga), float(wa), rtol=1e-4, atol=1e-3)
