"""Device-resident pass-1 compaction: device == host bit-identity lockdown.

The contract under test (see core/pipeline.py and kernels/compact.py): the
device-compaction pipeline (``device_compact=True``, the default) must be
**bit-identical** to the PR 2 host-compaction path (``device_compact=False``)
-- same survivors, same stable order, same zero padding, same features --
on every edge the host path handles: empty masks, zero-survivor keeps,
all-survivor keeps, exact cap-boundary counts, and case permutations.
Kernel-level parity (Pallas interpret == jnp ref == host numpy) is asserted
directly; pipeline-level parity runs the full two-pass extractor both ways.
Seeded plain-pytest mirrors of the hypothesis compaction invariants
(tests/test_prune_properties.py) ride along for the minimal container.
"""
import functools
import json

import numpy as np
import pytest

from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.kernels import compact as ck
from repro.kernels import ops
from repro.kernels import prune
from repro.runtime import autotune

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # parity must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@functools.lru_cache(maxsize=None)
def _case(shape, seed):
    return make_case(shape, seed=seed)


def _host_compact(verts, keep, cap):
    """The PR 2 host-side semantics: np.nonzero gather + zero pad."""
    idx = np.nonzero(keep)[0][:cap]
    out = np.zeros((cap, 3), np.float32)
    out[: len(idx)] = verts[idx]
    mask = np.zeros((cap,), bool)
    mask[: len(idx)] = True
    return out, mask, int(keep.sum())


def _keep_for(case: str, m: int, cap: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if case == "random":
        return rng.random(m) < 0.3
    if case == "zero-survivor":
        return np.zeros(m, bool)
    if case == "all-survivor":
        return np.ones(m, bool)
    if case == "cap-boundary":  # exactly M' == cap survivors
        keep = np.zeros(m, bool)
        keep[rng.choice(m, size=cap, replace=False)] = True
        return keep
    if case == "overflow":  # more survivors than the cap: excess dropped
        keep = np.zeros(m, bool)
        keep[rng.choice(m, size=cap + 57, replace=False)] = True
        return keep
    raise ValueError(case)


# ---------------------------------------------------------------------------
# kernel-level parity: Pallas interpret == jnp ref == host numpy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "case", ["random", "zero-survivor", "all-survivor", "cap-boundary",
             "overflow"]
)
def test_compact_kernel_matches_host(case):
    m, cap = 1024, 512
    rng = np.random.default_rng(7)
    verts = rng.normal(size=(m, 3)).astype(np.float32) * 20.0
    keep = _keep_for(case, m, cap)
    ro, rm, rn = (np.asarray(x) for x in
                  ck.compact_batch_ref(verts[None], keep[None], cap))
    po, pm, pn = (np.asarray(x) for x in ck.compact_batch_pallas(
        verts[None], keep[None], cap, block=128, interpret=True))
    ho, hm, hn = _host_compact(verts, keep, cap)
    for o, mk, n in ((ro[0], rm[0], rn[0]), (po[0], pm[0], pn[0])):
        np.testing.assert_array_equal(o, ho)
        np.testing.assert_array_equal(mk, hm)
        assert n == hn  # total survivor count, pre-drop


def test_compact_batch_offset_resets_between_cases():
    """The SMEM running offset must reset per case: a batch of ragged keeps
    compacts identically to three single-case launches."""
    m, cap = 768, 256
    rng = np.random.default_rng(3)
    verts = rng.normal(size=(3, m, 3)).astype(np.float32)
    keep = np.stack([rng.random(m) < f for f in (0.05, 0.6, 0.0)])
    bo, bm, bn = (np.asarray(x) for x in ck.compact_batch_pallas(
        verts, keep, cap, block=128, interpret=True))
    for b in range(3):
        so, sm, sn = (np.asarray(x) for x in ck.compact_batch_pallas(
            verts[b][None], keep[b][None], cap, block=128, interpret=True))
        np.testing.assert_array_equal(bo[b], so[0])
        np.testing.assert_array_equal(bm[b], sm[0])
        assert bn[b] == sn[0] == keep[b].sum()


@pytest.mark.parametrize("block", [64, 128, 512])
def test_compact_block_size_is_value_invariant(block):
    """The scatter block (the autotuned axis) must never change the result."""
    m, cap = 512, 512
    rng = np.random.default_rng(11)
    verts = rng.normal(size=(2, m, 3)).astype(np.float32)
    keep = rng.random((2, m)) < 0.4
    want = [np.asarray(x) for x in ck.compact_batch_ref(verts, keep, cap)]
    got = [np.asarray(x) for x in ck.compact_batch_pallas(
        verts, keep, cap, block=block, interpret=True)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


# ---------------------------------------------------------------------------
# plan_compaction: the shared pruned/keep-originals decision
# ---------------------------------------------------------------------------


def test_plan_compaction_degenerate_rules():
    plan = lambda mt, mv, mk: prune.plan_compaction(
        mt, mv, mk, ops.vertex_bucket
    )
    # < 2 valid vertices, < 2 survivors, nothing pruned: keep originals
    for mv, mk in ((1, 1), (100, 1), (100, 100), (100, 120)):
        cap, info = plan(4096, mv, mk)
        assert cap is None and not info.pruned and info.m_kept == mv
    # survivor bucket >= input cap: re-bucketing wins nothing
    cap, info = plan(512, 400, 100)
    assert cap is None and not info.pruned and info.m_kept == 400
    # a genuine shrink
    cap, info = plan(4096, 3000, 100)
    assert cap == 512 and info.pruned and info.m_kept == 100
    # cap boundary: M' == bucket exactly still shrinks 4096 -> 512
    cap, info = plan(4096, 3000, 512)
    assert cap == 512 and info.pruned


@pytest.mark.parametrize("seed", range(4))
def test_plan_matches_host_path_info(seed):
    """plan_compaction must reproduce the host path's PruneInfo exactly."""
    rng = np.random.default_rng(seed)
    m = 96 + 32 * seed
    verts = (rng.normal(size=(m, 3)) * 15.0).astype(np.float32)
    mask = rng.random(m) > 0.2
    if mask.sum() < 2:
        mask[:2] = True
    _, _, host_info = ops.prune_candidates(verts, mask)
    keep, _ = prune.candidate_keep_mask(verts, mask)
    _, info = prune.plan_compaction(
        m, int(mask.sum()), int(np.asarray(keep).sum()), ops.vertex_bucket
    )
    assert info == host_info


# ---------------------------------------------------------------------------
# pipeline-level parity: device_compact=True == device_compact=False
# ---------------------------------------------------------------------------


def _edge_cases():
    empty = (np.zeros((10, 10, 10), np.float32),
             np.zeros((10, 10, 10), np.float32), (1.0, 1.0, 1.0))
    voxel_m = np.zeros((9, 9, 9), np.float32)
    voxel_m[4, 4, 4] = 1.0
    voxel = (np.zeros((9, 9, 9), np.float32), voxel_m, (1.0, 1.0, 1.0))
    return [
        _case((48, 48, 48), 1),   # prunes to a smaller bucket
        empty,                    # empty mask: zero row
        _case((20, 18, 16), 5),   # small: keep-originals path
        voxel,                    # single voxel: degenerate prune
        _case((70, 20, 20), 4),   # different shape bucket
    ]


def test_device_compact_is_the_default():
    bx = BatchedExtractor(backend="ref")
    assert bx.device_compact
    _, stats = bx.run([_case((20, 18, 16), 5)])
    assert stats["device_compact"] and stats["two_pass"]
    _, stats = BatchedExtractor(backend="ref", device_compact=False).run(
        [_case((20, 18, 16), 5)]
    )
    assert not stats["device_compact"]


def test_device_vs_host_bit_identical_ref():
    cases = _edge_cases()
    dev = BatchedExtractor(backend="ref", device_compact=True)
    host = BatchedExtractor(backend="ref", device_compact=False)
    rd, sd = dev.run(cases)
    rh, sh = host.run(cases)
    for key in ("pruned_cases", "empty_cases", "vertex_buckets", "buckets",
                "mean_keep_fraction"):
        assert sd[key] == sh[key], key
    for i, (a, b) in enumerate(zip(rd, rh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f"case {i}")


def test_device_vs_host_bit_identical_interpret():
    """Pallas semantics: the compaction kernel itself runs (interpret) and
    the features must still match the host path bit-for-bit."""
    cases = [_case((48, 48, 48), 2), _case((20, 18, 16), 5)]
    dev = BatchedExtractor(backend="interpret", device_compact=True)
    host = BatchedExtractor(backend="interpret", device_compact=False)
    rd, sd = dev.run(cases)
    rh, _ = host.run(cases)
    assert sd["pruned_cases"] >= 1  # the compaction kernel actually ran
    for a, b in zip(rd, rh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # extract_one stays the single-case parity oracle of the device path
    np.testing.assert_array_equal(
        np.asarray(rd[0]), dev.extract_one(*cases[0])
    )


def test_device_permutation_invariance():
    """Device re-bucketing never drops, duplicates, or cross-contaminates."""
    cases = _edge_cases()
    bx = BatchedExtractor(backend="ref")
    base, _ = bx.run(cases)
    perm = [3, 0, 4, 1, 2]
    permuted, _ = bx.run([cases[i] for i in perm])
    for j, i in enumerate(perm):
        np.testing.assert_array_equal(
            np.asarray(permuted[j]), np.asarray(base[i])
        )


def test_ambient_mesh_without_data_axis_is_ignored():
    """A train/serve use_mesh context (no 'data' axis) must not hijack the
    pipeline: the ambient mesh is adopted only when it can shard the batch."""
    import jax

    from repro.parallel.sharding import use_mesh

    mesh = jax.make_mesh((1,), ("model",))
    with use_mesh(mesh):
        bx = BatchedExtractor(backend="ref")
    assert bx.mesh is None  # not adopted: it cannot shard the data axis
    res, stats = bx.run([_case((20, 18, 16), 5)])
    assert stats["data_parallel"] == 1 and np.all(np.isfinite(res[0]))
    # a mesh WITH the data axis is still picked up
    dmesh = jax.make_mesh((1,), ("data",))
    with use_mesh(dmesh):
        bx2 = BatchedExtractor(backend="ref")
    assert bx2.mesh is dmesh


def test_device_batch_padding_chunks():
    """batch_size forcing padded trailing chunks must not corrupt rows."""
    cases = _edge_cases()
    bx = BatchedExtractor(backend="ref")
    want = [bx.extract_one(*c) for c in cases]
    got, _ = bx.run(cases, batch_size=2)
    for w, r in zip(want, got):
        np.testing.assert_allclose(np.asarray(r), w, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# seeded mirrors of the hypothesis segmented-compaction invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_compaction_invariants_seeded(seed):
    rng = np.random.default_rng(seed)
    m, cap = 64 + 96 * seed, 128
    verts = rng.normal(size=(m, 3)).astype(np.float32)
    keep = rng.random(m) < rng.uniform(0.0, 1.0)
    out, mask, n = (np.asarray(x) for x in
                    ck.compact_batch_ref(verts[None], keep[None], cap))
    out, mask, n = out[0], mask[0], int(n[0])
    k = min(n, cap)
    assert n == keep.sum()                       # survivor count preserved
    np.testing.assert_array_equal(               # stable original order
        out[:k], verts[keep][:cap]
    )
    assert mask[:k].all() and not mask[k:].any() # no leak past M'
    assert np.all(out[k:] == 0.0)                # padding is exactly zero


# ---------------------------------------------------------------------------
# autotune: the compaction scatter block rides in the v2 cache
# ---------------------------------------------------------------------------


def test_compact_sweep_round_trip(tmp_path, monkeypatch):
    path = tmp_path / "compact_cache.json"
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")  # force-sweep on interpret
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    cfg = autotune.get_compact_config(512, "interpret", blocks=(128, 256),
                                      repeat=1)
    assert cfg.block in (128, 256)
    data = json.loads(path.read_text())
    assert data["schema"] == autotune.SCHEMA_VERSION
    rec = data["entries"]["compact/interpret/M512/B1"]
    assert rec["block"] == cfg.block and set(rec["table"]) == {"128", "256"}
    # second resolution is a pure cache hit even with sweeping disabled
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert autotune.get_compact_config(512, "interpret") == cfg
    # and the ref backend has no configuration axis at all
    assert autotune.get_compact_config(512, "ref") == \
        autotune.DEFAULT_COMPACT_CONFIG
