"""Hypothesis property tests for the pruning / re-bucketing invariants.

Gated on hypothesis being importable (see tests/conftest.py); seeded
plain-pytest mirrors live in tests/test_pipeline_pruned_batch.py so the
invariants are exercised even in the minimal container.

Invariants (the soundness argument of kernels/prune and the two-pass
pipeline's pass 1):

  1. the pruned set always contains EVERY endpoint of every pair attaining
     a combo maximum -- the property that makes pruned diameters exact;
  2. M' <= M_valid <= M_total, and survivors are a subset of the inputs;
  3. pruning (and the vmapped batched bound) is diameter-invariant under
     input permutation -- bit-identical on the Pallas kernels;
  4. the pipeline's re-bucketing partition never drops or duplicates a
     case index;
  5. segmented compaction (kernels/compact, pass 1c of the device-resident
     pipeline) preserves the survivor count, keeps the original order
     stable, never leaks a non-survivor past M', and the Pallas kernel is
     bit-identical to the jnp reference for every block size.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.pipeline import group_indices
from repro.kernels import compact as ck
from repro.kernels import diameter as dk
from repro.kernels import ops, prune

_SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _cloud(seed: int, m: int, scale: float, hole: float):
    rng = np.random.default_rng(seed)
    verts = (rng.normal(size=(m, 3)) * scale).astype(np.float32)
    mask = rng.random(m) > hole
    if mask.sum() < 2:
        mask[:2] = True
    return verts, mask


cloud_args = dict(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(8, 192),
    scale=st.floats(0.25, 80.0),
    hole=st.floats(0.0, 0.6),
)


@given(**cloud_args)
@settings(**_SETTINGS)
def test_pruned_set_contains_both_farthest_endpoints(seed, m, scale, hole):
    verts, mask = _cloud(seed, m, scale, hole)
    keep, lower_sq = prune.candidate_keep_mask(verts, mask)
    keep = np.asarray(keep)
    valid = np.nonzero(mask)[0]
    v = verts[valid]
    d = v[:, None, :] - v[None, :, :]
    q = (d * d).astype(np.float32)
    planes = (q[..., 0] + q[..., 1] + q[..., 2], q[..., 0] + q[..., 1],
              q[..., 0] + q[..., 2], q[..., 1] + q[..., 2])
    for c, s in enumerate(planes):
        mx = s.max()
        # the lower bound is a real achieved distance, so it can never
        # exceed the true combo maximum
        assert float(np.asarray(lower_sq)[c]) <= mx * (1.0 + 1e-5) + 1e-6
        ii, jj = np.nonzero(s == mx)
        ends = np.unique(np.concatenate([valid[ii], valid[jj]]))
        assert keep[ends].all(), f"combo {c}: true endpoint pruned"


@given(**cloud_args)
@settings(**_SETTINGS)
def test_m_prime_le_m_and_survivors_are_inputs(seed, m, scale, hole):
    verts, mask = _cloud(seed, m, scale, hole)
    v2, m2, info = prune.prune_vertices(verts, mask)
    assert info.m_kept <= info.m_valid <= info.m_total == m
    if info.pruned:
        # every survivor is one of the original valid vertices
        rows = {tuple(r) for r in verts[mask]}
        assert all(tuple(r) in rows for r in v2[m2])


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(8, 96),
       scale=st.floats(0.5, 50.0))
@settings(**_SETTINGS)
def test_prune_diameters_permutation_invariant(seed, m, scale):
    verts, mask = _cloud(seed, m, scale, 0.2)
    rng = np.random.default_rng(seed ^ 0x5EED)
    p = rng.permutation(m)
    a_v, a_m, _ = prune.prune_vertices(verts, mask)
    b_v, b_m, _ = prune.prune_vertices(verts[p], mask[p])
    a = np.asarray(dk.max_diameters_sq_pallas(a_v, a_m, block=64, interpret=True))
    b = np.asarray(dk.max_diameters_sq_pallas(b_v, b_m, block=64, interpret=True))
    np.testing.assert_array_equal(a, b)


@given(seed=st.integers(0, 2**31 - 1), b=st.integers(2, 4),
       m=st.integers(8, 64))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_batched_bound_matches_single_diameters(seed, b, m):
    """One vmapped pass-1 launch == B single launches, case for case."""
    clouds = [_cloud(seed + j, m, 10.0, 0.2) for j in range(b)]
    batch = ops.prune_candidates_batch(
        np.stack([v for v, _ in clouds]), np.stack([mk for _, mk in clouds])
    )
    assert len(batch) == b
    for (v, mk), (v2, m2, info) in zip(clouds, batch):
        assert info.m_kept <= info.m_valid
        sv, sm, _ = ops.prune_candidates(v, mk)
        got = np.asarray(dk.max_diameters_sq_pallas(v2, m2, block=64, interpret=True))
        want = np.asarray(dk.max_diameters_sq_pallas(sv, sm, block=64, interpret=True))
        np.testing.assert_array_equal(got, want)


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 300),
    cap_exp=st.integers(4, 9),
    frac=st.floats(0.0, 1.0),
)
@settings(**_SETTINGS)
def test_segmented_compaction_invariants(seed, m, cap_exp, frac):
    """Count preserved, order stable, nothing leaks past M', zero padding."""
    rng = np.random.default_rng(seed)
    cap = 2**cap_exp
    verts = (rng.normal(size=(m, 3)) * 30.0).astype(np.float32)
    keep = rng.random(m) < frac
    out, mask, n = (
        np.asarray(x) for x in ck.compact_batch_ref(verts[None], keep[None], cap)
    )
    out, mask, n = out[0], mask[0], int(n[0])
    assert n == int(keep.sum())  # survivor count preserved (pre-drop)
    k = min(n, cap)
    np.testing.assert_array_equal(out[:k], verts[keep][:cap])  # stable order
    assert mask[:k].all() and not mask[k:].any()  # no leak past M'
    assert np.all(out[k:] == 0.0)  # padding exactly zero


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 260),
    frac=st.floats(0.0, 1.0),
    block=st.sampled_from([64, 128, 256]),
)
@settings(**_SETTINGS)
def test_pallas_compaction_bit_identical_to_ref(seed, m, frac, block):
    """The one-hot-matmul scatter kernel == the jnp scatter, bit for bit,
    for every scatter block size (the autotuned axis must be value-free)."""
    rng = np.random.default_rng(seed)
    verts = (rng.normal(size=(2, m, 3)) * 50.0).astype(np.float32)
    keep = rng.random((2, m)) < frac
    cap = 128
    want = [np.asarray(x) for x in ck.compact_batch_ref(verts, keep, cap)]
    got = [
        np.asarray(x)
        for x in ck.compact_batch_pallas(
            verts, keep, cap, block=block, interpret=True
        )
    ]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


@given(st.lists(st.one_of(st.none(), st.integers(0, 5)), max_size=48))
@settings(max_examples=50, deadline=None)
def test_rebucketing_partition_never_drops_or_duplicates(keys):
    groups = group_indices(keys)
    flat = sorted(i for idxs in groups.values() for i in idxs)
    assert flat == [i for i, k in enumerate(keys) if k is not None]
    for k, idxs in groups.items():
        assert all(keys[i] == k for i in idxs)
        assert idxs == sorted(idxs)  # order-preserving
