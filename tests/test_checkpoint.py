"""CheckpointManager contract: round-trips, retention, torn-write recovery.

``tests/test_system.py`` exercises checkpointing through the trainer;
this file is the direct unit contract for ``runtime/checkpoint`` --
including the recovery path a resumable extraction run depends on:
``restore_latest`` must walk back past a checkpoint whose commit marker
survived but whose payload did not (disk-full / partial copy), and
return the newest step that actually deserializes.
"""
import json

import jax
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager

pytestmark = pytest.mark.tier1


def _tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": rng.standard_normal((4, 3)).astype(np.float32),
            "b": rng.standard_normal((3,)).astype(np.float32),
        },
        "step_scalar": np.int32(seed),
    }


def _assert_tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a, b,
    )


def test_save_restore_latest_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    t = _tree(7)
    m.save(42, t, extras={"kind": "unit"})
    step, got, extras = m.restore_latest(jax.tree.map(lambda x: x, t))
    assert step == 42
    assert extras == {"kind": "unit"}
    _assert_tree_equal(t, got)


def test_save_async_wait_then_restore(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    t = _tree(1)
    m.save_async(5, t, extras={"async": True})
    m.wait()
    step, got, extras = m.restore_latest(jax.tree.map(lambda x: x, t))
    assert step == 5 and extras == {"async": True}
    _assert_tree_equal(t, got)


def test_keep_gc_retains_newest_k(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in (1, 3, 8, 9):
        m.save(s, _tree(s))
    assert m.all_steps() == [8, 9]
    # keep=0 disables GC entirely
    m0 = CheckpointManager(tmp_path / "nogc", keep=0)
    for s in (1, 2, 3):
        m0.save(s, _tree(s))
    assert m0.all_steps() == [1, 2, 3]


def test_restore_latest_none_when_empty(tmp_path):
    m = CheckpointManager(tmp_path)
    assert m.restore_latest({"x": np.zeros(2)}) is None


def test_restore_latest_falls_back_over_torn_leaf(tmp_path):
    m = CheckpointManager(tmp_path, keep=0)
    t = _tree(3)
    m.save(1, t)
    m.save(2, _tree(4))
    # tear step 2 AFTER commit: truncate one leaf file mid-payload
    leaf = next((tmp_path / "step_00000002").glob("*.npy"))
    leaf.write_bytes(leaf.read_bytes()[:16])
    step, got, _ = m.restore_latest(jax.tree.map(lambda x: x, t))
    assert step == 1
    _assert_tree_equal(t, got)


def test_restore_latest_falls_back_over_corrupt_manifest(tmp_path):
    m = CheckpointManager(tmp_path, keep=0)
    t = _tree(5)
    m.save(1, t)
    m.save(2, _tree(6))
    (tmp_path / "step_00000002" / "MANIFEST.json").write_text("{ torn")
    step, got, _ = m.restore_latest(jax.tree.map(lambda x: x, t))
    assert step == 1
    _assert_tree_equal(t, got)


def test_restore_latest_warns_when_all_torn(tmp_path):
    m = CheckpointManager(tmp_path, keep=0)
    m.save(1, _tree(0))
    (tmp_path / "step_00000001" / "MANIFEST.json").write_text("{ torn")
    with pytest.warns(RuntimeWarning, match="no readable checkpoint"):
        assert m.restore_latest({"x": np.zeros(2)}) is None


def test_restore_named_step_stays_strict(tmp_path):
    m = CheckpointManager(tmp_path, keep=0)
    m.save(1, _tree(0))
    (tmp_path / "step_00000001" / "MANIFEST.json").write_text("{ torn")
    with pytest.raises(json.JSONDecodeError):
        m.restore(1, {"x": np.zeros(2)})
