"""GPipe-over-pod-axis correctness: pipelined == sequential layer stack.

Needs >1 host device, so the check runs in a subprocess with
``xla_force_host_platform_device_count=4`` (the conftest keeps the main
test process at 1 device on purpose).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_forward, pipeline_stages

    mesh = jax.make_mesh((4,), ("pod",))
    L, B, S, D = 8, 8, 16, 32
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.2, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    # sequential oracle
    want = x
    for i in range(L):
        want = layer_fn(jax.tree.map(lambda p: p[i], params), want)

    got = pipeline_forward(layer_fn, params, x, mesh, n_micro=4, axis="pod")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
    assert pipeline_stages(8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]
    print("PIPELINE-OK")
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert "PIPELINE-OK" in out.stdout, out.stdout + out.stderr
