"""Plan/executor split: streaming, static schedule, device-pool MC lockdown.

The contracts under test (see core/plan.py + core/executor.py):

* ``extract_stream`` == ``run`` == ``extract_one`` bit-identically -- in
  input order, across window boundaries, with empty-mask cases mid-stream;
* ``schedule='static'`` == ``schedule='counted'`` bit-identically on
  ref + interpret, INCLUDING the keep-originals retry path (the static
  target is the counted win boundary -- ``plan.static_bucket``);
* static pass 1 performs ZERO host fetches: asserted by the executor's
  ``transfer_log`` sync census AND by a guard that intercepts every
  device-array materialisation inside the pass-1 phase (the acceptance
  criterion is a counter, not a docstring);
* pass 2a consumes bucket-keyed device pools: device-pool MC must equal
  the host-stacked feed it replaced, bit-for-bit, on ref + interpret;
* the plan layer's metadata functions (spacing-aware memoised vertex
  hint, static bucket ladder, grouping, pad-waste stats) hold their
  invariants.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor as exmod
from repro.core import plan as planlib
from repro.core.pipeline import BatchedExtractor
from repro.data.synthetic import make_case
from repro.kernels import ops
from repro.kernels import prune as prune_kernels

pytestmark = pytest.mark.tier1


@pytest.fixture(autouse=True)
def _isolated_autotune(tmp_path, monkeypatch):
    # parity must not depend on (or pollute) the user's autotune cache
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@functools.lru_cache(maxsize=None)
def _case(shape, seed):
    return make_case(shape, seed=seed)


def _empty():
    z = np.zeros((10, 10, 10), np.float32)
    return (z, z.copy(), (1.0, 1.0, 1.0))


def _edge_cases():
    voxel_m = np.zeros((9, 9, 9), np.float32)
    voxel_m[4, 4, 4] = 1.0
    return [
        _case((48, 48, 48), 1),   # prunes to a smaller bucket
        _empty(),                 # empty mask mid-stream: zero row
        _case((20, 18, 16), 5),   # small: floor-cap keep-originals path
        (np.zeros((9, 9, 9), np.float32), voxel_m, (1.0, 1.0, 1.0)),
        _case((70, 20, 20), 4),   # different shape bucket
        _case((48, 48, 48), 2),   # same buckets as case 0, later window
    ]


# ---------------------------------------------------------------------------
# plan layer: vertex hint, static ladder, grouping, pad stats
# ---------------------------------------------------------------------------


def test_vertex_hint_spacing_aware_memoised_and_capped():
    iso = planlib.vertex_hint((40, 40, 40))
    assert iso == planlib.vertex_hint((40, 40, 40), (2.0, 2.0, 2.0))
    # anisotropic spacing cuts more voxel planes per unit physical surface
    aniso = planlib.vertex_hint((40, 40, 40), (1.0, 1.0, 5.0))
    assert aniso > iso
    # memoised: the second identical query is a pure cache hit
    planlib._vertex_hint.cache_clear()
    planlib.vertex_hint((17, 19, 23), (1.0, 1.5, 3.0))
    planlib.vertex_hint((17, 19, 23), (1.0, 1.5, 3.0))
    info = planlib._vertex_hint.cache_info()
    assert info.hits == 1 and info.misses == 1
    # capped at the volume's total edge count: a degenerate hint can never
    # allocate a cap group past what the mesh could physically produce
    tiny = planlib.vertex_hint((2, 2, 2), (1.0, 1.0, 1000.0))
    assert tiny <= 3 * 4 * 4 * 4
    for shape in ((3, 3, 3), (8, 64, 8), (100, 100, 100)):
        edges = 3 * np.prod([s + 2 for s in shape])
        assert 0 < planlib.vertex_hint(shape, (1.0, 1.0, 9.0)) <= edges


def test_static_bucket_is_the_counted_win_boundary():
    assert planlib.static_bucket(512) is None  # floor: no shrink possible
    assert planlib.static_bucket(1024) == 512
    assert planlib.static_bucket(4096) == 2048
    # alignment: for every cap, fitting the static target is EXACTLY the
    # counted schedule's re-bucketing decision -- the property that makes
    # the sync-free schedule safe (no survivor can overflow a case the
    # counted path would have compacted)
    for cap in (1024, 2048, 4096, 8192):
        t = planlib.static_bucket(cap)
        for m in (2, 3, 100, t - 1, t, t + 1, cap - 1, cap):
            counted_wins = ops.vertex_bucket(m) < cap
            assert counted_wins == (m <= t), (cap, m)


def test_build_plan_grouping_partition_and_stats():
    metas = [
        planlib.CaseMeta((64, 64, 64), (50, 50, 50), 4096, 3000),
        planlib.CaseMeta(None, None, 0, 0),  # empty case: excluded
        planlib.CaseMeta((64, 64, 64), (40, 60, 62), 512, 300),
        planlib.CaseMeta((96, 32, 32), (70, 22, 22), 4096, 2500),
    ]
    plan = planlib.build_plan(metas, "static")
    # every non-empty index lands in exactly one group of each pass
    for groups in (plan.shape_groups, plan.cap_groups):
        flat = sorted(i for idxs in groups.values() for i in idxs)
        assert flat == [0, 2, 3]
    assert plan.shape_groups[(64, 64, 64)] == [0, 2]
    assert plan.cap_groups[4096] == [0, 3]
    assert plan.static_targets == {4096: 2048, 512: None}
    s = plan.stats()
    assert s["cases"] == 4 and s["empty_cases"] == 1
    assert s["shape_buckets"] == 2 and s["cap_buckets"] == 2
    assert 0.0 < s["mask_pad_waste"] < 1.0
    assert 0.0 < s["vertex_pad_waste"] < 1.0
    # counted plans carry no static targets (they come from run-time counts)
    assert planlib.build_plan(metas, "counted").static_targets == {}
    with pytest.raises(ValueError, match="schedule"):
        planlib.build_plan(metas, "bogus")
    # metadata-only planning: same machinery, hint-sized caps
    mplan = planlib.plan_from_metadata(
        [(50, 50, 50), (20, 20, 20)], [(1.0, 1.0, 1.0)] * 2, "static"
    )
    assert mplan.n_cases == 2 and mplan.stats()["shape_buckets"] >= 1


def test_static_schedule_requires_device_resident_path():
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", schedule="static", prune=False)
    with pytest.raises(ValueError, match="device-resident"):
        BatchedExtractor(backend="ref", schedule="static",
                         device_compact=False)
    with pytest.raises(ValueError, match="schedule"):
        BatchedExtractor(backend="ref", schedule="eager")


# ---------------------------------------------------------------------------
# static == counted bit-identity (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_static_equals_counted_bit_identical_ref():
    cases = _edge_cases()
    counted = BatchedExtractor(backend="ref", schedule="counted")
    static = BatchedExtractor(backend="ref", schedule="static")
    rc, sc = counted.run(cases)
    rs, ss = static.run(cases)
    # the schedules make the SAME prune decision (deferred vs synced)
    for key in ("pruned_cases", "empty_cases", "mean_keep_fraction",
                "buckets"):
        assert sc[key] == ss[key], key
    for i, (a, b) in enumerate(zip(rc, rs)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"case {i}"
        )


def test_static_equals_counted_bit_identical_interpret():
    cases = [_case((48, 48, 48), 2), _case((20, 18, 16), 5)]
    counted = BatchedExtractor(backend="interpret", schedule="counted")
    static = BatchedExtractor(backend="interpret", schedule="static")
    rc, _ = counted.run(cases)
    rs, ss = static.run(cases)
    assert ss["pruned_cases"] >= 1  # the static chain actually compacted
    for a, b in zip(rc, rs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # extract_one stays the oracle of the static path too
    np.testing.assert_array_equal(
        np.asarray(rs[0]), static.extract_one(*cases[0])
    )


def _sphere_prepped(cap, n, seed=0):
    """Fabricated pass-0 state whose vertices all lie ON a sphere.

    Antipodal pairs make the centre upper bound tight (ub == L == 2R for
    every vertex), so the pruning bound provably keeps everything:
    ``m_kept == m_valid`` -- exactly a keep-originals case at a cap above
    the floor, which is the static schedule's deferred-retry path.
    """
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(n // 2, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    pts = np.concatenate([u, -u]) * 37.0
    verts = np.zeros((cap, 3), np.float32)
    verts[: len(pts)] = pts
    vmask = np.zeros((cap,), bool)
    vmask[: len(pts)] = True
    return exmod._Prepped(
        mask=jnp.zeros((8, 8, 8)), spacing=np.ones(3, np.float32),
        shape=(8, 8, 8), roi_shape=(8, 8, 8),
        verts=jnp.asarray(verts), vmask=jnp.asarray(vmask),
        n_vertices=len(pts), vertex_cap=cap,
    )


def test_static_retry_resolves_keep_originals_exactly():
    """A cap group the counted schedule keeps at its original cap must come
    out of the static schedule bit-identical, via the deferred re-sweep."""
    prepped_s = [_sphere_prepped(1024, 600), _sphere_prepped(1024, 700, 1)]
    prepped_c = [_sphere_prepped(1024, 600), _sphere_prepped(1024, 700, 1)]
    ex_s = BatchedExtractor(backend="ref", schedule="static").executor
    ex_c = BatchedExtractor(backend="ref", schedule="counted").executor
    metas = [ex_s._meta(p) for p in prepped_s]

    entries_s, aux = ex_s._pass1_static(
        planlib.build_plan(metas, "static"), prepped_s
    )
    assert aux, "the sphere cloud must take the static chain path"
    futs = ex_s._submit(entries_s, ex_s._diam_fn, ex_s._stacked_chunk)
    d_s = ex_s._drain(futs, "pass2b")
    window = exmod._Window(prepped_s, planlib.build_plan(metas, "static"),
                           [], [], [], aux, 0.0)
    ex_s._resolve_static_aux(window, d_s)
    assert ex_s.transfer_log.get("pass2b_retry", 0) >= 1  # retry really ran
    assert ex_s.transfer_log.get("pass1", 0) == 0

    entries_c, _ = ex_c._pass1_counted(
        planlib.build_plan(metas, "counted"), prepped_c
    )
    d_c = ex_c._drain(
        ex_c._submit(entries_c, ex_c._diam_fn, ex_c._stacked_chunk), "pass2b"
    )
    for i in range(2):
        # both schedules conclude keep-originals with identical PruneInfo...
        assert not prepped_s[i].prune_info.pruned
        assert prepped_s[i].prune_info == prepped_c[i].prune_info
        assert prepped_s[i].vertex_cap == prepped_c[i].vertex_cap == 1024
        # ...and bit-identical diameters
        np.testing.assert_array_equal(np.asarray(d_s[i]), np.asarray(d_c[i]))


# ---------------------------------------------------------------------------
# zero pass-1 host fetches under the static schedule (transfer counter)
# ---------------------------------------------------------------------------


class _GuardedNp:
    """numpy facade that records every device-array materialisation."""

    def __init__(self, real, log):
        self._real = real
        self._log = log

    def __getattr__(self, name):
        attr = getattr(self._real, name)
        if name in ("asarray", "array"):
            def guarded(x, *a, **kw):
                if isinstance(x, jax.Array):
                    self._log.append(name)
                return attr(x, *a, **kw)
            return guarded
        return attr


def test_static_pass1_performs_zero_host_fetches(monkeypatch):
    cases = [_case((48, 48, 48), 1), _case((20, 18, 16), 5),
             _case((70, 20, 20), 4)]
    stages = []
    bx = BatchedExtractor(backend="ref", schedule="static",
                          transfer_callback=lambda s, x: stages.append(s))
    _, stats = bx.run(cases)
    # the executor's sync census: not one pass-1 fetch happened
    assert "pass1" not in stats["host_fetches"]
    assert bx.executor.transfer_log.get("pass1", 0) == 0
    assert "pass1" not in stages
    # the deferred count fetch happened at collect time instead
    assert stats["host_fetches"].get("pass2b_counts", 0) >= 1

    # hardened guard: run the pass-1 phase alone with EVERY numpy
    # materialisation of a jax array intercepted -- the phase must not
    # touch one, whatever path it takes
    ex = bx.executor
    prepped = [ex._prep_case(*c) for c in cases]
    plan = planlib.build_plan([ex._meta(p) for p in prepped], "static")
    fetched = []
    monkeypatch.setattr(exmod, "np", _GuardedNp(np, fetched))
    entries, aux = ex._pass1_static(plan, prepped)
    monkeypatch.undo()
    assert fetched == [] and entries and aux

    # control: the counted schedule's pass 1 IS the count sync
    bc = BatchedExtractor(backend="ref", schedule="counted")
    exc = bc.executor
    prepped_c = [exc._prep_case(*c) for c in cases]
    plan_c = planlib.build_plan([exc._meta(p) for p in prepped_c], "counted")
    fetched_c = []
    monkeypatch.setattr(exmod, "np", _GuardedNp(np, fetched_c))
    exc._pass1_counted(plan_c, prepped_c)
    monkeypatch.undo()
    assert fetched_c  # the (B, 2) fetch was observed by the same guard
    assert exc.transfer_log.get("pass1", 0) == len(plan_c.cap_groups)


# ---------------------------------------------------------------------------
# streaming == batched == single, in input order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["counted", "static"])
def test_stream_equals_batched_bit_identical(schedule):
    cases = _edge_cases()
    bx = BatchedExtractor(backend="ref", schedule=schedule)
    batched, _ = bx.run(cases)
    # window=4 straddles: [blob, empty, small, voxel] | [elongated, blob2]
    streamed = list(bx.extract_stream(iter(cases), window=4))
    assert len(streamed) == len(cases)
    for i, (a, b) in enumerate(zip(batched, streamed)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"case {i}"
        )
    # the single-case oracle holds through the streaming front-end too
    for case, row in zip(cases, streamed):
        np.testing.assert_array_equal(np.asarray(row), bx.extract_one(*case))


def test_stream_window_edges():
    cases = _edge_cases()[:3]
    bx = BatchedExtractor(backend="ref")
    want, _ = bx.run(cases)
    for window in (1, 2, 3, 16):  # incl. window > n and window == n
        got = list(bx.extract_stream(iter(cases), window=window))
        assert len(got) == 3
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert list(bx.extract_stream(iter([]), window=4)) == []  # empty stream
    with pytest.raises(ValueError, match="window"):
        next(bx.extract_stream(iter(cases), window=0))


def test_stream_interpret_backend_bit_identical():
    cases = [_case((48, 48, 48), 2), _empty(), _case((20, 18, 16), 5)]
    bx = BatchedExtractor(backend="interpret", schedule="static")
    want, _ = bx.run(cases)
    got = list(bx.extract_stream(iter(cases), window=2))
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_stats_callback_reports_plan_census():
    cases = _edge_cases()
    bx = BatchedExtractor(backend="ref")
    seen = []
    list(bx.extract_stream(iter(cases), window=4,
                           stats_callback=lambda i, s: seen.append((i, s))))
    assert [i for i, _ in seen] == [0, 1]  # 6 cases / window 4 -> 2 windows
    for _, s in seen:
        assert {"shape_buckets", "cap_buckets", "mask_pad_waste",
                "vertex_pad_waste", "cases"} <= set(s)
    assert seen[0][1]["cases"] == 4 and seen[1][1]["cases"] == 2
    assert seen[0][1]["empty_cases"] == 1


# ---------------------------------------------------------------------------
# device-pool MC == the host-stacked feed it replaced
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_device_pool_mc_equals_host_stacked(backend):
    cases = [_case((48, 48, 48), 1), _case((20, 18, 16), 5),
             _case((48, 48, 48), 2)]
    bx = BatchedExtractor(backend=backend)
    rows, _ = bx.run(cases)
    ex = bx.executor
    prepped = [ex._prep_case(*c) for c in cases]
    plan = planlib.build_plan([ex._meta(p) for p in prepped], "counted")
    for shape, idxs in plan.shape_groups.items():
        # the PR 2/3 feed: per-chunk HOST re-stacking of the padded masks
        masks = jnp.asarray(np.stack([np.asarray(prepped[i].mask)
                                      for i in idxs]))
        sps = jnp.asarray(np.stack([prepped[i].spacing for i in idxs]))
        depth = len(idxs)
        want = np.asarray(ex._mc_fn(shape, depth)(masks, sps))
        for j, i in enumerate(idxs):
            np.testing.assert_array_equal(
                want[j], np.asarray(rows[i][:2], np.float32),
                err_msg=f"case {i} ({backend})",
            )


def test_masks_are_device_staged_once():
    """The pool entries ARE the staged per-case arrays: pass 2a must not
    re-materialise masks from host numpy."""
    bx = BatchedExtractor(backend="ref")
    ex = bx.executor
    p = ex._prep_case(*_case((20, 18, 16), 5))
    assert isinstance(p.mask, jax.Array)
    masks, sps = ex._pool([p], [0])
    assert isinstance(masks, jax.Array) and masks.shape[0] == 1


# ---------------------------------------------------------------------------
# plan-aware batch-depth autotune keys reach the kernels
# ---------------------------------------------------------------------------


def test_pipeline_resolves_depth_bucketed_configs(tmp_path, monkeypatch):
    """A cached depth-keyed diameter entry must be honoured by the batched
    path (and the depth-1 slot by the single-case oracle)."""
    from repro.runtime import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cache = autotune.AutotuneCache()
    for b in (1, 2, 4):
        cache.put(
            autotune.sweep_key(512, "interpret", batch=b),
            {"variant": "gram", "block": 128, "us": 1.0, "table": {}},
        )
    calls = []
    from repro.core import dispatcher
    orig = dispatcher.diameter_config

    def spy(backend, bucket, variant="auto", block=None, batch=1):
        calls.append((int(bucket), int(batch)))
        return orig(backend, bucket, variant, block, batch)

    monkeypatch.setattr(dispatcher, "diameter_config", spy)
    bx = BatchedExtractor(backend="interpret")
    # identical cases: guaranteed same cap group -> one depth-2 sub-batch
    cases = [_case((20, 18, 16), 5), _case((20, 18, 16), 5)]
    rows, _ = bx.run(cases)
    assert all(np.all(np.isfinite(r)) for r in rows)
    # the batched pass-2b resolution carried the sub-batch depth (2), the
    # oracle path resolves depth 1
    assert any(b == 2 for _, b in calls)
    bx.extract_one(*cases[0])
    assert calls[-1][1] == 1
