"""Hypothesis property tests for the first-order / GLCM family contracts.

Gated on hypothesis being importable (see tests/conftest.py); seeded
plain-pytest mirrors live in tests/test_features_families.py so the
invariants are exercised even in the minimal container.

Invariants (the parity argument of kernels/firstorder and kernels/glcm):

  1. first-order packed stats are BITWISE identical between the
     reference canonical fold and the Pallas kernel, for every
     CANON_CHUNK-multiple block -- on arbitrary volumes, masks, and
     intensity ranges (including constant and near-constant images);
  2. batched packed stats equal single-case stats bitwise (the canonical
     fold never sees the batch);
  3. GLCM count matrices are symmetric, integer-valued, equal to an
     independent ``np.add.at`` scatter oracle, and their total counts
     equal the number of valid in-mask neighbour pairs;
  4. quantized bin ids always land in ``[0, n_bins)`` and masked-out
     voxels always quantize to bin 0 (never perturbing the histogram).
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.kernels import firstorder as fok
from repro.kernels import glcm as gk
from repro.kernels import ref as rk

_SETTINGS = dict(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)

_shapes = st.tuples(
    st.integers(3, 12), st.integers(3, 12), st.integers(3, 12)
)


def _volume(seed, shape, mask_p, lo, hi, constant):
    rng = np.random.default_rng(seed)
    if constant:
        img = np.full(shape, np.float32(lo), np.float32)
    else:
        img = rng.uniform(lo, hi, size=shape).astype(np.float32)
    mask = (rng.random(shape) < mask_p).astype(np.float32)
    return img, mask


@st.composite
def cases(draw):
    shape = draw(_shapes)
    seed = draw(st.integers(0, 2**16))
    mask_p = draw(st.sampled_from([0.0, 0.1, 0.5, 0.95]))
    lo = draw(st.floats(-500, 500, allow_nan=False, width=32))
    span = draw(st.sampled_from([0.0, 1e-3, 1.0, 300.0]))
    constant = draw(st.booleans())
    return _volume(seed, shape, mask_p, lo, lo + span, constant)


@given(case=cases(), block_mult=st.sampled_from([1, 2, 4]))
@settings(**_SETTINGS)
def test_fo_ref_equals_pallas_any_block(case, block_mult):
    img, mask = case
    ref = np.asarray(fok.firstorder_packed_batch_ref(img[None], mask[None]))
    pal = np.asarray(fok.firstorder_packed_batch_pallas(
        img[None], mask[None], block=block_mult * fok.CANON_CHUNK,
        interpret=True,
    ))
    np.testing.assert_array_equal(ref, pal)


@given(seeds=st.lists(st.integers(0, 2**16), min_size=2, max_size=4,
                      unique=True))
@settings(**_SETTINGS)
def test_fo_batched_equals_single(seeds):
    vols = [_volume(s, (7, 9, 8), 0.5, -100.0, 200.0, False) for s in seeds]
    imgs = np.stack([v[0] for v in vols])
    msks = np.stack([v[1] for v in vols])
    batched = np.asarray(fok.firstorder_packed_batch_ref(imgs, msks))
    for i, (img, mask) in enumerate(vols):
        single = np.asarray(
            fok.firstorder_packed_batch_ref(img[None], mask[None])
        )[0]
        np.testing.assert_array_equal(batched[i], single)


@given(case=cases())
@settings(**_SETTINGS)
def test_glcm_matrix_invariants(case):
    img, mask = case
    g = np.asarray(gk.glcm_matrix_batch_pallas(img[None], mask[None],
                                               block=512, interpret=True))[0]
    np.testing.assert_array_equal(g, g.T)
    np.testing.assert_array_equal(g, np.round(g))
    assert (g >= 0).all()
    # total == 2 * (number of valid in-mask neighbour pairs)
    m = mask > 0
    pairs = sum(
        int(np.sum(m[tuple(slice(None, -o) if o else slice(None)
                           for o in off)]
                   & m[tuple(slice(o, None) for o in off)]))
        for off in gk.OFFSETS
    )
    assert g.sum() == 2 * pairs
    # and equals the independent scatter oracle
    ref = np.asarray(gk.glcm_matrix_batch_ref(img[None], mask[None]))[0]
    np.testing.assert_array_equal(g, ref)


@given(case=cases(), n_bins=st.sampled_from([8, 32]))
@settings(**_SETTINGS)
def test_quantize_bounds(case, n_bins):
    img, mask = case
    lo, hi = rk.intensity_range(img, mask)
    q, _ = rk.quantize_intensity(img, mask, lo, hi, n_bins)
    q = np.asarray(q)
    assert ((q >= 0) & (q <= n_bins - 1)).all()
    assert (q[np.asarray(mask) == 0] == 0).all()
