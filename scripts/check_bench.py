#!/usr/bin/env python
"""Bench-regression gate: fresh BENCH_*.json rows vs the committed trajectory.

``scripts/ci_smoke.sh`` re-emits ``BENCH_pipeline.json`` (cases/second per
pipeline mode) and ``BENCH_diameter.json`` (us/call per kernel variant) on
every run; this gate compares the freshly written rows against the rows
COMMITTED at the baseline ref (``git show <ref>:<path>`` -- the working
tree copy has already been overwritten by the time the gate runs) and
fails on a >``--threshold`` (default 30%) throughput regression for any
row name present in both records.

Noise policy: both benches already record best-of-N interleaved
measurements (see benchmarks/pipeline_throughput.py), so a 30% drop is a
real regression, not scheduler jitter.  Rows new to the fresh record
pass (there is nothing to compare), rows that VANISHED from the fresh
record FAIL unless explicitly named in ``--allow-vanished`` (a
deleted-but-still-gated bench mode must be acknowledged, never dropped
silently), and a missing baseline (first commit, renamed file, no git)
skips the gate with a notice rather than failing -- the gate guards
trajectories, it does not invent them.  Any OTHER baseline-lookup
failure (an unreadable object, a corrupt committed record) FAILS the
gate: a gate that skips on unexpected errors is a gate that silently
stops gating.

``--stages ci_stage_times.json`` additionally compares the per-stage
wall times ``scripts/ci_smoke.sh`` emits against the committed record
and WARNS (never fails: CI minutes are shared, noisy machines) when any
stage grew past ``--stage-factor`` (default 2x) -- CI wall time is a
perf surface too, and a quietly doubled stage is how a 10-minute gate
becomes an hour.

The baseline path is resolved REPO-RELATIVE before ``git show`` (via
``git rev-parse --show-toplevel``), so the gate works from any working
directory and with absolute fresh-record paths -- ``git show REF:path``
itself only understands paths rooted at the repo top level.

Usage (what ci_smoke.sh stage 'bench_gate' runs):

    python scripts/check_bench.py --pipeline BENCH_pipeline.json \
                                  --diameter BENCH_diameter.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# metric per bench record: (row key, higher-is-better)
METRICS = {
    "pipeline": ("cases_per_second", True),
    "diameter": ("us_per_call", False),
}

# --stages: baselines shorter than this are pure quantisation noise
# (integer seconds), so the >factor growth warning skips them
STAGE_MIN_SECS = 5.0

# git-show stderr fragments that mean "this baseline legitimately does
# not exist" (first commit, renamed/never-committed file, bad ref on a
# fresh clone) -- the documented skip cases.  Anything else is an error.
_MISSING_MARKERS = (
    "does not exist",
    "exists on disk, but not in",
    "unknown revision",
    "bad revision",
    "invalid object name",
    "not a valid object name",
)


def load_fresh(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _repo_relative(path: str) -> tuple[str, str] | None:
    """``(repo_top, path relative to it)`` (None when not in a repo).

    ``git show REF:path`` resolves paths against the repo ROOT, not the
    current directory, so a gate run from a subdirectory (or handed an
    absolute path) must translate first.  The repo is discovered from
    the RECORD's directory, not the gate's cwd: the fresh record sits
    next to its committed baseline.
    """
    anchor = os.path.dirname(os.path.abspath(path)) or "."
    try:
        proc = subprocess.run(
            ["git", "-C", anchor, "rev-parse", "--show-toplevel"],
            capture_output=True, text=True, timeout=60,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if proc.returncode != 0:
        return None
    top = proc.stdout.strip()
    rel = os.path.relpath(os.path.abspath(path), top)
    if rel.startswith(".."):
        return None  # outside the repo: nothing committed to compare to
    return top, rel.replace(os.sep, "/")


def load_baseline(path: str, ref: str):
    """The committed record at ``ref`` as ``(data, skip_reason, error)``.

    Exactly one of the three is non-None: ``data`` on success,
    ``skip_reason`` when no baseline legitimately exists (gate skips with
    a notice), ``error`` on any other lookup failure (gate FAILS).
    """
    located = _repo_relative(path)
    if located is None:
        return None, f"{path} is not inside a git repository", None
    top, rel = located
    try:
        proc = subprocess.run(
            ["git", "-C", top, "show", f"{ref}:{rel}"],
            capture_output=True, text=True, timeout=60,
        )
    except (OSError, subprocess.TimeoutExpired) as e:
        return None, f"git unavailable ({e})", None
    if proc.returncode != 0:
        detail = proc.stderr.strip().splitlines()
        detail = detail[0] if detail else f"git show exited {proc.returncode}"
        if any(m in detail.lower() for m in _MISSING_MARKERS):
            return None, f"no committed baseline at {ref}:{rel} ({detail})", None
        return None, None, f"baseline lookup {ref}:{rel} failed: {detail}"
    try:
        data = json.loads(proc.stdout)
    except ValueError as e:
        return None, None, f"committed baseline {ref}:{rel} is not JSON ({e})"
    if not isinstance(data, dict):
        return None, None, f"committed baseline {ref}:{rel} is not a record"
    return data, None, None


def check_record(label: str, fresh: dict, baseline: dict,
                 threshold: float,
                 allow_vanished: tuple = ()) -> list[str]:
    """Compare one bench record pair; returns failure messages."""
    metric, higher = METRICS[label]
    base_rows = {
        r.get("name"): r for r in baseline.get("rows", [])
        if isinstance(r, dict)
    }
    fresh_names = set()
    failures = []
    for row in fresh.get("rows", []):
        name = row.get("name")
        fresh_names.add(name)
        base = base_rows.get(name)
        if base is None:
            print(f"  {label}/{name}: NEW (no baseline row)")
            continue
        try:
            f, b = float(row[metric]), float(base[metric])
        except (KeyError, TypeError, ValueError):
            print(f"  {label}/{name}: metric {metric!r} unreadable, skipped")
            continue
        if b <= 0 or f <= 0:
            print(f"  {label}/{name}: non-positive {metric}, skipped")
            continue
        # ratio > 1 means the fresh row is FASTER than the baseline
        ratio = (f / b) if higher else (b / f)
        verdict = "OK" if ratio >= 1.0 - threshold else "REGRESSION"
        print(f"  {label}/{name}: base={b:.4g} fresh={f:.4g} "
              f"{metric} speed-ratio={ratio:.3f} {verdict}")
        if verdict != "OK":
            failures.append(
                f"{label}/{name}: {metric} regressed {(1 - ratio):.0%} "
                f"(base {b:.4g} -> fresh {f:.4g}, threshold "
                f"{threshold:.0%})"
            )
    for name in sorted(base_rows.keys() - fresh_names):
        if name in allow_vanished:
            print(f"  {label}/{name}: baseline row vanished "
                  "(allowed by --allow-vanished)")
            continue
        print(f"  {label}/{name}: baseline row MISSING from the fresh "
              "record (bench mode dropped?)")
        failures.append(
            f"{label}/{name}: committed baseline row vanished from the "
            "fresh record; a dropped bench mode must be named in "
            "--allow-vanished"
        )
    return failures


def check_stages(path: str, baseline: dict, factor: float) -> None:
    """Warn (never fail) on ci_smoke stages whose wall time grew > factor.

    Stage seconds are integer wall-clock on shared CI machines, so this
    is advisory: sub-``--stage-min`` baselines are skipped entirely (a
    1s stage 'doubling' to 2s is quantisation, not growth).
    """
    with open(path) as f:
        fresh = json.load(f)
    base_stages = baseline.get("stages", {})
    for name, secs in fresh.get("stages", {}).items():
        base = base_stages.get(name)
        if base is None:
            print(f"  stages/{name}: NEW (no baseline stage)")
            continue
        try:
            b, s = float(base), float(secs)
        except (TypeError, ValueError):
            print(f"  stages/{name}: unreadable wall time, skipped")
            continue
        if b < STAGE_MIN_SECS:
            print(f"  stages/{name}: base={b:.0f}s fresh={s:.0f}s "
                  "(below the noise floor, not compared)")
            continue
        verdict = "OK" if s <= b * factor else f"WARNING grew >{factor:g}x"
        print(f"  stages/{name}: base={b:.0f}s fresh={s:.0f}s {verdict}")
    for name in sorted(base_stages.keys() - fresh.get("stages", {}).keys()):
        print(f"  WARNING stages/{name}: stage missing from the fresh "
              "record (renamed? dropped?)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pipeline", default=None,
                    help="fresh BENCH_pipeline.json (also the baseline "
                         "path inside the git ref)")
    ap.add_argument("--diameter", default=None,
                    help="fresh BENCH_diameter.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max tolerated fractional slowdown (default 0.30)")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baseline")
    ap.add_argument("--allow-vanished", nargs="*", metavar="ROW",
                    default=[],
                    help="row names allowed to vanish from the fresh "
                         "record (vanished rows FAIL otherwise)")
    ap.add_argument("--stages", default=None, metavar="PATH",
                    help="fresh ci_stage_times.json: warn when any stage "
                         "wall time grew >--stage-factor vs the committed "
                         "record")
    ap.add_argument("--stage-factor", type=float, default=2.0,
                    help="max tolerated stage wall-time growth factor "
                         "(default 2.0; warns, never fails)")
    args = ap.parse_args(argv)
    if args.pipeline is None and args.diameter is None and args.stages is None:
        ap.error("nothing to check: pass --pipeline, --diameter and/or "
                 "--stages")

    failures: list[str] = []
    for label, path in (("pipeline", args.pipeline),
                        ("diameter", args.diameter)):
        if path is None:
            continue
        try:
            fresh = load_fresh(path)
        except (OSError, ValueError) as e:
            print(f"{label}: fresh record {path} unreadable ({e})")
            failures.append(f"{label}: fresh record unreadable")
            continue
        baseline, skip, error = load_baseline(path, args.ref)
        if error is not None:
            print(f"{label}: {error}")
            failures.append(f"{label}: {error}")
            continue
        if baseline is None:
            print(f"{label}: {skip}; skipping (nothing to regress against)")
            continue
        print(f"{label}: fresh {path} vs {args.ref}:{path}")
        failures += check_record(label, fresh, baseline, args.threshold,
                                 tuple(args.allow_vanished))

    if args.stages is not None:
        baseline, skip, error = load_baseline(args.stages, args.ref)
        if error is not None:
            print(f"stages: {error}")
            failures.append(f"stages: {error}")
        elif baseline is None:
            print(f"stages: {skip}; skipping (nothing to compare against)")
        else:
            print(f"stages: fresh {args.stages} vs {args.ref}:{args.stages}")
            try:
                check_stages(args.stages, baseline, args.stage_factor)
            except (OSError, ValueError) as e:
                print(f"stages: fresh record {args.stages} unreadable ({e})")
                failures.append("stages: fresh record unreadable")

    if failures:
        print("\nbench gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nbench gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
