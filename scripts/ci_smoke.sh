#!/usr/bin/env bash
# CI smoke gate: the ROADMAP tier-1 test command plus a fast interpret-mode
# benchmark pass, so regressions in kernel wiring (dispatch, autotune,
# pruning, benchmark plumbing) fail fast.
#
# Usage: scripts/ci_smoke.sh
#   SMOKE_TIER1_ONLY=1  run only @tier1-marked tests (quick local gate)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1) tier-1 gate (ROADMAP "Tier-1 verify"), fail-fast
python -m pytest -x -q ${SMOKE_TIER1_ONLY:+-m tier1}

# 2) kernel-wiring smoke: Fig.1 variant sweep (interpret mode) + the
#    BENCH_diameter.json perf-trajectory record
python -m benchmarks.run --only fig1 --json BENCH_diameter.json
test -s BENCH_diameter.json
echo "ci_smoke: OK"
