#!/usr/bin/env bash
# CI smoke gate, staged: the ROADMAP tier-1 test command, the explicitly
# named parity/schedule gates, the interpret-mode benchmark passes that
# re-emit the BENCH_*.json perf trajectories, and the bench-regression
# gate that compares them against the committed baseline -- with per-stage
# wall-time reporting so CI logs show where the minutes go (also written
# machine-readably to ci_stage_times.json and gated, warn-only, against
# the committed record by scripts/check_bench.py --stages).
#
# Usage: scripts/ci_smoke.sh
#   SMOKE_TIER1_ONLY=1  run only @tier1-marked tests (quick local gate)
#   SMOKE_SKIP_BENCH=1  skip the benchmark + bench-gate stages (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE_NAMES=()
STAGE_SECS=()
stage() {  # stage <name> <cmd...>: run one named stage, record wall time
  local name=$1; shift
  echo "== ci_smoke stage ${#STAGE_NAMES[@]}: ${name}"
  local t0=$SECONDS
  "$@"
  local dt=$((SECONDS - t0))
  STAGE_NAMES+=("$name")
  STAGE_SECS+=("$dt")
  echo "== ci_smoke stage ${name}: ${dt}s"
}

# machine-readable per-stage wall times, written next to the BENCH_*.json
# trajectories (uploaded as a CI artifact; `scripts/check_bench.py
# --stages` warns when any stage grows >2x vs the committed record)
emit_stage_times() {
  local out="ci_stage_times.json" i
  local last=$(( ${#STAGE_NAMES[@]} - 1 ))
  {
    printf '{\n "written_at": "%s",\n "stages": {\n' \
      "$(date +%Y-%m-%dT%H:%M:%S)"
    for i in "${!STAGE_NAMES[@]}"; do
      printf '  "%s": %s%s\n' "${STAGE_NAMES[$i]}" "${STAGE_SECS[$i]}" \
        "$([[ $i -lt $last ]] && echo ',')"
    done
    printf ' }\n}\n'
  } > "$out"
}

# 1) tier-1 gate (ROADMAP "Tier-1 verify"), fail-fast
stage tier1 python -m pytest -x -q ${SMOKE_TIER1_ONLY:+-m tier1}

# 2) parity + autotune-cache gates: named explicitly (under the tier1
#    marker) so the batched==single contract, the device==host compaction
#    bit-identity, the gram precision guardrail, and the cache schema can
#    never silently fall out of the gate
stage parity python -m pytest -q -m tier1 \
    tests/test_pipeline_pruned_batch.py \
    tests/test_pipeline_device_compact.py \
    tests/test_gram_precision.py \
    tests/test_autotune_cache.py

# 3) scheduling gates: stream==batch==single bit-identity, static==counted
#    (incl. the retry paths), zero pass-1/pass-0 host fetches under the
#    static schedule / hint prep, and the cost-model decision layer
#    (window='auto', schedule='auto', determinism)
stage schedule python -m pytest -q -m tier1 \
    tests/test_plan_executor_stream.py \
    tests/test_costmodel_schedule.py

# 4) resilience gates: manifest resume/torn-tail repair, quarantine
#    row-level errors, window retry bit-identity, checkpoint torn-write
#    fallback, and the kill/resume acceptance test (preempted+resumed
#    manifest == uninterrupted, at most one window redone)
stage resilience python -m pytest -q -m tier1 \
    tests/test_resilience.py \
    tests/test_checkpoint.py

# 5) feature-family gates: first-order/GLCM ref==pallas parity (bitwise /
#    integer-exact), batched==single, the sync-free family drain on the
#    plan/executor windows, the NIfTI loader quirks (scl scaling, 4D
#    squeeze, big-endian refusal), and the bench-gate failure-mode
#    contracts
stage families python -m pytest -q -m tier1 \
    tests/test_features_families.py \
    tests/test_nifti.py \
    tests/test_check_bench.py

# 6) serving gates: service==stream row parity (ref + interpret),
#    cross-tenant window fusion, deadline expiry without co-tenant
#    stalls, queue-byte backpressure -- plus a short mixed-traffic
#    smoke through the CLI entry point
stage serve python -m pytest -q -m tier1 tests/test_service.py
stage serve_smoke python -m repro.launch.serve --backend ref --smoke

# 7) out-of-core tiling gates: tiled==in-core row parity across tile
#    sizes, prune levels and backends plus the slab-reader contracts
#    (tier-1 suite), then the forced-tiny-budget engine smoke through
#    the CLI entry point (parity ladder + a volume streamed under a
#    budget far below its materialized size)
stage tiled python -m pytest -q -m tier1 \
    tests/test_tiled_pipeline.py
stage tiled_smoke python -m repro.launch.tiled_smoke --backend ref

# 8) roofline gates: the HLO/jaxpr cost parsers plus the agreement
#    contract -- the plan-derived FLOP/byte census must match XLA's
#    cost_analysis() within 10% on the ref backend, so the cost model's
#    roofline fallback prices real launches, not a drifted paper model
stage roofline python -m pytest -q -m tier1 tests/test_roofline.py

if [[ "${SMOKE_SKIP_BENCH:-0}" != "1" ]]; then
  # 9) kernel-wiring smoke: Fig.1 variant sweep (interpret mode) + the
  #    BENCH_diameter.json perf-trajectory record
  stage bench_diameter python -m benchmarks.run --only fig1 --json BENCH_diameter.json
  test -s BENCH_diameter.json

  # 10) batched-throughput smoke: the pipeline mode ladder (single loop ->
  #    streaming auto), the ~200-case faulted/preempted/resumed soak
  #    (SOAK_CASES), the serving-tier mixed-traffic p50/p99 rows, and the
  #    per-kernel roofline achieved-fraction rows, all recorded as the
  #    BENCH_pipeline.json trajectory, then gated against the committed
  #    trajectory (>30% cases/s or us/call regression on any named row
  #    fails; the latency rows encode 1/latency and the roofline rows
  #    their achieved fraction as cases_per_second, so the same rule
  #    gates latency and kernel efficiency)
  stage bench_pipeline env SOAK_CASES="${SOAK_CASES:-200}" \
      python -m benchmarks.run --only pipeline soak serve roofline --json-pipeline BENCH_pipeline.json
  test -s BENCH_pipeline.json
  # stage wall times so far (everything above the gate), so the gate can
  # also flag CI-minute regressions vs the committed record
  emit_stage_times
  stage bench_gate python scripts/check_bench.py \
      --pipeline BENCH_pipeline.json --diameter BENCH_diameter.json \
      --stages ci_stage_times.json
fi

# re-emit with the gate stage included (and so tier1-only / skip-bench
# runs still produce the artifact)
emit_stage_times

summary="ci_smoke: OK"
for i in "${!STAGE_NAMES[@]}"; do
  summary+=" ${STAGE_NAMES[$i]}=${STAGE_SECS[$i]}s"
done
echo "$summary"
