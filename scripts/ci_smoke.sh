#!/usr/bin/env bash
# CI smoke gate, staged: the ROADMAP tier-1 test command, the explicitly
# named parity/schedule gates, the interpret-mode benchmark passes that
# re-emit the BENCH_*.json perf trajectories, and the bench-regression
# gate that compares them against the committed baseline -- with per-stage
# wall-time reporting so CI logs show where the minutes go.
#
# Usage: scripts/ci_smoke.sh
#   SMOKE_TIER1_ONLY=1  run only @tier1-marked tests (quick local gate)
#   SMOKE_SKIP_BENCH=1  skip the benchmark + bench-gate stages (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE_NAMES=()
STAGE_SECS=()
stage() {  # stage <name> <cmd...>: run one named stage, record wall time
  local name=$1; shift
  echo "== ci_smoke stage ${#STAGE_NAMES[@]}: ${name}"
  local t0=$SECONDS
  "$@"
  local dt=$((SECONDS - t0))
  STAGE_NAMES+=("$name")
  STAGE_SECS+=("$dt")
  echo "== ci_smoke stage ${name}: ${dt}s"
}

# 1) tier-1 gate (ROADMAP "Tier-1 verify"), fail-fast
stage tier1 python -m pytest -x -q ${SMOKE_TIER1_ONLY:+-m tier1}

# 2) parity + autotune-cache gates: named explicitly (under the tier1
#    marker) so the batched==single contract, the device==host compaction
#    bit-identity, the gram precision guardrail, and the cache schema can
#    never silently fall out of the gate
stage parity python -m pytest -q -m tier1 \
    tests/test_pipeline_pruned_batch.py \
    tests/test_pipeline_device_compact.py \
    tests/test_gram_precision.py \
    tests/test_autotune_cache.py

# 3) scheduling gates: stream==batch==single bit-identity, static==counted
#    (incl. the retry paths), zero pass-1/pass-0 host fetches under the
#    static schedule / hint prep, and the cost-model decision layer
#    (window='auto', schedule='auto', determinism)
stage schedule python -m pytest -q -m tier1 \
    tests/test_plan_executor_stream.py \
    tests/test_costmodel_schedule.py

# 4) resilience gates: manifest resume/torn-tail repair, quarantine
#    row-level errors, window retry bit-identity, checkpoint torn-write
#    fallback, and the kill/resume acceptance test (preempted+resumed
#    manifest == uninterrupted, at most one window redone)
stage resilience python -m pytest -q -m tier1 \
    tests/test_resilience.py \
    tests/test_checkpoint.py

# 5) feature-family gates: first-order/GLCM ref==pallas parity (bitwise /
#    integer-exact), batched==single, the sync-free family drain on the
#    plan/executor windows, the NIfTI loader quirks (scl scaling, 4D
#    squeeze, big-endian refusal), and the bench-gate failure-mode
#    contracts
stage families python -m pytest -q -m tier1 \
    tests/test_features_families.py \
    tests/test_nifti.py \
    tests/test_check_bench.py

# 6) serving gates: service==stream row parity (ref + interpret),
#    cross-tenant window fusion, deadline expiry without co-tenant
#    stalls, queue-byte backpressure -- plus a short mixed-traffic
#    smoke through the CLI entry point
stage serve python -m pytest -q -m tier1 tests/test_service.py
stage serve_smoke python -m repro.launch.serve --backend ref --smoke

# 7) out-of-core tiling gates: tiled==in-core row parity across tile
#    sizes, prune levels and backends plus the slab-reader contracts
#    (tier-1 suite), then the forced-tiny-budget engine smoke through
#    the CLI entry point (parity ladder + a volume streamed under a
#    budget far below its materialized size)
stage tiled python -m pytest -q -m tier1 \
    tests/test_tiled_pipeline.py
stage tiled_smoke python -m repro.launch.tiled_smoke --backend ref

if [[ "${SMOKE_SKIP_BENCH:-0}" != "1" ]]; then
  # 6) kernel-wiring smoke: Fig.1 variant sweep (interpret mode) + the
  #    BENCH_diameter.json perf-trajectory record
  stage bench_diameter python -m benchmarks.run --only fig1 --json BENCH_diameter.json
  test -s BENCH_diameter.json

  # 7) batched-throughput smoke: the pipeline mode ladder (single loop ->
  #    streaming auto), the ~200-case faulted/preempted/resumed soak
  #    (SOAK_CASES), and the serving-tier mixed-traffic p50/p99 rows, all
  #    recorded as the BENCH_pipeline.json trajectory, then gated against
  #    the committed trajectory (>30% cases/s or us/call regression on
  #    any named row fails; the latency rows encode 1/latency as
  #    cases_per_second so the same rule gates latency)
  stage bench_pipeline env SOAK_CASES="${SOAK_CASES:-200}" \
      python -m benchmarks.run --only pipeline soak serve --json-pipeline BENCH_pipeline.json
  test -s BENCH_pipeline.json
  stage bench_gate python scripts/check_bench.py \
      --pipeline BENCH_pipeline.json --diameter BENCH_diameter.json
fi

summary="ci_smoke: OK"
for i in "${!STAGE_NAMES[@]}"; do
  summary+=" ${STAGE_NAMES[$i]}=${STAGE_SECS[$i]}s"
done
echo "$summary"
