#!/usr/bin/env bash
# CI smoke gate: the ROADMAP tier-1 test command plus a fast interpret-mode
# benchmark pass, so regressions in kernel wiring (dispatch, autotune,
# pruning, batched pipeline, benchmark plumbing) fail fast.
#
# Usage: scripts/ci_smoke.sh
#   SMOKE_TIER1_ONLY=1  run only @tier1-marked tests (quick local gate)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1) tier-1 gate (ROADMAP "Tier-1 verify"), fail-fast
python -m pytest -x -q ${SMOKE_TIER1_ONLY:+-m tier1}

# 2) two-pass parity + autotune-cache gates: named explicitly (under the
#    tier1 marker) so the batched==single contract, the device==host
#    compaction bit-identity, the gram precision guardrail, and the cache
#    schema can never silently fall out of the gate
python -m pytest -q -m tier1 tests/test_pipeline_pruned_batch.py \
    tests/test_pipeline_device_compact.py \
    tests/test_gram_precision.py \
    tests/test_autotune_cache.py

# 2b) streaming + static-schedule gates: extract_stream == run == single
#     bit-identity, static == counted bit-identity (incl. the retry path),
#     zero pass-1 host fetches under the static schedule, and device-pool
#     MC == the host-stacked feed it replaced
python -m pytest -q -m tier1 tests/test_plan_executor_stream.py

# 3) kernel-wiring smoke: Fig.1 variant sweep (interpret mode) + the
#    BENCH_diameter.json perf-trajectory record
python -m benchmarks.run --only fig1 --json BENCH_diameter.json
test -s BENCH_diameter.json

# 4) batched-throughput smoke: single loop vs unpruned vs two-pass pruned
#    cases/sec, recorded as the BENCH_pipeline.json trajectory
python -m benchmarks.run --only pipeline --json-pipeline BENCH_pipeline.json
test -s BENCH_pipeline.json
echo "ci_smoke: OK"
